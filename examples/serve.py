"""Batched serving of an MX-quantized model: the deployment mode the paper
targets — LATMiX-folded weights, online T3 block-Hadamard, MX fake-quant
matmuls, batched KV-cache decode.

    PYTHONPATH=src python examples/serve.py [--quant mxfp4|off] [--batch 4]
        [--scheduler wave|continuous] [--trace OUT.json] [--metrics]

Pass --artifact DIR to skip PTQ entirely and serve a packed artifact
exported earlier (examples/latmix_ptq.py --export or
`python -m repro.artifacts export`): weights load 4-bit packed and are
dequantized lazily per layer inside the compiled step.

--scheduler continuous switches the engine to the slot-pool
continuous-batching scheduler (chunked prefill, per-slot decode positions
— see docs/serving.md) and demonstrates the streaming submission API:
requests are submitted one by one and tokens stream back per step via
``Request.on_token`` while other requests are still decoding.

--temperature/--top-k/--top-p sample instead of greedy argmax (seeded,
replayable); --spec-k K adds self-drafting speculative decoding on the
continuous scheduler — same tokens, fewer forwards (docs/sampling.md).

--http HOST:PORT serves the engine over the asyncio HTTP/SSE front end
instead of the scripted demo (admission shedding via --max-queue-depth,
SIGTERM drains gracefully — docs/server.md); stream tokens back with
examples/client.py.
"""
import argparse

import jax
import numpy as np

from repro.core import ptq
from repro.core.quantize import QuantMode
from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import api
from repro.obs import MetricsRegistry, Tracer
from repro.serving.engine import Engine, Request
from repro.serving.policy import SchedulingPolicy, SpecConfig
from repro.serving.sampling import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", default="mxfp4",
                    choices=["mxfp4", "mxint4", "off"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--latmix", action="store_true",
                    help="learn+fold LATMiX transforms before serving")
    ap.add_argument("--artifact", default="",
                    help="serve a packed artifact directory (skips PTQ)")
    ap.add_argument("--eager", action="store_true",
                    help="with --artifact: dequantize all weights at load")
    ap.add_argument("--scheduler", default="wave",
                    choices=("wave", "continuous"),
                    help="static waves or continuous batching "
                         "(docs/serving.md)")
    ap.add_argument("--kv-cache", default="none",
                    choices=("none", "mxfp8", "mxint8", "mxfp4", "mxint4"),
                    help="MX-quantize the KV cache (docs/kv-cache.md)")
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=("contiguous", "paged"),
                    help="page the KV cache through block tables with "
                         "prefix caching (continuous scheduler only; "
                         "docs/paged-kv.md)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="end-to-end TTL per request; expired requests "
                         "end TIMED_OUT (docs/robustness.md)")
    ap.add_argument("--ttft-deadline-ms", type=float, default=None,
                    help="time-to-first-token bound in milliseconds")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="preemptions a request survives before the "
                         "terminal PREEMPTED state")
    ap.add_argument("--no-preemption", dest="preemption",
                    action="store_false", default=True,
                    help="disable priority preemption under pool pressure")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy argmax "
                         "(docs/sampling.md)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k logit filter (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus (top-p) logit filter")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base RNG seed; request i samples with seed+i")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding draft length (0 = off; "
                         "forces --scheduler continuous; outputs "
                         "unchanged — docs/sampling.md)")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="export a Chrome trace of the run — open in "
                         "https://ui.perfetto.dev "
                         "(docs/observability.md)")
    ap.add_argument("--metrics", action="store_true",
                    help="instrument kernel dispatches and print the "
                         "Prometheus metrics snapshot at exit")
    ap.add_argument("--http", default="", metavar="HOST:PORT",
                    help="serve over HTTP/SSE instead of the scripted "
                         "demo (SIGTERM drains — docs/server.md; "
                         "examples/client.py streams tokens back)")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="with --http: shed (429 + Retry-After) past "
                         "this queue depth (docs/server.md)")
    args = ap.parse_args()
    if args.kv_layout == "paged" or args.spec_k > 0:
        args.scheduler = "continuous"  # paged / spec are continuous-only
    if args.http:
        args.scheduler = "continuous"  # token streaming is per-slot
    args.policy = SchedulingPolicy(deadline_ms=args.deadline_ms,
                                   ttft_deadline_ms=args.ttft_deadline_ms,
                                   preemption=args.preemption,
                                   max_retries=args.max_retries,
                                   max_queue_depth=args.max_queue_depth)
    args.spec = SpecConfig(k=args.spec_k) if args.spec_k > 0 else None
    args.sampling = (SamplingParams(temperature=args.temperature,
                                    top_k=args.top_k, top_p=args.top_p,
                                    seed=args.sample_seed)
                     if (args.temperature > 0 or args.top_k > 0
                         or args.top_p < 1.0) else None)

    tracer = Tracer() if args.trace else None
    metrics = MetricsRegistry() if args.metrics else None
    if metrics is not None:          # kernel-dispatch hooks (ops.py)
        ops.instrument(metrics, tracer)

    if args.artifact:
        eng = Engine.from_artifact(args.artifact, batch_size=args.batch,
                                   max_len=128, eager=args.eager,
                                   scheduler=args.scheduler,
                                   kv_cache=args.kv_cache,
                                   kv_layout=args.kv_layout,
                                   metrics=metrics, tracer=tracer,
                                   policy=args.policy, spec=args.spec)
        cfg = eng.cfg
        print(f"serving artifact {args.artifact} "
              f"({'eager' if args.eager else 'packed-lazy'} weights, "
              f"scheduler={args.scheduler})")
        _run(eng, cfg, args)
        return

    cfg = ArchConfig(name="serve-demo", family="dense", n_layers=3,
                     d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
                     d_ff=352, vocab_size=512, attn_chunk=64)
    params = api.init(jax.random.PRNGKey(0), cfg)

    if args.quant == "off":
        qm = QuantMode.off()
    elif args.latmix:
        from repro.data import synthetic
        import jax.numpy as jnp
        src = synthetic.make_source(cfg, 8, 64, 0)
        calib = [{k: jnp.asarray(v) for k, v in src.batch(i).items()}
                 for i in range(2)]
        res = ptq.apply_method("latmix-lu", params, cfg, calib,
                               fmt=args.quant, steps=60)
        params, qm = res.params, res.qm
        print("LATMiX transforms learned and folded.")
    else:
        qm = (QuantMode.mxfp4(t3=False) if args.quant == "mxfp4"
              else QuantMode.mxint4(t3=False))

    eng = Engine(params, cfg, qm, batch_size=args.batch, max_len=128,
                 scheduler=args.scheduler, kv_cache=args.kv_cache,
                 kv_layout=args.kv_layout, metrics=metrics, tracer=tracer,
                 policy=args.policy, spec=args.spec)
    _run(eng, cfg, args)


def _run(eng, cfg, args):
    if args.http:
        import json
        from repro.serving.server import ServerConfig, serve
        host, _, port = args.http.rpartition(":")
        report = serve(eng, ServerConfig(host=host or "127.0.0.1",
                                         port=int(port or 8100)))
        print("drain report: " + json.dumps(report), flush=True)
        raise SystemExit(0 if report["clean"] else 1)
    rng = np.random.default_rng(0)
    # mixed-length traffic: the regime where continuous batching wins.
    # Under --kv-layout paged every request shares a system prompt, so
    # the streaming demo shows prefix hits accumulating per admission.
    sys_prompt = (rng.integers(0, cfg.vocab_size, eng.page_size)
                  .astype(np.int32) if eng.kv_layout == "paged" else
                  np.zeros(0, np.int32))
    reqs = [Request(prompt=np.concatenate(
                [sys_prompt, rng.integers(0, cfg.vocab_size, 8 + 5 * i)
                 .astype(np.int32)]),
                    max_new=max(4, args.new - 3 * i),
                    sampling=(None if args.sampling is None else
                              SamplingParams(
                                  temperature=args.temperature,
                                  top_k=args.top_k, top_p=args.top_p,
                                  seed=args.sample_seed + i)))
            for i in range(args.batch * 2)]

    if eng.scheduler == "continuous":
        # streaming submission: enqueue everything, then step the
        # scheduler and watch tokens stream back per slot
        streamed = {i: [] for i in range(len(reqs))}
        done = []
        for i, r in enumerate(reqs):
            r.on_token = streamed[i].append
            eng.submit(r)
        while len(done) < len(reqs):
            done.extend(eng.step())   # one admission + decode step
        for i, r in enumerate(reqs):
            assert list(r.out) == streamed[i]
            print(f"req{i}: prompt={len(r.prompt)}t -> streamed "
                  f"{len(streamed[i])} tokens, out[:6]={streamed[i][:6]}")
        if eng.kv_layout == "paged":
            st = eng.stats()
            print(f"paged KV: prefix_hit_tokens={st['prefix_hit_tokens']} "
                  f"blocks_in_use={st['blocks_in_use']} "
                  f"blocks_evicted={st['blocks_evicted']} "
                  f"kv_bytes_resident={eng.kv_bytes_resident()}")
    else:
        done = eng.generate(reqs)
        for i, r in enumerate(done):
            # m_* are the monotonic (perf_counter) stamps — durations
            # never use wall-clock t_* (NTP can step those backwards)
            print(f"req{i}: prompt[-4:]={list(r.prompt[-4:])} "
                  f"-> out[:8]={list(r.out[:8])} "
                  f"({len(r.out)} tokens in {r.m_done-r.m_submit:.2f}s)")

    st = eng.stats()
    if any(v for k, v in st["terminal"].items() if k != "finished"):
        print("terminal states: " + ", ".join(
            f"{k}={v}" for k, v in st["terminal"].items() if v))
    if args.spec is not None:
        print(f"speculative decoding: {st['spec_proposed_tokens']} "
              f"drafted, {st['spec_accepted_tokens']} accepted "
              f"(acceptance {st['spec_acceptance']:.2f})")

    stats = eng.throughput(n_requests=args.batch, prompt_len=16,
                           max_new=args.new, sampling=args.sampling)
    src = (f"artifact {args.artifact}" if args.artifact
           else f"{args.quant}{' + LATMiX' if args.latmix else ''}")
    print(f"\nthroughput: {stats['tok_per_s']:.1f} tok/s ({src}, "
          f"scheduler={stats['scheduler']}, "
          f"kv_cache={stats['kv_cache']}, "
          f"decode utilization {stats['decode_utilization']:.2f})")
    if stats.get("ttft_p50") is not None:
        print(f"latency: ttft p50={stats['ttft_p50']*1e3:.1f}ms "
              f"p99={stats['ttft_p99']*1e3:.1f}ms")
    if args.trace:
        print(f"trace -> {eng.tracer.export(args.trace)} "
              f"({len(eng.tracer.events())} events)")
    if args.metrics:
        print("\n" + eng.metrics.render_prometheus())


if __name__ == "__main__":
    main()
