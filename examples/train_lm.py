"""End-to-end training driver: train a language model on the synthetic
corpus with the full distributed trainer (checkpointing, resume, grad
accumulation).

Default is a CPU-friendly ~3M model for a few hundred steps; pass
``--preset 100m`` for the ~100M-parameter configuration (the driver the
deliverable asks for — hours on CPU, minutes on a TPU slice):

    PYTHONPATH=src python examples/train_lm.py                 # small
    PYTHONPATH=src python examples/train_lm.py --preset 100m   # full
    PYTHONPATH=src python examples/train_lm.py --arch tinyllama-1.1b \
        --reduced                                              # any arch
"""
import argparse

from repro import configs
from repro.configs.base import ArchConfig
from repro.training import optimizer as opt
from repro.training.trainer import TrainConfig, Trainer

PRESETS = {
    "small": (ArchConfig(name="lm-3m", family="dense", n_layers=4,
                         d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
                         d_ff=352, vocab_size=2048, attn_chunk=64),
              dict(steps=300, batch_size=16, seq_len=64, lr=3e-3)),
    "100m": (ArchConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
                        d_ff=2048, vocab_size=32000, attn_chunk=256,
                        remat=True),
             dict(steps=300, batch_size=32, seq_len=512, lr=6e-4)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--arch", default="")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.arch:
        cfg = (configs.get_reduced(args.arch) if args.reduced
               else configs.get(args.arch))
        hp = dict(steps=300, batch_size=8, seq_len=64, lr=1e-3)
    else:
        cfg, hp = PRESETS[args.preset]
    steps = args.steps or hp["steps"]
    n = cfg.param_count()
    print(f"training {cfg.name}: {n/1e6:.1f}M params, {steps} steps, "
          f"batch {hp['batch_size']}x{hp['seq_len']}")
    tc = TrainConfig(
        steps=steps, batch_size=hp["batch_size"], seq_len=hp["seq_len"],
        ckpt_every=max(50, steps // 4),
        ckpt_dir=args.ckpt_dir or f"checkpoints/{cfg.name}",
        log_every=20,
        opt=opt.AdamWConfig(lr=hp["lr"], warmup_steps=max(10, steps // 20),
                            total_steps=steps))
    tr = Trainer(cfg, tc)
    tr.train()
    ppl = tr.eval_ppl()
    from repro.data.synthetic import DataConfig, unigram_ppl
    base = unigram_ppl(DataConfig(cfg.vocab_size, hp["seq_len"],
                                  hp["batch_size"]))
    print(f"\nfinal held-out ppl: {ppl:.2f}  "
          f"(no-learning unigram baseline ≈ {base:.1f})")


if __name__ == "__main__":
    main()
