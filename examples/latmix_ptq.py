"""Full LATMiX PTQ pipeline on a trained checkpoint:

  load checkpoint -> fold norms -> learn T1/T2 (KL distillation + L_vol)
  -> fold transforms -> GPTQ the weights -> evaluate every method.

Run examples/train_lm.py first (or let this script train the benchmark
model). Compares RTN / GPTQ / QuaRot / block-Hadamard / SpinQuant-like /
LATMiX-LU / LATMiX-QR under MXFP4.

    PYTHONPATH=src python examples/latmix_ptq.py [--fmt mxint4] [--steps 80]

With --export DIR, each quantized method's result is additionally written
as a packed artifact under DIR/<method> — the deployable checkpoint that
examples/serve.py --artifact serves with zero re-quantization.
"""
import argparse
import sys

sys.path.insert(0, "benchmarks") if False else None

from repro.core import ptq
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fmt", default="mxfp4",
                    choices=["mxfp4", "mxint4"])
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--methods", default="rtn,gptq,quarot,block_hadamard,"
                                         "spinquant,latmix-lu,latmix-qr")
    ap.add_argument("--export", default="",
                    help="export each method's packed artifact under "
                         "<dir>/<method>")
    args = ap.parse_args()

    from benchmarks import common
    params, cfg = common.get_model()
    calib = common.calib_batches(cfg)
    ev = common.eval_tokens(cfg)

    fp = api.perplexity(params, cfg, ev)
    print(f"\n{'method':16s} {'ppl':>9s} {'vs FP':>8s}")
    print(f"{'fp16':16s} {fp:9.3f} {'100.0%':>8s}")
    for m in args.methods.split(","):
        res = ptq.apply_method(m, params, cfg, calib, fmt=args.fmt,
                               steps=args.steps)
        ppl = ptq.eval_ppl(res, cfg, ev)
        print(f"{m:16s} {ppl:9.3f} {100*fp/ppl:7.1f}%")
        if args.export:
            import pathlib
            out = res.export(cfg, pathlib.Path(args.export) / m)
            print(f"{'':16s}   exported -> {out}")
        if res.tset is not None and m.startswith("latmix"):
            from repro.core import transforms as tfm
            dev = float(tfm.orthogonality_deviation(res.tset.a1))
            off = float(tfm.offblock_norm(res.tset.a1, 32))
            print(f"{'':16s}   A1: orth-dev={dev:.3f} offblock={off:.3f}"
                  f" (Fig. 3 metrics)")


if __name__ == "__main__":
    main()
