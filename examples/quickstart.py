"""Quickstart: build a small LM, quantize it to MXFP4 with LATMiX, and
compare perplexity against RTN — in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import ptq
from repro.data import synthetic
from repro.models import api

# 1. a small llama-style model (random init for speed; see examples/
#    train_lm.py + examples/latmix_ptq.py for the trained pipeline)
cfg = ArchConfig(name="quickstart", family="dense", n_layers=3,
                 d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
                 d_ff=352, vocab_size=512, attn_chunk=64)
params = api.init(jax.random.PRNGKey(0), cfg)
print(f"model: {cfg.param_count()/1e6:.2f}M params")

# 2. calibration + eval data (synthetic Zipf–Markov corpus)
src = synthetic.make_source(cfg, 8, 64, seed=0)
calib = [{k: jnp.asarray(v) for k, v in src.batch(i).items()}
         for i in range(3)]
ev = jnp.asarray(src.batch(100)["inputs"])

fp_ppl = api.perplexity(params, cfg, ev)
print(f"FP32 ppl          : {fp_ppl:9.2f}")

# 3. RTN baseline vs LATMiX-LU (learned affine transforms + GPTQ)
for method in ["rtn", "latmix-lu"]:
    res = ptq.apply_method(method, params, cfg, calib, fmt="mxfp4",
                           steps=60)
    ppl = ptq.eval_ppl(res, cfg, ev)
    print(f"MXFP4 {method:12s}: {ppl:9.2f}  "
          f"(recovery {100*fp_ppl/ppl:.1f}% of FP ppl ratio)")
