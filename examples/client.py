"""Minimal streaming client for the HTTP/SSE serving front end.

    # terminal 1: a demo server on a tiny random-init model
    PYTHONPATH=src python -m repro.serving.server --port 8100

    # terminal 2: stream a generation
    PYTHONPATH=src python examples/client.py --port 8100 \
        --prompt 1,2,3,4 --max-new 32

Stdlib only (one socket, HTTP/1.1, ``Connection: close``). Demonstrates
the three client-side contracts of docs/server.md:

* **SSE consumption** — ``event: token`` frames stream as the engine
  emits them (a frame carrying several tokens is a coalesced flush from
  the server's bounded buffer); ``event: done`` carries the terminal
  lifecycle state.
* **Retry-After honoring** — a 429 (admission shed) or 503 (draining)
  response names its backoff; the client sleeps exactly that long
  before retrying (``X-Retry-After-S`` when present — exact float —
  else the integer ``Retry-After``), up to ``--retries`` attempts.
  Retrying *sooner* than the server asked defeats overload shedding.
* **Clean Ctrl-C disconnect** — closing the socket mid-stream is the
  whole protocol: the server cancels the request within one engine
  step and frees its KV pages. No goodbye frame needed.

Payloads speak token ids (ints), not text — tokenization is out of
scope for the reproduction.
"""
from __future__ import annotations

import argparse
import json
import socket
import sys
import time


def _read_headers(sock_file):
    status = sock_file.readline().decode("latin1")
    if not status:
        raise ConnectionError("server closed the connection")
    code = int(status.split()[1])
    headers = {}
    while True:
        line = sock_file.readline().decode("latin1")
        if line in ("\r\n", "\n", ""):
            break
        if ":" in line:
            k, v = line.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    return code, headers


def _sse_events(sock_file):
    """Yield (event, data_dict) frames until the connection closes."""
    event, data = None, None
    for raw in sock_file:
        line = raw.decode().rstrip("\n").rstrip("\r")
        if line.startswith("event:"):
            event = line[6:].strip()
        elif line.startswith("data:"):
            data = json.loads(line[5:].strip())
        elif not line and event is not None:
            yield event, data
            event, data = None, None


def request_once(host: str, port: int, body: dict, timeout_s: float):
    """One POST /v1/generate. Returns ('ok', result) after a completed
    stream, or ('retry', seconds) when the server shed/drained us."""
    sock = socket.create_connection((host, port), timeout=timeout_s)
    try:
        payload = json.dumps(body).encode()
        sock.sendall((f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Type: application/json\r\n"
                      f"Content-Length: {len(payload)}\r\n"
                      f"Connection: close\r\n\r\n").encode() + payload)
        f = sock.makefile("rb")
        code, headers = _read_headers(f)
        if code in (429, 503):
            # honor the server's backoff — exact float when offered
            wait = float(headers.get("x-retry-after-s",
                                     headers.get("retry-after", "1")))
            return "retry", wait
        if code != 200:
            raise RuntimeError(f"HTTP {code}: {f.read().decode()!r}")
        tokens, result = [], None
        t0 = time.perf_counter()
        for event, data in _sse_events(f):
            if event == "token":
                if not tokens:
                    print(f"# first token after "
                          f"{(time.perf_counter()-t0)*1e3:.0f}ms",
                          file=sys.stderr)
                tokens.extend(data["tokens"])
                mark = "+" if data.get("coalesced") else ""
                print(f"token[{data['i']}]{mark}: {data['tokens']}")
            elif event == "done":
                result = data
                break
        if result is None:
            raise ConnectionError("stream ended without a done event")
        return "ok", result
    finally:
        sock.close()   # Ctrl-C lands here too: close IS the cancel


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8100)
    ap.add_argument("--prompt", default="1,2,3,4",
                    help="comma-separated token ids")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--priority", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--retries", type=int, default=5,
                    help="attempts when shed (429) or draining (503)")
    ap.add_argument("--timeout-s", type=float, default=60.0)
    args = ap.parse_args(argv)

    body = {"prompt": [int(t) for t in args.prompt.split(",")],
            "max_new": args.max_new, "priority": args.priority,
            "stream": True}
    if args.temperature > 0:
        body.update(temperature=args.temperature, seed=args.seed)

    try:
        for attempt in range(args.retries + 1):
            kind, value = request_once(args.host, args.port, body,
                                       args.timeout_s)
            if kind == "ok":
                print(f"done: state={value['state']} "
                      f"n_tokens={value['n_tokens']}"
                      + (f" error={value['error']}" if value["error"]
                         else ""))
                return 0 if value["state"] == "finished" else 2
            print(f"# shed/draining — retrying in {value:g}s "
                  f"(attempt {attempt + 1}/{args.retries})",
                  file=sys.stderr)
            time.sleep(value)
        print("# out of retries", file=sys.stderr)
        return 3
    except KeyboardInterrupt:
        # socket already closed by the finally in request_once; the
        # server cancels our request within one engine step
        print("\n# interrupted — disconnect sent", file=sys.stderr)
        return 130


if __name__ == "__main__":
    sys.exit(main())
