"""Kernel microbenchmarks: the *actual* serving dispatch paths.

Times (jit, CPU):
  * the PackedWeight ``qlinear`` fallback — new skip-requant + LUT decode
    vs the old decode->encode->decode round-trip it replaced,
  * the fused packed-native dispatch vs the reference path (on CPU the
    Pallas kernel runs in interpret mode, so its wall-clock is a
    correctness-path number, not a deployment number — the TPU story is
    the roofline projection below),
  * decode attention over the KV cache: dense jnp at f32/bf16 vs the
    packed-KV Pallas flash-decode kernel at 1k/4k/16k context, with the
    per-step KV bytes each cache format streams (the ~2x mxfp8 / ~4x
    mxfp4 traffic cut) and a bandwidth-bound TPU projection,
  * chunked prefill over the *paged* packed pool: the dense jnp path vs
    the fused flash-prefill kernel (both include quantize-on-append of
    the chunk), with packed-prefix-read + packed-chunk-write byte
    accounting and a prefill TPU projection,
  * the jnp fake-quant primitives (historical trajectory rows),

plus packed-vs-dense weight byte accounting and analytic TPU-roofline
projections for the Pallas kernels (v5e bandwidth, packed 4-bit byte
counts from DESIGN.md §2).

Writes the standard experiments/benchmarks/kernels_bench.json and a
repo-root BENCH_kernels.json so the perf trajectory is populated.
``--smoke`` shrinks shapes for CI.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.core import transforms as tfm
from repro.core.quantize import QuantMode, qlinear
from repro.kernels import ops, packing
from repro.kernels.packing import PackedWeight
from repro.models import layers
from . import common

HBM_BW = 819e9
PEAK = 197e12

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _packed_weight(key, k, n, fmt="mxfp4"):
    w = jax.random.normal(key, (k, n), jnp.float32) * 0.1
    # pack_weight RTN-quantizes off-grid values itself, so from_dense on
    # the raw weight lands on the MX grid in one pass
    return PackedWeight.from_dense(w, fmt)


def _attention_rows(rows, log, smoke: bool):
    """Decode attention over the KV cache: the jnp dense path vs the
    packed-KV flash-decode kernel (CPU interpret mode — correctness-path
    wall clock; the TPU story is the bandwidth projection row), plus the
    KV bytes a decode step streams per layer under each cache format."""
    B, H, kvh, Dh = 1, 8, 2, 64
    D = kvh * Dh
    contexts = (256,) if smoke else (1024, 4096, 16384)
    key = jax.random.PRNGKey(21)
    for S in contexts:
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, S), 3)
        q = jax.random.normal(k1, (B, 1, H, Dh), jnp.float32)
        kd = jax.random.normal(k2, (B, S, D), jnp.float32)
        vd = jax.random.normal(k3, (B, S, D), jnp.float32)
        q_pos = jnp.full((B, 1), S - 1, jnp.int32)
        kv_len = jnp.full((B,), S, jnp.int32)

        def dense_attn(qq, kk, vv):
            return layers.attention(
                qq, kk.reshape(B, S, kvh, Dh), vv.reshape(B, S, kvh, Dh),
                causal=True, q_pos=q_pos, kv_len=kv_len, chunk=512)

        f_j = jax.jit(dense_attn)
        for dt, name in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
            us = common.timed(f_j, q, kd.astype(dt), vd.astype(dt)) * 1e6
            kv_bytes = 2 * S * D * jnp.dtype(dt).itemsize
            rows.append({"name": f"attn_decode_jnp_{name}_S{S}",
                         "us_per_call": us,
                         "derived": f"kv_bytes={kv_bytes}"})
        us_bf16 = rows[-1]["us_per_call"]
        bytes_bf16 = 2 * S * D * 2
        bytes_f32 = 2 * S * D * 4
        for fmt in ("mxfp8", "mxfp4"):
            kc, ks = packing.kv_encode(kd, fmt)
            vc, vs = packing.kv_encode(vd, fmt)
            qf = q.reshape(B, H, Dh)

            # the two ways the engine can read a quantized cache (the
            # qlinear_dispatch_{ref,fused} pairing, KV edition): decode
            # the packed cache in place + dense jnp attention (the 'ref'
            # backend) vs the packed-native flash-decode kernel
            def packed_ref(qq, a, b, c, d):
                kk = packing.kv_decode(a, b, fmt).reshape(B, S, kvh, Dh)
                vv = packing.kv_decode(c, d, fmt).reshape(B, S, kvh, Dh)
                return layers.attention(qq.reshape(B, 1, H, Dh), kk, vv,
                                        causal=True, q_pos=q_pos,
                                        kv_len=kv_len, chunk=512)

            def packed_attn(qq, a, b, c, d):
                return ops.mx_flash_decode(qq, a, b, c, d,
                                           q_pos[:, 0], kv_len, fmt,
                                           interpret=True)

            us_ref = common.timed(jax.jit(packed_ref),
                                  qf, kc, ks, vc, vs) * 1e6
            us = common.timed(jax.jit(packed_attn), qf, kc, ks, vc, vs) * 1e6
            kv_bytes = 2 * (int(kc.size) + int(ks.size))
            rows.append({
                "name": f"attn_decode_packed_ref_{fmt}_S{S}",
                "us_per_call": us_ref,
                "derived": (f"kv_bytes={kv_bytes};"
                            "decode-in-place + jnp attention "
                            "(the ref-backend read of a packed cache)")})
            rows.append({
                "name": f"attn_decode_packed_{fmt}_S{S}",
                "us_per_call": us,
                "derived": (
                    f"kv_bytes={kv_bytes};"
                    f"bytes_reduction_vs_bf16={bytes_bf16/kv_bytes:.2f}x;"
                    f"bytes_reduction_vs_f32={bytes_f32/kv_bytes:.2f}x;"
                    f"us_vs_packed_ref={us_ref/us:.2f}x;"
                    f"us_vs_jnp_bf16={us_bf16/us:.2f}x;"
                    "cpu_interpret=TRUE (correctness-path timing; "
                    "compiled Mosaic on TPU)")})
    # TPU roofline: decode attention is pure KV streaming at long context
    S = contexts[-1]
    qb = H * Dh * 2
    for fmt, per_elem in (("bf16", 2.0), ("mxfp8", 1 + 1 / 32),
                          ("mxfp4", 0.5 + 1 / 32)):
        kv_bytes = 2 * S * D * per_elem
        t_mem = (kv_bytes + qb) / HBM_BW
        rows.append({
            "name": f"attn_decode_tpu_projection_{fmt}_S{S}",
            "us_per_call": t_mem * 1e6,
            "derived": (f"kv_bytes={int(kv_bytes)};bound=memory;"
                        f"speedup_vs_bf16_at_bw_bound="
                        f"{(2 * S * D * 2.0 + qb) / (kv_bytes + qb):.2f}x")})


def _prefill_rows(rows, log, smoke: bool):
    """Chunked prefill over the paged packed pool: the dense jnp path vs
    the fused flash-prefill kernel (quantize-on-append included in both),
    with the bytes each path moves — the packed read of the prefix pages
    plus the packed chunk written back, vs decoding the whole logical
    cache to f32."""
    B, H, kvh, Dh = 1, 8, 2, 64
    D = kvh * Dh
    C = 64 if smoke else 128           # prompt chunk per call
    P = 64 if smoke else 256           # page size
    contexts = (256,) if smoke else (1024, 4096, 16384)
    key = jax.random.PRNGKey(23)
    for S in contexts:
        start = S - C                  # chunk is the prompt's tail
        maxp = -(-S // P)
        ks_ = jax.random.split(jax.random.fold_in(key, S), 6)
        q = jax.random.normal(ks_[0], (B, C, H, Dh), jnp.float32)
        pool_k = jax.random.normal(ks_[1], (maxp, P, D), jnp.float32)
        pool_v = jax.random.normal(ks_[2], (maxp, P, D), jnp.float32)
        kch = jax.random.normal(ks_[3], (B, C, D), jnp.float32)
        vch = jax.random.normal(ks_[4], (B, C, D), jnp.float32)
        bt = jax.random.permutation(ks_[5], maxp).astype(jnp.int32)[None]
        st = jnp.full((B,), start, jnp.int32)
        kl = jnp.full((B,), S, jnp.int32)
        q_pos = start + jnp.arange(C, dtype=jnp.int32)[None, :]

        # dense baseline: the whole logical KV materialized contiguous,
        # chunk queries through the dense jnp attention
        def dense_prefill(qq, kk, vv):
            return layers.attention(
                qq, kk.reshape(B, S, kvh, Dh), vv.reshape(B, S, kvh, Dh),
                causal=True, q_pos=q_pos, kv_len=kl, chunk=512)

        kd = jax.random.normal(ks_[1], (B, S, D), jnp.float32)
        vd = jax.random.normal(ks_[2], (B, S, D), jnp.float32)
        f_j = jax.jit(dense_prefill)
        for dt, name in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
            us = common.timed(f_j, q, kd.astype(dt), vd.astype(dt)) * 1e6
            kv_bytes = 2 * S * D * jnp.dtype(dt).itemsize
            rows.append({"name": f"attn_prefill_jnp_{name}_S{S}",
                         "us_per_call": us,
                         "derived": f"kv_bytes={kv_bytes};chunk={C}"})
        bytes_bf16 = 2 * S * D * 2
        bytes_f32 = 2 * S * D * 4
        for fmt in ("mxfp8", "mxfp4"):
            kc, ksc = packing.kv_encode(pool_k, fmt)
            vc, vsc = packing.kv_encode(pool_v, fmt)

            # the two engine reads of a packed paged pool during chunked
            # prefill (attn_decode_packed_{ref,} pairing, prefill
            # edition) — both include the chunk's quantize-on-append
            def packed_ref(qq, kk, vv, a, b, c, d):
                return ops.mx_prefill_ref(qq, kk, vv, a, b, c, d,
                                          bt, st, kl, fmt)

            def packed_attn(qq, kk, vv, a, b, c, d):
                return ops.mx_flash_prefill(qq, kk, vv, a, b, c, d,
                                            bt, st, kl, fmt,
                                            interpret=True)

            args = (q, kch, vch, kc, ksc, vc, vsc)
            us_ref = common.timed(jax.jit(packed_ref), *args) * 1e6
            us = common.timed(jax.jit(packed_attn), *args) * 1e6
            # bytes a fused prefill call moves: packed prefix pages read
            # + dense chunk in + packed chunk bytes out (never a dense
            # round-trip of the pool)
            out = packed_attn(*args)
            chunk_out = sum(int(o.size) for o in out[1:])
            kv_bytes = (2 * (int(kc.size) + int(ksc.size))
                        + 2 * C * D * 4 + chunk_out)
            rows.append({
                "name": f"attn_prefill_packed_ref_{fmt}_S{S}",
                "us_per_call": us_ref,
                "derived": (f"kv_bytes={kv_bytes};chunk={C};"
                            "gather + decode-in-place + jnp attention "
                            "(the fallback read of the paged pool)")})
            rows.append({
                "name": f"attn_prefill_packed_{fmt}_S{S}",
                "us_per_call": us,
                "derived": (
                    f"kv_bytes={kv_bytes};chunk={C};pages={maxp};"
                    f"bytes_reduction_vs_bf16={bytes_bf16/kv_bytes:.2f}x;"
                    f"bytes_reduction_vs_f32={bytes_f32/kv_bytes:.2f}x;"
                    f"us_vs_packed_ref={us_ref/us:.2f}x;"
                    "cpu_interpret=TRUE (correctness-path timing; "
                    "compiled Mosaic on TPU)")})
    # TPU roofline: prefill streams the packed prefix once per chunk
    S = contexts[-1]
    qb = C * H * Dh * 2
    for fmt, per_elem in (("bf16", 2.0), ("mxfp8", 1 + 1 / 32),
                          ("mxfp4", 0.5 + 1 / 32)):
        kv_bytes = 2 * S * D * per_elem
        flops = 4 * C * S * H * Dh
        t_mem = (kv_bytes + qb) / HBM_BW
        t_cmp = flops / PEAK
        rows.append({
            "name": f"attn_prefill_tpu_projection_{fmt}_S{S}",
            "us_per_call": max(t_mem, t_cmp) * 1e6,
            "derived": (f"kv_bytes={int(kv_bytes)};"
                        f"bound={'memory' if t_mem > t_cmp else 'compute'};"
                        f"mem_us={t_mem*1e6:.1f};"
                        f"compute_us={t_cmp*1e6:.1f}")})


def run(log=print, smoke: bool = False):
    rows = []
    if smoke:
        M, K, N = 64, 256, 256          # CI: seconds, not minutes
        Md, Kd, Nd = 16, 256, 256
        Mf, Kf, Nf = 16, 128, 128
    else:
        M, K, N = 2048, 4096, 4096
        Md, Kd, Nd = 64, 4096, 4096     # decode-shaped: weight-bound
        Mf, Kf, Nf = 64, 1024, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    cfg = mxlib.MXConfig(fmt="mxfp4")

    # --- jnp fake-quant primitives (trajectory rows) ---
    f_quant = jax.jit(lambda t: mxlib.quantize(t, cfg, ste=False))
    us = common.timed(f_quant, x) * 1e6
    rows.append({"name": f"mx_quant_jnp_{M}x{K}", "us_per_call": us,
                 "derived": f"gbps={x.size*4/us*1e6/1e9:.2f}"})

    h = tfm.hadamard_matrix(32)
    f_t3 = jax.jit(lambda t: mxlib.quantize(tfm.apply_blockwise(t, h),
                                            cfg, ste=False))
    us = common.timed(f_t3, x) * 1e6
    rows.append({"name": f"hadamard_quant_jnp_{M}x{K}", "us_per_call": us,
                 "derived": f"gbps={x.size*4/us*1e6/1e9:.2f}"})

    # --- PackedWeight qlinear fallback: skip-requant + LUT decode vs the
    # old decode->encode->decode round-trip (the PR's fallback fix) ---
    xd = jax.random.normal(jax.random.PRNGKey(2), (Md, Kd), jnp.float32)
    pw = _packed_weight(jax.random.PRNGKey(3), Kd, Nd)
    qm_ref = QuantMode.mxfp4(t3=False)

    def old_requant(xx, p):  # pre-PR behavior, reconstructed
        w = p.to_dense()
        xq = mxlib.quantize(xx, cfg, ste=False)
        wq = jnp.swapaxes(mxlib.quantize(jnp.swapaxes(w, -1, -2), cfg,
                                         ste=False), -1, -2)
        return xq @ wq

    f_old = jax.jit(old_requant)
    f_new = jax.jit(lambda xx, p: qlinear(xx, p, None, qm_ref, "ffn_in"))
    us_old = common.timed(f_old, xd, pw) * 1e6
    us_new = common.timed(f_new, xd, pw) * 1e6
    rows.append({"name": f"qlinear_packed_requant_old_{Md}x{Kd}x{Nd}",
                 "us_per_call": us_old, "derived": "decode+encode+decode"})
    rows.append({"name": f"qlinear_packed_fallback_{Md}x{Kd}x{Nd}",
                 "us_per_call": us_new,
                 "derived": f"skip_requant_speedup={us_old/us_new:.2f}x"})

    # --- fused dispatch (packed-native Pallas, CPU interpret mode) vs the
    # reference path on identical inputs ---
    xf = jax.random.normal(jax.random.PRNGKey(4), (Mf, Kf), jnp.float32)
    pwf = _packed_weight(jax.random.PRNGKey(5), Kf, Nf)
    qm_fused = qm_ref.with_backend("fused")
    f_refp = jax.jit(lambda xx, p: qlinear(xx, p, None, qm_ref, "ffn_in"))
    f_fused = jax.jit(lambda xx, p: qlinear(xx, p, None, qm_fused,
                                            "ffn_in"))
    us_ref = common.timed(f_refp, xf, pwf) * 1e6
    us_fus = common.timed(f_fused, xf, pwf) * 1e6
    rows.append({"name": f"qlinear_dispatch_ref_{Mf}x{Kf}x{Nf}",
                 "us_per_call": us_ref, "derived": "reference path"})
    rows.append({"name": f"qlinear_dispatch_fused_{Mf}x{Kf}x{Nf}",
                 "us_per_call": us_fus,
                 "derived": "cpu_interpret=TRUE (correctness-path timing; "
                            "compiled Mosaic on TPU)"})

    # --- decode attention: jnp dense-KV vs packed-KV flash decode ---
    _attention_rows(rows, log, smoke)

    # --- chunked prefill: jnp dense vs paged flash-prefill kernel ---
    _prefill_rows(rows, log, smoke)

    # --- packed vs dense weight bytes (the HBM-traffic win) ---
    rows.append({
        "name": f"weight_bytes_packed_vs_dense_{Kd}x{Nd}",
        "us_per_call": 0.0,
        "derived": (f"packed={pw.nbytes_packed};dense={pw.nbytes_dense};"
                    f"ratio={pw.nbytes_dense/pw.nbytes_packed:.2f}x")})

    # --- TPU roofline projections for the Pallas kernels (packed) ---
    flops = 2 * M * K * N
    wbytes = mxlib.packed_nbytes((K, N), cfg)
    abytes = M * K * 2                     # bf16 activations in
    obytes = M * N * 2
    t_mem = (wbytes + abytes + obytes) / HBM_BW
    t_cmp = flops / PEAK
    rows.append({
        "name": "mx_matmul_tpu_projection", "us_per_call": t_cmp * 1e6,
        "derived": (f"mem_us={t_mem*1e6:.1f};compute_us={t_cmp*1e6:.1f};"
                    f"bound={'memory' if t_mem > t_cmp else 'compute'};"
                    f"ai={flops/(wbytes+abytes+obytes):.1f}")})
    # bf16 baseline projection: weight bytes 2 B/param -> more traffic
    t_mem_bf16 = (K * N * 2 + abytes + obytes) / HBM_BW
    rows.append({
        "name": "mx_vs_bf16_weight_traffic", "us_per_call": 0.0,
        "derived": f"speedup_at_bw_bound={t_mem_bf16/t_mem:.2f}x"})

    for r in rows:
        log(f"[kernels] {r['name']:42s} {r['us_per_call']:10.1f}us "
            f"{r['derived']}")
    # smoke shapes would pollute the perf trajectory (both JSONs)
    common.emit(rows, "kernels_bench", persist=not smoke)
    if not smoke:
        (ROOT / "BENCH_kernels.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    run(smoke=ap.parse_args().smoke)
