"""Kernel microbenchmarks: jnp-path timings (jit, CPU) of the three MX ops
plus analytic TPU-roofline projections for the Pallas kernels (the CPU
interpreter is for correctness; the projection uses the v5e bandwidth and
the packed 4-bit byte counts from DESIGN.md §2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.core import transforms as tfm
from repro.kernels import ops
from . import common

HBM_BW = 819e9
PEAK = 197e12


def run(log=print):
    rows = []
    M, K, N = 2048, 4096, 4096
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32) * 0.1
    cfg = mxlib.MXConfig(fmt="mxfp4")

    # jnp fake-quant path timings (CPU reference implementation)
    f_quant = jax.jit(lambda t: mxlib.quantize(t, cfg, ste=False))
    us = common.timed(f_quant, x) * 1e6
    rows.append({"name": "mx_quant_jnp_2048x4096", "us_per_call": us,
                 "derived": f"gbps={x.size*4/us*1e6/1e9:.2f}"})

    h = tfm.hadamard_matrix(32)
    f_t3 = jax.jit(lambda t: mxlib.quantize(tfm.apply_blockwise(t, h),
                                            cfg, ste=False))
    us = common.timed(f_t3, x) * 1e6
    rows.append({"name": "hadamard_quant_jnp_2048x4096", "us_per_call": us,
                 "derived": f"gbps={x.size*4/us*1e6/1e9:.2f}"})

    wq = jax.jit(lambda t: jnp.swapaxes(
        mxlib.quantize(jnp.swapaxes(t, 0, 1), cfg, ste=False), 0, 1))(w)
    f_mm = jax.jit(lambda a, b: mxlib.quantize(a, cfg, ste=False) @ b)
    us = common.timed(f_mm, x, wq) * 1e6
    flops = 2 * M * K * N
    rows.append({"name": "mx_matmul_jnp_2048x4096x4096", "us_per_call": us,
                 "derived": f"gflops={flops/us*1e6/1e9:.1f}"})

    # TPU roofline projections for the Pallas kernels (packed layout)
    wbytes = mxlib.packed_nbytes((K, N), cfg)
    abytes = M * K * 2                     # bf16 activations in
    obytes = M * N * 2
    t_mem = (wbytes + abytes + obytes) / HBM_BW
    t_cmp = flops / PEAK
    rows.append({
        "name": "mx_matmul_tpu_projection", "us_per_call": t_cmp * 1e6,
        "derived": (f"mem_us={t_mem*1e6:.1f};compute_us={t_cmp*1e6:.1f};"
                    f"bound={'memory' if t_mem > t_cmp else 'compute'};"
                    f"ai={flops/(wbytes+abytes+obytes):.1f}")})
    # bf16 baseline projection: weight bytes 2 B/param -> 3.76x more traffic
    t_mem_bf16 = (K * N * 2 + abytes + obytes) / HBM_BW
    rows.append({
        "name": "mx_vs_bf16_weight_traffic", "us_per_call": 0.0,
        "derived": f"speedup_at_bw_bound={t_mem_bf16/t_mem:.2f}x"})
    for r in rows:
        log(f"[kernels] {r['name']:32s} {r['us_per_call']:10.1f}us "
            f"{r['derived']}")
    common.emit(rows, "kernels_bench")
    return rows


if __name__ == "__main__":
    run()
