"""App. E.3 / E.5 ablations:
  - loss function for Ω: MSE vs CE vs KL (Table 8) — KL best on the
    out-of-distribution proxy, CE best in-distribution.
  - calibration-set size (Table 9): 1 -> 8 batches.
  - regularization factor λ robustness (Table 12).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import latmix as lx_lib
from repro.core import gptq as gptq_lib
from repro.core import mx as mxlib
from repro.core.quantize import QuantMode
from repro.models import api
from . import common


def _quantized_ppl(params, cfg, tset, lx, ev):
    folded = api.fold(params, cfg, tset)
    mxcfg = mxlib.MXConfig(fmt="mxfp4", block_size=32)
    qp = gptq_lib.quantize_weights_rtn(folded, cfg, mxcfg)
    qm = QuantMode(enabled=True, act_cfg=mxcfg, t3_block=lx.t3_block)
    return api.perplexity(qp, cfg, ev, qm)


def run(log=print, steps=80):
    params, cfg = common.get_model(log)
    pn = api.fold_norms(params, cfg)
    ev = common.eval_tokens(cfg)
    rows = []

    # ---- Table 8: loss ablation ----
    for loss in ["mse", "ce", "kl"]:
        lx = lx_lib.LatmixConfig(kind="lu", steps=steps, loss=loss)
        _, tset, _ = lx_lib.learn_transforms(pn, cfg, lx,
                                             common.calib_batches(cfg))
        ppl = _quantized_ppl(pn, cfg, tset, lx, ev)
        log(f"[table8] loss={loss:4s} ppl={ppl:.3f}")
        rows.append({"name": f"table8_loss_{loss}", "us_per_call": 0.0,
                     "derived": f"ppl={ppl:.3f}", "ppl": ppl})

    # ---- Table 9: calibration size ----
    for n in [1, 2, 8]:
        lx = lx_lib.LatmixConfig(kind="lu", steps=steps)
        _, tset, _ = lx_lib.learn_transforms(
            pn, cfg, lx, common.calib_batches(cfg, n=n))
        ppl = _quantized_ppl(pn, cfg, tset, lx, ev)
        log(f"[table9] calib_batches={n} ppl={ppl:.3f}")
        rows.append({"name": f"table9_calib{n}", "us_per_call": 0.0,
                     "derived": f"ppl={ppl:.3f}", "ppl": ppl})

    # ---- Table 12: λ robustness ----
    ppls = []
    for lam in [0.01, 0.1, 1.0]:
        lx = lx_lib.LatmixConfig(kind="lu", steps=steps, lambda_vol=lam)
        _, tset, _ = lx_lib.learn_transforms(pn, cfg, lx,
                                             common.calib_batches(cfg))
        ppl = _quantized_ppl(pn, cfg, tset, lx, ev)
        ppls.append(ppl)
        log(f"[table12] lambda={lam} ppl={ppl:.3f}")
        rows.append({"name": f"table12_lambda{lam}", "us_per_call": 0.0,
                     "derived": f"ppl={ppl:.3f}", "ppl": ppl})
    spread = (max(ppls) - min(ppls)) / min(ppls)
    rows.append({"name": "table12_robustness", "us_per_call": 0.0,
                 "derived": f"rel_spread={100*spread:.2f}%;"
                            f"robust={bool(spread < 0.08)}"})
    common.emit(rows, "table8_ablations")
    return rows


if __name__ == "__main__":
    run()
