"""Table 3 — computational-invariance relaxation: FP model perplexity after
fusing the learned T1/T2 at several transform-training step counts. The
paper's claim (C4): fusing the learned affine transforms changes FP quality
negligibly (distillation keeps the models consistent)."""
from __future__ import annotations

from repro.core import latmix as lx_lib
from repro.core.quantize import QuantMode
from repro.models import api
from . import common


def run(log=print):
    params, cfg = common.get_model(log)
    calib = common.calib_batches(cfg)
    ev = common.eval_tokens(cfg)
    fp_ppl = api.perplexity(params, cfg, ev)
    rows = [{"name": "table3_fp16", "us_per_call": 0.0,
             "derived": f"ppl={fp_ppl:.4f}", "ppl": fp_ppl}]
    pn = api.fold_norms(params, cfg)
    for steps in [0, 1, 50, 150]:
        lx = lx_lib.LatmixConfig(kind="lu", steps=max(steps, 1),
                                 lr=1e-3 if steps else 0.0)
        if steps == 0:
            omega = lx_lib.init_omega(
                __import__("jax").random.PRNGKey(0), cfg, lx)
            tset = lx_lib.materialize_set(omega, cfg, lx)
        else:
            _, tset, _ = lx_lib.learn_transforms(pn, cfg, lx, calib)
        folded = api.fold(pn, cfg, tset)
        ppl = api.perplexity(folded, cfg, ev, QuantMode.off(t3=32))
        drift = abs(ppl - fp_ppl) / fp_ppl
        log(f"[table3] steps={steps:4d} fused-FP ppl={ppl:.4f} "
            f"(drift {100*drift:.2f}%)")
        rows.append({"name": f"table3_steps{steps}", "us_per_call": 0.0,
                     "derived": f"ppl={ppl:.4f};drift={100*drift:.2f}%",
                     "ppl": ppl, "drift": drift})
    worst = max(r["drift"] for r in rows if "drift" in r)
    rows.append({"name": "table3_claimC4", "us_per_call": 0.0,
                 "derived": f"max_drift={100*worst:.2f}%;"
                            f"negligible={bool(worst < 0.10)}"})
    common.emit(rows, "table3_invariance")
    return rows


if __name__ == "__main__":
    run()
