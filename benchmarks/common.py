"""Shared benchmark substrate: a once-trained base model + calibration and
evaluation data, cached under experiments/bench_model.

The paper PTQs pretrained Llama/Qwen checkpoints; offline we train our own
small llama-family model on the synthetic Zipf–Markov corpus until it has
real structure (ppl << unigram baseline), then PTQ *that* — all relative
method orderings (the paper's claims) are evaluated on it.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data import synthetic
from repro.models import api
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.trainer import TrainConfig, Trainer

BENCH_DIR = pathlib.Path("experiments/bench_model")

BENCH_CFG = ArchConfig(
    name="bench-llama", family="dense", n_layers=4, d_model=128,
    n_heads=8, n_kv_heads=4, head_dim=16, d_ff=352, vocab_size=512,
    attn_chunk=64)

TRAIN = TrainConfig(steps=250, batch_size=16, seq_len=64,
                    ckpt_every=250, ckpt_dir=str(BENCH_DIR),
                    log_every=50,
                    opt=opt.AdamWConfig(lr=3e-3, warmup_steps=20,
                                        total_steps=250))


def get_model(log=print, outliers: bool = True):
    """Train (or load) the shared benchmark model. Returns (params, cfg).

    outliers=True (default): return an *exactly equivalence-class* variant
    whose residual stream has the outlier channels documented for real
    LLMs (Dettmers et al. 2022) — built by folding a diagonal invertible
    transform with a few large entries through our own folding machinery
    (fold(diag(s)), Appendix C). CPU-scale models trained for minutes do
    not develop emergent outliers, so this reconstructs the regime the
    paper targets while keeping every method on the same footing (the
    diagonal transform is itself within the search space of the learned
    methods)."""
    cfg = BENCH_CFG
    if ckpt.latest_step(BENCH_DIR) is None:
        log(f"[bench] training base model ({cfg.param_count()/1e6:.1f}M "
            f"params, {TRAIN.steps} steps)...")
        tr = Trainer(cfg, TRAIN, log=log)
        tr.train()
        log(f"[bench] base model ppl={tr.eval_ppl():.3f}")
    tr = Trainer(cfg, TRAIN, log=lambda *_: None)
    tr.init_or_resume()
    params = tr.params
    if outliers:
        from repro.core import folding as fl
        rng = np.random.default_rng(13)
        s = np.exp(rng.normal(0.0, 0.4, cfg.d_model)).astype(np.float32)
        hot = rng.choice(cfg.d_model, 5, replace=False)
        s[hot] *= np.asarray([8.0, 6.0, 5.0, 4.0, 4.0], np.float32)
        a1 = jnp.diag(jnp.asarray(s))
        ts = fl.TransformSet(
            a1=a1, v1=jnp.zeros(cfg.d_model),
            a2=jnp.tile(jnp.eye(cfg.head_dim)[None], (cfg.n_layers, 1, 1)),
            v2=jnp.zeros((cfg.n_layers, cfg.head_dim)), t3_block=0)
        params = api.fold(api.fold_norms(params, cfg), cfg, ts)
    return params, cfg


def calib_batches(cfg, n=4, batch=8, seq=64, seed=100):
    src = synthetic.make_source(cfg, batch, seq, 0)
    return [{k: jnp.asarray(v) for k, v in src.batch(seed + i).items()}
            for i in range(n)]


def eval_tokens(cfg, batch=16, seq=64, seed=5000):
    src = synthetic.make_source(cfg, batch, seq + 1, 0)
    b = src.batch(seed)
    toks = np.concatenate([b["inputs"], b["labels"][:, -1:]], axis=1)
    return jnp.asarray(toks)


def eval_batches(cfg, n=3, batch=16, seq=64, seed=7000):
    src = synthetic.make_source(cfg, batch, seq, 0)
    return [src.batch(seed + i) for i in range(n)]


def timed(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return min(ts)


def emit(rows, name, persist: bool = True):
    """Print the required ``name,us_per_call,derived`` CSV rows and persist
    the full records. persist=False (CI --smoke runs) skips the JSON write
    so toy shapes never overwrite the tracked perf-trajectory records."""
    if persist:
        outdir = pathlib.Path("experiments/benchmarks")
        outdir.mkdir(parents=True, exist_ok=True)
        (outdir / f"{name}.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        us = r.get("us_per_call", 0.0)
        print(f"{r['name']},{us:.1f},{r.get('derived', '')}")
