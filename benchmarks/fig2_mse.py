"""Fig. 2 — transformation MSE vs MX block size (2a) and per-block error
profile (2c), on real activations of the trained benchmark model.

Paper claims reproduced (C1): learned affine < block-Hadamard / Hadamard <
none; full rotations flatten the error across blocks, block-Hadamard
reduces dominant blocks, learned affine lowers all blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mx as mxlib
from repro.core import transforms as tfm
from repro.models import api
from repro.models.layers import rms_norm
from . import common


def capture_activations(params, cfg, batches):
    """Residual-stream activations entering layer 0's attention (the T1
    input distribution)."""
    outs = []
    for b in batches:
        x = jnp.take(params["embed"], b["inputs"], axis=0)
        p0 = jax.tree.map(lambda a: a[0], params["blocks"])
        h = rms_norm(x, p0["ln1"], cfg.norm_eps)
        outs.append(np.asarray(h).reshape(-1, cfg.d_model))
    return jnp.asarray(np.concatenate(outs, 0))


def learn_affine_mse(x, block_size, steps=150, lr=1e-3, kind="lu"):
    """Directly minimize E(T) (Eq. 2) over the LU parameterization — the
    'learned affine' curve of Fig. 2 (numerical study). Keeps the best
    iterate (init is a block-diagonal rotation, so the result can never
    be worse than block-Hadamard)."""
    d = x.shape[-1]
    spec = tfm.TransformSpec(kind=kind, d=d, block=min(block_size, d))
    params = tfm.init_params(jax.random.PRNGKey(0), spec)
    cfg = mxlib.MXConfig(fmt="mxfp4", block_size=block_size)

    learn, fixed = params["learn"], params["fixed"]

    def loss(lr_):
        p = {"learn": lr_, "fixed": fixed}
        a, v = tfm.materialize(p, spec)
        y = tfm.forward(x, a, v)
        q = mxlib.quantize(y, cfg)           # STE
        back = tfm.backward(q, tfm.inverse(a), v)
        mse = jnp.mean(jnp.sum((x - back) ** 2, -1) / d)
        return mse + 0.1 * tfm.loss_vol(p, spec)

    def eval_mse(lr_):
        a, v = tfm.materialize({"learn": lr_, "fixed": fixed}, spec)
        return float(tfm.transform_mse(x, a, v, cfg))

    from repro.training import optimizer as opt
    state = opt.init_state(learn)
    ocfg = opt.AdamWConfig(lr=lr, weight_decay=0.0, warmup_steps=10,
                           total_steps=steps, grad_clip=1.0)
    step = jax.jit(lambda l, s: opt.apply_updates(
        l, jax.grad(loss)(l), s, ocfg)[:2])
    best, best_mse = learn, eval_mse(learn)
    for i in range(steps):
        learn, state = step(learn, state)
        if (i + 1) % 25 == 0:
            m = eval_mse(learn)
            if m < best_mse:
                best, best_mse = learn, m
    a, v = tfm.materialize({"learn": best, "fixed": fixed}, spec)
    return a, v


def run(log=print):
    params, cfg = common.get_model(log)
    x = capture_activations(params, cfg, common.eval_batches(cfg, n=2))
    d = cfg.d_model
    rows = []
    for B in [8, 16, 32, 64]:
        mxcfg = mxlib.MXConfig(fmt="mxfp4", block_size=B)
        errs = {}
        for kind in ["identity", "hadamard", "block_hadamard"]:
            spec = tfm.TransformSpec(kind=kind, d=d, block=B)
            p = tfm.init_params(jax.random.PRNGKey(1), spec)
            a, v = tfm.materialize(p, spec)
            errs[kind] = float(tfm.transform_mse(x, a, v, mxcfg))
        a, v = learn_affine_mse(x, B)
        errs["learned_affine"] = float(tfm.transform_mse(x, a, v, mxcfg))
        rows.append({"name": f"fig2a_mse_B{B}", "us_per_call": 0.0,
                     "derived": ";".join(f"{k}={v:.5f}"
                                         for k, v in errs.items()),
                     **errs})
        ok = (errs["learned_affine"] <= errs["block_hadamard"] + 1e-6
              and errs["block_hadamard"] < errs["identity"])
        rows[-1]["claim_C1"] = bool(ok)

    # Fig 2c: per-block error at B=32 (vanilla vs block-hadamard vs learned)
    B = 32
    mxcfg = mxlib.MXConfig(fmt="mxfp4", block_size=B)
    prof = {}
    for kind in ["identity", "hadamard", "block_hadamard"]:
        spec = tfm.TransformSpec(kind=kind, d=d, block=B)
        a, v = tfm.materialize(tfm.init_params(jax.random.PRNGKey(2), spec),
                               spec)
        y = tfm.forward(x, a, v)
        back = tfm.backward(mxlib.quantize(y, mxcfg, ste=False),
                            tfm.inverse(a), v)
        prof[kind] = np.asarray(mxlib.blockwise_error(x, back, B)).tolist()
    a, v = learn_affine_mse(x, B)
    y = tfm.forward(x, a, v)
    back = tfm.backward(mxlib.quantize(y, mxcfg, ste=False),
                        tfm.inverse(a), v)
    prof["learned_affine"] = np.asarray(
        mxlib.blockwise_error(x, back, B)).tolist()
    rows.append({"name": "fig2c_blockwise", "us_per_call": 0.0,
                 "derived": "max_block_err:" + ";".join(
                     f"{k}={max(v):.5f}" for k, v in prof.items()),
                 "profiles": prof})
    common.emit(rows, "fig2_mse")
    return rows


if __name__ == "__main__":
    run()
