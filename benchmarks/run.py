"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: fig2,table1,table2,"
                         "table3,table8,fig4,kernels,serving,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="fewer transform-learning steps")
    ap.add_argument("--load", action="store_true", default=True,
                    help="include the serving latency-under-load sweep "
                         "(Poisson arrivals; default on)")
    ap.add_argument("--no-load", dest="load", action="store_false",
                    help="skip the latency-under-load sweep")
    args = ap.parse_args()
    wanted = set(args.only.split(",")) if args.only else None

    from . import (fig2_mse, fig4_throughput, kernels_bench,
                   roofline_report, serving_bench, table1_methods,
                   table2_granularity, table3_invariance, table8_ablations)

    benches = [
        ("fig2", fig2_mse.run, {}),
        ("table1", table1_methods.run,
         {"steps": 40} if args.fast else {}),
        ("table2", table2_granularity.run,
         {"steps": 40} if args.fast else {}),
        ("table3", table3_invariance.run, {}),
        ("table8", table8_ablations.run,
         {"steps": 30} if args.fast else {}),
        ("fig4", fig4_throughput.run, {}),
        ("kernels", kernels_bench.run, {}),
        ("serving", serving_bench.run, {"load": args.load}),
        ("roofline", roofline_report.run, {}),
    ]
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn, kw in benches:
        if wanted and name not in wanted:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        fn(log=lambda m: print(m, file=sys.stderr), **kw)
    print(f"# total {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
