"""Table 2 — transformation type × granularity ablation (MXFP4 ppl):
random Hadamard / learned orthogonal (±bias) / learned invertible /
LATMiX-LU, each at Block and Full granularity.

Paper claim reproduced (C2): Full + affine (LATMiX) is best; learning
helps over fixed rotations; the bias term helps at full granularity.
"""
from __future__ import annotations

from repro.core import latmix as lx_lib
from repro.core import gptq as gptq_lib
from repro.core import mx as mxlib
from repro.core.quantize import QuantMode
from repro.models import api
from . import common

VARIANTS = [
    # (label, kind, learn_bias)
    ("none", None, False),
    ("random_hadamard", "hadamard", False),
    ("learned_orth", "orthogonal", False),
    ("learned_orth_bias", "orthogonal", True),
    ("learned_inv", "invertible", False),
    ("latmix_lu", "lu", True),
]


def run(log=print, steps=100):
    params, cfg = common.get_model(log)
    calib = common.calib_batches(cfg)
    ev = common.eval_tokens(cfg)
    mxcfg = mxlib.MXConfig(fmt="mxfp4", block_size=32)
    rows = []
    for label, kind, bias in VARIANTS:
        grans = ["full"] if kind in (None,) else ["block", "full"]
        if kind == "hadamard":
            grans = ["block", "full"]
        for gran in grans:
            if kind is None:
                qparams = gptq_lib.quantize_weights_rtn(params, cfg, mxcfg)
                qm = QuantMode(enabled=True, act_cfg=mxcfg, t3_block=0)
                ppl = api.perplexity(qparams, cfg, ev, qm)
            else:
                k = ("block_hadamard" if (kind == "hadamard"
                                          and gran == "block") else kind)
                lx = lx_lib.LatmixConfig(
                    kind=k, learn_bias=bias, steps=steps,
                    granularity="full" if k == "block_hadamard" else gran)
                pn = api.fold_norms(params, cfg)
                _, tset, _ = lx_lib.learn_transforms(pn, cfg, lx, calib)
                folded = api.fold(pn, cfg, tset)
                qparams = gptq_lib.quantize_weights_rtn(folded, cfg, mxcfg)
                qm = QuantMode(enabled=True, act_cfg=mxcfg,
                               t3_block=lx.t3_block)
                ppl = api.perplexity(qparams, cfg, ev, qm)
            name = f"table2_{label}_{gran}"
            log(f"[table2] {label:18s} {gran:5s} ppl={ppl:.3f}")
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"ppl={ppl:.3f}", "ppl": ppl})
    by = {r["name"]: r["ppl"] for r in rows}
    ok = by.get("table2_latmix_lu_full", 9e9) <= min(
        v for k, v in by.items() if k != "table2_latmix_lu_full") * 1.05
    rows.append({"name": "table2_claimC2", "us_per_call": 0.0,
                 "derived": f"latmix_full_best={bool(ok)}"})
    common.emit(rows, "table2_granularity")
    return rows


if __name__ == "__main__":
    run()
