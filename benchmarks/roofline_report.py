"""Roofline summary benchmark: reads experiments/roofline/*.json (produced
by repro.roofline.analyze from the dry-run compiles) and emits one CSV row
per (arch × shape) cell with the three terms and the dominant bottleneck."""
from __future__ import annotations

import json
import pathlib

from . import common


def run(log=print):
    root = pathlib.Path("experiments/roofline_final")
    if not any(root.glob("*__*.json")) if root.exists() else True:
        root = pathlib.Path("experiments/roofline")
    rows = []
    if not root.exists():
        rows.append({"name": "roofline_missing", "us_per_call": 0.0,
                     "derived": "run repro.roofline.analyze first"})
        common.emit(rows, "roofline_report")
        return rows
    for f in sorted(root.glob("*__*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        t = r["terms_s"]
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}",
            "us_per_call": r["step_time_lower_bound_s"] * 1e6,
            "derived": (f"dom={r['dominant']};"
                        f"cmp_ms={t['compute']*1e3:.2f};"
                        f"mem_ms={t['memory']*1e3:.2f};"
                        f"col_ms={t['collective']*1e3:.2f};"
                        f"frac={r['roofline_fraction']:.3f}")})
    common.emit(rows, "roofline_report")
    return rows


if __name__ == "__main__":
    run()
