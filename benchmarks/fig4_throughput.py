"""Fig. 4 — inference throughput of (a) FP16/bf16, (b) MX quantized
(MR-GPTQ-style, no T3), (c) MX + online T3 (LATMiX path), (d) LATMiX
without the bias (Learned-Inv): tokens/s of the serving engine (CPU-jit
relative comparison — the paper's claim C5 is that LATMiX adds at most
negligible overhead vs the other quantized paths) + the per-op cost of the
online T3 transform itself."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import QuantMode
from repro.core import transforms as tfm
from repro.serving.engine import Engine
from . import common


def run(log=print):
    params, cfg = common.get_model(log)
    rows = []
    modes = [
        ("bf16", QuantMode.off()),
        ("mx_no_t3", QuantMode.mxfp4(t3=False)),
        ("mx_t3_latmix", QuantMode.mxfp4(t3=True)),
        ("mx_t3_nobias", QuantMode.mxfp4(t3=True)),  # same runtime path
    ]
    base = None
    for name, qm in modes:
        eng = Engine(params, cfg, qm, batch_size=8, max_len=128)
        stats = eng.throughput(n_requests=8, prompt_len=32, max_new=16)
        tps = stats["tok_per_s"]
        if base is None:
            base = tps
        log(f"[fig4] {name:14s} {tps:9.1f} tok/s "
            f"({100*tps/base:.1f}% of bf16)")
        rows.append({"name": f"fig4_{name}",
                     "us_per_call": 1e6 / max(tps, 1e-9),
                     "derived": f"tok_per_s={tps:.1f};rel={tps/base:.3f}",
                     "tok_per_s": tps})
    # scheduler comparison on the LATMiX path: mixed-length traffic, wave
    # vs continuous batching (same requests, token-identical outputs per
    # request; the deep-dive lives in benchmarks/serving_bench.py)
    from .serving_bench import bench_scheduler, mixed_requests
    sched_stats = {}
    for sched in ("wave", "continuous"):
        reqs = mixed_requests(cfg, 16, seed=0, len_range=(8, 48),
                              new_range=(4, 24))
        r = bench_scheduler(params, cfg, QuantMode.mxfp4(t3=True), sched,
                            reqs, batch=4, max_len=96)
        sched_stats[sched] = r
        log(f"[fig4] sched_{sched:11s} {r['tok_per_s']:9.1f} tok/s "
            f"(decode utilization {r['decode_utilization']:.3f})")
        rows.append({"name": f"fig4_sched_{sched}",
                     "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
                     "derived": (f"tok_per_s={r['tok_per_s']:.1f};"
                                 f"util={r['decode_utilization']:.3f}")})

    # isolated T3 cost: one online block-Hadamard over a d_ff activation
    x = jax.random.normal(jax.random.PRNGKey(0), (512, cfg.d_ff))
    h = tfm.hadamard_matrix(32)
    f = jax.jit(lambda t: tfm.apply_blockwise(t, h))
    us = common.timed(f, x) * 1e6
    rows.append({"name": "fig4_t3_op", "us_per_call": us,
                 "derived": f"bytes={x.size*4}"})
    t3_rel = rows[2]["tok_per_s"] / max(rows[1]["tok_per_s"], 1e-9)
    rows.append({"name": "fig4_claimC5", "us_per_call": 0.0,
                 "derived": f"latmix_vs_mx={t3_rel:.3f};"
                            f"negligible_overhead={bool(t3_rel > 0.85)}"})
    common.emit(rows, "fig4_throughput")
    return rows


if __name__ == "__main__":
    run()
