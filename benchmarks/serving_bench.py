"""Serving-scheduler benchmark: wave vs continuous batching on a
mixed-length workload, plus latency under load (the production traffic
shape — prompts and decode budgets spread over a wide range, arriving
as a Poisson process rather than all at once).

The wave scheduler pads every request in a wave to the wave's longest
prompt and decodes until the wave's largest ``max_new`` — so on mixed
traffic most decode slot-steps produce tokens nobody asked for. The
continuous scheduler refills finished slots from the queue the step they
free up, so its decode-step utilization (useful tokens / decode
slot-steps) approaches 1.0 with a deep queue.

The **load section** measures what batch throughput numbers hide:
per-request TTFT (submit -> first token) and TPOT (per-token decode
interval) under open-loop Poisson arrivals, swept across offered load
(0.5x / 1x / 2x of the engine's measured offline capacity). p50 stays
flat while p99 degrades as offered load crosses capacity — the
latency-under-load curve (``docs/observability.md``).

The **batched-prefill rows** (``serving_prefill_batched_{off,on}``)
serve identical long-prompt paged traffic with admission batching off
(``max_prefill_lanes_per_step=1`` — one lane chunk-prefills per engine
step, the pre-batching behavior) vs on: co-admitted lanes share one
chunked-prefill dispatch per chunk index, so ``prefill_chunk_steps``
collapses from sum(chunks) toward max(chunks) per wave and TTFT p99
drops, with outputs asserted token-identical
(``docs/serving.md#batched-prefill-admission``).

The **HTTP overload rows** (``serving_http_overload_{shed,noshed}``)
push the same 2x-capacity Poisson traffic through the real HTTP/SSE
front end (``repro.serving.server``, one socket per request) with
admission shedding on vs off: with a queue-depth cap the excess is
refused at the door (429 + Retry-After) and the *admitted* requests'
client-observed p99 TTFT stays bounded; without it everything queues
and p99 TTFT grows several-fold (``docs/server.md``). ``--http-only``
re-runs just these arms and merges the rows into the existing
artifacts.

Writes the standard experiments/benchmarks/serving_bench.json and a
repo-root BENCH_serving.json (the perf-trajectory artifact). Rows are
schema-versioned: ``"schema": 2`` marks rows carrying the telemetry
fields (offered_rps, ttft/tpot percentiles); rows without the key are
v1 (pre-telemetry). ``--smoke`` uses a tiny random-init model and small
traffic for CI; ``--trace OUT.json`` exports a Chrome trace of the
continuous-scheduler runs (open in https://ui.perfetto.dev).

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
        [--trace OUT.json] [--http-only]
"""
from __future__ import annotations

import argparse
import asyncio
import collections
import json
import pathlib
import socket
import threading
import time

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.models import api
from repro.obs import Tracer
from repro.serving.engine import Engine, Request
from repro.serving.policy import RequestState, SchedulingPolicy, SpecConfig
from repro.serving.server import Server, ServerConfig
from . import common

ROOT = pathlib.Path(__file__).resolve().parent.parent

# BENCH_serving.json row-format version. v1 rows (no "schema" key) are
# the pre-telemetry format; v2 adds the latency-under-load rows and
# stamps every row.
SCHEMA_VERSION = 2

# Offered-load sweep points, as fractions of measured offline capacity.
LOAD_FRACS = (0.5, 1.0, 2.0)

SMOKE_CFG = ArchConfig(
    name="serve-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128, attn_chunk=16)


def mixed_requests(cfg: ArchConfig, n: int, seed: int = 0,
                   len_range=(8, 48), new_range=(4, 32)):
    """A mixed-length workload: prompt lengths and decode budgets drawn
    uniformly from the given ranges (fixed seed — both schedulers serve
    the identical request list)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        s = int(rng.integers(len_range[0], len_range[1] + 1))
        m = int(rng.integers(new_range[0], new_range[1] + 1))
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
            max_new=m))
    return reqs


def prefix_requests(cfg: ArchConfig, n: int, prefix_len: int,
                    seed: int = 0, tail_range=(8, 32),
                    new_range=(4, 16)):
    """A prefix-heavy workload: every request shares one ``prefix_len``-
    token system prompt and carries its own mixed-length tail — the
    traffic shape the paged engine's hash-based prefix caching targets
    (the shared prefix is chunk-prefilled once and reused by
    reference)."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size,
                              prefix_len).astype(np.int32)
    reqs = []
    for _ in range(n):
        t = int(rng.integers(tail_range[0], tail_range[1] + 1))
        m = int(rng.integers(new_range[0], new_range[1] + 1))
        reqs.append(Request(prompt=np.concatenate(
            [sys_prompt,
             rng.integers(0, cfg.vocab_size, t).astype(np.int32)]),
            max_new=m))
    return reqs


def repetitive_requests(cfg: ArchConfig, n: int, seed: int = 0,
                        period: int = 3, prompt_len: int = 12,
                        max_new: int = 48):
    """A repetition-friendly workload for the speculative-decoding rows:
    each prompt tiles a short random motif, and the decode budget is
    long enough that greedy decode settles into a short token cycle —
    exactly what the prompt-lookup drafter proposes, so acceptance is
    high. (Fixed seed — spec on/off serve the identical request list.)"""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        motif = rng.integers(0, cfg.vocab_size, period)
        prompt = np.tile(motif, prompt_len // period + 1)[:prompt_len]
        reqs.append(Request(prompt=prompt.astype(np.int32),
                            max_new=max_new))
    return reqs


def poisson_requests(cfg: ArchConfig, rate_rps: float, n: int,
                     seed: int = 0, len_range=(8, 48),
                     new_range=(4, 32)):
    """``n`` mixed-length requests with Poisson arrival offsets at
    ``rate_rps`` requests/s (exponential inter-arrival gaps, fixed
    seed). Returns ``[(arrival_offset_s, Request), ...]`` sorted by
    arrival."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate_rps))
        s = int(rng.integers(len_range[0], len_range[1] + 1))
        m = int(rng.integers(new_range[0], new_range[1] + 1))
        out.append((t, Request(
            prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
            max_new=m)))
    return out


def run_load(eng: Engine, arrivals) -> float:
    """Open-loop load test: submit each request once the wall clock
    passes its arrival offset (never waiting for the engine — queueing
    delay is part of what we measure), stepping the engine in between.
    Returns elapsed seconds from first arrival's epoch to drain."""
    pending = collections.deque(arrivals)
    t0 = time.perf_counter()
    while pending or eng.busy:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            eng.submit(pending.popleft()[1])
        if eng.busy:
            eng.step()
        elif pending:            # idle gap: sleep to the next arrival
            time.sleep(max(0.0, min(pending[0][0] - now, 0.02)))
    return time.perf_counter() - t0


def _pct(xs, q) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else None


def bench_load(params, cfg, qm, rate_rps: float, n_req: int, *,
               batch: int, max_len: int, len_range, new_range,
               tracer=None, seed: int = 7) -> dict:
    """One offered-load point: fresh continuous engine, warmed up (jit
    compiles out of the timed window), then ``n_req`` Poisson arrivals
    at ``rate_rps``. Latencies come from per-request monotonic
    timestamps (``Request.m_submit/m_first/m_done``)."""
    eng = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                 scheduler="continuous", tracer=tracer)
    eng.generate(mixed_requests(cfg, 2, seed=99, len_range=len_range,
                                new_range=new_range))    # warm the jits
    eng.reset_stats()
    arrivals = poisson_requests(cfg, rate_rps, n_req, seed=seed,
                                len_range=len_range, new_range=new_range)
    elapsed = run_load(eng, arrivals)
    reqs = [r for _, r in arrivals]
    ttft = [r.m_first - r.m_submit for r in reqs]
    tpot = [(r.m_done - r.m_first) / (len(r.out) - 1)
            for r in reqs if len(r.out) > 1 and r.m_done > r.m_first]
    toks = sum(len(r.out) for r in reqs)
    return {
        "kind": "latency_under_load",
        "offered_rps": rate_rps,
        "achieved_rps": len(reqs) / elapsed if elapsed > 0 else 0.0,
        "n_requests": len(reqs), "elapsed_s": elapsed,
        "tok_per_s": toks / elapsed if elapsed > 0 else 0.0,
        "ttft_p50_ms": _pct(ttft, 50) * 1e3,
        "ttft_p99_ms": _pct(ttft, 99) * 1e3,
        "tpot_p50_ms": _pct(tpot, 50) * 1e3 if tpot else None,
        "tpot_p99_ms": _pct(tpot, 99) * 1e3 if tpot else None,
    }


def bench_overload(params, cfg, qm, cap_rps: float, n_req: int, *,
                   batch: int, max_len: int, len_range, new_range,
                   seed: int = 13):
    """Overload behavior (docs/robustness.md): the same 2x-capacity
    Poisson traffic served with deadlines on vs off. With deadlines the
    engine sheds the excess as TIMED_OUT and the p99 TTFT of requests
    that *do* complete stays bounded near the deadline; without them
    every request completes but p99 TTFT grows with queue depth.
    Returns the two rows plus the deadline used (ms)."""
    rate = cap_rps * 2.0
    # roughly the back half of the offered traffic cannot meet this
    # budget at 2x load, so the shed/served split is exercised
    deadline_ms = 0.5 * n_req / max(cap_rps, 1e-9) * 1e3
    rows = []
    for tag, policy in (
            ("on", SchedulingPolicy(deadline_ms=deadline_ms,
                                    ttft_deadline_ms=deadline_ms)),
            ("off", SchedulingPolicy())):
        eng = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                     scheduler="continuous", policy=policy)
        warm = mixed_requests(cfg, 2, seed=99, len_range=len_range,
                              new_range=new_range)
        for r in warm:        # jit-compile time must not expire these
            r.deadline_ms = r.ttft_deadline_ms = 1e9
        eng.generate(warm)
        eng.reset_stats()
        arrivals = poisson_requests(cfg, rate, n_req, seed=seed,
                                    len_range=len_range,
                                    new_range=new_range)
        elapsed = run_load(eng, arrivals)
        reqs = [r for _, r in arrivals]
        fin = [r for r in reqs if r.state is RequestState.FINISHED]
        ttft = [r.m_first - r.m_submit for r in fin]
        within = sum((r.m_done - r.m_submit) * 1e3 <= deadline_ms
                     for r in fin)
        # count terminals off the arrival requests themselves — the
        # engine counters are cumulative and include the warm-up
        timed_out = sum(r.state is RequestState.TIMED_OUT for r in reqs)
        preempts = sum(r.preemptions for r in reqs)
        rows.append({
            "name": f"serving_overload_deadline_{tag}",
            "kind": "overload",
            "us_per_call": (_pct(ttft, 99) or 0.0) * 1e6,
            "offered_rps": rate, "n_requests": n_req,
            "deadline_ms": deadline_ms, "elapsed_s": elapsed,
            "completed": len(fin),
            "timed_out": timed_out,
            "preemptions": preempts,
            "ttft_p50_ms": (_pct(ttft, 50) or 0.0) * 1e3,
            "ttft_p99_ms": (_pct(ttft, 99) or 0.0) * 1e3,
            "completed_within_deadline": within / n_req,
            "derived": (f"deadline_ms={deadline_ms:.0f};"
                        f"completed={len(fin)}/{n_req};"
                        f"timed_out={timed_out};"
                        f"ttft_p99_ms={(_pct(ttft, 99) or 0.0)*1e3:.1f};"
                        f"within_deadline={within / n_req:.2f}"),
        })
    return rows, deadline_ms


def _serve_in_thread(eng, drain_timeout_s: float = 120.0):
    """Boot a :class:`Server` (ephemeral port) on a dedicated asyncio
    loop thread so blocking client sockets can drive it from bench
    threads. Returns (server, loop, thread)."""
    srv = Server(eng, ServerConfig(port=0, drain_timeout_s=drain_timeout_s))
    loop = asyncio.new_event_loop()
    started = threading.Event()

    def runner():
        asyncio.set_event_loop(loop)
        loop.run_until_complete(srv.start())
        started.set()
        loop.run_forever()

    t = threading.Thread(target=runner, name="bench-http-server",
                         daemon=True)
    t.start()
    started.wait()
    return srv, loop, t


def _stop_server(srv, loop, t) -> dict:
    """Drain the threaded server and return its drain report."""
    report = asyncio.run_coroutine_threadsafe(
        srv.shutdown(), loop).result(timeout=300)
    loop.call_soon_threadsafe(loop.stop)
    t.join(10)
    loop.close()
    return report


def _http_stream_generate(port: int, prompt, max_new: int,
                          timeout_s: float = 300.0) -> dict:
    """One streamed generation over a blocking socket. Returns
    ``{"status", "ttft_s", "state"}`` — ``ttft_s`` is client-observed
    submit -> first ``event: token`` (None when shed/errored)."""
    body = json.dumps({"prompt": [int(x) for x in prompt],
                       "max_new": int(max_new), "stream": True}).encode()
    t0 = time.perf_counter()
    with socket.create_connection(("127.0.0.1", port),
                                  timeout=timeout_s) as s:
        s.sendall((f"POST /v1/generate HTTP/1.1\r\nHost: b\r\n"
                   f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        buf, ttft, status = b"", None, None
        while True:
            try:
                chunk = s.recv(65536)
            except socket.timeout:
                break
            if not chunk:
                break
            buf += chunk
            if status is None and b"\r\n" in buf:
                status = int(buf.split(b"\r\n", 1)[0].split()[1])
            if ttft is None and b"event: token" in buf:
                ttft = time.perf_counter() - t0
    state = None
    for line in buf.split(b"\r\n\r\n", 1)[-1].splitlines():
        if line.startswith(b"data:"):
            try:
                state = json.loads(line[5:]).get("state", state)
            except json.JSONDecodeError:
                pass
    return {"status": status, "ttft_s": ttft, "state": state}


def _http_arm(params, cfg, qm, policy, arrivals, *, batch: int,
              max_len: int, len_range, new_range,
              step_pad_s: float = 0.0):
    """One HTTP traffic arm: fresh warmed engine under a threaded
    server, one client thread per arrival (blocking socket, SSE),
    graceful drain asserted clean. ``step_pad_s`` pads every engine
    step via the deterministic ``slow_step`` fault point. Returns
    (results, elapsed_s)."""
    from repro.serving.faults import FaultInjector
    faults = (FaultInjector(seed=0).inject("slow_step", every=1,
                                           delay_s=step_pad_s)
              if step_pad_s > 0 else None)
    eng = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                 scheduler="continuous", policy=policy, faults=faults)
    for wr in mixed_requests(cfg, 2, seed=99, len_range=len_range,
                             new_range=new_range):  # warm the jits, one
        eng.generate([wr])       # at a time: admission caps stay clear
    eng.reset_stats()
    srv, loop, thr = _serve_in_thread(eng)
    results = [None] * len(arrivals)
    t0 = time.perf_counter()

    def client(i, offset, req):
        time.sleep(max(0.0, offset - (time.perf_counter() - t0)))
        results[i] = _http_stream_generate(srv.port, req.prompt,
                                           req.max_new)

    threads = [threading.Thread(target=client, args=(i, off, req))
               for i, (off, req) in enumerate(arrivals)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    elapsed = time.perf_counter() - t0
    report = _stop_server(srv, loop, thr)
    assert report["clean"], f"unclean drain: {report}"
    return results, elapsed


def bench_http_overload(params, cfg, qm, n_req: int, *, batch: int,
                        max_len: int, len_range, new_range,
                        step_pad_s: float = 0.04, seed: int = 17,
                        log=print):
    """Overload through the HTTP front end (docs/server.md): identical
    2x-capacity Poisson traffic, each request a real socket streaming
    SSE, served with admission shedding on (``max_queue_depth=batch``;
    the excess is refused at the door with 429 + Retry-After) vs off
    (everything queues). With shedding the *admitted* requests' p99
    TTFT stays near the unloaded figure at the price of a shed
    fraction; without it every request is eventually served but client-
    observed p99 TTFT grows with the queue. Every arm ends in a
    graceful drain whose report must be clean.

    Three measurement choices keep "2x capacity" honest on a bench
    model whose raw decode step is ~10ms (a scale where wall-clock
    queueing would drown in client/HTTP noise):

    * capacity is probed through the server itself — the same workload
      slammed in closed-loop — not taken from the offline batch tok/s
      figure (which measures a different utilization pattern);
    * every arm serves in the burst-capped posture (a far-future
      default deadline activates ``deadline_burst_cap``, the fairness
      path real deployments with deadlines run). Without it the
      scheduler decodes a sparse arrival to completion in ONE
      uninterrupted step — per-request service under light load is
      then several times faster than under saturation and "2x" never
      builds a queue;
    * every engine step is padded by ``step_pad_s`` via the
      deterministic ``slow_step`` fault point, standing in for a
      production-scale model's step time — the bench measures the
      front end's overload behavior (queueing vs shedding), not the
      toy model's speed. The pad is identical in the probe and both
      arms, so the 2x ratio is unaffected by its value.
    """
    probe = [(0.0, r) for r in
             mixed_requests(cfg, n_req, seed=seed + 1,
                            len_range=len_range, new_range=new_range)]
    _, probe_s = _http_arm(params, cfg, qm,
                           SchedulingPolicy(deadline_ms=1e9), probe,
                           batch=batch, max_len=max_len,
                           len_range=len_range, new_range=new_range,
                           step_pad_s=step_pad_s)
    cap_rps = n_req / max(probe_s, 1e-9)
    rate = cap_rps * 2.0
    depth = max(1, batch // 2)
    log(f"[serving] http capacity probe: {cap_rps:.2f} rps "
        f"({n_req} closed-loop requests in {probe_s:.2f}s, "
        f"step_pad={step_pad_s * 1e3:.0f}ms)")
    rows = []
    for tag, policy in (
            ("shed", SchedulingPolicy(deadline_ms=1e9,
                                      max_queue_depth=depth)),
            ("noshed", SchedulingPolicy(deadline_ms=1e9))):
        arrivals = poisson_requests(cfg, rate, n_req, seed=seed,
                                    len_range=len_range,
                                    new_range=new_range)
        results, elapsed = _http_arm(params, cfg, qm, policy, arrivals,
                                     batch=batch, max_len=max_len,
                                     len_range=len_range,
                                     new_range=new_range,
                                     step_pad_s=step_pad_s)
        shed = [r for r in results if r and r["status"] == 429]
        admitted = [r for r in results
                    if r and r["status"] == 200 and r["state"] == "finished"]
        ttft = [r["ttft_s"] for r in admitted if r["ttft_s"] is not None]
        shed_frac = len(shed) / len(arrivals)
        p50 = (_pct(ttft, 50) or 0.0) * 1e3
        p99 = (_pct(ttft, 99) or 0.0) * 1e3
        log(f"[serving] http 2x shed={tag == 'shed'!s:5s} "
            f"admitted={len(admitted)}/{n_req}  shed={len(shed)}  "
            f"ttft p50={p50:.1f}ms p99={p99:.1f}ms  drain_clean=True")
        rows.append({
            "name": f"serving_http_overload_{tag}",
            "kind": "http_overload",
            "us_per_call": p99 * 1e3,       # p99 TTFT of admitted, in us
            "capacity_rps": cap_rps,
            "offered_rps": rate, "n_requests": n_req,
            "admitted": len(admitted), "shed": len(shed),
            "shed_fraction": shed_frac, "elapsed_s": elapsed,
            "ttft_p50_ms": p50, "ttft_p99_ms": p99,
            "max_queue_depth": depth if tag == "shed" else None,
            "step_pad_ms": step_pad_s * 1e3,
            "drain_clean": True,
            "derived": (f"capacity_rps={cap_rps:.2f};"
                        f"offered_rps={rate:.2f};"
                        f"admitted={len(admitted)}/{n_req};"
                        f"shed_fraction={shed_frac:.2f};"
                        f"ttft_p50_ms={p50:.1f};ttft_p99_ms={p99:.1f};"
                        f"max_queue_depth="
                        f"{depth if tag == 'shed' else 'off'};"
                        f"step_pad_ms={step_pad_s * 1e3:.0f};"
                        f"drain_clean=True"),
        })
    return rows


def bench_scheduler(params, cfg, qm, scheduler: str, reqs, *,
                    batch: int, max_len: int, kv_cache=None,
                    kv_layout: str = "contiguous",
                    page_size=None, policy=None, warm=None,
                    tracer=None) -> dict:
    eng = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                 scheduler=scheduler, kv_cache=kv_cache,
                 kv_layout=kv_layout, page_size=page_size,
                 bucket_prompts=(kv_layout != "paged"), policy=policy,
                 tracer=tracer)
    if warm:                     # jit compiles out of the timed window
        eng.generate(warm)
        eng.reset_stats()
    t0 = time.perf_counter()
    done = eng.generate(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    stats = eng.stats()
    return {"tok_per_s": toks / dt if dt > 0 else float("inf"),
            "tokens": toks, "seconds": dt,
            "kv_bytes_resident": eng.kv_bytes_resident(), **stats}


def _settles_into_cycle(tail, max_period: int = 8) -> bool:
    """True if ``tail`` is periodic with some period <= ``max_period``."""
    t = np.asarray(tail)
    for p in range(1, max_period + 1):
        if len(t) > p and bool(np.all(t[p:] == t[:-p])):
            return True
    return False


def _screen_repetitive_prompts(params, cfg, qm, n: int, *, batch: int,
                               max_len: int, prompt_len: int,
                               probe_new: int = 48, seed: int = 3):
    """Pick ``n`` motif prompts whose *greedy continuation* actually
    settles into a short token cycle, and *warm* each one with its own
    probe continuation so the timed run starts inside the cycle.
    Prompt-lookup drafting wins exactly when decode is repetitive, and
    on a tiny bench model only a fraction of random motifs induce a
    settled cycle — the rest wander over the vocab, every suffix n-gram
    is novel, and the drafter has nothing to propose. Appending the
    probe's greedy output to the prompt removes the pre-cycle ramp the
    same way a real repetition-heavy request arrives mid-pattern (code
    with an established convention, templated text): the cycle is
    already in the context, so the drafter locks on from the first
    step. The probe runs untimed on a plain engine; the screened warm
    prompts then serve both the spec-off and spec-on arms identically.
    If too few motifs settle, the list is topped up with unscreened
    warm candidates (the row's acceptance column says what the drafter
    really got)."""
    rng = np.random.default_rng(seed)
    probe = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                   scheduler="continuous", kv_layout="paged",
                   kv_cache="mxfp8", bucket_prompts=False)
    keep, fallback = [], []
    for _ in range(8):                       # candidate rounds
        cands = []
        for _ in range(n):
            motif = rng.integers(0, cfg.vocab_size, 3)
            cands.append(np.tile(motif, prompt_len // 3 + 1)
                         [:prompt_len].astype(np.int32))
        reqs = [Request(prompt=p.copy(), max_new=probe_new)
                for p in cands]
        probe.generate(reqs)
        for p, r in zip(cands, reqs):
            warm = np.concatenate([p, np.asarray(r.out, np.int32)])
            if _settles_into_cycle(r.out[probe_new // 2:]):
                keep.append(warm)
            else:
                fallback.append(warm)
        if len(keep) >= n:
            break
    return (keep + fallback)[:n]


def bench_spec(params, cfg, n_req: int, *, batch: int, max_len: int,
               prompt_len: int, max_new: int, spec_k: int = 6,
               log=print):
    """Speculative decoding over the paged MX cache: the identical
    repetition-friendly greedy workload served spec-off vs spec-on
    (continuous scheduler, paged layout, mxfp8 KV). Outputs are asserted
    token-identical — spec changes only how many forwards produce them.
    Since every prompt chunk-prefills in one step, wall tok/s is
    decode-dominated and the tok/s ratio is the decode speedup; the
    tokens-per-decode-step ratio is the dispatch-count view of the same
    gain.

    Setup choices, all documented in docs/sampling.md:

    * ``batch=1`` is the regime that matters: single-stream interactive
      generation is latency-bound — every decode step pays the full
      per-step cost (pool gather/dequant, dispatch, host sync) for ONE
      token, which is exactly the idle capacity speculative decoding
      exists to spend. It is also how speculative decoding is
      conventionally benchmarked. Batched throughput serving amortizes
      those per-step costs across lanes on its own (the
      serving_continuous rows), so spec's margin there shrinks to the
      compute-bound verify-vs-decode FLOP ratio of this CPU rig.
    * Weights stay dense (the MX in this row is the paged mxfp8 KV pool
      the drafts verify against): prompt-lookup drafting needs the
      model's greedy cycle to be *stable*, and on this tiny bench model
      mxfp4 weight noise makes the trajectory chaotic — acceptance
      collapses for any drafter, which would measure the model, not the
      spec machinery.
    * Both arms serve in the streaming posture — an ``eos_id`` is
      configured (one chosen so the workload never emits it, keeping
      the arms' token counts identical), so the scheduler observes
      every step's tokens as real deployments with a stop token must.

    Returns (rows, results-by-tag)."""
    qm = QuantMode.off()
    prompts = _screen_repetitive_prompts(params, cfg, qm, n_req,
                                         batch=batch, max_len=max_len,
                                         prompt_len=prompt_len)
    # a stop token the workload will essentially never emit: greedy
    # decode of these prompts revisits tokens from their settled
    # cycles, so an id absent from prompts-plus-probe-outputs is chosen
    # (and if a wandering fallback lane does emit it, both arms stop
    # that lane at the same token — the comparison stays identical)
    seen = set()
    for p in prompts:
        seen.update(int(t) for t in p)
    eos = next(t for t in range(cfg.vocab_size) if t not in seen)
    rows, results, outs = [], {}, {}
    for tag, spec in (("off", None), ("on", SpecConfig(k=spec_k))):
        eng = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                     scheduler="continuous", kv_layout="paged",
                     kv_cache="mxfp8", bucket_prompts=False, spec=spec,
                     eos_id=eos)
        eng.generate(repetitive_requests(cfg, 2, seed=99,
                                         prompt_len=prompt_len,
                                         max_new=4))      # warm the jits
        eng.reset_stats()
        reqs = [Request(prompt=p.copy(), max_new=max_new)
                for p in prompts]
        t0 = time.perf_counter()
        done = eng.generate(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        st = eng.stats()
        r = {"tok_per_s": toks / dt if dt > 0 else float("inf"),
             "tokens": toks, "seconds": dt,
             "tokens_per_decode_step":
                 st["useful_decode_tokens"] / max(st["decode_steps"], 1),
             **st}
        results[tag] = r
        outs[tag] = [list(x.out) for x in reqs]
        acc = (f"  acceptance={r['spec_acceptance']:.2f}  "
               f"proposed={r['spec_proposed_tokens']}"
               if tag == "on" else "")
        log(f"[serving] spec={tag:3s}    {r['tok_per_s']:9.1f} tok/s  "
            f"steps={r['decode_steps']}  "
            f"tok/step={r['tokens_per_decode_step']:.2f}{acc}")
        rows.append({
            "name": f"serving_spec_{tag}",
            "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
            "derived": (f"tok_per_s={r['tok_per_s']:.1f};"
                        f"decode_steps={r['decode_steps']};"
                        f"tokens_per_decode_step="
                        f"{r['tokens_per_decode_step']:.2f};"
                        f"spec_proposed={r['spec_proposed_tokens']};"
                        f"spec_accepted={r['spec_accepted_tokens']};"
                        f"spec_acceptance={r['spec_acceptance']:.3f};"
                        f"kv_layout=paged;kv_cache=mxfp8;"
                        f"weights=dense;posture=streaming_eos"),
            **r})
    assert outs["on"] == outs["off"], \
        "greedy spec decoding changed the emitted tokens"
    off, on = results["off"], results["on"]
    tokps_gain = on["tok_per_s"] / max(off["tok_per_s"], 1e-9)
    step_gain = (on["tokens_per_decode_step"]
                 / max(off["tokens_per_decode_step"], 1e-9))
    rows.append({
        "name": "serving_spec_speedup", "us_per_call": 0.0,
        "derived": (f"tokps_gain={tokps_gain:.2f}x;"
                    f"tokens_per_step_gain={step_gain:.2f}x;"
                    f"decode_steps={off['decode_steps']}->"
                    f"{on['decode_steps']};"
                    f"acceptance={on['spec_acceptance']:.3f};"
                    f"outputs_identical=True;"
                    f"spec_beats_1p5x={tokps_gain >= 1.5}"),
        "tokps_gain": tokps_gain, "tokens_per_step_gain": step_gain})
    log(f"[serving] spec speedup: {off['tok_per_s']:.1f} -> "
        f"{on['tok_per_s']:.1f} tok/s ({tokps_gain:.2f}x), "
        f"steps {off['decode_steps']} -> {on['decode_steps']}, "
        f"acceptance {on['spec_acceptance']:.2f}")
    return rows


def run(log=print, smoke: bool = False, trace=None, load: bool = True):
    if smoke:
        cfg = SMOKE_CFG
        params = api.init(jax.random.PRNGKey(0), cfg)
        n_req, batch, max_len = 10, 2, 96
        len_range, new_range = (4, 24), (2, 12)
        n_load = 6
    else:
        params, cfg = common.get_model(log)
        n_req, batch, max_len = 32, 4, 128
        len_range, new_range = (8, 48), (4, 32)
        n_load = 16

    tracer = Tracer() if trace else None
    qm = QuantMode.mxfp4(t3=True)
    rows = []
    results = {}
    for sched in ("wave", "continuous"):
        reqs = mixed_requests(cfg, n_req, seed=0, len_range=len_range,
                              new_range=new_range)
        r = bench_scheduler(params, cfg, qm, sched, reqs,
                            batch=batch, max_len=max_len,
                            tracer=tracer if sched == "continuous"
                            else None)
        results[sched] = r
        log(f"[serving] {sched:10s} {r['tok_per_s']:9.1f} tok/s  "
            f"util={r['decode_utilization']:.3f}  "
            f"steps={r['decode_steps']}  slot_steps={r['slot_steps']}")
        rows.append({
            "name": f"serving_{sched}",
            "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
            "derived": (f"tok_per_s={r['tok_per_s']:.1f};"
                        f"decode_utilization={r['decode_utilization']:.3f};"
                        f"decode_steps={r['decode_steps']};"
                        f"slot_steps={r['slot_steps']};"
                        f"useful={r['useful_decode_tokens']}"),
            **r})

    # quantized KV cache: same mixed workload through the continuous
    # scheduler with the cache stored as MX codes + E8M0 scale bytes
    # (--kv-cache row; outputs are within-tolerance of the dense cache,
    # see docs/kv-cache.md — tokens counted, not compared, here)
    for kv in ("mxfp8",):
        reqs = mixed_requests(cfg, n_req, seed=0, len_range=len_range,
                              new_range=new_range)
        r = bench_scheduler(params, cfg, qm, "continuous", reqs,
                            batch=batch, max_len=max_len, kv_cache=kv)
        results[f"continuous+{kv}"] = r
        log(f"[serving] {'cont+' + kv:10s} {r['tok_per_s']:9.1f} tok/s  "
            f"util={r['decode_utilization']:.3f}  "
            f"steps={r['decode_steps']}  slot_steps={r['slot_steps']}")
        rows.append({
            "name": f"serving_continuous_kv_{kv}",
            "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
            "derived": (f"tok_per_s={r['tok_per_s']:.1f};"
                        f"kv_cache={kv};"
                        f"decode_utilization={r['decode_utilization']:.3f};"
                        f"decode_steps={r['decode_steps']}"),
            **r})

    # prefix-heavy workload: a shared system prompt with mixed tails,
    # served contiguous vs paged (block tables + ref-counted prefix
    # caching — docs/paged-kv.md). The paged engine chunk-prefills the
    # shared prefix once and reuses it by reference, so its prefill work
    # collapses while per-request outputs stay identical; the
    # kv_bytes_resident column is the memory story (pages track actual
    # lengths instead of reserving (B, max_len) lanes).
    if smoke:
        prefix_len, tail_range, pnew = 32, (2, 10), (2, 8)
        page_size, pmax_len = 32, 96
    else:
        prefix_len, tail_range, pnew = 256, (8, 32), (4, 16)
        page_size, pmax_len = 64, 384
    for layout in ("contiguous", "paged"):
        reqs = prefix_requests(cfg, n_req, prefix_len, seed=1,
                               tail_range=tail_range, new_range=pnew)
        r = bench_scheduler(
            params, cfg, qm, "continuous", reqs, batch=batch,
            max_len=pmax_len, kv_layout=layout,
            page_size=page_size if layout == "paged" else None)
        results[f"prefix_{layout}"] = r
        log(f"[serving] prefix/{layout[:6]:6s} {r['tok_per_s']:9.1f} "
            f"tok/s  prefill_chunks={r['prefill_chunk_steps']}  "
            f"prefix_hits={r['prefix_hit_tokens']}  "
            f"kv_resident={r['kv_bytes_resident']}")
        rows.append({
            "name": f"serving_prefix_{layout}",
            "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
            "derived": (f"tok_per_s={r['tok_per_s']:.1f};"
                        f"prefill_chunk_steps={r['prefill_chunk_steps']};"
                        f"prefix_hit_tokens={r['prefix_hit_tokens']};"
                        f"kv_bytes_resident={r['kv_bytes_resident']};"
                        f"blocks_evicted={r['blocks_evicted']}"),
            **r})
    pc, pp = results["prefix_contiguous"], results["prefix_paged"]
    rows.append({
        "name": "serving_paged_vs_contiguous", "us_per_call": 0.0,
        "derived": (
            f"tokps_gain={pp['tok_per_s']/max(pc['tok_per_s'],1e-9):.2f}x;"
            f"prefill_chunk_steps={pc['prefill_chunk_steps']}->"
            f"{pp['prefill_chunk_steps']};"
            f"prefix_hit_tokens={pp['prefix_hit_tokens']};"
            f"kv_bytes_resident={pc['kv_bytes_resident']}->"
            f"{pp['kv_bytes_resident']};"
            f"paged_beats_contiguous="
            f"{pp['tok_per_s'] >= pc['tok_per_s']}")})
    log(f"[serving] paged prefix-heavy: "
        f"{pc['tok_per_s']:.1f} -> {pp['tok_per_s']:.1f} tok/s "
        f"({pp['tok_per_s']/max(pc['tok_per_s'],1e-9):.2f}x), "
        f"chunk prefills {pc['prefill_chunk_steps']} -> "
        f"{pp['prefill_chunk_steps']}")

    # batched prefill admission (docs/serving.md#batched-prefill-
    # admission): long-prompt traffic — every admission multi-chunk —
    # served paged with the admission batcher off (one lane per engine
    # step, the pre-batching behavior, max_prefill_lanes_per_step=1) vs
    # on. Lanes admitted together share one chunked-prefill dispatch
    # per chunk index, so a wave costs max(chunks) steps instead of
    # sum(chunks): prefill_chunk_steps collapses and queued requests
    # reach their first token sooner — TTFT p99 is the headline column.
    # Outputs are asserted token-identical (the batcher changes
    # dispatch count, never results).
    if smoke:
        blen, bnew, bml, bps = (48, 80), (2, 6), 128, 32
    else:
        blen, bnew, bml, bps = (160, 288), (4, 8), 384, 64
    bknob = max(2, min(4, batch))
    bres, bouts = {}, {}
    for tag, knob in (("off", 1), ("on", bknob)):
        reqs = mixed_requests(cfg, n_req, seed=5, len_range=blen,
                              new_range=bnew)
        # batch+1 warm requests compile both admission signatures (the
        # batched wave at t=0 and the straggler's serial admit)
        warm = mixed_requests(cfg, batch + 1, seed=98, len_range=blen,
                              new_range=(2, 4))
        r = bench_scheduler(
            params, cfg, qm, "continuous", reqs, batch=batch,
            max_len=bml, kv_layout="paged", page_size=bps,
            policy=SchedulingPolicy(max_prefill_lanes_per_step=knob),
            warm=warm)
        ttft = [q.m_first - q.m_submit for q in reqs]
        r["ttft_p50_ms"] = _pct(ttft, 50) * 1e3
        r["ttft_p99_ms"] = _pct(ttft, 99) * 1e3
        bres[tag] = r
        bouts[tag] = [list(q.out) for q in reqs]
        log(f"[serving] prefill batch={tag:3s} "
            f"{r['tok_per_s']:9.1f} tok/s  "
            f"chunk_steps={r['prefill_chunk_steps']}  "
            f"lanes/step={r['prefill_lanes_per_step']:.2f}  "
            f"ttft p99={r['ttft_p99_ms']:.1f}ms")
        rows.append({
            "name": f"serving_prefill_batched_{tag}",
            "us_per_call": r["ttft_p99_ms"] * 1e3,
            "derived": (f"max_prefill_lanes_per_step={knob};"
                        f"tok_per_s={r['tok_per_s']:.1f};"
                        f"prefill_chunk_steps={r['prefill_chunk_steps']};"
                        f"prefill_lane_steps={r['prefill_lane_steps']};"
                        f"prefill_batched_steps="
                        f"{r['prefill_batched_steps']};"
                        f"prefill_lanes_per_step="
                        f"{r['prefill_lanes_per_step']:.2f};"
                        f"ttft_p50_ms={r['ttft_p50_ms']:.1f};"
                        f"ttft_p99_ms={r['ttft_p99_ms']:.1f}"),
            **r})
    assert bouts["on"] == bouts["off"], \
        "batched prefill admission changed the emitted tokens"
    boff, bon = bres["off"], bres["on"]
    rows.append({
        "name": "serving_prefill_batching", "us_per_call": 0.0,
        "derived": (
            f"prefill_chunk_steps={boff['prefill_chunk_steps']}->"
            f"{bon['prefill_chunk_steps']};"
            f"lane_steps={boff['prefill_lane_steps']}->"
            f"{bon['prefill_lane_steps']};"
            f"ttft_p99_ms={boff['ttft_p99_ms']:.1f}->"
            f"{bon['ttft_p99_ms']:.1f};"
            f"outputs_identical=True;"
            f"batched_reduces_chunk_steps="
            f"{bon['prefill_chunk_steps'] < boff['prefill_chunk_steps']}")})
    log(f"[serving] prefill batching: chunk steps "
        f"{boff['prefill_chunk_steps']} -> {bon['prefill_chunk_steps']}, "
        f"ttft p99 {boff['ttft_p99_ms']:.1f} -> "
        f"{bon['ttft_p99_ms']:.1f}ms")

    # speculative decoding over the paged MX cache (docs/sampling.md):
    # single-stream repetition-friendly greedy traffic, identical
    # outputs, fewer forwards — tok/s and tokens-per-step both reported
    if smoke:
        spec_args = dict(n_req=3, batch=1, max_len=96,
                         prompt_len=12, max_new=24)
    else:
        spec_args = dict(n_req=8, batch=1, max_len=192,
                         prompt_len=16, max_new=96)
    rows.extend(bench_spec(params, cfg, log=log, **spec_args))

    w, c = results["wave"], results["continuous"]
    util_gain = (c["decode_utilization"] / w["decode_utilization"]
                 if w["decode_utilization"] else float("inf"))
    tokps_gain = (c["tok_per_s"] / w["tok_per_s"]
                  if w["tok_per_s"] else float("inf"))
    rows.append({
        "name": "serving_continuous_vs_wave", "us_per_call": 0.0,
        "derived": (f"util_gain={util_gain:.2f}x;"
                    f"tokps_gain={tokps_gain:.2f}x;"
                    f"wave_util={w['decode_utilization']:.3f};"
                    f"cont_util={c['decode_utilization']:.3f};"
                    f"step_reduction="
                    f"{w['slot_steps']/max(c['slot_steps'],1):.2f}x"),
        "util_gain": util_gain, "tokps_gain": tokps_gain})
    # the PR-4 sync-hoist fix: before it, the continuous scheduler synced
    # the sampled tokens to host every decode step — and its fresh
    # (uncommitted) pool-cache/cur/pos inputs silently double-compiled
    # every step function inside the timed run — so it LOST to wave on
    # tok/s despite 1.35x fewer slot-steps (committed PR-3 numbers
    # below). Decode now runs in bursts between lane completions with one
    # batched host fetch, and fresh inputs are committed to the steps'
    # steady-state sharding (one jit signature each).
    rows.append({
        "name": "serving_continuous_sync_hoist", "us_per_call": 0.0,
        "derived": (f"before_source=PR3_committed_BENCH (historical, "
                    f"different machine/run — compare the wave/cont "
                    f"RATIO, not absolute tok/s);"
                    f"before_wave_tok_per_s=26.3;"
                    f"before_cont_tok_per_s=25.5;"
                    f"after_wave_tok_per_s={w['tok_per_s']:.1f};"
                    f"after_cont_tok_per_s={c['tok_per_s']:.1f};"
                    f"cont_beats_wave={c['tok_per_s'] > w['tok_per_s']}")})
    log(f"[serving] continuous utilization gain: {util_gain:.2f}x "
        f"({w['decode_utilization']:.3f} -> {c['decode_utilization']:.3f}); "
        f"tok/s gain {tokps_gain:.2f}x")

    # latency under load: open-loop Poisson arrivals swept across
    # offered load relative to the continuous scheduler's measured
    # offline capacity (tok/s / mean tokens-per-request from the batch
    # run above — the RPS at which the engine saturates).
    if load:
        cap_rps = c["tok_per_s"] / max(c["tokens"] / n_req, 1e-9)
        for frac in LOAD_FRACS:
            rate = cap_rps * frac
            r = bench_load(params, cfg, qm, rate, n_load, batch=batch,
                           max_len=max_len, len_range=len_range,
                           new_range=new_range, tracer=tracer)
            tp50 = r["tpot_p50_ms"]
            log(f"[serving] load {frac:g}x ({rate:6.2f} rps)  "
                f"ttft p50={r['ttft_p50_ms']:.1f}ms "
                f"p99={r['ttft_p99_ms']:.1f}ms  "
                f"tpot p50="
                f"{'n/a' if tp50 is None else f'{tp50:.1f}ms'}")
            rows.append({
                "name": f"serving_load_{frac:g}x",
                "us_per_call": r["ttft_p50_ms"] * 1e3,
                "derived": (f"offered_rps={r['offered_rps']:.2f};"
                            f"achieved_rps={r['achieved_rps']:.2f};"
                            f"ttft_p50_ms={r['ttft_p50_ms']:.1f};"
                            f"ttft_p99_ms={r['ttft_p99_ms']:.1f};"
                            f"tpot_p50_ms={r['tpot_p50_ms']};"
                            f"tpot_p99_ms={r['tpot_p99_ms']}"),
                **r})

        # overload: the same traffic shape at 2x capacity, deadlines +
        # preemption on vs off (docs/robustness.md — bounded p99 TTFT
        # with load shedding vs unbounded queueing)
        orows, dms = bench_overload(params, cfg, qm, cap_rps, n_load,
                                    batch=batch, max_len=max_len,
                                    len_range=len_range,
                                    new_range=new_range)
        for r in orows:
            tag = r["name"].rsplit("_", 1)[-1]
            log(f"[serving] overload 2x deadline={tag:3s} "
                f"(budget {dms:.0f}ms)  "
                f"completed={r['completed']}/{r['n_requests']}  "
                f"timed_out={r['timed_out']}  "
                f"ttft p99={r['ttft_p99_ms']:.1f}ms  "
                f"within_deadline={r['completed_within_deadline']:.2f}")
        rows.extend(orows)

        # the same 2x traffic through the HTTP front end: admission
        # shedding (429 + Retry-After) on vs off, TTFT measured from
        # the client's socket, graceful drain asserted clean
        # (docs/server.md)
        rows.extend(bench_http_overload(
            params, cfg, qm, 2 * n_load, batch=batch, max_len=max_len,
            len_range=len_range, new_range=new_range, log=log))

    for r in rows:                   # v1 rows predate the "schema" key
        r.setdefault("schema", SCHEMA_VERSION)

    if tracer is not None:
        tracer.export(trace)
        log(f"[serving] trace -> {trace} "
            f"({len(tracer.events())} events)")

    # smoke traffic would pollute the perf trajectory (both JSONs)
    common.emit(rows, "serving_bench", persist=not smoke)
    if not smoke:
        (ROOT / "BENCH_serving.json").write_text(json.dumps(rows, indent=1))
    return rows


def _merge_rows(path: pathlib.Path, new_rows) -> None:
    """Replace same-name rows in ``path`` (append the rest) — the
    ``--http-only`` update path that leaves every other committed row's
    numbers untouched."""
    old = json.loads(path.read_text()) if path.exists() else []
    by_name = {r["name"]: r for r in new_rows}
    merged = ([by_name.pop(r["name"], r) for r in old]
              + list(by_name.values()))
    path.write_text(json.dumps(merged, indent=1))


def run_http_only(log=print, smoke: bool = False):
    """Run only the HTTP overload arms and merge their rows into the
    existing serving bench artifacts (no full re-run of the offline
    rows). Capacity comes from the bench's own closed-loop probe, so
    the 2x offered rate tracks this machine, not the committed file's."""
    if smoke:
        cfg = SMOKE_CFG
        params = api.init(jax.random.PRNGKey(0), cfg)
        batch, max_len = 2, 96
        len_range, new_range = (4, 24), (2, 12)
        n_load = 6
    else:
        params, cfg = common.get_model(log)
        batch, max_len = 4, 128
        len_range, new_range = (8, 48), (4, 32)
        n_load = 16
    qm = QuantMode.mxfp4(t3=True)
    rows = bench_http_overload(params, cfg, qm, 2 * n_load, batch=batch,
                               max_len=max_len, len_range=len_range,
                               new_range=new_range, log=log)
    for r in rows:
        r.setdefault("schema", SCHEMA_VERSION)
    common.emit(rows, "serving_bench", persist=False)  # CSV only
    if not smoke:
        _merge_rows(pathlib.Path("experiments/benchmarks")
                    / "serving_bench.json", rows)
        _merge_rows(ROOT / "BENCH_serving.json", rows)
        log(f"[serving] merged {len(rows)} http rows into "
            f"BENCH_serving.json")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + small traffic for CI")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export a Chrome trace of the continuous-"
                         "scheduler runs (open in Perfetto)")
    ap.add_argument("--no-load", action="store_true",
                    help="skip the latency-under-load sweep")
    ap.add_argument("--http-only", action="store_true",
                    help="run only the HTTP overload arms and merge "
                         "their rows into the existing artifacts")
    args = ap.parse_args()
    if args.http_only:
        run_http_only(smoke=args.smoke)
    else:
        run(smoke=args.smoke, trace=args.trace, load=not args.no_load)
