"""Serving-scheduler benchmark: wave vs continuous batching on a
mixed-length workload, plus latency under load (the production traffic
shape — prompts and decode budgets spread over a wide range, arriving
as a Poisson process rather than all at once).

The wave scheduler pads every request in a wave to the wave's longest
prompt and decodes until the wave's largest ``max_new`` — so on mixed
traffic most decode slot-steps produce tokens nobody asked for. The
continuous scheduler refills finished slots from the queue the step they
free up, so its decode-step utilization (useful tokens / decode
slot-steps) approaches 1.0 with a deep queue.

The **load section** measures what batch throughput numbers hide:
per-request TTFT (submit -> first token) and TPOT (per-token decode
interval) under open-loop Poisson arrivals, swept across offered load
(0.5x / 1x / 2x of the engine's measured offline capacity). p50 stays
flat while p99 degrades as offered load crosses capacity — the
latency-under-load curve (``docs/observability.md``).

Writes the standard experiments/benchmarks/serving_bench.json and a
repo-root BENCH_serving.json (the perf-trajectory artifact). Rows are
schema-versioned: ``"schema": 2`` marks rows carrying the telemetry
fields (offered_rps, ttft/tpot percentiles); rows without the key are
v1 (pre-telemetry). ``--smoke`` uses a tiny random-init model and small
traffic for CI; ``--trace OUT.json`` exports a Chrome trace of the
continuous-scheduler runs (open in https://ui.perfetto.dev).

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
        [--trace OUT.json]
"""
from __future__ import annotations

import argparse
import collections
import json
import pathlib
import time

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.models import api
from repro.obs import Tracer
from repro.serving.engine import Engine, Request
from repro.serving.policy import RequestState, SchedulingPolicy
from . import common

ROOT = pathlib.Path(__file__).resolve().parent.parent

# BENCH_serving.json row-format version. v1 rows (no "schema" key) are
# the pre-telemetry format; v2 adds the latency-under-load rows and
# stamps every row.
SCHEMA_VERSION = 2

# Offered-load sweep points, as fractions of measured offline capacity.
LOAD_FRACS = (0.5, 1.0, 2.0)

SMOKE_CFG = ArchConfig(
    name="serve-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128, attn_chunk=16)


def mixed_requests(cfg: ArchConfig, n: int, seed: int = 0,
                   len_range=(8, 48), new_range=(4, 32)):
    """A mixed-length workload: prompt lengths and decode budgets drawn
    uniformly from the given ranges (fixed seed — both schedulers serve
    the identical request list)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        s = int(rng.integers(len_range[0], len_range[1] + 1))
        m = int(rng.integers(new_range[0], new_range[1] + 1))
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
            max_new=m))
    return reqs


def prefix_requests(cfg: ArchConfig, n: int, prefix_len: int,
                    seed: int = 0, tail_range=(8, 32),
                    new_range=(4, 16)):
    """A prefix-heavy workload: every request shares one ``prefix_len``-
    token system prompt and carries its own mixed-length tail — the
    traffic shape the paged engine's hash-based prefix caching targets
    (the shared prefix is chunk-prefilled once and reused by
    reference)."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size,
                              prefix_len).astype(np.int32)
    reqs = []
    for _ in range(n):
        t = int(rng.integers(tail_range[0], tail_range[1] + 1))
        m = int(rng.integers(new_range[0], new_range[1] + 1))
        reqs.append(Request(prompt=np.concatenate(
            [sys_prompt,
             rng.integers(0, cfg.vocab_size, t).astype(np.int32)]),
            max_new=m))
    return reqs


def poisson_requests(cfg: ArchConfig, rate_rps: float, n: int,
                     seed: int = 0, len_range=(8, 48),
                     new_range=(4, 32)):
    """``n`` mixed-length requests with Poisson arrival offsets at
    ``rate_rps`` requests/s (exponential inter-arrival gaps, fixed
    seed). Returns ``[(arrival_offset_s, Request), ...]`` sorted by
    arrival."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate_rps))
        s = int(rng.integers(len_range[0], len_range[1] + 1))
        m = int(rng.integers(new_range[0], new_range[1] + 1))
        out.append((t, Request(
            prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
            max_new=m)))
    return out


def run_load(eng: Engine, arrivals) -> float:
    """Open-loop load test: submit each request once the wall clock
    passes its arrival offset (never waiting for the engine — queueing
    delay is part of what we measure), stepping the engine in between.
    Returns elapsed seconds from first arrival's epoch to drain."""
    pending = collections.deque(arrivals)
    t0 = time.perf_counter()
    while pending or eng.busy:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            eng.submit(pending.popleft()[1])
        if eng.busy:
            eng.step()
        elif pending:            # idle gap: sleep to the next arrival
            time.sleep(max(0.0, min(pending[0][0] - now, 0.02)))
    return time.perf_counter() - t0


def _pct(xs, q) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else None


def bench_load(params, cfg, qm, rate_rps: float, n_req: int, *,
               batch: int, max_len: int, len_range, new_range,
               tracer=None, seed: int = 7) -> dict:
    """One offered-load point: fresh continuous engine, warmed up (jit
    compiles out of the timed window), then ``n_req`` Poisson arrivals
    at ``rate_rps``. Latencies come from per-request monotonic
    timestamps (``Request.m_submit/m_first/m_done``)."""
    eng = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                 scheduler="continuous", tracer=tracer)
    eng.generate(mixed_requests(cfg, 2, seed=99, len_range=len_range,
                                new_range=new_range))    # warm the jits
    eng.reset_stats()
    arrivals = poisson_requests(cfg, rate_rps, n_req, seed=seed,
                                len_range=len_range, new_range=new_range)
    elapsed = run_load(eng, arrivals)
    reqs = [r for _, r in arrivals]
    ttft = [r.m_first - r.m_submit for r in reqs]
    tpot = [(r.m_done - r.m_first) / (len(r.out) - 1)
            for r in reqs if len(r.out) > 1 and r.m_done > r.m_first]
    toks = sum(len(r.out) for r in reqs)
    return {
        "kind": "latency_under_load",
        "offered_rps": rate_rps,
        "achieved_rps": len(reqs) / elapsed if elapsed > 0 else 0.0,
        "n_requests": len(reqs), "elapsed_s": elapsed,
        "tok_per_s": toks / elapsed if elapsed > 0 else 0.0,
        "ttft_p50_ms": _pct(ttft, 50) * 1e3,
        "ttft_p99_ms": _pct(ttft, 99) * 1e3,
        "tpot_p50_ms": _pct(tpot, 50) * 1e3 if tpot else None,
        "tpot_p99_ms": _pct(tpot, 99) * 1e3 if tpot else None,
    }


def bench_overload(params, cfg, qm, cap_rps: float, n_req: int, *,
                   batch: int, max_len: int, len_range, new_range,
                   seed: int = 13):
    """Overload behavior (docs/robustness.md): the same 2x-capacity
    Poisson traffic served with deadlines on vs off. With deadlines the
    engine sheds the excess as TIMED_OUT and the p99 TTFT of requests
    that *do* complete stays bounded near the deadline; without them
    every request completes but p99 TTFT grows with queue depth.
    Returns the two rows plus the deadline used (ms)."""
    rate = cap_rps * 2.0
    # roughly the back half of the offered traffic cannot meet this
    # budget at 2x load, so the shed/served split is exercised
    deadline_ms = 0.5 * n_req / max(cap_rps, 1e-9) * 1e3
    rows = []
    for tag, policy in (
            ("on", SchedulingPolicy(deadline_ms=deadline_ms,
                                    ttft_deadline_ms=deadline_ms)),
            ("off", SchedulingPolicy())):
        eng = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                     scheduler="continuous", policy=policy)
        warm = mixed_requests(cfg, 2, seed=99, len_range=len_range,
                              new_range=new_range)
        for r in warm:        # jit-compile time must not expire these
            r.deadline_ms = r.ttft_deadline_ms = 1e9
        eng.generate(warm)
        eng.reset_stats()
        arrivals = poisson_requests(cfg, rate, n_req, seed=seed,
                                    len_range=len_range,
                                    new_range=new_range)
        elapsed = run_load(eng, arrivals)
        reqs = [r for _, r in arrivals]
        fin = [r for r in reqs if r.state is RequestState.FINISHED]
        ttft = [r.m_first - r.m_submit for r in fin]
        within = sum((r.m_done - r.m_submit) * 1e3 <= deadline_ms
                     for r in fin)
        # count terminals off the arrival requests themselves — the
        # engine counters are cumulative and include the warm-up
        timed_out = sum(r.state is RequestState.TIMED_OUT for r in reqs)
        preempts = sum(r.preemptions for r in reqs)
        rows.append({
            "name": f"serving_overload_deadline_{tag}",
            "kind": "overload",
            "us_per_call": (_pct(ttft, 99) or 0.0) * 1e6,
            "offered_rps": rate, "n_requests": n_req,
            "deadline_ms": deadline_ms, "elapsed_s": elapsed,
            "completed": len(fin),
            "timed_out": timed_out,
            "preemptions": preempts,
            "ttft_p50_ms": (_pct(ttft, 50) or 0.0) * 1e3,
            "ttft_p99_ms": (_pct(ttft, 99) or 0.0) * 1e3,
            "completed_within_deadline": within / n_req,
            "derived": (f"deadline_ms={deadline_ms:.0f};"
                        f"completed={len(fin)}/{n_req};"
                        f"timed_out={timed_out};"
                        f"ttft_p99_ms={(_pct(ttft, 99) or 0.0)*1e3:.1f};"
                        f"within_deadline={within / n_req:.2f}"),
        })
    return rows, deadline_ms


def bench_scheduler(params, cfg, qm, scheduler: str, reqs, *,
                    batch: int, max_len: int, kv_cache=None,
                    kv_layout: str = "contiguous",
                    page_size=None, tracer=None) -> dict:
    eng = Engine(params, cfg, qm, batch_size=batch, max_len=max_len,
                 scheduler=scheduler, kv_cache=kv_cache,
                 kv_layout=kv_layout, page_size=page_size,
                 bucket_prompts=(kv_layout != "paged"), tracer=tracer)
    t0 = time.perf_counter()
    done = eng.generate(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    stats = eng.stats()
    return {"tok_per_s": toks / dt if dt > 0 else float("inf"),
            "tokens": toks, "seconds": dt,
            "kv_bytes_resident": eng.kv_bytes_resident(), **stats}


def run(log=print, smoke: bool = False, trace=None, load: bool = True):
    if smoke:
        cfg = SMOKE_CFG
        params = api.init(jax.random.PRNGKey(0), cfg)
        n_req, batch, max_len = 10, 2, 96
        len_range, new_range = (4, 24), (2, 12)
        n_load = 6
    else:
        params, cfg = common.get_model(log)
        n_req, batch, max_len = 32, 4, 128
        len_range, new_range = (8, 48), (4, 32)
        n_load = 16

    tracer = Tracer() if trace else None
    qm = QuantMode.mxfp4(t3=True)
    rows = []
    results = {}
    for sched in ("wave", "continuous"):
        reqs = mixed_requests(cfg, n_req, seed=0, len_range=len_range,
                              new_range=new_range)
        r = bench_scheduler(params, cfg, qm, sched, reqs,
                            batch=batch, max_len=max_len,
                            tracer=tracer if sched == "continuous"
                            else None)
        results[sched] = r
        log(f"[serving] {sched:10s} {r['tok_per_s']:9.1f} tok/s  "
            f"util={r['decode_utilization']:.3f}  "
            f"steps={r['decode_steps']}  slot_steps={r['slot_steps']}")
        rows.append({
            "name": f"serving_{sched}",
            "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
            "derived": (f"tok_per_s={r['tok_per_s']:.1f};"
                        f"decode_utilization={r['decode_utilization']:.3f};"
                        f"decode_steps={r['decode_steps']};"
                        f"slot_steps={r['slot_steps']};"
                        f"useful={r['useful_decode_tokens']}"),
            **r})

    # quantized KV cache: same mixed workload through the continuous
    # scheduler with the cache stored as MX codes + E8M0 scale bytes
    # (--kv-cache row; outputs are within-tolerance of the dense cache,
    # see docs/kv-cache.md — tokens counted, not compared, here)
    for kv in ("mxfp8",):
        reqs = mixed_requests(cfg, n_req, seed=0, len_range=len_range,
                              new_range=new_range)
        r = bench_scheduler(params, cfg, qm, "continuous", reqs,
                            batch=batch, max_len=max_len, kv_cache=kv)
        results[f"continuous+{kv}"] = r
        log(f"[serving] {'cont+' + kv:10s} {r['tok_per_s']:9.1f} tok/s  "
            f"util={r['decode_utilization']:.3f}  "
            f"steps={r['decode_steps']}  slot_steps={r['slot_steps']}")
        rows.append({
            "name": f"serving_continuous_kv_{kv}",
            "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
            "derived": (f"tok_per_s={r['tok_per_s']:.1f};"
                        f"kv_cache={kv};"
                        f"decode_utilization={r['decode_utilization']:.3f};"
                        f"decode_steps={r['decode_steps']}"),
            **r})

    # prefix-heavy workload: a shared system prompt with mixed tails,
    # served contiguous vs paged (block tables + ref-counted prefix
    # caching — docs/paged-kv.md). The paged engine chunk-prefills the
    # shared prefix once and reuses it by reference, so its prefill work
    # collapses while per-request outputs stay identical; the
    # kv_bytes_resident column is the memory story (pages track actual
    # lengths instead of reserving (B, max_len) lanes).
    if smoke:
        prefix_len, tail_range, pnew = 32, (2, 10), (2, 8)
        page_size, pmax_len = 32, 96
    else:
        prefix_len, tail_range, pnew = 256, (8, 32), (4, 16)
        page_size, pmax_len = 64, 384
    for layout in ("contiguous", "paged"):
        reqs = prefix_requests(cfg, n_req, prefix_len, seed=1,
                               tail_range=tail_range, new_range=pnew)
        r = bench_scheduler(
            params, cfg, qm, "continuous", reqs, batch=batch,
            max_len=pmax_len, kv_layout=layout,
            page_size=page_size if layout == "paged" else None)
        results[f"prefix_{layout}"] = r
        log(f"[serving] prefix/{layout[:6]:6s} {r['tok_per_s']:9.1f} "
            f"tok/s  prefill_chunks={r['prefill_chunk_steps']}  "
            f"prefix_hits={r['prefix_hit_tokens']}  "
            f"kv_resident={r['kv_bytes_resident']}")
        rows.append({
            "name": f"serving_prefix_{layout}",
            "us_per_call": 1e6 / max(r["tok_per_s"], 1e-9),
            "derived": (f"tok_per_s={r['tok_per_s']:.1f};"
                        f"prefill_chunk_steps={r['prefill_chunk_steps']};"
                        f"prefix_hit_tokens={r['prefix_hit_tokens']};"
                        f"kv_bytes_resident={r['kv_bytes_resident']};"
                        f"blocks_evicted={r['blocks_evicted']}"),
            **r})
    pc, pp = results["prefix_contiguous"], results["prefix_paged"]
    rows.append({
        "name": "serving_paged_vs_contiguous", "us_per_call": 0.0,
        "derived": (
            f"tokps_gain={pp['tok_per_s']/max(pc['tok_per_s'],1e-9):.2f}x;"
            f"prefill_chunk_steps={pc['prefill_chunk_steps']}->"
            f"{pp['prefill_chunk_steps']};"
            f"prefix_hit_tokens={pp['prefix_hit_tokens']};"
            f"kv_bytes_resident={pc['kv_bytes_resident']}->"
            f"{pp['kv_bytes_resident']};"
            f"paged_beats_contiguous="
            f"{pp['tok_per_s'] >= pc['tok_per_s']}")})
    log(f"[serving] paged prefix-heavy: "
        f"{pc['tok_per_s']:.1f} -> {pp['tok_per_s']:.1f} tok/s "
        f"({pp['tok_per_s']/max(pc['tok_per_s'],1e-9):.2f}x), "
        f"chunk prefills {pc['prefill_chunk_steps']} -> "
        f"{pp['prefill_chunk_steps']}")

    w, c = results["wave"], results["continuous"]
    util_gain = (c["decode_utilization"] / w["decode_utilization"]
                 if w["decode_utilization"] else float("inf"))
    tokps_gain = (c["tok_per_s"] / w["tok_per_s"]
                  if w["tok_per_s"] else float("inf"))
    rows.append({
        "name": "serving_continuous_vs_wave", "us_per_call": 0.0,
        "derived": (f"util_gain={util_gain:.2f}x;"
                    f"tokps_gain={tokps_gain:.2f}x;"
                    f"wave_util={w['decode_utilization']:.3f};"
                    f"cont_util={c['decode_utilization']:.3f};"
                    f"step_reduction="
                    f"{w['slot_steps']/max(c['slot_steps'],1):.2f}x"),
        "util_gain": util_gain, "tokps_gain": tokps_gain})
    # the PR-4 sync-hoist fix: before it, the continuous scheduler synced
    # the sampled tokens to host every decode step — and its fresh
    # (uncommitted) pool-cache/cur/pos inputs silently double-compiled
    # every step function inside the timed run — so it LOST to wave on
    # tok/s despite 1.35x fewer slot-steps (committed PR-3 numbers
    # below). Decode now runs in bursts between lane completions with one
    # batched host fetch, and fresh inputs are committed to the steps'
    # steady-state sharding (one jit signature each).
    rows.append({
        "name": "serving_continuous_sync_hoist", "us_per_call": 0.0,
        "derived": (f"before_source=PR3_committed_BENCH (historical, "
                    f"different machine/run — compare the wave/cont "
                    f"RATIO, not absolute tok/s);"
                    f"before_wave_tok_per_s=26.3;"
                    f"before_cont_tok_per_s=25.5;"
                    f"after_wave_tok_per_s={w['tok_per_s']:.1f};"
                    f"after_cont_tok_per_s={c['tok_per_s']:.1f};"
                    f"cont_beats_wave={c['tok_per_s'] > w['tok_per_s']}")})
    log(f"[serving] continuous utilization gain: {util_gain:.2f}x "
        f"({w['decode_utilization']:.3f} -> {c['decode_utilization']:.3f}); "
        f"tok/s gain {tokps_gain:.2f}x")

    # latency under load: open-loop Poisson arrivals swept across
    # offered load relative to the continuous scheduler's measured
    # offline capacity (tok/s / mean tokens-per-request from the batch
    # run above — the RPS at which the engine saturates).
    if load:
        cap_rps = c["tok_per_s"] / max(c["tokens"] / n_req, 1e-9)
        for frac in LOAD_FRACS:
            rate = cap_rps * frac
            r = bench_load(params, cfg, qm, rate, n_load, batch=batch,
                           max_len=max_len, len_range=len_range,
                           new_range=new_range, tracer=tracer)
            tp50 = r["tpot_p50_ms"]
            log(f"[serving] load {frac:g}x ({rate:6.2f} rps)  "
                f"ttft p50={r['ttft_p50_ms']:.1f}ms "
                f"p99={r['ttft_p99_ms']:.1f}ms  "
                f"tpot p50="
                f"{'n/a' if tp50 is None else f'{tp50:.1f}ms'}")
            rows.append({
                "name": f"serving_load_{frac:g}x",
                "us_per_call": r["ttft_p50_ms"] * 1e3,
                "derived": (f"offered_rps={r['offered_rps']:.2f};"
                            f"achieved_rps={r['achieved_rps']:.2f};"
                            f"ttft_p50_ms={r['ttft_p50_ms']:.1f};"
                            f"ttft_p99_ms={r['ttft_p99_ms']:.1f};"
                            f"tpot_p50_ms={r['tpot_p50_ms']};"
                            f"tpot_p99_ms={r['tpot_p99_ms']}"),
                **r})

        # overload: the same traffic shape at 2x capacity, deadlines +
        # preemption on vs off (docs/robustness.md — bounded p99 TTFT
        # with load shedding vs unbounded queueing)
        orows, dms = bench_overload(params, cfg, qm, cap_rps, n_load,
                                    batch=batch, max_len=max_len,
                                    len_range=len_range,
                                    new_range=new_range)
        for r in orows:
            tag = r["name"].rsplit("_", 1)[-1]
            log(f"[serving] overload 2x deadline={tag:3s} "
                f"(budget {dms:.0f}ms)  "
                f"completed={r['completed']}/{r['n_requests']}  "
                f"timed_out={r['timed_out']}  "
                f"ttft p99={r['ttft_p99_ms']:.1f}ms  "
                f"within_deadline={r['completed_within_deadline']:.2f}")
        rows.extend(orows)

    for r in rows:                   # v1 rows predate the "schema" key
        r.setdefault("schema", SCHEMA_VERSION)

    if tracer is not None:
        tracer.export(trace)
        log(f"[serving] trace -> {trace} "
            f"({len(tracer.events())} events)")

    # smoke traffic would pollute the perf trajectory (both JSONs)
    common.emit(rows, "serving_bench", persist=not smoke)
    if not smoke:
        (ROOT / "BENCH_serving.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + small traffic for CI")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="export a Chrome trace of the continuous-"
                         "scheduler runs (open in Perfetto)")
    ap.add_argument("--no-load", action="store_true",
                    help="skip the latency-under-load sweep")
    args = ap.parse_args()
    run(smoke=args.smoke, trace=args.trace, load=not args.no_load)
