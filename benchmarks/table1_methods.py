"""Table 1 (+ Table 6) — all PTQ methods under MXFP4 and MXINT4:
perplexity on held-out synthetic data and zero-shot-proxy accuracy with
recovery vs the FP16 baseline.

Paper claims reproduced (C3): LATMiX-LU/QR beat RTN/GPTQ/QuaRot/
block-Hadamard/learned-rotation baselines on average.
"""
from __future__ import annotations

import time

from repro.core import ptq
from repro.models import api
from . import common

METHODS = ["fp", "rtn", "gptq", "quarot-rtn", "quarot", "block_hadamard",
           "spinquant", "ostquant", "flatquant", "inv", "latmix-lu",
           "latmix-qr"]


def run(log=print, methods=METHODS, fmts=("mxfp4", "mxint4"), steps=100):
    import jax.numpy as jnp
    from repro.models import api as mapi
    params, cfg = common.get_model(log)
    calib = common.calib_batches(cfg)
    ev_toks = common.eval_tokens(cfg)
    ev_batches = common.eval_batches(cfg)
    # teacher logits for hard-negative distractors (method-independent)
    teacher = [mapi.forward(params, cfg, jnp.asarray(b["inputs"]))
               for b in ev_batches]
    fp_res = ptq.apply_method("fp", params, cfg, calib)
    fp_ppl = ptq.eval_ppl(fp_res, cfg, ev_toks)
    fp_acc = ptq.zero_shot_proxy(fp_res, cfg, ev_batches,
                                 teacher_logits=teacher)
    rows = [{"name": "table1_fp16", "us_per_call": 0.0,
             "derived": f"ppl={fp_ppl:.3f};acc={fp_acc:.3f}",
             "ppl": fp_ppl, "acc": fp_acc}]
    results = {}
    for fmt in fmts:
        for m in methods:
            if m == "fp":
                continue
            t0 = time.time()
            res = ptq.apply_method(m, params, cfg, calib, fmt=fmt,
                                   steps=steps)
            ppl = ptq.eval_ppl(res, cfg, ev_toks)
            acc = ptq.zero_shot_proxy(res, cfg, ev_batches,
                                      teacher_logits=teacher)
            rec = 100.0 * acc / max(fp_acc, 1e-9)
            dt = (time.time() - t0) * 1e6
            results[(fmt, m)] = (ppl, acc)
            log(f"[table1] {fmt:7s} {m:15s} ppl={ppl:8.3f} "
                f"acc={acc:.3f} rec={rec:6.2f}% ({dt/1e6:.0f}s)")
            rows.append({"name": f"table1_{fmt}_{m}",
                         "us_per_call": dt,
                         "derived": f"ppl={ppl:.3f};acc={acc:.3f};"
                                    f"recovery={rec:.2f}%",
                         "ppl": ppl, "acc": acc, "recovery": rec})
    # claim check: LATMiX-LU beats the non-affine baselines on ppl per fmt
    for fmt in fmts:
        base = [v[0] for (f, m), v in results.items()
                if f == fmt and m in ("rtn", "gptq", "quarot",
                                      "block_hadamard", "spinquant",
                                      "ostquant")]
        lat = results.get((fmt, "latmix-lu"), (float("inf"),))[0]
        rows.append({"name": f"table1_claimC3_{fmt}", "us_per_call": 0.0,
                     "derived": f"latmix_lu_ppl={lat:.3f};"
                                f"best_baseline={min(base):.3f};"
                                f"wins={bool(lat <= min(base) * 1.02)}"})
    common.emit(rows, "table1_methods")
    return rows


if __name__ == "__main__":
    run()
