"""MX artifact store — the deployable layer between PTQ and serving.

Calibrate once, fold the learned transforms, quantize to MX, then
``export_artifact`` the result; every serving run thereafter loads the
packed bytes directly (``load_artifact`` / ``Engine.from_artifact``)
with zero re-quantization and bit-identical logits.
"""
from .manifest import (ArtifactError, IntegrityError, Manifest,
                       TensorRecord, array_sha256)
from .store import (export_artifact, load_artifact, quant_mode_from_json,
                    quant_mode_to_json, verify_artifact)

__all__ = ["ArtifactError", "IntegrityError", "Manifest", "TensorRecord",
           "array_sha256", "export_artifact", "load_artifact",
           "quant_mode_from_json", "quant_mode_to_json", "verify_artifact"]
