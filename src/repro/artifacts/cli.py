"""Artifact CLI — the NeMo-style export / inspect / verify workflow:

    PYTHONPATH=src python -m repro.artifacts export \
        --arch tinyllama-1.1b --reduced --method latmix-lu --fmt mxfp4 \
        --out artifacts/tinyllama-mxfp4

    PYTHONPATH=src python -m repro.artifacts inspect artifacts/tinyllama-mxfp4
    PYTHONPATH=src python -m repro.artifacts verify  artifacts/tinyllama-mxfp4

`export` runs the PTQ pipeline (optionally from a training checkpoint)
and writes the packed artifact; `inspect` prints the manifest summary and
per-tensor layout; `verify` recomputes content hashes and cross-checks
the packed byte totals against the roofline accounting, exiting non-zero
on any mismatch.
"""
from __future__ import annotations

import argparse
import sys


def _cmd_export(args) -> int:
    import time

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.core import ptq
    from repro.data import synthetic
    from repro.models import api
    from repro.training import checkpoint as ckpt

    from .store import export_artifact

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get(args.arch))
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        aparams = jax.eval_shape(
            lambda k: api.init(k, cfg), jax.random.PRNGKey(0))
        restored, man = ckpt.restore(args.ckpt_dir,
                                     {"params": aparams, "opt": None})
        params = restored["params"]
        print(f"loaded checkpoint step {man['step']}")
    else:
        params = api.init(jax.random.PRNGKey(args.seed), cfg)
        print("no checkpoint — random init (demo mode)")

    src = synthetic.make_source(cfg, args.calib_batch, args.calib_len, 0)
    calib = [{k: jnp.asarray(v) for k, v in src.batch(i).items()}
             for i in range(args.calib_batches)]
    t0 = time.time()
    res = ptq.apply_method(args.method, params, cfg, calib, fmt=args.fmt,
                           steps=args.steps)
    print(f"PTQ [{args.method} / {args.fmt}] in {time.time() - t0:.0f}s")
    out = export_artifact(res, cfg, args.out)
    print(f"exported artifact -> {out}")
    return 0


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n} B"
        n /= 1024
    return f"{n} B"


def _cmd_inspect(args) -> int:
    import pathlib

    from .manifest import MANIFEST_FILE, ArtifactError, Manifest

    try:
        man = Manifest.load(pathlib.Path(args.path) / MANIFEST_FILE)
    except ArtifactError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    a = man.arch
    print(f"artifact:    {args.path}")
    print(f"schema:      v{man.schema_version} ({man.kind})")
    print(f"method/fmt:  {man.method} / {man.fmt}")
    print(f"arch:        {a['name']} [{a['family']}] "
          f"L={a['n_layers']} d={a['d_model']} ff={a['d_ff']} "
          f"V={a['vocab_size']}")
    qmj = man.quant_mode
    act = qmj.get("act_cfg") or {}
    print(f"quant mode:  enabled={qmj['enabled']} "
          f"act={act.get('fmt')}/b{act.get('block_size')}"
          f"/{act.get('scale_mode')} t3_block={qmj['t3_block']} "
          f"quantize_head={qmj['quantize_head']}")
    print(f"packed:      {_fmt_bytes(man.packed_total_nbytes)} "
          f"in {sum(1 for t in man.tensors if t.kind == 'packed')} tensors")
    print(f"raw (fp):    {_fmt_bytes(man.raw_total_nbytes)} "
          f"in {sum(1 for t in man.tensors if t.kind == 'raw')} tensors")
    if args.tensors:
        print(f"\n{'tensor':32s} {'kind':7s} {'dtype':9s} "
              f"{'bytes':>12s}  shape")
        for t in man.tensors:
            nb = t.packed_nbytes if t.kind == "packed" else t.nbytes
            print(f"{t.key:32s} {t.kind:7s} {t.dtype:9s} "
                  f"{nb:>12d}  {tuple(t.shape)}")
    return 0


def _cmd_verify(args) -> int:
    from .manifest import ArtifactError
    from .store import verify_artifact

    try:
        rep = verify_artifact(args.path)
    except ArtifactError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(f"OK: {rep['n_tensors']} tensors, "
          f"{_fmt_bytes(rep['packed_nbytes'])} packed "
          f"({rep['method']} / {rep['fmt']}), hashes and roofline "
          f"byte accounting verified")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.artifacts",
        description="MX artifact store: export/inspect/verify packed "
                    "quantized checkpoints")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ex = sub.add_parser("export", help="run PTQ and export an artifact")
    ex.add_argument("--arch", default="tinyllama-1.1b")
    ex.add_argument("--reduced", action="store_true", default=True)
    ex.add_argument("--full", dest="reduced", action="store_false")
    ex.add_argument("--ckpt-dir", default="")
    ex.add_argument("--method", default="latmix-lu")
    ex.add_argument("--fmt", default="mxfp4", choices=["mxfp4", "mxint4"])
    ex.add_argument("--steps", type=int, default=60)
    ex.add_argument("--seed", type=int, default=0)
    ex.add_argument("--calib-batches", type=int, default=3)
    ex.add_argument("--calib-batch", type=int, default=8)
    ex.add_argument("--calib-len", type=int, default=64)
    ex.add_argument("--out", required=True)
    ex.set_defaults(func=_cmd_export)

    ins = sub.add_parser("inspect", help="print manifest summary")
    ins.add_argument("path")
    ins.add_argument("--tensors", action="store_true",
                     help="also print the per-tensor table")
    ins.set_defaults(func=_cmd_inspect)

    ver = sub.add_parser("verify", help="hash + byte-accounting check")
    ver.add_argument("path")
    ver.set_defaults(func=_cmd_verify)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.func(args)


if __name__ == "__main__":
    sys.exit(main())
