"""Artifact manifest: the JSON contract between export and serving.

An artifact directory is the unit of deployment (calibrate once, fold,
quantize, export — then every serving run loads the same bytes):

    artifact/
      manifest.json     schema + arch + quant mode + per-tensor records
      weights.npz       packed quantized weights: "<key>.codes" uint8
                        (K//2 two-per-byte nibbles, contraction axis) and
                        "<key>.scales" uint8 (E8M0, one per 32-block)
      aux.npz           non-quantized leaves (norms, embeddings, head,
                        biases, folded input transforms) in fp16/fp32

Every stored array carries a sha256 content hash in the manifest, and the
manifest records the packed byte totals so `verify` can cross-check the
on-disk layout against the roofline accounting (`mx.packed_nbytes`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import List, Optional

import numpy as np

SCHEMA_VERSION = 1
ARTIFACT_KIND = "mx-quantized-checkpoint"

MANIFEST_FILE = "manifest.json"
WEIGHTS_FILE = "weights.npz"
AUX_FILE = "aux.npz"


class ArtifactError(RuntimeError):
    """Malformed, unsupported, or incompatible artifact."""


class IntegrityError(ArtifactError):
    """Stored bytes do not match the manifest's content hashes."""


def array_sha256(a: np.ndarray) -> str:
    """Content hash of an array: dtype + shape + raw bytes (C order)."""
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(tuple(a.shape)).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class TensorRecord:
    """One params-tree leaf. kind='packed' leaves store two arrays in
    weights.npz; kind='raw' leaves store one array in aux.npz."""

    key: str                     # '/'-joined tree path, e.g. "blocks/wq"
    kind: str                    # 'packed' | 'raw'
    shape: List[int]             # logical (dense) shape
    dtype: str                   # logical dtype the leaf dequantizes to
    fmt: Optional[str] = None    # element format for packed leaves
    packed_nbytes: Optional[int] = None
    nbytes: Optional[int] = None
    sha256_codes: Optional[str] = None
    sha256_scales: Optional[str] = None
    sha256: Optional[str] = None

    def to_json(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    @classmethod
    def from_json(cls, d: dict) -> "TensorRecord":
        return cls(**d)


@dataclasses.dataclass
class Manifest:
    method: str                  # PTQ method that produced the weights
    fmt: str                     # MX element format of the packed weights
    arch: dict                   # dataclasses.asdict(ArchConfig)
    quant_mode: dict             # QuantMode fields (act_cfg/weight_cfg dicts)
    tensors: List[TensorRecord]
    schema_version: int = SCHEMA_VERSION
    kind: str = ARTIFACT_KIND
    extra: Optional[dict] = None

    @property
    def packed_total_nbytes(self) -> int:
        return sum(t.packed_nbytes or 0 for t in self.tensors)

    @property
    def raw_total_nbytes(self) -> int:
        return sum(t.nbytes or 0 for t in self.tensors)

    def record(self, key: str) -> TensorRecord:
        for t in self.tensors:
            if t.key == key:
                return t
        raise ArtifactError(f"no tensor record for {key!r}")

    def to_json(self) -> dict:
        return {
            "schema_version": self.schema_version,
            "kind": self.kind,
            "method": self.method,
            "fmt": self.fmt,
            "arch": self.arch,
            "quant_mode": self.quant_mode,
            "totals": {"packed_nbytes": self.packed_total_nbytes,
                       "raw_nbytes": self.raw_total_nbytes,
                       "n_packed": sum(1 for t in self.tensors
                                       if t.kind == "packed"),
                       "n_raw": sum(1 for t in self.tensors
                                    if t.kind == "raw")},
            "tensors": [t.to_json() for t in self.tensors],
            "extra": self.extra or {},
        }

    @classmethod
    def from_json(cls, d: dict) -> "Manifest":
        if d.get("kind") != ARTIFACT_KIND:
            raise ArtifactError(f"not an MX artifact (kind={d.get('kind')!r})")
        ver = d.get("schema_version")
        if ver != SCHEMA_VERSION:
            raise ArtifactError(
                f"unsupported artifact schema_version {ver} "
                f"(this build reads {SCHEMA_VERSION})")
        return cls(method=d["method"], fmt=d["fmt"], arch=d["arch"],
                   quant_mode=d["quant_mode"],
                   tensors=[TensorRecord.from_json(t) for t in d["tensors"]],
                   schema_version=ver, kind=d["kind"],
                   extra=d.get("extra") or None)

    def save(self, path: pathlib.Path):
        path.write_text(json.dumps(self.to_json(), indent=1, sort_keys=False))

    @classmethod
    def load(cls, path: pathlib.Path) -> "Manifest":
        try:
            d = json.loads(path.read_text())
        except FileNotFoundError:
            raise ArtifactError(f"no {MANIFEST_FILE} under {path.parent} "
                                f"(not an artifact directory?)")
        except json.JSONDecodeError as e:
            raise ArtifactError(f"corrupt manifest {path}: {e}")
        return cls.from_json(d)
