"""Export / load of MX-quantized artifacts.

Export takes an in-memory :class:`~repro.core.ptq.PTQResult` (weights
already on the MX grid — GPTQ/RTN output) and writes the deployable
layout: 4-bit packed codes + E8M0 scale bytes per quantized weight, fp
for everything else, plus a manifest with content hashes. Packing an
on-grid weight is bitwise lossless (checked at export), so a load does
**zero re-quantization** and serving an artifact reproduces the
in-memory result's logits exactly.

Load returns a servable ``(params, cfg, qm)`` triple. By default the
quantized weights come back as :class:`~repro.kernels.packing.PackedWeight`
leaves — packed uint8 stays in HBM and the dense weight is reconstructed
lazily inside the compiled step (per layer under ``lax.scan``).
``eager=True`` dequantizes everything at load instead.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import shutil
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import mx as mxlib
from repro.core.gptq import WEIGHT_KEYS
from repro.core.quantize import QuantMode
from repro.kernels import packing

from .manifest import (AUX_FILE, MANIFEST_FILE, WEIGHTS_FILE, ArtifactError,
                       IntegrityError, Manifest, TensorRecord, array_sha256)


# ---------------------------------------------------------------------------
# Tree <-> flat-key helpers (params trees are nested dicts)
# ---------------------------------------------------------------------------

def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p)
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _nest(flat: dict) -> dict:
    tree: dict = {}
    for key, value in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return tree


def _is_quantized_key(key: str, leaf: np.ndarray) -> bool:
    """Mirror of gptq.quantize_weights_rtn's traversal: a leaf is a
    quantized linear weight iff its name is a known weight key and it is
    at least 2-D (contraction axis = -2)."""
    return key.split("/")[-1] in WEIGHT_KEYS and leaf.ndim >= 2


# ---------------------------------------------------------------------------
# QuantMode (de)serialization
# ---------------------------------------------------------------------------

def _mxcfg_to_json(c):
    if c is None:
        return None
    return {"fmt": c.fmt, "block_size": c.block_size,
            "scale_mode": c.scale_mode, "stochastic": c.stochastic}


def _mxcfg_from_json(d):
    return None if d is None else mxlib.MXConfig(**d)


def quant_mode_to_json(qm: QuantMode) -> dict:
    return {"enabled": qm.enabled,
            "act_cfg": _mxcfg_to_json(qm.act_cfg),
            "weight_cfg": _mxcfg_to_json(qm.weight_cfg),
            "t3_block": qm.t3_block,
            "quantize_head": qm.quantize_head}


def quant_mode_from_json(d: dict) -> QuantMode:
    return QuantMode(enabled=d["enabled"],
                     act_cfg=_mxcfg_from_json(d["act_cfg"]),
                     weight_cfg=_mxcfg_from_json(d["weight_cfg"]),
                     t3_block=d["t3_block"],
                     quantize_head=d["quantize_head"])


# ---------------------------------------------------------------------------
# Export
# ---------------------------------------------------------------------------

def export_artifact(result, cfg: ArchConfig, out_dir, *,
                    extra: dict | None = None) -> pathlib.Path:
    """Write ``result`` (a PTQResult) as an artifact directory and return
    its path. The on-disk layout is specified in docs/artifact-format.md:
    every quantized (K, N)-contraction weight becomes uint8
    "<key>.codes" (K//2, N) + "<key>.scales" (K//32, N) in weights.npz;
    every other leaf keeps its logical dtype in aux.npz; manifest.json
    records shapes, dtypes, sha256 content hashes, and byte totals.
    The write is atomic (tmp dir + rename).

    Raises ArtifactError if the result is unquantized ('fp' teacher), the
    format is not 4-bit packable, or any supposedly-quantized weight is
    not bitwise-exactly representable in the packed layout (which would
    mean serving the artifact diverges from serving the PTQResult).
    """
    qm = result.qm
    if not qm.enabled:
        raise ArtifactError(
            "PTQResult is unquantized (method 'fp'); the artifact store "
            "only ships quantized deployments — run a PTQ method first")
    wcfg = qm.weight_cfg or qm.act_cfg
    if wcfg is None:
        raise ArtifactError("QuantMode carries no MXConfig to pack with")
    packing._check_packable(wcfg.fmt, wcfg.block_size, wcfg.scale_mode)
    fmt = wcfg.fmt

    flat = _flatten(result.params)
    weights_npz: Dict[str, np.ndarray] = {}
    aux_npz: Dict[str, np.ndarray] = {}
    records = []
    for key in sorted(flat):
        leaf = flat[key]
        if _is_quantized_key(key, leaf):
            bundle = packing.pack_weight(jnp.asarray(leaf), fmt)
            rt = np.asarray(packing.unpack_weight(bundle, leaf.dtype))
            if not np.array_equal(rt, leaf):
                raise ArtifactError(
                    f"weight {key!r} is not on the {fmt} grid — packing "
                    f"would silently re-quantize it; export only accepts "
                    f"quantized PTQ results")
            codes = np.asarray(bundle["codes_packed"])
            scales = np.asarray(bundle["scales_e8m0"])
            nb = packing.packed_bundle_nbytes(bundle)
            acct = mxlib.packed_nbytes(
                leaf.shape, mxlib.MXConfig(fmt=fmt, block_size=32))
            if nb != acct:
                raise ArtifactError(
                    f"{key}: packed bytes {nb} != roofline accounting {acct}")
            weights_npz[f"{key}.codes"] = codes
            weights_npz[f"{key}.scales"] = scales
            records.append(TensorRecord(
                key=key, kind="packed", shape=list(leaf.shape),
                dtype=str(leaf.dtype), fmt=fmt, packed_nbytes=nb,
                sha256_codes=array_sha256(codes),
                sha256_scales=array_sha256(scales)))
        else:
            # npz cannot round-trip ml_dtypes (bfloat16 lands as void and
            # poisons the artifact): store the raw bytes, keep the logical
            # dtype in the record, and hash the *logical* array.
            store = leaf.view(np.uint8) if leaf.dtype.kind == "V" else leaf
            aux_npz[key] = store
            records.append(TensorRecord(
                key=key, kind="raw", shape=list(leaf.shape),
                dtype=str(leaf.dtype), nbytes=int(leaf.nbytes),
                sha256=array_sha256(leaf)))
    if not weights_npz:
        raise ArtifactError("no quantized weights found in PTQResult params")

    man = Manifest(method=result.method, fmt=fmt,
                   arch=dataclasses.asdict(cfg),
                   quant_mode=quant_mode_to_json(qm),
                   tensors=records, extra=extra)

    out = pathlib.Path(out_dir)
    out.parent.mkdir(parents=True, exist_ok=True)
    tmp = out.parent / f".tmp_artifact_{out.name}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    np.savez(tmp / WEIGHTS_FILE, **weights_npz)
    np.savez(tmp / AUX_FILE, **aux_npz)
    man.save(tmp / MANIFEST_FILE)
    if out.exists():
        shutil.rmtree(out)
    os.replace(tmp, out)              # atomic on POSIX
    return out


# ---------------------------------------------------------------------------
# Load / verify
# ---------------------------------------------------------------------------

def _load_npz(path: pathlib.Path) -> dict:
    """Read every array in an npz store, translating the zip layer's
    failure zoo (BadZipFile, truncated reads, CRC mismatches — all of
    which otherwise surface deep inside numpy's unpacking) into one
    descriptive IntegrityError naming the file and the cure."""
    try:
        with np.load(path) as z:
            return {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise ArtifactError(f"missing {path.name} in artifact directory")
    except Exception as e:  # BadZipFile / truncated / bit-flipped stores
        raise IntegrityError(
            f"artifact tensor file {path.name} is corrupt or truncated "
            f"({type(e).__name__}: {e}) — the artifact cannot be served; "
            f"re-export it or restore the file from backup")


def _decode_raw(t: TensorRecord, arr: np.ndarray) -> np.ndarray:
    """Undo the uint8 byte-encoding of ml_dtypes leaves (importing jax
    registers their names with numpy, so np.dtype(t.dtype) resolves)."""
    want = np.dtype(t.dtype)
    if arr.dtype == np.uint8 and want.kind == "V":
        return arr.view(want).reshape(t.shape)
    return arr


def _read_arrays(root: pathlib.Path, man: Manifest,
                 verify: bool) -> Tuple[dict, dict]:
    weights = _load_npz(root / WEIGHTS_FILE)
    aux = _load_npz(root / AUX_FILE)
    expect_w, expect_a = set(), set()
    for t in man.tensors:
        if t.kind == "packed":
            expect_w.update((f"{t.key}.codes", f"{t.key}.scales"))
        else:
            expect_a.add(t.key)
    if set(weights) != expect_w or set(aux) != expect_a:
        raise IntegrityError(
            f"stored arrays do not match manifest: weights "
            f"{sorted(set(weights) ^ expect_w)}, aux "
            f"{sorted(set(aux) ^ expect_a)} differ")
    for t in man.tensors:
        if t.kind != "packed":
            aux[t.key] = _decode_raw(t, aux[t.key])
    if verify:
        for t in man.tensors:
            if t.kind == "packed":
                if (array_sha256(weights[f"{t.key}.codes"]) != t.sha256_codes
                        or array_sha256(weights[f"{t.key}.scales"])
                        != t.sha256_scales):
                    raise IntegrityError(
                        f"content hash mismatch for packed tensor "
                        f"{t.key!r}: the stored bytes differ from the "
                        f"manifest's sha256 — the file was modified or "
                        f"corrupted after export")
            else:
                if array_sha256(aux[t.key]) != t.sha256:
                    raise IntegrityError(
                        f"content hash mismatch for tensor {t.key!r}: "
                        f"the stored bytes differ from the manifest's "
                        f"sha256 — the file was modified or corrupted "
                        f"after export")
    return weights, aux


def load_artifact(path, *, eager: bool = False, verify: bool = True,
                  backend: str | None = None
                  ) -> Tuple[dict, ArchConfig, QuantMode]:
    """Load an artifact into a servable ``(params, cfg, qm)`` triple —
    params is the nested pytree the model API expects, cfg the
    ArchConfig from the manifest, qm the serving QuantMode.

    eager=False (default): quantized weights are PackedWeight leaves —
    packed uint8 bytes in HBM, dequantized to the record's logical dtype
    lazily at each use site (or consumed packed-native by the fused
    backend). eager=True: dense fp weights are materialized once at load
    (the fused kernels then never engage — dense weights fall back to
    the reference path).
    verify=True: recompute content hashes before trusting the bytes
    (raises IntegrityError on any mismatch; malformed/unsupported
    artifacts raise ArtifactError).
    backend: optional execution-backend override for the returned
    QuantMode ('ref' | 'fused'). The backend is a serving-time choice,
    not a model property, so it is never stored in the manifest.
    """
    root = pathlib.Path(path)
    man = Manifest.load(root / MANIFEST_FILE)
    weights, aux = _read_arrays(root, man, verify)

    cfg = ArchConfig(**man.arch)
    qm = quant_mode_from_json(man.quant_mode)
    if backend is not None:
        qm = qm.with_backend(backend)

    flat = {}
    for t in man.tensors:
        if t.kind == "packed":
            pw = packing.PackedWeight(
                jnp.asarray(weights[f"{t.key}.codes"]),
                jnp.asarray(weights[f"{t.key}.scales"]),
                t.fmt, t.dtype)
            if list(pw.shape) != list(t.shape):
                raise IntegrityError(
                    f"{t.key}: packed arrays imply shape {pw.shape}, "
                    f"manifest says {t.shape}")
            flat[t.key] = pw.to_dense() if eager else pw
        else:
            flat[t.key] = jnp.asarray(aux[t.key], dtype=jnp.dtype(t.dtype))
    return _nest(flat), cfg, qm


def verify_artifact(path) -> dict:
    """Full integrity + accounting check. Raises on any mismatch; returns
    a summary dict (used by the CLI)."""
    root = pathlib.Path(path)
    man = Manifest.load(root / MANIFEST_FILE)
    weights, _ = _read_arrays(root, man, verify=True)
    stored_packed = sum(int(a.nbytes) for a in weights.values())
    if stored_packed != man.packed_total_nbytes:
        raise IntegrityError(
            f"stored packed bytes {stored_packed} != manifest total "
            f"{man.packed_total_nbytes}")
    for t in man.tensors:
        if t.kind != "packed":
            continue
        acct = mxlib.packed_nbytes(
            t.shape, mxlib.MXConfig(fmt=t.fmt, block_size=32))
        if t.packed_nbytes != acct:
            raise IntegrityError(
                f"{t.key}: manifest packed_nbytes {t.packed_nbytes} != "
                f"roofline accounting {acct}")
    return {"ok": True, "method": man.method, "fmt": man.fmt,
            "n_tensors": len(man.tensors),
            "packed_nbytes": man.packed_total_nbytes,
            "raw_nbytes": man.raw_total_nbytes}
