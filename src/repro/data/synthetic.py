"""Deterministic synthetic LM corpus — the offline stand-in for WikiText2.

A Zipf–Markov source: token t+1 follows a fixed random permutation of token
t with probability ``p_follow``, otherwise it is drawn from a Zipf marginal.
The planted bigram structure is learnable (a trained model's perplexity
drops far below the unigram entropy), so *relative* comparisons between
quantization methods — the paper's claims — are meaningful.

Determinism: batch(i) depends only on (seed, i) — restarts replay exactly
(fault-tolerance requirement), and any worker can compute its own shard.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    p_follow: float = 0.6
    zipf_a: float = 1.2


def _zipf_probs(v: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, v + 1) ** a
    return p / p.sum()


class SyntheticLM:
    def __init__(self, dc: DataConfig):
        self.dc = dc
        rng = np.random.default_rng(dc.seed)
        self.perm = rng.permutation(dc.vocab_size)
        self.zipf = _zipf_probs(dc.vocab_size, dc.zipf_a)
        # shuffle so the frequent tokens are spread over the id space
        self.rank2id = rng.permutation(dc.vocab_size)

    def batch(self, step: int) -> dict:
        dc = self.dc
        rng = np.random.default_rng((dc.seed + 1) * 1_000_003 + step)
        B, S = dc.batch_size, dc.seq_len
        toks = np.empty((B, S + 1), dtype=np.int32)
        zipf_draws = self.rank2id[
            rng.choice(dc.vocab_size, size=(B, S + 1), p=self.zipf)]
        follow = rng.random((B, S + 1)) < dc.p_follow
        toks[:, 0] = zipf_draws[:, 0]
        for t in range(1, S + 1):
            toks[:, t] = np.where(follow[:, t],
                                  self.perm[toks[:, t - 1]],
                                  zipf_draws[:, t])
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}

    def token_stream(self, n_batches: int):
        for i in range(n_batches):
            yield self.batch(i)


class SyntheticEmbed:
    """For stub-frontend archs (hubert / internvl2): token stream mapped
    through a fixed codebook + noise -> (B, S, d) embeddings."""

    def __init__(self, dc: DataConfig, d_model: int, n_classes: int,
                 next_token_labels: bool):
        self.lm = SyntheticLM(dc)
        rng = np.random.default_rng(dc.seed + 7)
        self.codebook = rng.standard_normal(
            (dc.vocab_size, d_model)).astype(np.float32) * 0.5
        self.n_classes = n_classes
        self.next_token = next_token_labels
        self.noise = 0.05

    def batch(self, step: int) -> dict:
        b = self.lm.batch(step)
        rng = np.random.default_rng(991 + step)
        toks = b["inputs"]
        emb = self.codebook[toks]
        emb = emb + rng.standard_normal(emb.shape).astype(np.float32) * self.noise
        if self.next_token:
            labels = b["labels"] % self.n_classes
        else:
            labels = toks % self.n_classes  # per-frame classification
        return {"inputs": emb, "labels": labels.astype(np.int32)}


def make_source(cfg: ArchConfig, batch_size: int, seq_len: int,
                seed: int = 0):
    """Data source matched to the architecture's input modality."""
    if cfg.embed_inputs:
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                        batch_size=batch_size, seed=seed)
        return SyntheticLM(dc)
    dc = DataConfig(vocab_size=min(4096, max(64, cfg.vocab_size)),
                    seq_len=seq_len, batch_size=batch_size, seed=seed)
    return SyntheticEmbed(dc, cfg.d_model, cfg.vocab_size,
                          next_token_labels=(cfg.family == "vlm"))


def unigram_ppl(dc: DataConfig) -> float:
    """Entropy of the marginal — the no-learning baseline perplexity."""
    src = SyntheticLM(dc)
    p_f, z = dc.p_follow, src.zipf
    # stationary marginal ~ zipf (permutation preserves marginals)
    h_follow = -(p_f * np.log(p_f))
    h = -np.sum(z * np.log(z))
    return float(np.exp((1 - p_f) * h))
