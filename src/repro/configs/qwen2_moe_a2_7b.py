"""Qwen1.5-MoE-A2.7B — 60 routed top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=151936,
    qkv_bias=True, n_experts=60, top_k=4, n_shared_experts=4,
    capacity_factor=1.25, moe_groups=32, rope_theta=1e6, dtype="bfloat16",
    remat=True,
)

REDUCED = ArchConfig(
    name="qwen2-moe-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=96, vocab_size=512,
    qkv_bias=True, n_experts=6, top_k=2, n_shared_experts=2,
    capacity_factor=3.0, attn_chunk=64,
)
