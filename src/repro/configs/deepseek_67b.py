"""DeepSeek-67B — dense llama-arch [arXiv:2401.02954; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense", n_layers=95, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22016, vocab_size=102400,
    rope_theta=10000.0, attn_repeat_kv=True, dtype="bfloat16",
    remat=True,
)

REDUCED = ArchConfig(
    name="deepseek-67b-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=8, n_kv_heads=1, head_dim=16, d_ff=352, vocab_size=512,
    attn_chunk=64,
)
