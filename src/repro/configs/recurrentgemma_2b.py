"""RecurrentGemma-2B — RG-LRU + local attention (1:2), MQA
[arXiv:2402.19427; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, head_dim=256, d_ff=7680, vocab_size=256000,
    window=2048, lru_width=2560, rope_theta=10000.0, tie_embeddings=True,
    dtype="bfloat16", remat=True,
)

REDUCED = ArchConfig(
    name="recurrentgemma-2b-smoke", family="hybrid", n_layers=5, d_model=128,
    n_heads=4, n_kv_heads=1, head_dim=32, d_ff=384, vocab_size=512,
    window=32, lru_width=128, attn_chunk=64,
)
