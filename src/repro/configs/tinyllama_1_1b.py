"""TinyLlama-1.1B — llama2-arch small, GQA kv=4 [arXiv:2401.02385; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
    n_heads=32, n_kv_heads=4, head_dim=64, d_ff=5632, vocab_size=32000,
    rope_theta=10000.0, attn_repeat_kv=True, dtype="bfloat16",
    remat=True,
)

REDUCED = ArchConfig(
    name="tinyllama-1.1b-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=8, n_kv_heads=1, head_dim=16, d_ff=352, vocab_size=512,
    attn_chunk=64,
)
