"""Moonshot/Moonlight-16B-A3B — MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=163840,
    n_experts=64, top_k=6, n_shared_experts=0, capacity_factor=1.25,
    moe_groups=32, rope_theta=50000.0, dtype="bfloat16", remat=True,
)

REDUCED = ArchConfig(
    name="moonshot-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=96, vocab_size=512,
    n_experts=8, top_k=2, n_shared_experts=0, capacity_factor=4.0,
    attn_chunk=64,
)
