"""InternVL2-26B — InternLM2 LM backbone; InternViT frontend is a stub
(input_specs provides patch embeddings) [arXiv:2404.16821; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab_size=92553,
    embed_inputs=False, rope_theta=1e6, attn_repeat_kv=True,
    dtype="bfloat16", remat=True,
)

REDUCED = ArchConfig(
    name="internvl2-smoke", family="vlm", n_layers=3, d_model=128,
    n_heads=8, n_kv_heads=2, head_dim=16, d_ff=384, vocab_size=512,
    embed_inputs=False, attn_chunk=64,
)
