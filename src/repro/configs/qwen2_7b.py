"""Qwen2-7B — dense, GQA kv=4, QKV bias [arXiv:2407.10671; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6, dtype="bfloat16", remat=True,
)

REDUCED = ArchConfig(
    name="qwen2-7b-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=8, n_kv_heads=2, head_dim=16, d_ff=608, vocab_size=512,
    qkv_bias=True, attn_chunk=64,
)
