"""Architecture configuration shared by the model zoo, configs, and launch."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    causal: bool = True
    embed_inputs: bool = True      # False: stub frontend feeds embeddings
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1            # routing groups (locality knob for EP)
    # --- hybrid (RG-LRU + local attention, Griffin pattern) ---
    window: int = 0                # local attention window (0 = full)
    lru_width: int = 0
    # --- ssm (Mamba2 / SSD) ---
    ssm_state: int = 0
    expand: int = 2
    conv_kernel: int = 4
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 128
    # --- execution ---
    attn_chunk: int = 1024         # KV-chunk for online-softmax attention
    attn_repeat_kv: bool = False   # materialize GQA kv to H heads so the
    #                                head axis divides the TP degree (kills
    #                                GSPMD involuntary replication; §Perf)
    dtype: str = "float32"
    remat: bool = False
    scan_layers: bool = True       # False: unroll (roofline per-layer costs)

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived ----------------------------------------------------------
    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_ngroups * self.ssm_state

    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts routed experts at
        top_k/n_experts utilization (for MoE MODEL_FLOPS = 6·N_active·D)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if not self.embed_inputs:
            emb = self.vocab_size * d  # head only, frontend stubbed
        if self.family == "ssm":
            di, H, N, G = self.d_inner, self.ssm_nheads, self.ssm_state, self.ssm_ngroups
            per = (d * (2 * di + 2 * G * N + H)       # in_proj
                   + self.conv_dim * self.conv_kernel  # conv
                   + 3 * H + di                        # A, D, dt_bias, norm
                   + di * d)                           # out_proj
            return emb + L * per + d
        att = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "moe":
            e_all = self.n_experts + self.n_shared_experts
            e_act = self.top_k + self.n_shared_experts
            ffn_full = 3 * d * self.d_ff * e_all + d * self.n_experts
            ffn_act = 3 * d * self.d_ff * e_act + d * self.n_experts
            ffn = ffn_act if active_only else ffn_full
            return emb + L * (att + ffn + 2 * d) + d
        if self.family == "hybrid":
            n_rec = self.n_rec_layers
            n_att = L - n_rec
            lru = self.lru_width
            rec = (2 * d * lru + lru * self.conv_kernel + 3 * lru
                   + lru * d + lru)
            ffn = 3 * d * self.d_ff
            return (emb + n_att * (att + ffn + 2 * d)
                    + n_rec * (rec + ffn + 2 * d) + d)
        ffn = 3 * d * self.d_ff
        return emb + L * (att + ffn + 2 * d) + d

    @property
    def n_rec_layers(self) -> int:
        """Hybrid pattern (rec, rec, attn) repeated + rec tail."""
        n_super = self.n_layers // 3
        tail = self.n_layers - 3 * n_super
        return 2 * n_super + tail

    @property
    def n_super_blocks(self) -> int:
        return self.n_layers // 3

    @property
    def n_tail_rec(self) -> int:
        return self.n_layers - 3 * self.n_super_blocks


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    # the paper's calibration workload (§5.1: 256 seqs of 1k tokens),
    # lowered as a distributed transform-learning step (--shape calib_1k)
    "calib_1k": ShapeConfig("calib_1k", 1024, 256, "latmix"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Spec'd skips: encoder-only has no decode; long_500k needs
    sub-quadratic attention (ssm / hybrid only)."""
    if cfg.family == "encoder" and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k requires sub-quadratic attention"
    if shape.kind == "latmix" and not cfg.embed_inputs:
        return False, "calibration step demo is token-input only"
    return True, ""


ASSIGNED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
