"""Mamba2-130M — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    vocab_size=50280, ssm_state=128, expand=2, conv_kernel=4,
    ssm_headdim=64, ssm_ngroups=1, ssm_chunk=256, tie_embeddings=True,
    dtype="bfloat16", remat=True,
)

REDUCED = ArchConfig(
    name="mamba2-smoke", family="ssm", n_layers=3, d_model=96,
    vocab_size=512, ssm_state=16, expand=2, conv_kernel=4,
    ssm_headdim=16, ssm_ngroups=1, ssm_chunk=16, tie_embeddings=True,
)
