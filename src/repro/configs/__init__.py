"""Architecture config registry: ``get(name)`` / ``get_reduced(name)``."""
from __future__ import annotations

import importlib

from .base import ArchConfig, SHAPES, ShapeConfig, shape_applicable  # noqa

ARCH_IDS = [
    "deepseek_67b",
    "qwen2_7b",
    "qwen2_0_5b",
    "tinyllama_1_1b",
    "recurrentgemma_2b",
    "moonshot_v1_16b_a3b",
    "qwen2_moe_a2_7b",
    "hubert_xlarge",
    "internvl2_26b",
    "mamba2_130m",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "deepseek-67b": "deepseek_67b",
    "qwen2-7b": "qwen2_7b",
    "qwen2-0.5b": "qwen2_0_5b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "hubert-xlarge": "hubert_xlarge",
    "internvl2-26b": "internvl2_26b",
    "mamba2-130m": "mamba2_130m",
})


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def get(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_reduced(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.REDUCED


def all_configs():
    return {i: get(i) for i in ARCH_IDS}
