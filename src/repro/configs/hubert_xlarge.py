"""HuBERT-XLarge — encoder-only audio backbone; the conv frontend is a
stub (input_specs provides frame embeddings) [arXiv:2106.07447]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, head_dim=80, d_ff=5120, vocab_size=504,
    causal=False, embed_inputs=False, attn_repeat_kv=True,
    dtype="bfloat16", remat=True,
)

REDUCED = ArchConfig(
    name="hubert-smoke", family="encoder", n_layers=3, d_model=128,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=320, vocab_size=64,
    causal=False, embed_inputs=False, attn_chunk=64,
)
