"""Block-wise GPTQ (Frantar et al. 2023) adapted to the MX format
(MR-GPTQ-style): error-compensated weight quantization with per-MX-block
scales recomputed from the *current* (compensated) weights at each block
boundary along the input dimension.

Stage 2 of the PTQ pipeline — applied to the transform-folded weights.
Hessians H = Σ x xᵀ are accumulated from calibration activations captured
at every linear's input (post-transform, post-T3 for the down projection).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import mx as mxlib
from repro.core import transforms as tfm
from repro.core.quantize import QuantMode
from repro.models.layers import rms_norm
from repro.models import transformer as dense


# ---------------------------------------------------------------------------
# Core GPTQ on one matrix
# ---------------------------------------------------------------------------

def gptq_matrix(w: np.ndarray, hess: np.ndarray, cfg: mxlib.MXConfig,
                damp: float = 0.01) -> np.ndarray:
    """Quantize ``w`` (d_in, d_out) along d_in with MX blocks, compensating
    error through the Hessian (d_in, d_in) of the layer inputs."""
    w = np.array(w, dtype=np.float64)
    d_in, d_out = w.shape
    B = cfg.block_size
    H = np.array(hess, dtype=np.float64)
    # dead inputs
    dead = np.diag(H) == 0
    H[dead, dead] = 1.0
    w[dead, :] = 0.0
    H += np.eye(d_in) * damp * np.mean(np.diag(H))
    # Hinv = Uᵀ U with U upper-triangular — the GPTQ propagation factors
    Hinv = np.linalg.inv(H)
    U = _upper_cholesky(Hinv)

    q = np.zeros_like(w)
    grid = np.asarray(cfg.element.grid, dtype=np.float64)
    mids = (grid[1:] + grid[:-1]) / 2.0

    for b0 in range(0, d_in, B):
        b1 = min(b0 + B, d_in)
        # MX scales from the *current* compensated weights of this block
        amax = np.max(np.abs(w[b0:b1, :]), axis=0)          # (d_out,)
        if cfg.scale_mode == "pow2":
            safe = np.where(amax > 0, amax, 1.0)
            s = np.exp2(np.floor(np.log2(safe)) - cfg.element.r_max)
            s = np.where(amax > 0, s, 1.0)
        else:
            s = np.where(amax > 0, amax / cfg.element.max_val, 1.0)
        err_block = np.zeros((b1 - b0, d_out))
        for i in range(b0, b1):
            z = w[i, :] / s
            idx = np.searchsorted(mids, np.abs(z), side="right")
            qi = np.sign(z) * grid[idx] * s
            q[i, :] = qi
            e = (w[i, :] - qi) / U[i, i]
            if i + 1 < b1:
                w[i + 1:b1, :] -= np.outer(U[i, i + 1:b1], e)
            err_block[i - b0, :] = e
        if b1 < d_in:
            w[b1:, :] -= U[b0:b1, b1:].T @ err_block
    return q.astype(np.float32)


def _upper_cholesky(m: np.ndarray) -> np.ndarray:
    """Upper-triangular U with m = Uᵀ U (the GPTQ propagation factors):
    the transpose of the standard lower Cholesky factor."""
    return np.linalg.cholesky(m).T


def rtn_matrix(w: np.ndarray, cfg: mxlib.MXConfig) -> np.ndarray:
    """Round-to-nearest along d_in (no compensation)."""
    wq = mxlib.quantize(jnp.asarray(w).T, cfg, ste=False).T
    return np.asarray(wq, dtype=np.float32)


# ---------------------------------------------------------------------------
# Hessian capture for the dense-transformer family
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HessianStats:
    """Per-layer input Hessians keyed by role."""
    h_attn_in: np.ndarray     # (L, d, d)  — input of wq/wk/wv
    h_attn_out: np.ndarray    # (L, qd, qd)
    h_ffn_in: np.ndarray      # (L, d, d)
    h_ffn_down: np.ndarray    # (L, f, f)  — includes online T3


def capture_hessians(params, cfg: ArchConfig, batches: List[dict],
                     qm: QuantMode) -> HessianStats:
    """Unrolled dense forward capturing Σ xᵀx at each linear input.

    The activations are the *quantized-path* inputs (act quant on), matching
    what the deployed GEMMs see."""
    L, d, f, qd = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.q_dim
    hs = HessianStats(
        h_attn_in=np.zeros((L, d, d)), h_attn_out=np.zeros((L, qd, qd)),
        h_ffn_in=np.zeros((L, d, d)), h_ffn_down=np.zeros((L, f, f)))

    @jax.jit
    def layer_io(x, pl, pos):
        h1 = rms_norm(x, pl["ln1"], cfg.norm_eps)
        x2, _, _ = dense.attn_sublayer(x, pl, cfg, qm, pos,
                                       window=cfg.window)
        h2 = rms_norm(x2, pl["ln2"], cfg.norm_eps)
        x3 = dense.ffn_sublayer(x2, pl, cfg, qm)
        # recompute attention output input & down-proj input
        import jax.numpy as jnp2
        from repro.core.quantize import qlinear
        g = qlinear(h2, pl["wg"], pl.get("bg"), qm, "ffn_in")
        u = qlinear(h2, pl["wu"], pl.get("bu"), qm, "ffn_in")
        hmid = jax.nn.silu(g.astype(jnp2.float32)).astype(x.dtype) * u
        if qm.t3_block:
            hmid = tfm.apply_blockwise(
                hmid, tfm.hadamard_matrix(qm.t3_block, dtype=hmid.dtype))
        return x3, h1, h2, hmid

    for b in batches:
        x = dense.embed_inputs(params, cfg, jnp.asarray(b["inputs"]))
        S = x.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        for l in range(L):
            pl = jax.tree.map(lambda a: a[l], params["blocks"])
            xn, h1, h2, hmid = layer_io(x, pl, pos)
            # attention-out input: recompute q/k/v path output pre-wo
            flat = lambda t: np.asarray(
                t.astype(jnp.float32)).reshape(-1, t.shape[-1])
            a1 = flat(h1)
            hs.h_attn_in[l] += a1.T @ a1
            a2 = flat(h2)
            hs.h_ffn_in[l] += a2.T @ a2
            am = flat(hmid)
            hs.h_ffn_down[l] += am.T @ am
            x = xn
    return hs


def quantize_weights_gptq(params, cfg: ArchConfig, stats: HessianStats,
                          mxcfg: mxlib.MXConfig, t3_block: int = 32):
    """GPTQ the dense-family weights using captured Hessians; weights with
    no Hessian (wo — cheap to add, embeddings, head) fall back to RTN."""
    p = dict(params)
    b = dict(p["blocks"])
    L = cfg.n_layers

    def per_layer(name, hess_key):
        ws = np.asarray(b[name], dtype=np.float32)
        out = np.empty_like(ws)
        for l in range(L):
            hess = getattr(stats, hess_key)[l]
            out[l] = gptq_matrix(ws[l], hess, mxcfg)
        b[name] = jnp.asarray(out, dtype=b[name].dtype)

    per_layer("wq", "h_attn_in")
    per_layer("wk", "h_attn_in")
    per_layer("wv", "h_attn_in")
    per_layer("wg", "h_ffn_in")
    per_layer("wu", "h_ffn_in")
    per_layer("wd", "h_ffn_down")
    b["wo"] = jnp.asarray(
        np.stack([rtn_matrix(np.asarray(b["wo"][l], np.float32), mxcfg)
                  for l in range(L)]), dtype=b["wo"].dtype)
    p["blocks"] = b
    return p


# ---------------------------------------------------------------------------
# RTN for any family (generic tree traversal)
# ---------------------------------------------------------------------------

WEIGHT_KEYS = {"wq", "wk", "wv", "wo", "wg", "wu", "wd", "router",
               "eg", "eu", "ed", "sg", "su", "sd", "in_proj", "out_proj",
               "wx", "wy", "wor"}
_WEIGHT_KEYS = WEIGHT_KEYS  # back-compat alias


def quantize_weights_rtn(params, cfg: ArchConfig, mxcfg: mxlib.MXConfig):
    """Fake-quantize every linear weight along its input axis (axis -2)."""
    def visit(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in _WEIGHT_KEYS and leaf.ndim >= 2:
            wt = jnp.swapaxes(leaf, -1, -2)
            wq = mxlib.quantize(wt, mxcfg, ste=False)
            return jnp.swapaxes(wq, -1, -2).astype(leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, params)
