"""Microscaling (MX) quantization — OCP MX spec (Rouhani et al., 2023b).

Implements Eq. (1) of the paper:

    s_i = 2^( floor(log2(max_{j in I_i} |x_j|)) - r_max )
    Q(x)_j = s_i * Q_e(x_j / s_i)

for block-wise power-of-two dynamic scaling with low-precision element
formats (FP4 E2M1, INT4, FP8 E4M3, FP6 E2M3), plus the NVFP4 variant
(B=16, FP8-quantized non-pow2 scales) used in Appendix E.6.

Everything here is "fake-quant": values stay in the compute dtype but land
exactly on the element grid times the block scale. The packed-code path
(uint8 codes + fp32 scales) used by the Pallas kernels lives in
``encode``/``decode``. A straight-through estimator makes every op
differentiable so transformations can be learned through the quantizer
(Section 3.2).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Element formats
# ---------------------------------------------------------------------------

# FP4 E2M1 positive grid per OCP MX spec: max exponent r_max = 2, max = 6.0
_FP4_POS = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float64)
# FP6 E2M3 positive grid: mantissa 3 bits, exponents {2^0(subnormal step .125) .. 2^2}
_FP6_POS = np.concatenate(
    [
        np.arange(0, 8) / 8.0,          # subnormals of exponent 0: 0 .. 0.875
        (8 + np.arange(0, 8)) / 8.0,    # e=0: 1.0 .. 1.875
        (8 + np.arange(0, 8)) / 4.0,    # e=1: 2.0 .. 3.75
        (8 + np.arange(0, 8)) / 2.0,    # e=2: 4.0 .. 7.5
    ]
).astype(np.float64)


def _fp8_e4m3_grid() -> np.ndarray:
    """Positive representable values of FP8 E4M3 (OCP variant, max 448)."""
    vals = [0.0]
    for e in range(0, 16):
        for m in range(0, 8):
            if e == 0:
                v = (m / 8.0) * 2.0 ** (-6)
            else:
                v = (1 + m / 8.0) * 2.0 ** (e - 7)
            vals.append(v)
    vals = sorted(set(v for v in vals if v <= 448.0))
    return np.array(vals, dtype=np.float64)


@dataclasses.dataclass(frozen=True)
class ElementFormat:
    """A symmetric low-precision element format defined by its value grid."""

    name: str
    bits: int
    grid: tuple  # positive half-grid including 0, ascending
    r_max: int   # max representable power-of-two exponent (for scale calc)

    @property
    def max_val(self) -> float:
        return float(self.grid[-1])

    def full_grid(self) -> np.ndarray:
        pos = np.asarray(self.grid, dtype=np.float64)
        return np.concatenate([-pos[::-1][:-1], pos])


FP4 = ElementFormat("fp4_e2m1", 4, tuple(_FP4_POS.tolist()), r_max=2)
FP6 = ElementFormat("fp6_e2m3", 6, tuple(_FP6_POS.tolist()), r_max=2)
FP8 = ElementFormat("fp8_e4m3", 8, tuple(_fp8_e4m3_grid().tolist()), r_max=8)
# INT4 symmetric: codes -7..7. r_max chosen so max code magnitude (7) sits
# just inside [2^r_max, 2^(r_max+1)) => r_max = 2 (MR-GPTQ convention).
INT4 = ElementFormat(
    "int4", 4, tuple(np.arange(0.0, 8.0).tolist()), r_max=2
)
INT8 = ElementFormat("int8", 8, tuple(np.arange(0.0, 128.0).tolist()), r_max=6)

FORMATS = {f.name: f for f in (FP4, FP6, FP8, INT4, INT8)}
FORMATS.update({"mxfp4": FP4, "mxint4": INT4, "mxfp8": FP8, "mxfp6": FP6,
                "mxint8": INT8})


@dataclasses.dataclass(frozen=True)
class MXConfig:
    """Configuration of an MX quantizer.

    ``block_size`` divides the *last* axis of the tensor being quantized.
    ``scale_mode``: 'pow2' (OCP MX, Eq. 1) or 'fp8' (NVFP4-style real scales
    quantized to FP8 E4M3).
    """

    fmt: str = "mxfp4"
    block_size: int = 32
    scale_mode: str = "pow2"
    stochastic: bool = False  # stochastic rounding for the element quantizer

    @property
    def element(self) -> ElementFormat:
        return FORMATS[self.fmt]


NVFP4 = MXConfig(fmt="mxfp4", block_size=16, scale_mode="fp8")


# ---------------------------------------------------------------------------
# Element quantizer Q_e — snap to nearest grid point (ties-to-even-ish via
# midpoint comparison; the grids are tiny so a bucketize is exact & fast).
# ---------------------------------------------------------------------------

def _snap_to_grid(x: jnp.ndarray, grid: np.ndarray) -> jnp.ndarray:
    """Round each element of ``x`` to the nearest value in ``grid``.

    grid: ascending positive half-grid including 0. Symmetric handling of
    sign. Values beyond the max saturate.
    """
    g = jnp.asarray(grid, dtype=x.dtype)
    mids = (g[1:] + g[:-1]) / 2.0
    mag = jnp.abs(x)
    idx = jnp.searchsorted(mids, mag, side="right")  # 0..len(grid)-1
    snapped = g[idx]
    return jnp.sign(x) * snapped


def _snap_stochastic(x: jnp.ndarray, grid: np.ndarray,
                     key: jax.Array) -> jnp.ndarray:
    """Stochastic rounding between the two bracketing grid points."""
    g = jnp.asarray(grid, dtype=x.dtype)
    mag = jnp.clip(jnp.abs(x), 0.0, g[-1])
    hi_idx = jnp.clip(jnp.searchsorted(g, mag, side="left"), 0, len(grid) - 1)
    lo_idx = jnp.clip(hi_idx - 1, 0, len(grid) - 1)
    lo, hi = g[lo_idx], g[hi_idx]
    span = jnp.where(hi > lo, hi - lo, 1.0)
    p_hi = (mag - lo) / span
    u = jax.random.uniform(key, x.shape, dtype=x.dtype)
    snapped = jnp.where(u < p_hi, hi, lo)
    return jnp.sign(x) * snapped


# ---------------------------------------------------------------------------
# Block scales
# ---------------------------------------------------------------------------

def compute_scales(x: jnp.ndarray, cfg: MXConfig) -> jnp.ndarray:
    """Per-block scales for the last axis of ``x``.

    Returns an array of shape x.shape[:-1] + (x.shape[-1] // B,).
    """
    B = cfg.block_size
    *lead, d = x.shape
    if d % B != 0:
        raise ValueError(f"last dim {d} not divisible by block size {B}")
    xb = x.reshape(*lead, d // B, B)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    if cfg.scale_mode == "pow2":
        # s = 2^(floor(log2 amax) - r_max); amax==0 -> scale 1 (block is 0).
        safe = jnp.where(amax > 0, amax, 1.0)
        e = jnp.floor(jnp.log2(safe.astype(jnp.float32)))
        s = jnp.exp2(e - cfg.element.r_max)
        return jnp.where(amax > 0, s, 1.0).astype(jnp.float32)
    elif cfg.scale_mode == "fp8":
        # NVFP4: real scale amax / max_code, itself snapped to FP8 E4M3.
        s = amax.astype(jnp.float32) / cfg.element.max_val
        s = _snap_to_grid(s, np.asarray(FP8.grid))
        return jnp.where(s > 0, s, 1.0)
    raise ValueError(f"unknown scale_mode {cfg.scale_mode}")


# ---------------------------------------------------------------------------
# Fake-quantization (value-domain) with straight-through estimator
# ---------------------------------------------------------------------------

def _quantize_value(x: jnp.ndarray, cfg: MXConfig,
                    key: jax.Array | None = None) -> jnp.ndarray:
    B = cfg.block_size
    *lead, d = x.shape
    scales = compute_scales(x, cfg)  # (*lead, d//B)
    xb = x.reshape(*lead, d // B, B)
    z = xb / scales[..., None].astype(x.dtype)
    grid = np.asarray(cfg.element.grid)
    if cfg.stochastic and key is not None:
        q = _snap_stochastic(z, grid, key)
    else:
        q = _snap_to_grid(z, grid)
    out = q * scales[..., None].astype(x.dtype)
    return out.reshape(*lead, d)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def quantize_ste(x: jnp.ndarray, fmt: str, block_size: int, scale_mode: str):
    cfg = MXConfig(fmt=fmt, block_size=block_size, scale_mode=scale_mode)
    return _quantize_value(x, cfg)


def _q_fwd(x, fmt, block_size, scale_mode):
    return quantize_ste(x, fmt, block_size, scale_mode), None


def _q_bwd(fmt, block_size, scale_mode, _, g):
    # Straight-through: d quantize / dx = I.
    return (g,)


quantize_ste.defvjp(_q_fwd, _q_bwd)


def quantize(x: jnp.ndarray, cfg: MXConfig | None = None, *,
             ste: bool = True, key: jax.Array | None = None) -> jnp.ndarray:
    """MX fake-quantize ``x`` along its last axis. STE-differentiable."""
    cfg = cfg or MXConfig()
    if cfg.stochastic and key is not None:
        return _quantize_value(x, cfg, key)
    if ste:
        return quantize_ste(x, cfg.fmt, cfg.block_size, cfg.scale_mode)
    return _quantize_value(x, cfg)


def quantization_mse(x: jnp.ndarray, cfg: MXConfig | None = None) -> jnp.ndarray:
    """Mean squared quantization error of x under cfg (Definition 3.2 with
    T = identity)."""
    cfg = cfg or MXConfig()
    q = _quantize_value(x, cfg)
    return jnp.mean((x - q) ** 2)


def blockwise_error(x: jnp.ndarray, q: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Per-MX-block squared error E_B^i (Sec. 3.1 numerical analysis)."""
    *lead, d = x.shape
    e = ((x - q) ** 2).reshape(*lead, d // block_size, block_size)
    return jnp.mean(e, axis=(-1,) + tuple(range(len(lead))))


# ---------------------------------------------------------------------------
# Packed-code path (used by kernels & serving): uint8 codes + fp32 scales
# ---------------------------------------------------------------------------

def encode(x: jnp.ndarray, cfg: MXConfig | None = None):
    """Quantize and return (codes uint8, scales fp32).

    Codes index the *full* symmetric grid: code = index into
    ``full_grid()`` (length 2*len(grid)-1), so decoding is a table lookup.
    """
    cfg = cfg or MXConfig()
    B = cfg.block_size
    *lead, d = x.shape
    scales = compute_scales(x, cfg)
    xb = x.reshape(*lead, d // B, B)
    z = (xb / scales[..., None].astype(x.dtype)).reshape(*lead, d)
    # magnitude-symmetric code (matches _snap_to_grid tie behaviour and the
    # Pallas kernels): code = center ± halfgrid_index(|z|)
    g = jnp.asarray(cfg.element.grid, dtype=jnp.float32)
    mids = (g[1:] + g[:-1]) / 2.0
    zf = z.astype(jnp.float32)
    idx = jnp.searchsorted(mids, jnp.abs(zf), side="right")
    center = len(cfg.element.grid) - 1
    codes = center + jnp.where(zf < 0, -idx, idx)
    return codes.astype(jnp.uint8), scales


@functools.lru_cache(maxsize=None)
def _full_grid_np(fmt: str) -> np.ndarray:
    """Cached full symmetric grid (decode LUT) per element format."""
    return FORMATS[fmt].full_grid()


def decode(codes: jnp.ndarray, scales: jnp.ndarray,
           cfg: MXConfig | None = None, dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of ``encode``: one LUT gather (``jnp.take``) + a per-block
    scale multiply — the whole dequant cost of the fast fallback path."""
    cfg = cfg or MXConfig()
    B = cfg.block_size
    full = jnp.asarray(_full_grid_np(cfg.fmt), dtype=dtype)
    vals = jnp.take(full, codes.astype(jnp.int32), axis=0)
    *lead, d = vals.shape
    vb = vals.reshape(*lead, d // B, B) * scales[..., None].astype(dtype)
    return vb.reshape(*lead, d)


def packed_nbytes(shape: Sequence[int], cfg: MXConfig | None = None) -> int:
    """Deployable byte count: 4-bit packed codes + 1 byte scale per block.

    Used for roofline memory terms (the uint8 layout is only for the CPU
    interpreter)."""
    cfg = cfg or MXConfig()
    n = int(np.prod(shape))
    code_bytes = n * cfg.element.bits // 8
    scale_bytes = n // cfg.block_size  # E8M0 shared exponent = 1 byte
    return code_bytes + scale_bytes
