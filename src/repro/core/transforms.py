"""Invertible affine transformations for outlier diffusion (Section 3.2).

Row convention: activations are rows, ``T(X) = X @ A + v`` with
``A in R^{d x d}``; ``T^{-1}(Y) = Y @ A^{-1} - v @ A^{-1}`` (Appendix B uses
the same convention for multi-token inputs).

Two free-form parameterizations of ``A``:

  LU (Eq. 5):  A = P · L · (U + diag(s))       — P fixed permutation,
               L unit-lower-triangular, U strictly-upper, s = sign ⊙ e^{logs}
  QR (Eq. 6):  A = exp(½(G − Gᵀ)) · (R + diag(s))

plus restricted families used as baselines / ablations:

  - orthogonal-only (learn G, fix R=0, s=1)   → SpinQuant-like learned
    rotation with unconstrained optimization (matrix exponential instead of
    Stiefel-manifold steps),
  - invertible-only (LU with v frozen at 0)    → "Learned Inv. Matrix",
  - fixed random/block Hadamard                → QuaRot / MR-GPTQ,
  - Kronecker product of two small matrices    → FlatQuant's structure.

Volume regularizer (Eq. 7, stable log form): L_vol = (Σ_i log|s_i|)².
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Hadamard / orthogonal constructions
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _hadamard_np(n: int) -> np.ndarray:
    """Sylvester construction, cached: the np.block doubling loop runs once
    per size instead of on every ffn_down call (T3 is on the serving hot
    path — decode rebuilds it every step otherwise)."""
    if n & (n - 1) != 0:
        raise ValueError(f"Hadamard size must be a power of 2, got {n}")
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h / np.sqrt(n)


def hadamard_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Sylvester-construction Hadamard matrix, scaled to be orthogonal.

    Requires n to be a power of two (all our widths/blocks are)."""
    return jnp.asarray(_hadamard_np(n), dtype=dtype)


def random_hadamard(key: jax.Array, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """H · diag(random ±1): a random orthogonal matrix with Hadamard
    incoherence (QuIP#/QuaRot construction)."""
    signs = jax.random.rademacher(key, (n,), dtype=dtype)
    return hadamard_matrix(n, dtype) * signs[None, :]


def random_orthogonal(key: jax.Array, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Haar-random orthogonal via QR of a Gaussian."""
    g = jax.random.normal(key, (n, n), dtype=jnp.float32)
    q, r = jnp.linalg.qr(g)
    q = q * jnp.sign(jnp.diagonal(r))[None, :]
    return q.astype(dtype)


def block_diagonal(blocks: jnp.ndarray) -> jnp.ndarray:
    """(nb, b, b) stack -> (nb*b, nb*b) block-diagonal matrix."""
    nb, b, _ = blocks.shape
    eye = jnp.eye(nb, dtype=blocks.dtype)
    # (nb, nb, b, b) -> (nb*b, nb*b)
    full = jnp.einsum("ij,ibc->ibjc", eye, blocks)
    return full.reshape(nb * b, nb * b)


def block_diag_init(key: jax.Array, d: int, block: int, kind: str = "hadamard",
                    noise: float = 1e-3, dtype=jnp.float32) -> jnp.ndarray:
    """Block-diagonal rotation init + small off-block Gaussian noise
    (Appendix E.2's best rows: BD Hadamard + Noise / BD Orthogonal + Noise).
    """
    nb = d // block
    keys = jax.random.split(key, nb + 1)
    if kind == "hadamard":
        blocks = jnp.stack([random_hadamard(keys[i], block, dtype)
                            for i in range(nb)])
    elif kind == "orthogonal":
        blocks = jnp.stack([random_orthogonal(keys[i], block, dtype)
                            for i in range(nb)])
    elif kind == "identity":
        blocks = jnp.tile(jnp.eye(block, dtype=dtype)[None], (nb, 1, 1))
    else:
        raise ValueError(kind)
    a = block_diagonal(blocks)
    if noise > 0:
        off = jax.random.normal(keys[-1], (d, d), dtype=dtype) * noise
        mask = 1.0 - block_diagonal(
            jnp.ones((nb, block, block), dtype=dtype))
        a = a + off * mask
    return a


def apply_blockwise(x: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Multiply the last axis of x by block-diagonal(h) without materializing
    the full matrix: x (..., d), h (b, b), d % b == 0.

    This is the online T3 op (block Hadamard before the down projection)."""
    b = h.shape[0]
    *lead, d = x.shape
    xb = x.reshape(*lead, d // b, b)
    yb = jnp.einsum("...kb,bc->...kc", xb, h.astype(x.dtype))
    return yb.reshape(*lead, d)


# ---------------------------------------------------------------------------
# Parameterizations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformSpec:
    """What family of transformation to learn.

    kind:   'lu' | 'qr' | 'orthogonal' | 'invertible' | 'hadamard' |
            'block_hadamard' | 'identity' | 'kron'
    d:      dimension
    learn_bias: include the affine shift v (Aff(d) vs GL(d))
    block:  MX block size (for block-diagonal variants & init)
    init:   'bd_hadamard' | 'bd_orthogonal' | 'identity' | 'hadamard' |
            'orthogonal'
    """

    kind: str = "lu"
    d: int = 0
    learn_bias: bool = True
    block: int = 32
    init: str = "bd_hadamard"
    init_noise: float = 1e-3
    granularity: str = "full"   # 'full' | 'block' (block-diagonal learnable,
    #                             the MR-GPTQ/BRQ restriction — Table 2)


def _init_matrix(key: jax.Array, spec: TransformSpec) -> jnp.ndarray:
    d, b = spec.d, min(spec.block, spec.d)
    if spec.init == "bd_hadamard":
        return block_diag_init(key, d, b, "hadamard", spec.init_noise)
    if spec.init == "bd_orthogonal":
        return block_diag_init(key, d, b, "orthogonal", spec.init_noise)
    if spec.init == "identity":
        return block_diag_init(key, d, b, "identity", spec.init_noise)
    if spec.init == "hadamard":
        return random_hadamard(key, d)
    if spec.init == "orthogonal":
        return random_orthogonal(key, d)
    raise ValueError(spec.init)


def init_params(key: jax.Array, spec: TransformSpec) -> Params:
    """Initialize learnable parameters + fixed buffers for ``spec``.

    Learnable leaves sit under 'learn'; fixed buffers under 'fixed'.
    """
    d = spec.d
    if spec.granularity == "block" and spec.kind in ("lu", "qr", "orthogonal",
                                                     "invertible",
                                                     "orth_scale"):
        nb = d // spec.block
        sub = dataclasses.replace(spec, d=spec.block, granularity="full",
                                  init=spec.init.replace("bd_", ""))
        keys = jax.random.split(key, nb)
        per = [init_params(keys[i], sub) for i in range(nb)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        if spec.learn_bias:
            # learn one full-width bias (cheap; block-local A)
            stacked["learn"]["v_full"] = jnp.zeros((d,), jnp.float32)
        return stacked
    k_mat, k_misc = jax.random.split(key)

    if spec.kind in ("hadamard", "identity"):
        a0 = (random_hadamard(k_mat, d) if spec.kind == "hadamard"
              else jnp.eye(d))
        return {"learn": {}, "fixed": {"A": a0}}

    if spec.kind == "block_hadamard":
        a0 = block_diag_init(k_mat, d, min(spec.block, d), "hadamard", 0.0)
        return {"learn": {}, "fixed": {"A": a0}}

    a0 = np.asarray(_init_matrix(k_mat, spec), dtype=np.float64)

    if spec.kind in ("lu", "invertible"):
        import scipy.linalg as sla
        p, l, u = sla.lu(a0)
        s = np.diagonal(u).copy()
        learn = {
            "L": jnp.asarray(np.tril(l, -1), jnp.float32),
            "U": jnp.asarray(np.triu(u, 1), jnp.float32),
            "logs": jnp.asarray(np.log(np.abs(s) + 1e-12), jnp.float32),
        }
        fixed = {
            "perm": jnp.asarray(np.argmax(p, axis=1), jnp.int32),
            "sign": jnp.asarray(np.sign(s), jnp.float32),
        }
    elif spec.kind in ("qr", "orthogonal", "orth_scale"):
        import scipy.linalg as sla
        q, r = np.linalg.qr(a0)
        # ensure det(q) = +1 so the real matrix log exists & is skew
        detq = np.linalg.det(q)
        if detq < 0:
            q[:, 0] *= -1.0
            r[0, :] *= -1.0
        g = np.real(sla.logm(q))
        g = (g - g.T)  # exact skew; materialize uses exp(0.5(G - G^T))
        s = np.diagonal(r).copy()
        learn = {"G": jnp.asarray(g, jnp.float32)}
        fixed = {"sign": jnp.asarray(np.sign(s), jnp.float32)}
        if spec.kind == "qr":
            learn["R"] = jnp.asarray(np.triu(r, 1), jnp.float32)
            learn["logs"] = jnp.asarray(np.log(np.abs(s) + 1e-12), jnp.float32)
        elif spec.kind == "orth_scale":
            # OSTQuant-style: orthogonal Q × learned diagonal scaling
            fixed["R"] = jnp.zeros((d, d), jnp.float32)
            learn["logs"] = jnp.zeros((d,), jnp.float32)
            fixed["sign"] = jnp.ones((d,), jnp.float32)
        else:  # orthogonal-only: R=0, s=1 fixed
            fixed["R"] = jnp.zeros((d, d), jnp.float32)
            fixed["logs"] = jnp.zeros((d,), jnp.float32)
            fixed["sign"] = jnp.ones((d,), jnp.float32)
    elif spec.kind == "kron":
        # FlatQuant structure: A = A1 ⊗ A2 with d = d1*d2, d1,d2 ~ sqrt(d)
        d1 = _near_sqrt_factor(d)
        d2 = d // d1
        learn = {
            "K1": jnp.asarray(np.eye(d1), jnp.float32),
            "K2": jnp.asarray(np.eye(d2), jnp.float32),
        }
        fixed = {}
    else:
        raise ValueError(spec.kind)

    if spec.learn_bias and spec.kind != "kron":
        learn["v"] = jnp.zeros((d,), jnp.float32)
    elif spec.learn_bias and spec.kind == "kron":
        learn["v"] = jnp.zeros((d,), jnp.float32)
    return {"learn": learn, "fixed": fixed}


def _near_sqrt_factor(d: int) -> int:
    best = 1
    for f in range(1, int(np.sqrt(d)) + 1):
        if d % f == 0:
            best = f
    return best


def materialize(params: Params, spec: TransformSpec):
    """Build (A, v) from parameters. Differentiable."""
    learn, fixed = params["learn"], params["fixed"]
    d = spec.d
    if spec.granularity == "block" and spec.kind in ("lu", "qr", "orthogonal",
                                                     "invertible",
                                                     "orth_scale"):
        sub = dataclasses.replace(spec, d=spec.block, granularity="full")
        v_full = learn.get("v_full", jnp.zeros((d,), jnp.float32))
        inner = {"learn": {k: v_ for k, v_ in learn.items()
                           if k != "v_full"},
                 "fixed": fixed}
        blocks, _ = jax.vmap(lambda p: materialize(p, sub))(inner)
        return block_diagonal(blocks), v_full
    v = learn.get("v", jnp.zeros((d,), jnp.float32))

    if spec.kind in ("hadamard", "identity", "block_hadamard"):
        return fixed["A"], v

    if spec.kind in ("lu", "invertible"):
        eye = jnp.eye(d, dtype=jnp.float32)
        l = jnp.tril(learn["L"], -1) + eye
        s = fixed["sign"] * jnp.exp(learn["logs"])
        u = jnp.triu(learn["U"], 1) + jnp.diag(s)
        a = (l @ u)[fixed["perm"], :]  # P @ (L @ U): row permutation
        return a, v

    if spec.kind in ("qr", "orthogonal", "orth_scale"):
        g = learn["G"]
        skew = 0.5 * (g - g.T)
        q = jax.scipy.linalg.expm(skew)
        r_off = learn.get("R", fixed.get("R"))
        logs = learn.get("logs", fixed.get("logs"))
        sign = fixed["sign"]
        r = jnp.triu(r_off, 1) + jnp.diag(sign * jnp.exp(logs))
        return q @ r, v

    if spec.kind == "kron":
        a = jnp.kron(learn["K1"], learn["K2"])
        return a, v

    raise ValueError(spec.kind)


def inverse(a: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.inv(a.astype(jnp.float32))


def loss_vol(params: Params, spec: TransformSpec) -> jnp.ndarray:
    """Volume-preserving regularizer (Eq. 7, log form):
    (Σ_i log|s_i|)² — shares minima with (∏|s_i| − 1)² but stable."""
    learn = params["learn"]
    if "logs" in learn:
        return jnp.sum(learn["logs"]) ** 2
    if spec.kind == "kron":
        # |det(A1⊗A2)| = |det A1|^{d2} |det A2|^{d1}
        s1 = jnp.linalg.slogdet(learn["K1"])[1]
        s2 = jnp.linalg.slogdet(learn["K2"])[1]
        d1, d2 = learn["K1"].shape[0], learn["K2"].shape[0]
        return (d2 * s1 + d1 * s2) ** 2
    return jnp.asarray(0.0, jnp.float32)


def diag_reg(params: Params) -> jnp.ndarray:
    """Secondary regularizer (Appendix D.1): keep diag entries near one."""
    learn = params["learn"]
    if "logs" in learn:
        return jnp.sum(learn["logs"] ** 2)
    return jnp.asarray(0.0, jnp.float32)


# ---------------------------------------------------------------------------
# Application helpers
# ---------------------------------------------------------------------------

def forward(x: jnp.ndarray, a: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """T(x) = x @ A + v (rows)."""
    return x @ a.astype(x.dtype) + v.astype(x.dtype)


def backward(y: jnp.ndarray, a_inv: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """T^{-1}(y) = (y - v) @ A^{-1}."""
    return (y - v.astype(y.dtype)) @ a_inv.astype(y.dtype)


def transform_mse(x: jnp.ndarray, a: jnp.ndarray, v: jnp.ndarray,
                  mx_cfg) -> jnp.ndarray:
    """Definition 3.2: E(T) = 1/d E||x − T⁻¹(Q(T(x)))||² (for analysis)."""
    from . import mx as mxlib
    y = forward(x, a, v)
    q = mxlib.quantize(y, mx_cfg, ste=False)
    back = backward(q, inverse(a), v)
    return jnp.mean(jnp.sum((x - back) ** 2, axis=-1) / x.shape[-1])


def orthogonality_deviation(a: jnp.ndarray) -> jnp.ndarray:
    """Fig. 3a metric: ||AᵀA − I||_σ."""
    d = a.shape[0]
    m = a.T @ a - jnp.eye(d, dtype=a.dtype)
    return jnp.linalg.norm(m, ord=2)


def offblock_norm(a: jnp.ndarray, block: int) -> jnp.ndarray:
    """Fig. 3b metric: spectral norm of A with block-diagonal zeroed."""
    d = a.shape[0]
    nb = d // block
    mask = 1.0 - np.kron(np.eye(nb), np.ones((block, block)))
    return jnp.linalg.norm(a * jnp.asarray(mask, a.dtype), ord=2)
