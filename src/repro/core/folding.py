"""Transformation folding (Appendix C), row convention ``y = x @ W + b``.

A ``TransformSet`` carries the learned transformations:

  A1 (d, d), v1 (d,)          — global residual-stream transform T1
  A2 (L, Dh, Dh), v2 (L, Dh)  — per-layer per-head value transform T2
  t3_block                    — online block-Hadamard size (inverse folded
                                into the down projection here)

Role helpers (each exact, differentiable — the LATMiX student *is* the
folded network, so gradients flow through these into Ω):

  read:      W ← A1⁻¹ W,  b ← b − v1 @ (A1⁻¹ W)        (Eq. 30)
  write:     W ← W A1,    b ← b @ A1                     (Eq. 31)
  embed:     W_e ← W_e A1 + v1                           (Eq. 32)
  value:     per-head  W_V ← (A1⁻¹ W_V) A2 (+v2)         (Eq. 33)
  attn_out:  per-head  W_O ← A2⁻¹ W_O, then · A1; bias −v2 correction
                                                         (Eq. 34)
  t3:        W_down ← blockdiag(H)ᵀ W_down (runtime applies H online)
  head:      = read (the LM head reads the stream through the final norm)

RMSNorm γ's are folded into their adjacent linears *before* any of this
(``fold_norm_into``) so the norms are scale-free and the stream algebra is
exact up to the (relaxed, distillation-compensated) norm non-commutation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import transforms as tfm


@dataclasses.dataclass
class TransformSet:
    a1: jnp.ndarray                     # (d, d)
    v1: jnp.ndarray                     # (d,)
    a2: Optional[jnp.ndarray] = None    # (L, Dh, Dh)
    v2: Optional[jnp.ndarray] = None    # (L, Dh)
    t3_block: int = 32

    @property
    def a1_inv(self) -> jnp.ndarray:
        return tfm.inverse(self.a1)

    def a2_inv(self) -> jnp.ndarray:
        return jax.vmap(tfm.inverse)(self.a2)


def identity_set(d: int, n_layers: int, head_dim: int,
                 t3_block: int = 32) -> TransformSet:
    return TransformSet(
        a1=jnp.eye(d, dtype=jnp.float32),
        v1=jnp.zeros((d,), jnp.float32),
        a2=jnp.tile(jnp.eye(head_dim, dtype=jnp.float32)[None],
                    (n_layers, 1, 1)),
        v2=jnp.zeros((n_layers, head_dim), jnp.float32),
        t3_block=t3_block,
    )


# ---------------------------------------------------------------------------
# Norm folding (exact)
# ---------------------------------------------------------------------------

def fold_norm_into(gamma: jnp.ndarray, *ws: jnp.ndarray):
    """Return (ones_like(gamma), [diag(γ) @ W ...]) — exact rewrite of
    ``rmsnorm(x)*γ @ W``. Supports stacked (L, d, out) weights with
    stacked (L, d) gammas."""
    new_ws = []
    for w in ws:
        if w.ndim == gamma.ndim + 1:
            new_ws.append(w * gamma[..., :, None].astype(w.dtype))
        else:
            raise ValueError(f"shape mismatch {gamma.shape} vs {w.shape}")
    return jnp.ones_like(gamma), new_ws


# ---------------------------------------------------------------------------
# Role folds. All support an optional leading layer axis via vmap.
# ---------------------------------------------------------------------------

def fold_read(w: jnp.ndarray, b: Optional[jnp.ndarray],
              a1_inv: jnp.ndarray, v1: jnp.ndarray):
    """W (…, d, out) ← A1⁻¹ W;  b ← b − v1 @ (A1⁻¹ W)."""
    def one(wl):
        wt = a1_inv.astype(wl.dtype) @ wl
        return wt
    wt = _map_layers(one, w, a1_inv.ndim)
    corr = jnp.einsum("d,...do->...o", v1.astype(wt.dtype), wt)
    bt = (-corr) if b is None else (b - corr)
    return wt, bt


def fold_write(w: jnp.ndarray, b: Optional[jnp.ndarray], a1: jnp.ndarray):
    """W (…, in, d) ← W A1;  b ← b @ A1."""
    wt = w @ a1.astype(w.dtype)
    bt = None if b is None else b @ a1.astype(b.dtype)
    return wt, bt


def fold_embed(w_e: jnp.ndarray, a1: jnp.ndarray, v1: jnp.ndarray):
    """(V, d) table ← W_e A1 + v1 per row."""
    return w_e @ a1.astype(w_e.dtype) + v1.astype(w_e.dtype)[None, :]


def fold_value(w_v: jnp.ndarray, b_v: Optional[jnp.ndarray],
               a1_inv: jnp.ndarray, v1: jnp.ndarray,
               a2: jnp.ndarray, v2: jnp.ndarray, n_kv: int):
    """Value projection: stream-read fold then per-head T2.

    w_v: (…, d, n_kv*Dh). Returns same shape; bias gains +v2 per head."""
    wt, bt = fold_read(w_v, b_v, a1_inv, v1)
    *lead, d, kd = wt.shape
    dh = kd // n_kv
    wh = wt.reshape(*lead, d, n_kv, dh)
    wh = jnp.einsum("...dkh,...hj->...dkj", wh, a2.astype(wh.dtype))
    wt = wh.reshape(*lead, d, kd)
    bh = bt.reshape(*lead, n_kv, dh)
    bh = jnp.einsum("...kh,...hj->...kj", bh, a2.astype(bh.dtype))
    bh = bh + v2[..., None, :].astype(bh.dtype)
    return wt, bh.reshape(*lead, kd)


def fold_attn_out(w_o: jnp.ndarray, b_o: Optional[jnp.ndarray],
                  a1: jnp.ndarray, a2_inv: jnp.ndarray, v2: jnp.ndarray,
                  n_heads: int):
    """Output projection: per-head T2⁻¹, then stream-write fold (Eq. 34).

    w_o: (…, n_heads*Dh, d)."""
    *lead, hd, d = w_o.shape
    dh = hd // n_heads
    wh = w_o.reshape(*lead, n_heads, dh, d)
    wh = jnp.einsum("...ij,...kjd->...kid", a2_inv.astype(wh.dtype), wh)
    # bias correction: each head's value stream carries +v2 (softmax rows
    # sum to one, Appendix B), removed here: − Σ_h v2 @ (A2⁻¹ W_O[h]);
    # note wh already holds A2⁻¹ W_O[h].
    corr = jnp.einsum("...j,...kjd->...d", v2.astype(wh.dtype), wh)
    wt = wh.reshape(*lead, hd, d)
    b0 = (-corr) if b_o is None else (b_o - corr)
    return fold_write(wt, b0, a1)


def fold_t3(w_down: jnp.ndarray, block: int):
    """W_down (…, f, d) ← blockdiag(H_b)ᵀ W_down.

    Runtime then computes (x·blockdiag(H)) @ W̃ = x @ W — exact since H is
    orthogonal. This moves the outlier-diffusing rotation of the down-proj
    *input* online (T3) while its inverse is free (folded here)."""
    h = tfm.hadamard_matrix(block, dtype=w_down.dtype)
    *lead, f, d = w_down.shape
    wb = w_down.reshape(*lead, f // block, block, d)
    # W̃_block = Hᵀ @ W_block  (runtime applies x_block @ H, H orthogonal)
    wb = jnp.einsum("jb,...kjd->...kbd", h, wb)
    return wb.reshape(*lead, f, d)


def _map_layers(fn, w, base_ndim):
    """Apply fn to (d, out) matrices, vmapping over any leading axes."""
    extra = w.ndim - 2
    for _ in range(extra):
        fn = jax.vmap(fn)
    return fn(w)
