"""End-to-end PTQ pipeline — every method evaluated in the paper, under one
interface:

    qparams, qm, info = apply_method(method, params, cfg, calib, fmt)

Methods (Table 1 / Table 2 / Table 6 rows):
  'fp'              no quantization (teacher)
  'rtn'             MX RTN on weights+acts, no transform
  'gptq'            MX GPTQ on weights, acts RTN, no transform
  'quarot'          fixed full random-Hadamard T1/T2 (+GPTQ)
  'quarot-rtn'      same transform, RTN weights
  'block_hadamard'  fixed block-diagonal Hadamard (MR-GPTQ/BRQ structure)
  'spinquant'       learned orthogonal T1/T2 (CE loss, per App. D.2)
  'ostquant'        learned orthogonal × diagonal scaling (OSTQuant-style)
  'flatquant'       learned Kronecker-structured invertible T1 (+distill)
  'inv'             learned invertible (LU, no bias) — "Learned Inv. Matrix"
  'latmix-lu'       LATMiX, LU parameterization (Eq. 5)
  'latmix-qr'       LATMiX, QR parameterization (Eq. 6)
  '*-block'         any learned method at block granularity (Table 2)

All transform-based methods share the same pipeline (fold norms -> learn or
fix Ω -> fold -> weight quant), exactly as the paper's fair-comparison
setup."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import gptq as gptq_lib
from repro.core import latmix as lx_lib
from repro.core import mx as mxlib
from repro.core.quantize import QuantMode
from repro.models import api

METHODS = ["fp", "rtn", "gptq", "quarot", "quarot-rtn", "block_hadamard",
           "spinquant", "ostquant", "flatquant", "inv", "latmix-lu",
           "latmix-qr"]


@dataclasses.dataclass
class PTQResult:
    params: dict
    qm: QuantMode
    tset: Optional[object]
    history: list
    method: str

    def export(self, cfg: ArchConfig, out_dir, **kw):
        """Persist as a deployable packed artifact directory (see
        repro.artifacts): calibrate once, export, serve many times."""
        from repro.artifacts import export_artifact  # deferred: no cycle
        return export_artifact(self, cfg, out_dir, **kw)


def _mx_cfg(fmt: str) -> mxlib.MXConfig:
    if fmt == "nvfp4":
        return mxlib.NVFP4
    return mxlib.MXConfig(fmt=fmt, block_size=32)


def _lat_cfg(method: str, fmt: str, steps: int, block: bool) -> lx_lib.LatmixConfig:
    gran = "block" if block else "full"
    c = _mx_cfg(fmt)
    base = dict(act_fmt=c.fmt, block_size=c.block_size,
                scale_mode=c.scale_mode, steps=steps, granularity=gran)
    if method == "quarot" or method == "quarot-rtn":
        return lx_lib.LatmixConfig(kind="hadamard", learn_bias=False, **base)
    if method == "block_hadamard":
        return lx_lib.LatmixConfig(kind="block_hadamard", learn_bias=False,
                                   **base)
    if method == "spinquant":
        return lx_lib.LatmixConfig(kind="orthogonal", learn_bias=False,
                                   loss="ce", **base)
    if method == "ostquant":
        # OSTQuant (Hu et al. 2025): orthogonal + scaling transformations
        return lx_lib.LatmixConfig(kind="orth_scale", learn_bias=False,
                                   **base)
    if method == "flatquant":
        return lx_lib.LatmixConfig(kind="kron", learn_bias=True, **base)
    if method == "inv":
        return lx_lib.LatmixConfig(kind="invertible", learn_bias=False,
                                   **base)
    if method == "latmix-lu":
        return lx_lib.LatmixConfig(kind="lu", learn_bias=True, **base)
    if method == "latmix-qr":
        return lx_lib.LatmixConfig(kind="qr", learn_bias=True, **base)
    raise ValueError(method)


def apply_method(method: str, params, cfg: ArchConfig, calib: List[dict],
                 fmt: str = "mxfp4", steps: int = 120,
                 weight_quant: str = "gptq", log=None) -> PTQResult:
    block = method.endswith("-block")
    base_method = method[:-6] if block else method
    mxcfg = _mx_cfg(fmt)

    if base_method == "fp":
        return PTQResult(params, QuantMode.off(), None, [], method)

    if base_method in ("rtn", "gptq"):
        qm = QuantMode(enabled=True, act_cfg=mxcfg, weight_cfg=None,
                       t3_block=0)
        if base_method == "rtn" or cfg.family != "dense":
            qp = gptq_lib.quantize_weights_rtn(params, cfg, mxcfg)
        else:
            stats = gptq_lib.capture_hessians(params, cfg, calib, qm)
            qp = gptq_lib.quantize_weights_gptq(params, cfg, stats, mxcfg,
                                                t3_block=0)
        return PTQResult(qp, qm, None, [], method)

    # ---- transform-based methods ----
    lx = _lat_cfg(base_method, fmt, steps, block)
    pn = api.fold_norms(params, cfg)
    omega, tset, hist = lx_lib.learn_transforms(pn, cfg, lx, calib, log=log)
    folded = api.fold(pn, cfg, tset)
    qm = QuantMode(enabled=True, act_cfg=mxcfg, weight_cfg=None,
                   t3_block=lx.t3_block)
    wq = weight_quant
    if base_method == "quarot-rtn":
        wq = "rtn"
    if wq == "gptq" and cfg.family == "dense":
        stats = gptq_lib.capture_hessians(folded, cfg, calib, qm)
        qp = gptq_lib.quantize_weights_gptq(folded, cfg, stats, mxcfg,
                                            t3_block=lx.t3_block)
    else:
        qp = gptq_lib.quantize_weights_rtn(folded, cfg, mxcfg)
    return PTQResult(qp, qm, tset, hist, method)


def eval_ppl(result: PTQResult, cfg: ArchConfig, tokens) -> float:
    return api.perplexity(result.params, cfg, tokens, result.qm)


def zero_shot_proxy(result: PTQResult, cfg: ArchConfig, eval_batches,
                    n_choices: int = 4, seed: int = 0,
                    teacher_logits=None) -> float:
    """Multiple-choice proxy for the zero-shot suites: rank the true next
    token against hard negatives. Distractors are drawn from the *teacher's*
    top predictions at each position (method-independent hard negatives),
    falling back to uniform sampling when no teacher is given — the hard
    variant keeps the metric below ceiling so method differences show."""
    import numpy as np
    rng = np.random.default_rng(seed)
    correct = total = 0
    for bi, b in enumerate(eval_batches):
        toks = b["inputs"]
        logits = api.forward(result.params, cfg, jnp.asarray(toks),
                             result.qm)
        lp = np.asarray(jax.nn.log_softmax(logits.astype(jnp.float32),
                                           axis=-1))
        labels = np.asarray(b["labels"])
        B, S = labels.shape
        pos = rng.integers(S // 2, S, size=(B, 4))
        tl = (np.asarray(teacher_logits[bi])
              if teacher_logits is not None else None)
        for i in range(B):
            for t in pos[i]:
                t = int(t)
                gold = labels[i, t]
                if tl is not None:
                    top = np.argsort(-tl[i, t])[:n_choices + 2]
                    distract = [c for c in top if c != gold][:n_choices - 1]
                    distract = np.asarray(distract)
                else:
                    distract = rng.choice(cfg.vocab_size,
                                          size=n_choices - 1)
                cand = np.concatenate([[gold], distract])
                scores = lp[i, t, cand]
                correct += int(np.argmax(scores) == 0)
                total += 1
    return correct / max(total, 1)
