"""Quantized execution mode: MX fake-quant linears + online T3 transform.

Model code routes every matmul through :func:`qlinear`. A ``QuantMode``
threads through the model and decides, per call-site role, whether the
activation and/or weight is MX-fake-quantized (STE-differentiable, so the
same path serves LATMiX transform learning and quantized evaluation).

Roles (mirroring the paper's Fig. 5 placement):
  'qkv', 'attn_out', 'ffn_in', 'router', 'head', 'ssm_in', 'ssm_out', ...
  'ffn_down'  — the one call-site with the *online* T3 block-Hadamard.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.kernels.packing import maybe_dense

from . import mx as mxlib
from . import transforms as tfm


@dataclasses.dataclass(frozen=True)
class QuantMode:
    """How to execute linears.

    enabled=False           -> pure FP path (training / teacher).
    act_cfg / weight_cfg    -> MXConfig for activations / weights
                               (weight_cfg=None => FP weights: transform-
                               learning stage quantizes activations only).
    t3_block                -> online block-Hadamard size before ffn_down
                               (0 disables T3). Applied whenever nonzero —
                               also in FP mode — because its inverse is
                               folded into the weights offline; a folded
                               model must run with the matching t3_block.
    quantize_head           -> whether the LM head matmul is quantized
                               (papers keep head/embeddings FP; default off).
    """

    enabled: bool = False
    act_cfg: Optional[mxlib.MXConfig] = None
    weight_cfg: Optional[mxlib.MXConfig] = None
    t3_block: int = 0
    quantize_head: bool = False

    @staticmethod
    def off(t3: int = 0) -> "QuantMode":
        return QuantMode(enabled=False, t3_block=t3)

    @staticmethod
    def mxfp4(weights: bool = True, t3: bool = True) -> "QuantMode":
        c = mxlib.MXConfig(fmt="mxfp4", block_size=32)
        return QuantMode(enabled=True, act_cfg=c,
                         weight_cfg=c if weights else None,
                         t3_block=32 if t3 else 0)

    @staticmethod
    def mxint4(weights: bool = True, t3: bool = True) -> "QuantMode":
        c = mxlib.MXConfig(fmt="mxint4", block_size=32)
        return QuantMode(enabled=True, act_cfg=c,
                         weight_cfg=c if weights else None,
                         t3_block=32 if t3 else 0)

    @staticmethod
    def nvfp4(weights: bool = True, t3: bool = True) -> "QuantMode":
        c = mxlib.NVFP4
        return QuantMode(enabled=True, act_cfg=c,
                         weight_cfg=c if weights else None,
                         t3_block=32 if t3 else 0)


def _maybe_quant_act(x: jnp.ndarray, qm: QuantMode) -> jnp.ndarray:
    if qm.enabled and qm.act_cfg is not None:
        return mxlib.quantize(x, qm.act_cfg)
    return x


def _maybe_quant_weight(w: jnp.ndarray, qm: QuantMode) -> jnp.ndarray:
    """Weights are MX-blocked along the contraction (first) axis so the
    GEMM dequantizes per k-block (matching the kernel layout)."""
    if qm.enabled and qm.weight_cfg is not None:
        wt = jnp.swapaxes(w, -1, -2)
        wq = mxlib.quantize(wt, qm.weight_cfg)
        return jnp.swapaxes(wq, -1, -2)
    return w


def qlinear(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray],
            qm: QuantMode, role: str = "") -> jnp.ndarray:
    """y = Q(x) @ Q(w) + b under the quant mode; plain x@w+b otherwise.

    role='ffn_down' additionally applies the online T3 block-Hadamard to the
    activation *before* quantization (its inverse is folded into w offline,
    see core.folding.fold_t3).

    ``w`` may be a :class:`repro.kernels.packing.PackedWeight` (artifact
    serving): it is dequantized here, inside the compiled step, so HBM
    holds only the 4-bit layout."""
    w = maybe_dense(w)
    if qm.t3_block and role == "ffn_down":
        h = tfm.hadamard_matrix(qm.t3_block, dtype=x.dtype)
        x = tfm.apply_blockwise(x, h)
    if role == "head" and not qm.quantize_head:
        y = x @ w
        return y if b is None else y + b
    xq = _maybe_quant_act(x, qm)
    wq = _maybe_quant_weight(w, qm)
    y = xq @ wq
    return y if b is None else y + b


def qeinsum(spec: str, x: jnp.ndarray, w: jnp.ndarray,
            qm: QuantMode, role: str = "") -> jnp.ndarray:
    """Quantized einsum for expert-batched weights, e.g. 'ecd,edf->ecf'.

    Activation is quantized along its last axis; the weight along the
    einsum contraction axis (assumed to be its second-to-last axis).
    ``w`` may be a PackedWeight (see :func:`qlinear`)."""
    w = maybe_dense(w)
    if qm.t3_block and role == "ffn_down":
        h = tfm.hadamard_matrix(qm.t3_block, dtype=x.dtype)
        x = tfm.apply_blockwise(x, h)
    xq = _maybe_quant_act(x, qm)
    wq = _maybe_quant_weight(w, qm)
    return jnp.einsum(spec, xq, wq)
