"""Quantized execution mode: MX linears with a kernel-dispatch backend.

Model code routes every matmul through :func:`qlinear` (or
:func:`qeinsum` for expert-batched weights). A ``QuantMode`` threads
through the model and decides, per call-site role, whether the activation
and/or weight is MX-quantized (STE-differentiable, so the same path serves
LATMiX transform learning and quantized evaluation) — and *how* the matmul
executes:

``backend="ref"`` (default)
    Pure-jnp fake-quant path. A :class:`~repro.kernels.packing.PackedWeight`
    is dequantized in place (one LUT decode — packed weights are already on
    the MX grid, so no re-quantization round-trip) and the GEMM runs dense.
    Differentiable; used for training, transform learning and as the golden
    reference.

``backend="fused"``
    Packed-native execution: when the weight is a ``PackedWeight`` whose
    layout matches the activation config (4-bit packable fmt, 32-blocks,
    pow2 scales) and the call-site quantizes, the matmul dispatches to the
    Pallas kernel :func:`repro.kernels.ops.mx_gemm_packed` — activations
    are flattened ``(B, S, K) -> (M, K)``, quantized on the fly inside the
    kernel, and the 4-bit codes + E8M0 scale bytes are decoded per tile
    (no dense weight is ever materialized). ``role='ffn_down'`` with
    ``t3_block=32`` folds the online T3 block-Hadamard into the kernel's
    activation-quantize prologue. Layer-stacked and expert-stacked (MoE)
    weights are mapped over their leading axes. Anything that does not
    meet the kernel contract — dense weights, non-packable formats, NVFP4
    scales, a non-32 t3 block, unquantized roles like the default LM head —
    falls back to the reference path bit-identically.

Roles (mirroring the paper's Fig. 5 placement):
  'qkv', 'attn_out', 'ffn_in', 'router', 'head', 'ssm_in', 'ssm_out', ...
  'ffn_down'  — the one call-site with the *online* T3 block-Hadamard.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.mx_quant import MXBLOCK
from repro.kernels.packing import KV_FMTS, PackedWeight, maybe_dense

from . import mx as mxlib
from . import transforms as tfm

BACKENDS = ("ref", "fused")


@dataclasses.dataclass(frozen=True)
class KVCacheQuant:
    """How the serving KV cache is stored (see ``docs/kv-cache.md``).

    fmt: MX element format of the stored keys/values — 'mxfp8' / 'mxint8'
    (one code byte per element) or 'mxfp4' / 'mxint4' (nibble-packed).
    Scales are E8M0 bytes per 32-block along the cache feature axis
    (kv_dim; blocks sit inside heads whenever head_dim % 32 == 0).
    ``None`` — i.e. :meth:`parse` of 'none'/'' — keeps the dense fp cache.
    """

    fmt: str = "mxfp8"

    def __post_init__(self):
        if self.fmt not in KV_FMTS:
            raise ValueError(f"unknown KV-cache fmt {self.fmt!r} "
                             f"(expected one of {KV_FMTS} or 'none')")

    @staticmethod
    def parse(spec) -> "Optional[KVCacheQuant]":
        """'mxfp8' -> KVCacheQuant('mxfp8'); None/''/'none' -> None (dense
        cache); an existing KVCacheQuant passes through."""
        if spec is None or isinstance(spec, KVCacheQuant):
            return spec
        if spec in ("", "none", "off", "bf16", "fp"):
            return None
        return KVCacheQuant(fmt=spec)


@dataclasses.dataclass(frozen=True)
class QuantMode:
    """How to execute linears.

    enabled=False           -> pure FP path (training / teacher).
    act_cfg / weight_cfg    -> MXConfig for activations / weights
                               (weight_cfg=None => FP weights: transform-
                               learning stage quantizes activations only).
    t3_block                -> online block-Hadamard size before ffn_down
                               (0 disables T3). Applied whenever nonzero —
                               also in FP mode — because its inverse is
                               folded into the weights offline; a folded
                               model must run with the matching t3_block.
    quantize_head           -> whether the LM head matmul is quantized
                               (papers keep head/embeddings FP; default off).
    backend                 -> 'ref' | 'fused': see module docstring. The
                               backend never changes values beyond fp
                               accumulation order; 'fused' engages only
                               where the packed kernel contract holds and
                               falls back to 'ref' everywhere else.
    """

    enabled: bool = False
    act_cfg: Optional[mxlib.MXConfig] = None
    weight_cfg: Optional[mxlib.MXConfig] = None
    t3_block: int = 0
    quantize_head: bool = False
    backend: str = "ref"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(expected one of {BACKENDS})")

    def with_backend(self, backend: str) -> "QuantMode":
        return dataclasses.replace(self, backend=backend)

    @staticmethod
    def off(t3: int = 0) -> "QuantMode":
        return QuantMode(enabled=False, t3_block=t3)

    @staticmethod
    def mxfp4(weights: bool = True, t3: bool = True,
              backend: str = "ref") -> "QuantMode":
        c = mxlib.MXConfig(fmt="mxfp4", block_size=32)
        return QuantMode(enabled=True, act_cfg=c,
                         weight_cfg=c if weights else None,
                         t3_block=32 if t3 else 0, backend=backend)

    @staticmethod
    def mxint4(weights: bool = True, t3: bool = True,
               backend: str = "ref") -> "QuantMode":
        c = mxlib.MXConfig(fmt="mxint4", block_size=32)
        return QuantMode(enabled=True, act_cfg=c,
                         weight_cfg=c if weights else None,
                         t3_block=32 if t3 else 0, backend=backend)

    @staticmethod
    def nvfp4(weights: bool = True, t3: bool = True) -> "QuantMode":
        c = mxlib.NVFP4
        return QuantMode(enabled=True, act_cfg=c,
                         weight_cfg=c if weights else None,
                         t3_block=32 if t3 else 0)


def _maybe_quant_act(x: jnp.ndarray, qm: QuantMode) -> jnp.ndarray:
    if qm.enabled and qm.act_cfg is not None:
        return mxlib.quantize(x, qm.act_cfg)
    return x


def _maybe_quant_weight(w: jnp.ndarray, qm: QuantMode) -> jnp.ndarray:
    """Weights are MX-blocked along the contraction (first) axis so the
    GEMM dequantizes per k-block (matching the kernel layout)."""
    if qm.enabled and qm.weight_cfg is not None:
        wt = jnp.swapaxes(w, -1, -2)
        wq = mxlib.quantize(wt, qm.weight_cfg)
        return jnp.swapaxes(wq, -1, -2)
    return w


def _cfg_matches_packed(cfg: Optional[mxlib.MXConfig], fmt: str) -> bool:
    return (cfg is not None and cfg.fmt == fmt
            and cfg.block_size == MXBLOCK and cfg.scale_mode == "pow2")


def _packed_on_grid(w, qm: QuantMode) -> bool:
    """A PackedWeight decodes to values already on the MX grid of a
    matching weight_cfg, so the reference path's decode->encode->decode
    round-trip is the identity and can be skipped (bit-exact: pow2-scale
    MX quantization is idempotent — the property the artifact store's
    lossless pack/unpack tests pin down)."""
    return (isinstance(w, PackedWeight)
            and _cfg_matches_packed(qm.weight_cfg, w.fmt))


def _fused_t3(qm: QuantMode, role: str) -> bool:
    return bool(qm.t3_block) and role == "ffn_down"


def _mode_fusable(w, qm: QuantMode, role: str) -> bool:
    """Does (mode, weight, role) meet the packed-kernel contract?"""
    if qm.backend != "fused" or not qm.enabled or qm.act_cfg is None:
        return False
    if not isinstance(w, PackedWeight):
        return False
    if role == "head" and not qm.quantize_head:
        return False  # head stays fp
    a = qm.act_cfg
    if not _cfg_matches_packed(a, w.fmt) or a.stochastic:
        return False
    if qm.weight_cfg is not None and not _cfg_matches_packed(
            qm.weight_cfg, w.fmt):
        return False  # mode would re-quantize to a different grid
    if _fused_t3(qm, role) and qm.t3_block != MXBLOCK:
        return False  # kernel prologue is fixed at 32-wide Hadamard blocks
    k = w.shape[-2]
    return k % MXBLOCK == 0


def _out_dtype(x: jnp.ndarray, w: PackedWeight):
    return jnp.result_type(x.dtype, jnp.dtype(w.dtype))


def _fused_linear(x: jnp.ndarray, w: PackedWeight, b, qm: QuantMode,
                  role: str) -> jnp.ndarray:
    """Flatten (..., K) -> (M, K) and run the packed-native kernel. For a
    stacked weight (*lead, K, N) the leading axes become vmap axes and x
    must be (*lead, M, K) — the reference path's batched-matmul shape."""
    k, n = w.shape[-2], w.shape[-1]
    if w.ndim == 2:
        lead = x.shape[:-1]
        m = int(np.prod(lead)) if lead else 1
        x2 = x.reshape(m, k)
    else:
        lead = x.shape[:-1]
        x2 = x
    y = ops.mx_gemm_packed(x2, w.codes_packed, w.scales_e8m0,
                           w.fmt, t3=_fused_t3(qm, role))
    y = y.reshape(*lead, n).astype(_out_dtype(x, w))
    return y if b is None else y + b


def _fusable_shapes(x: jnp.ndarray, w: PackedWeight) -> bool:
    if x.shape[-1] != w.shape[-2]:
        return False
    if w.ndim == 2:
        return True
    # stacked: x (*lead, M, K) against w (*lead, K, N), lead-for-lead
    return x.ndim == w.ndim and x.shape[:-2] == w.shape[:-2]


def qlinear(x: jnp.ndarray, w: jnp.ndarray, b: Optional[jnp.ndarray],
            qm: QuantMode, role: str = "") -> jnp.ndarray:
    """y = Q(x) @ Q(w) + b under the quant mode; plain x@w+b otherwise.

    Shapes/dtypes: x (..., K) float; w (K, N) — or layer-stacked
    (*lead, K, N) with x (*lead, M, K); b (N,) or None. Returns
    (..., N) in the promoted float dtype of x and w.

    role='ffn_down' additionally applies the online T3 block-Hadamard to the
    activation *before* quantization (its inverse is folded into w offline,
    see core.folding.fold_t3).

    ``w`` may be a :class:`repro.kernels.packing.PackedWeight` (artifact
    serving). Under ``backend='fused'`` it is consumed in its packed HBM
    layout by the Pallas kernel (T3 fused into the kernel prologue);
    otherwise it is dequantized here, inside the compiled step, so HBM
    holds only the 4-bit layout either way."""
    if _mode_fusable(w, qm, role) and _fusable_shapes(x, w):
        ops.record_quant_path("qlinear", "fused", role)
        return _fused_linear(x, w, b, qm, role)
    ops.record_quant_path("qlinear", "ref", role)
    on_grid = _packed_on_grid(w, qm)
    w = maybe_dense(w)
    if _fused_t3(qm, role):
        h = tfm.hadamard_matrix(qm.t3_block, dtype=x.dtype)
        x = tfm.apply_blockwise(x, h)
    if role == "head" and not qm.quantize_head:
        y = x @ w
        return y if b is None else y + b
    xq = _maybe_quant_act(x, qm)
    wq = w if on_grid else _maybe_quant_weight(w, qm)
    y = xq @ wq
    return y if b is None else y + b


def _parse_expert_spec(spec: str):
    """Recognize expert-batched einsums of the shape
    ``(..., E, ..., K), (E, K, N) -> (..., E, ..., N)`` — e.g. the MoE
    dispatch/combine specs 'gecd,edf->gecf' and 'gecf,efd->gecd'.

    Returns (expert-axis position in the activation, activation rank the
    spec demands), or None if the spec does not match the packed-kernel
    contract. Callers must also check the actual x rank so the fused path
    rejects exactly what the reference einsum would reject."""
    try:
        ins, out = spec.replace(" ", "").split("->")
        in1, in2 = ins.split(",")
    except ValueError:
        return None
    if len(in2) != 3 or len(set(in1)) != len(in1):
        return None
    e, k, n = in2
    if in1[-1] != k or e not in in1[:-1] or n in in1:
        return None
    if out != in1[:-1] + n:
        return None
    return in1.index(e), len(in1)


def qeinsum(spec: str, x: jnp.ndarray, w: jnp.ndarray,
            qm: QuantMode, role: str = "") -> jnp.ndarray:
    """Quantized einsum for expert-batched weights, e.g. 'ecd,edf->ecf'.

    Activation is quantized along its last axis; the weight along the
    einsum contraction axis (assumed to be its second-to-last axis).
    ``w`` may be a PackedWeight (see :func:`qlinear`): under
    ``backend='fused'`` the expert axis becomes a vmap (leading grid) axis
    of the packed-native kernel."""
    if _mode_fusable(w, qm, role) and w.ndim == 3:
        parsed = _parse_expert_spec(spec)
        if parsed is not None:
            e_pos, x_rank = parsed
        if (parsed is not None and x.ndim == x_rank
                and x.shape[e_pos] == w.shape[0]
                and x.shape[-1] == w.shape[-2]):
            ops.record_quant_path("qeinsum", "fused", role)
            xe = jnp.moveaxis(x, e_pos, 0)           # (E, *rest, K)
            rest = xe.shape[1:-1]
            m = int(np.prod(rest)) if rest else 1
            y = ops.mx_gemm_packed(
                xe.reshape(w.shape[0], m, w.shape[-2]),
                w.codes_packed, w.scales_e8m0, w.fmt,
                t3=_fused_t3(qm, role))
            y = y.reshape(w.shape[0], *rest, w.shape[-1])
            y = jnp.moveaxis(y, 0, e_pos).astype(_out_dtype(x, w))
            return y
    ops.record_quant_path("qeinsum", "ref", role)
    on_grid = _packed_on_grid(w, qm)
    w = maybe_dense(w)
    if _fused_t3(qm, role):
        h = tfm.hadamard_matrix(qm.t3_block, dtype=x.dtype)
        x = tfm.apply_blockwise(x, h)
    xq = _maybe_quant_act(x, qm)
    wq = w if on_grid else _maybe_quant_weight(w, qm)
    return jnp.einsum(spec, xq, wq)
