"""LATMiX — learning the affine transformations Ω (Section 3.2).

Stage 1 of the PTQ pipeline: with FP weights, learn
  T1 (global, d_model) and T2 (per attention layer, head_dim)
by minimizing  L = KL(f(x) || f̃_Ω(x)) + λ·L_vol  (Eq. 9) over a small
calibration set, where f̃_Ω is the *folded* network (fold is differentiable,
so transforming activations ≡ folding — Appendix C) executed with MX
fake-quantized activations (STE).

The same machinery, restricted, yields the baselines:
  kind='orthogonal'                  -> SpinQuant-like learned rotation
  kind='invertible' (no bias)        -> "Learned Inv. Matrix"
  kind='kron'                        -> FlatQuant's matrix structure
  granularity='block'                -> BRQ/MR-GPTQ-style block-diagonal
  fixed kinds ('hadamard', ...)      -> QuaRot / block-Hadamard (no training)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import mx as mxlib
from repro.core import transforms as tfm
from repro.core.folding import TransformSet
from repro.core.quantize import QuantMode
from repro.models import api
from repro.training import optimizer as opt


@dataclasses.dataclass(frozen=True)
class LatmixConfig:
    kind: str = "lu"                 # transform family (see module doc)
    granularity: str = "full"        # 'full' | 'block'
    learn_bias: bool = True
    learn_t2: bool = True
    act_fmt: str = "mxfp4"
    block_size: int = 32
    scale_mode: str = "pow2"         # 'fp8' => NVFP4 (App. E.6)
    t3_block: int = 32
    steps: int = 150
    lr: float = 1e-3
    weight_decay: float = 1e-4
    lambda_vol: float = 0.1
    lambda_diag: float = 0.1
    temperature: float = 1.5
    loss: str = "kl"                 # 'kl' | 'ce' | 'mse'
    seed: int = 0

    @property
    def trainable(self) -> bool:
        return self.kind not in ("hadamard", "block_hadamard", "identity")


def _n_t2(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_super_blocks
    return cfg.n_layers


def t2_applicable(cfg: ArchConfig) -> bool:
    return cfg.family != "ssm"       # attention-free: no value path


def _specs(cfg: ArchConfig, lx: LatmixConfig):
    init = ("bd_hadamard" if lx.kind in ("lu", "invertible", "kron")
            else "bd_orthogonal")
    s1 = tfm.TransformSpec(kind=lx.kind, d=cfg.d_model,
                           learn_bias=lx.learn_bias, block=lx.block_size,
                           init=init, granularity=lx.granularity)
    s2 = tfm.TransformSpec(kind=lx.kind, d=cfg.head_dim,
                           learn_bias=lx.learn_bias,
                           block=min(lx.block_size, cfg.head_dim),
                           init=init, granularity=lx.granularity)
    return s1, s2


def init_omega(key, cfg: ArchConfig, lx: LatmixConfig):
    s1, s2 = _specs(cfg, lx)
    k1, k2 = jax.random.split(key)
    omega = {"t1": tfm.init_params(k1, s1)}
    if lx.learn_t2 and t2_applicable(cfg):
        n = _n_t2(cfg)
        keys = jax.random.split(k2, n)
        per = [tfm.init_params(keys[i], s2) for i in range(n)]
        omega["t2"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return omega


def materialize_set(omega, cfg: ArchConfig, lx: LatmixConfig) -> TransformSet:
    s1, s2 = _specs(cfg, lx)
    a1, v1 = tfm.materialize(omega["t1"], s1)
    if "t2" in omega:
        a2, v2 = jax.vmap(lambda p: tfm.materialize(p, s2))(omega["t2"])
    else:
        n = _n_t2(cfg)
        a2 = jnp.tile(jnp.eye(cfg.head_dim, dtype=jnp.float32)[None],
                      (n, 1, 1))
        v2 = jnp.zeros((n, cfg.head_dim), jnp.float32)
    return TransformSet(a1=a1, v1=v1, a2=a2, v2=v2, t3_block=lx.t3_block)


def reg_loss(omega, cfg: ArchConfig, lx: LatmixConfig) -> jnp.ndarray:
    s1, s2 = _specs(cfg, lx)
    l = tfm.loss_vol(omega["t1"], s1)
    ld = tfm.diag_reg(omega["t1"])
    if "t2" in omega:
        l = l + jnp.sum(jax.vmap(lambda p: tfm.loss_vol(p, s2))(omega["t2"]))
        ld = ld + jnp.sum(jax.vmap(tfm.diag_reg)(omega["t2"]))
    return lx.lambda_vol * l + lx.lambda_diag * ld


def student_qm(lx: LatmixConfig) -> QuantMode:
    """Stage-1 student: quantized activations, FP weights (Liu et al.)."""
    return QuantMode(enabled=True,
                     act_cfg=mxlib.MXConfig(fmt=lx.act_fmt,
                                            block_size=lx.block_size,
                                            scale_mode=lx.scale_mode),
                     weight_cfg=None, t3_block=lx.t3_block)


def learn_transforms(params, cfg: ArchConfig, lx: LatmixConfig,
                     calib_batches: List[dict],
                     log: Optional[Callable[[str], None]] = None):
    """Run stage 1. ``params`` must already be norm-folded
    (api.fold_norms). Returns (omega, TransformSet, history)."""
    key = jax.random.PRNGKey(lx.seed)
    omega = init_omega(key, cfg, lx)
    qm = student_qm(lx)

    # teacher logits are fixed -> precompute once per calibration batch
    teacher_fn = jax.jit(lambda b: api.forward(params, cfg, b))
    teachers = [jax.device_get(teacher_fn(b["inputs"]))
                for b in calib_batches]

    if not lx.trainable:
        tset = materialize_set(omega, cfg, lx)
        return omega, tset, []

    ocfg = opt.AdamWConfig(lr=lx.lr, weight_decay=lx.weight_decay,
                           warmup_steps=max(1, lx.steps // 10),
                           total_steps=lx.steps, grad_clip=1.0)
    # grad only w.r.t. the 'learn' subtrees (fixed buffers hold int perms)
    learn0 = {k: v["learn"] for k, v in omega.items()}
    fixed = {k: v["fixed"] for k, v in omega.items()}
    state = opt.init_state(learn0)

    def join(learn):
        return {k: {"learn": learn[k], "fixed": fixed[k]}
                for k in learn}

    def loss_fn(learn, batch, teacher):
        om = join(learn)
        tset = materialize_set(om, cfg, lx)
        folded = api.fold(params, cfg, tset)
        student = api.forward(folded, cfg, batch["inputs"], qm)
        if lx.loss == "kl":
            task = api.kl_divergence(teacher, student, lx.temperature)
        elif lx.loss == "ce":
            task = api.cross_entropy(student, batch["labels"])
        else:  # 'mse' on logits (FlatQuant-style local objective proxy)
            task = jnp.mean((student.astype(jnp.float32)
                             - teacher.astype(jnp.float32)) ** 2)
        return task + reg_loss(om, cfg, lx), task

    @jax.jit
    def step(learn, st, batch, teacher):
        (loss, task), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            learn, batch, teacher)
        learn, st, info = opt.apply_updates(learn, grads, st, ocfg)
        return learn, st, loss, task, info

    hist = []
    t0 = time.time()
    learn = learn0
    for i in range(lx.steps):
        b = calib_batches[i % len(calib_batches)]
        t = jnp.asarray(teachers[i % len(calib_batches)])
        learn, state, loss, task, info = step(learn, state, b, t)
        omega = join(learn)
        if i % max(1, lx.steps // 10) == 0 or i == lx.steps - 1:
            hist.append({"step": i, "loss": float(loss),
                         "task": float(task),
                         "grad_norm": float(info["grad_norm"])})
            if log:
                log(f"[latmix:{lx.kind}] step {i:4d} loss={float(loss):.4f} "
                    f"task={float(task):.4f} ({time.time()-t0:.1f}s)")
    tset = materialize_set(omega, cfg, lx)
    return omega, tset, hist


def transform_metrics(omega, cfg: ArchConfig, lx: LatmixConfig) -> dict:
    """Fig. 3 metrics: orthogonality deviation + off-block spectral norm."""
    tset = materialize_set(omega, cfg, lx)
    return {
        "orthogonality_deviation": float(
            tfm.orthogonality_deviation(tset.a1)),
        "offblock_norm": float(tfm.offblock_norm(tset.a1, lx.block_size)),
        "condition_number": float(jnp.linalg.cond(tset.a1)),
    }
