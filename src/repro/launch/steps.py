"""Train / prefill / serve step builders + abstract input specs.

The step functions are closed over the ArchConfig and are what dryrun.py,
the trainer, and the serving engine jit. ``input_specs`` provides
ShapeDtypeStruct stand-ins (weak-type-correct, no allocation) for every
model input of a (arch × shape) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.quantize import QuantMode
from repro.models import api
from repro.training import optimizer as opt


def param_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def abstract_params(cfg: ArchConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: api.init(k, cfg, param_dtype(cfg)), key)


def abstract_opt_state(cfg: ArchConfig):
    aparams = abstract_params(cfg)
    return jax.eval_shape(opt.init_state, aparams)


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: api.init_cache(cfg, batch, max_len, param_dtype(cfg)))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, ocfg: Optional[opt.AdamWConfig] = None,
                    qm: QuantMode = QuantMode.off(), accum: int = 1):
    """Train step with optional gradient accumulation: ``accum``
    microbatches are processed with a lax.scan, gradients accumulated in
    fp32 (param-sharded, so the buffer is ZeRO-sharded too), then a single
    AdamW update. Keeps the saved-activation footprint at one microbatch
    regardless of the global batch."""
    ocfg = ocfg or opt.AdamWConfig()

    def train_step(params, opt_state, batch):
        if accum <= 1:
            loss, grads = jax.value_and_grad(api.lm_loss)(params, cfg,
                                                          batch, qm)
        else:
            B = jax.tree.leaves(batch)[0].shape[0]
            mb = B // accum
            micro = jax.tree.map(
                lambda a: a.reshape((accum, mb) + a.shape[1:]), batch)

            def body(carry, mb_batch):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(api.lm_loss)(params, cfg,
                                                       mb_batch, qm)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0)),
                                            micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        params, opt_state, info = opt.apply_updates(params, grads,
                                                    opt_state, ocfg)
        return params, opt_state, loss, info["grad_norm"]
    return train_step


def make_prefill_step(cfg: ArchConfig, qm: QuantMode = QuantMode.off()):
    if cfg.family == "encoder":
        # encoder "prefill" = the full bidirectional forward (per-frame
        # classification); there is no cache.
        def encoder_step(params, inputs):
            logits = api.forward(params, cfg, inputs, qm)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return encoder_step

    def prefill_step(params, inputs):
        logits, cache = api.prefill(params, cfg, inputs, qm)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return prefill_step


def make_serve_step(cfg: ArchConfig, qm: QuantMode = QuantMode.off()):
    """One decode step: new token in, next token + updated cache out."""
    def serve_step(params, cache, inputs, cur_len):
        logits, cache = api.decode(params, cfg, cache, inputs, cur_len, qm)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return serve_step


def make_latmix_step(cfg: ArchConfig, lx_cfg=None):
    """One transform-learning step (the paper's calibration workload) —
    lowered in the dry-run for the paper-representative cell."""
    from repro.core import latmix as lx_lib
    lx_cfg = lx_cfg or lx_lib.LatmixConfig()
    qm = lx_lib.student_qm(lx_cfg)
    ocfg = opt.AdamWConfig(lr=lx_cfg.lr, weight_decay=lx_cfg.weight_decay,
                           total_steps=lx_cfg.steps)

    def latmix_step(params, learn, fixed, ostate, batch, teacher):
        def loss_fn(lrn):
            om = {k: {"learn": lrn[k], "fixed": fixed[k]} for k in lrn}
            tset = lx_lib.materialize_set(om, cfg, lx_cfg)
            folded = api.fold(params, cfg, tset)
            student = api.forward(folded, cfg, batch["inputs"], qm)
            kl = api.kl_divergence(teacher, student, lx_cfg.temperature)
            om_full = {k: {"learn": lrn[k], "fixed": fixed[k]} for k in lrn}
            return kl + lx_lib.reg_loss(om_full, cfg, lx_cfg)
        loss, grads = jax.value_and_grad(loss_fn)(learn)
        learn, ostate, _ = opt.apply_updates(learn, grads, ostate, ocfg)
        return learn, ostate, loss
    return latmix_step


# ---------------------------------------------------------------------------
# Abstract inputs per (arch × shape)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the cell's step."""
    B, S = shape.global_batch, shape.seq_len
    dt = param_dtype(cfg)
    tok = jnp.int32

    if shape.kind == "train":
        if cfg.embed_inputs:
            inputs = jax.ShapeDtypeStruct((B, S), tok)
        else:
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        return {"batch": {"inputs": inputs,
                          "labels": jax.ShapeDtypeStruct((B, S), tok)}}

    if shape.kind == "prefill":
        if cfg.embed_inputs:
            inputs = jax.ShapeDtypeStruct((B, S), tok)
        else:
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        return {"inputs": inputs}

    # decode: one new token against a cache of seq_len
    cache = abstract_cache(cfg, B, S)
    if cfg.embed_inputs:
        inputs = jax.ShapeDtypeStruct((B,), tok)
    else:
        inputs = jax.ShapeDtypeStruct((B, cfg.d_model), dt)
    return {"cache": cache, "inputs": inputs,
            "cur_len": jax.ShapeDtypeStruct((), tok)}
