"""Lightweight partitioning context.

Model code calls ``pctx.shard(x, "batch", None, "model")`` to annotate
activation shardings without threading a mesh through every signature.
Outside a distributed context (unit tests, single-device runs) the calls
are no-ops. The launch layer activates the context around lowering:

    with pctx.activate(mesh, batch_axes=("pod", "data"), model_axis="model"):
        jax.jit(step, ...).lower(...)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _get():
    if not hasattr(_state, "ctx"):
        _state.ctx = None
    return _state.ctx


@contextlib.contextmanager
def activate(mesh: Mesh, batch_axes: Sequence[str] = ("data",),
             model_axis: Optional[str] = "model",
             seq_axis: Optional[str] = None):
    """seq_axis: mesh axis for sequence parallelism — the residual stream
    carried between layers is sharded along sequence over this axis
    (training only), so saved-for-backward activations shrink by the TP
    degree; GSPMD inserts the all-gather/reduce-scatter pair per layer
    (Megatron-SP)."""
    prev = _get()
    _state.ctx = {
        "mesh": mesh,
        "batch": tuple(batch_axes) if batch_axes else None,
        "model": model_axis,
        "seq": seq_axis,
    }
    try:
        yield
    finally:
        _state.ctx = prev


def active() -> bool:
    return _get() is not None


def resolve(name) -> Optional[object]:
    """Map a logical axis name to mesh axes (or None)."""
    ctx = _get()
    if ctx is None or name is None:
        return None
    if name == "batch":
        return ctx["batch"]
    if name == "model":
        return ctx["model"]
    if name == "seq":
        return ctx.get("seq")
    return None


def spec(*names) -> P:
    return P(*[resolve(n) for n in names])


def shard(x: jax.Array, *names) -> jax.Array:
    """Apply a sharding constraint by logical names; no-op when inactive.
    Divisibility-guarded: axes that do not divide the dimension are
    dropped (e.g. batch=1 long-context decode, odd vocab sizes)."""
    ctx = _get()
    if ctx is None:
        return x
    mesh = ctx["mesh"]

    def ok(dim, axes):
        if axes is None:
            return None
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        size = 1
        for a in tup:
            size *= mesh.shape[a]
        return axes if dim % size == 0 else None

    resolved = [ok(dim, resolve(n)) for dim, n in zip(x.shape, names)]
    s = NamedSharding(mesh, P(*resolved))
    return jax.lax.with_sharding_constraint(x, s)
