"""Rule-based parameter / batch / cache shardings.

Training: FSDP over "data" × TP over "model" (2D-sharded params; the
"pod" axis is pure DP — params are *not* sharded across pods, gradients
are all-reduced over it). Optimizer state mirrors the params (ZeRO-3).

Serving: TP over "model" only (weights resident per pod, batch over
data axes).

Every rule is divisibility-guarded: a dimension that the mesh axis does
not divide is left unsharded (e.g. batch=1 long-context, hubert's 504-way
head, mamba's 3352-wide in_proj output).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from . import mesh as mesh_lib

# weight-name role sets (shared by all families; path's last dict key)
_COL = {"wq", "wk", "wv", "wg", "wu", "wx", "wy", "in_proj", "sg", "su"}
_ROW = {"wo", "wd", "wor", "out_proj", "sd"}
_EXP_COL = {"eg", "eu"}
_EXP_ROW = {"ed"}
_REPL = {"ln", "ln1", "ln2", "ln_f", "norm", "conv_b", "lam", "ga_w",
         "ga_b", "gx_w", "gx_b", "A_log", "D", "dt_bias", "perm", "sign"}
_BIAS = {"bq", "bk", "bv", "bo", "bg", "bu", "bd", "b_in", "b_out", "bx",
         "by", "bor", "brouter", "bhead", "beg", "beu", "bsg", "bsu"}


def _size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _div(dim: int, axes, mesh):
    """axes if it divides dim, else None (unsharded)."""
    if axes is None or dim <= 0:
        return None
    return axes if dim % _size(mesh, axes) == 0 else None


def param_spec(name: str, shape, cfg: ArchConfig, mode: str, mesh) -> P:
    fsdp = "data" if mode == "train" else None
    tp = "model"
    nd = len(shape)

    def lead(n_extra):  # leading stacked-layer axes
        return (None,) * (nd - n_extra)

    if name in _REPL:
        return P(*([None] * nd))
    if name in _BIAS:
        return P(*lead(1), _div(shape[-1], tp, mesh))
    if name == "conv_w":          # (L, C, K)
        return P(*lead(2), _div(shape[-2], tp, mesh), None)
    if name in _COL:              # (..., d_in, d_out)
        return P(*lead(2), _div(shape[-2], fsdp, mesh),
                 _div(shape[-1], tp, mesh))
    if name in _ROW:              # (..., d_in, d_out): d_in is the wide dim
        return P(*lead(2), _div(shape[-2], tp, mesh),
                 _div(shape[-1], fsdp, mesh))
    if name in _EXP_COL:          # (L, E, d, fe)
        if shape[-3] % _size(mesh, tp) == 0:   # expert parallel
            return P(*lead(3), tp, _div(shape[-2], fsdp, mesh), None)
        return P(*lead(3), None, _div(shape[-2], fsdp, mesh),
                 _div(shape[-1], tp, mesh))
    if name in _EXP_ROW:          # (L, E, fe, d)
        if shape[-3] % _size(mesh, tp) == 0:
            return P(*lead(3), tp, None, _div(shape[-1], fsdp, mesh))
        return P(*lead(3), None, _div(shape[-2], tp, mesh),
                 _div(shape[-1], fsdp, mesh))
    if name == "router":          # (L, d, E)
        return P(*lead(2), _div(shape[-2], fsdp, mesh), None)
    if name == "embed":           # (V, d)
        return P(_div(shape[0], tp, mesh), _div(shape[1], fsdp, mesh))
    if name == "head":            # (d, V)
        v_ax = _div(shape[1], tp, mesh)
        if v_ax is None:          # odd vocab: row-parallel fallback
            return P(_div(shape[0], tp, mesh), None)
        return P(_div(shape[0], fsdp, mesh), v_ax)
    if name in ("a", "v"):        # input_transform (d, d) / (d,)
        return P(*([None] * nd))
    # default: replicate
    return P(*([None] * nd))


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def params_shardings(abstract_params, cfg: ArchConfig, mode: str, mesh):
    def visit(path, leaf):
        spec = param_spec(_leaf_name(path), leaf.shape, cfg, mode, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(visit, abstract_params)


def opt_state_shardings(abstract_state, params_sh, mesh):
    """AdamWState(step, m, v): m/v mirror the params."""
    from repro.training.optimizer import AdamWState
    return AdamWState(step=NamedSharding(mesh, P()),
                      m=params_sh, v=jax.tree.map(lambda s: s, params_sh))


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_spec(cfg: ArchConfig, batch: int, mesh) -> P:
    dp = mesh_lib.dp_axes(mesh)
    return _div(batch, dp, mesh)


def train_batch_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh):
    dp = batch_spec(cfg, shape.global_batch, mesh)
    if cfg.embed_inputs:
        inputs = NamedSharding(mesh, P(dp, None))
    else:
        inputs = NamedSharding(mesh, P(dp, None, None))
    labels = NamedSharding(mesh, P(dp, None))
    return {"inputs": inputs, "labels": labels}


def cache_shardings(abstract_cache, cfg: ArchConfig, batch: int, mesh):
    dp = batch_spec(cfg, batch, mesh)
    tp = "model"

    def visit(path, leaf):
        name = _leaf_name(path)
        sh = leaf.shape
        if name in ("k", "v"):            # (L, B, S, kd)
            return NamedSharding(mesh, P(None, dp, None,
                                         _div(sh[-1], tp, mesh)))
        if name in ("attn_k", "attn_v"):  # (ns, B, A, kd)
            return NamedSharding(mesh, P(None, dp, None,
                                         _div(sh[-1], tp, mesh)))
        if name == "rec_h":               # (ns, 2, B, lru)
            return NamedSharding(mesh, P(None, None, dp,
                                         _div(sh[-1], tp, mesh)))
        if name == "rec_conv":            # (ns, 2, B, lru, K-1)
            return NamedSharding(mesh, P(None, None, dp,
                                         _div(sh[-2], tp, mesh), None))
        if name == "tail_h":              # (nt, B, lru)
            return NamedSharding(mesh, P(None, dp,
                                         _div(sh[-1], tp, mesh)))
        if name == "tail_conv":           # (nt, B, lru, K-1)
            return NamedSharding(mesh, P(None, dp,
                                         _div(sh[-2], tp, mesh), None))
        if name == "ssm":                 # (L, B, H, P, N)
            return NamedSharding(mesh, P(None, dp, None, None,
                                         _div(sh[-1], tp, mesh)))
        if name == "conv":                # (L, B, conv_dim, K-1)
            return NamedSharding(mesh, P(None, dp,
                                         _div(sh[-2], tp, mesh), None))
        return NamedSharding(mesh, P(*([None] * len(sh))))
    return jax.tree_util.tree_map_with_path(visit, abstract_cache)
