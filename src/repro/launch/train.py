"""Training entry point (single-host CPU or multi-host TPU via
``jax.distributed.initialize`` — see scripts/launch_pod.sh)."""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--coordinator", default="")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.distributed:
        import jax
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id)

    from repro import configs
    from repro.training import optimizer as opt
    from repro.training.trainer import TrainConfig, Trainer

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get(args.arch))
    tc = TrainConfig(
        steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        accum=args.accum, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        opt=opt.AdamWConfig(lr=args.lr, total_steps=args.steps))
    trainer = Trainer(cfg, tc)
    trainer.train()
    print(f"final eval ppl: {trainer.eval_ppl():.3f}")


if __name__ == "__main__":
    main()
