"""Production meshes.

A function (never a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips (v5e pod),
("data", "model"). Multi-pod: 2×16×16 = 512 chips with a leading pure-DP
"pod" axis — scaling to N pods extends that axis only (gradient all-reduce
crosses DCI once per step; no model collective ever leaves a pod).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the installed
    jax exposes them (older releases have no jax.sharding.AxisType and
    default to auto sharding anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axis(mesh):
    return "model" if "model" in mesh.shape else None
