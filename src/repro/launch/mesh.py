"""Production meshes.

A function (never a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips (v5e pod),
("data", "model"). Multi-pod: 2×16×16 = 512 chips with a leading pure-DP
"pod" axis — scaling to N pods extends that axis only (gradient all-reduce
crosses DCI once per step; no model collective ever leaves a pod).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axis(mesh):
    return "model" if "model" in mesh.shape else None
