import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (architecture × input shape × mesh) cell
lowers, SPMD-partitions, and compiles on the production meshes.

  single pod : (16, 16)    ("data", "model")        256 chips
  multi-pod  : (2, 16, 16) ("pod", "data", "model") 512 chips

For each cell we jit the step (train_step for training shapes, prefill /
serve_step for inference shapes), lower with abstract ShapeDtypeStruct
inputs (no allocation), compile, and record:

  · compiled.memory_analysis()  — per-device bytes (proves it fits)
  · compiled.cost_analysis()    — per-device FLOPs / bytes accessed
  · collective bytes parsed from compiled.as_text() (per op kind)

Results go to experiments/dryrun/<arch>__<shape>__<mesh>.json and are the
inputs of the roofline analysis (repro.roofline.analyze).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--quant] [--accum auto]
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, shape_applicable
from repro.core.quantize import QuantMode
from repro.launch import mesh as mesh_lib
from repro.launch import pcontext as pctx
from repro.launch import shardings as sh
from repro.launch import steps as steps_lib
from repro.training import optimizer as opt

# per-arch gradient-accumulation defaults (microbatch = 1 sequence/device
# for the giants; more for small models)
ACCUM = {
    # §Perf: sequence parallelism makes saved activations cheap, so the
    # accumulation count is set by the HBM budget, not activation memory —
    # fewer microbatches = fewer FSDP param re-gathers per step.
    "deepseek_67b": 4, "internvl2_26b": 16, "qwen2_7b": 4,
    "moonshot_v1_16b_a3b": 4, "qwen2_moe_a2_7b": 2, "recurrentgemma_2b": 2,
    "hubert_xlarge": 2, "tinyllama_1_1b": 2, "qwen2_0_5b": 1,
    "mamba2_130m": 1,
}

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def parse_collectives(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op, per kind."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        n = 1
        for tok in dims.split(","):
            if tok:
                n *= int(tok)
        b = n * _DTYPE_BYTES.get(dt, 4)
        e = out.setdefault(kind, {"count": 0, "bytes": 0})
        e["count"] += 1
        e["bytes"] += b
    return out


def build_cell(cfg, shape, mesh, quant: bool, accum: str = "auto",
               baked: bool = False):
    """Returns (step_fn, in_shardings, args) ready for jit().lower().

    baked=True serves with *pre-quantized* weights (weight_cfg=None: GPTQ/
    RTN already snapped them to the MX grid offline) — the deployable path;
    baked=False re-fake-quantizes weights inside the step (the naive
    baseline, §Perf cell 3)."""
    dp = mesh_lib.dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]

    aparams = steps_lib.abstract_params(cfg)
    mode = "train" if shape.kind == "train" else "serve"
    psh = sh.params_shardings(aparams, cfg, mode, mesh)
    specs = steps_lib.input_specs(cfg, shape)
    if quant and shape.kind != "train":
        qm = QuantMode.mxfp4(weights=not baked)
    else:
        qm = QuantMode.off()

    if shape.kind == "train":
        n_acc = ACCUM.get(cfg.name.replace("-", "_").replace(".", "_"), 1) \
            if accum == "auto" else int(accum)
        per_dev = max(1, shape.global_batch // dp_total)
        while n_acc > 1 and (shape.global_batch % n_acc
                             or (shape.global_batch // n_acc) % dp_total):
            n_acc //= 2
        n_acc = min(n_acc, per_dev)
        step = steps_lib.make_train_step(cfg, opt.AdamWConfig(),
                                         accum=n_acc)
        ost = steps_lib.abstract_opt_state(cfg)
        osh = sh.opt_state_shardings(ost, psh, mesh)
        bsh = sh.train_batch_shardings(cfg, shape, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        scalar = NamedSharding(mesh, P())
        return (step, (psh, osh, bsh), (psh, osh, scalar, scalar),
                (aparams, ost, specs["batch"]), {"accum": n_acc})

    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(mesh, P())
    dp_or_none = sh.batch_spec(cfg, shape.global_batch, mesh)
    tok_sh = NamedSharding(mesh, P(dp_or_none))

    if shape.kind == "prefill":
        step = steps_lib.make_prefill_step(cfg, qm)
        in_sh = (psh, NamedSharding(
            mesh, P(dp_or_none, *([None] * (1 if cfg.embed_inputs else 2)))))
        if cfg.family == "encoder":     # forward-only: (B, S) predictions
            out_sh = NamedSharding(mesh, P(dp_or_none, None))
            return (step, in_sh, out_sh, (aparams, specs["inputs"]), {})
        out_cache = jax.eval_shape(step, aparams, specs["inputs"])[1]
        csh = sh.cache_shardings(out_cache, cfg, shape.global_batch, mesh)
        return (step, in_sh, (tok_sh, csh), (aparams, specs["inputs"]), {})

    if shape.kind == "latmix":
        # the paper's own workload: one distributed transform-learning step
        from repro.core import latmix as lx_lib
        lx = lx_lib.LatmixConfig(kind="lu", steps=100)
        step = steps_lib.make_latmix_step(cfg, lx)
        # init_omega uses scipy (LU/QR of the init matrix) — not traceable
        # under eval_shape; build concretely once and abstract the shapes
        omega_c = lx_lib.init_omega(jax.random.PRNGKey(0), cfg, lx)
        omega = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), omega_c)
        del omega_c
        learn = {k: v["learn"] for k, v in omega.items()}
        fixd = {k: v["fixed"] for k, v in omega.items()}
        from repro.training import optimizer as opt_lib
        ost = jax.eval_shape(opt_lib.init_state, learn)
        B, S = shape.global_batch, shape.seq_len
        batch = {"inputs": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        teacher = jax.ShapeDtypeStruct(
            (B, S, cfg.vocab_size), steps_lib.param_dtype(cfg))
        rep = jax.tree.map(lambda _: NamedSharding(mesh, P()), learn)
        rep_f = jax.tree.map(lambda _: NamedSharding(mesh, P()), fixd)
        rep_o = jax.tree.map(lambda _: NamedSharding(mesh, P()), ost)
        bsh2 = sh.train_batch_shardings(cfg, shape, mesh)
        tsh = NamedSharding(mesh, P(dp_or_none, None, None))
        in_sh = (psh, rep, rep_f, rep_o, bsh2, tsh)
        out_sh = (rep, rep_o, scalar)
        args = (aparams, learn, fixd, ost, batch, teacher)
        return (step, in_sh, out_sh, args, {})

    # decode
    step = steps_lib.make_serve_step(cfg, qm)
    csh = sh.cache_shardings(specs["cache"], cfg, shape.global_batch, mesh)
    if cfg.embed_inputs:
        in_inp = NamedSharding(mesh, P(dp_or_none))
    else:
        in_inp = NamedSharding(mesh, P(dp_or_none, None))
    in_sh = (psh, csh, in_inp, scalar)
    args = (aparams, specs["cache"], specs["inputs"], specs["cur_len"])
    return (step, in_sh, (tok_sh, csh), args, {})


def run_cell(arch: str, shape_name: str, multi_pod: bool, quant: bool,
             outdir: pathlib.Path, accum: str = "auto",
             arch_cfg=None, baked: bool = True) -> dict:
    cfg = arch_cfg or configs.get(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "family": cfg.family, "quant": bool(quant and
                                               shape.kind != "train")}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        outdir.mkdir(parents=True, exist_ok=True)
        (outdir / f"{arch}__{shape_name}__{mesh_name}.json").write_text(
            json.dumps(rec, indent=1))
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        step, in_sh, out_sh, args, extra = build_cell(cfg, shape, mesh,
                                                      quant, accum,
                                                      baked=baked)
        rec.update(extra)
        seq_ax = "model" if shape.kind == "train" else None
        with mesh, pctx.activate(mesh, batch_axes=mesh_lib.dp_axes(mesh),
                                 model_axis="model", seq_axis=seq_ax):
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
        # CPU-backend bf16 emulation (f32 operand converts, loop-hoisted)
        # inflates temp memory with phantom buffers absent on TPU; an
        # all-f32 compile has no emulation, so f32/2 is the faithful bf16
        # estimate for float-dominated programs (serve cells).
        bf16_est = None
        if shape.kind != "train" and cfg.dtype == "bfloat16":
            import dataclasses as _dc
            cfg32 = _dc.replace(cfg, dtype="float32")
            step32, in32, out32, args32, _ = build_cell(
                cfg32, shape, mesh, quant, accum, baked=baked)
            with mesh, pctx.activate(mesh,
                                     batch_axes=mesh_lib.dp_axes(mesh),
                                     model_axis="model"):
                c32 = jax.jit(step32, in_shardings=in32,
                              out_shardings=out32).lower(*args32).compile()
                m32 = c32.memory_analysis()
            bf16_est = int((m32.argument_size_in_bytes
                            + m32.temp_size_in_bytes) / 2)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_accessed_per_device": float(ca.get("bytes accessed",
                                                      0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
            "memory": {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "generated_code_bytes": int(
                    ma.generated_code_size_in_bytes),
            },
            "collectives": parse_collectives(hlo),
            "memory_bf16_estimate_bytes": bf16_est,
            "n_devices": int(mesh.size),
            "param_count": cfg.param_count(),
            "param_count_active": cfg.param_count(active_only=True),
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    outdir.mkdir(parents=True, exist_ok=True)
    path = outdir / f"{arch}__{shape_name}__{mesh_name}.json"
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--quant", action="store_true", default=True)
    ap.add_argument("--no-quant", dest="quant", action="store_false")
    ap.add_argument("--accum", default="auto")
    ap.add_argument("--baked", action="store_true", default=True,
                    help="serve with pre-quantized weights (deployable)")
    ap.add_argument("--no-baked", dest="baked", action="store_false")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs.base import ASSIGNED_SHAPES
    archs = configs.ARCH_IDS if args.arch == "all" else [
        configs.canonical(args.arch)]
    shapes = (list(ASSIGNED_SHAPES) if args.shape == "all"
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    outdir = pathlib.Path(args.out)

    summary = []
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_cell(arch, shp, mp, args.quant, outdir,
                               args.accum, baked=args.baked)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    gb = (rec["memory"]["argument_bytes"]
                          + rec["memory"]["temp_bytes"]) / 2**30
                    est = rec.get("memory_bf16_estimate_bytes")
                    if est:
                        gb = est / 2**30
                    extra = (f" mem/dev={gb:.2f}GiB "
                             f"flops/dev={rec['flops_per_device']:.3e} "
                             f"({rec['compile_s']:.0f}s compile)")
                elif status == "failed":
                    extra = " " + rec["error"][:120]
                elif status == "skipped":
                    extra = " " + rec["reason"]
                print(f"[{status:7s}] {arch:22s} {shp:12s} "
                      f"{'multi' if mp else 'single':6s}"
                      f"{extra} ({time.time()-t0:.0f}s)", flush=True)
                summary.append(rec)
    n_ok = sum(1 for r in summary if r["status"] == "ok")
    n_skip = sum(1 for r in summary if r["status"] == "skipped")
    n_fail = sum(1 for r in summary if r["status"] == "failed")
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_fail} FAILED")
    (outdir / "summary.json").write_text(json.dumps(summary, indent=1))
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
