"""Serving entry point: PTQ a model (or load a checkpoint) and serve
batched requests with the MX-quantized engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --method latmix-lu --fmt mxfp4 --requests 8

Artifact workflow (calibrate once, serve many times): add --export DIR
to persist the packed quantized checkpoint after PTQ, and start future
runs with --artifact DIR to skip calibration/quantization entirely.

Scheduling: --scheduler wave (static batching, default) or continuous
(slot-pool continuous batching — per-request outputs are token-identical,
decode-step utilization is much higher on mixed-length traffic; see
docs/serving.md).

Sampling: --temperature / --top-k / --top-p switch decode from greedy
argmax to seeded stochastic sampling (--sample-seed; reruns replay
token-for-token). --spec-k K turns on self-drafting speculative
decoding — prompt-lookup drafts up to K tokens per step, one batched
verify forward scores them all; outputs are unchanged (docs/sampling.md).

Observability: --trace OUT.json exports a Chrome trace of the run
(request lifecycles + engine steps, open in Perfetto); --metrics
instruments kernel dispatches and prints the Prometheus metrics
snapshot at exit (docs/observability.md).

HTTP serving: --http HOST:PORT skips the synthetic throughput run and
starts the asyncio HTTP/SSE front end over the built engine instead
(POST /v1/generate, /healthz, /readyz, /metrics; admission shedding via
--max-queue-depth / --admit-token-budget; SIGTERM drains gracefully —
docs/server.md). ``examples/client.py`` is the matching client.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--method", default="latmix-lu")
    ap.add_argument("--fmt", default="mxfp4")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--artifact", default="",
                    help="serve a packed artifact directory (skips PTQ)")
    ap.add_argument("--export", default="",
                    help="export the PTQ result as a packed artifact")
    ap.add_argument("--eager", action="store_true",
                    help="with --artifact: dequantize weights at load")
    ap.add_argument("--backend", default="ref", choices=("ref", "fused"),
                    help="matmul execution backend: 'fused' routes packed "
                         "weights through the Pallas MX kernels "
                         "(interpret-mode off-TPU: correctness only)")
    ap.add_argument("--scheduler", default="wave",
                    choices=("wave", "continuous"),
                    help="request scheduler: 'wave' = static batching; "
                         "'continuous' = slot-pool continuous batching "
                         "(chunked prefill, per-slot decode positions; "
                         "see docs/serving.md)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a request at (and including) this token id")
    ap.add_argument("--kv-cache", default="none",
                    choices=("none", "mxfp8", "mxint8", "mxfp4", "mxint4"),
                    help="store the KV cache MX-quantized (codes + E8M0 "
                         "scale bytes; ~4x less decode KV traffic for "
                         "mxfp4 vs bf16, ~2x for mxfp8 — see "
                         "docs/kv-cache.md). 'none' keeps the dense cache")
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=("contiguous", "paged"),
                    help="KV-cache layout: 'paged' addresses a pool of "
                         "fixed-size pages through block tables with "
                         "ref-counted prefix caching (continuous "
                         "scheduler only; see docs/paged-kv.md)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page under --kv-layout paged "
                         "(multiple of 32 and of the attention chunk; "
                         "default: smallest attn_chunk multiple >= 64)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="KV pool size in pages under --kv-layout paged "
                         "(default: scrap + batch * ceil(max_len/page))")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="end-to-end TTL per request in milliseconds; "
                         "expired requests end TIMED_OUT instead of "
                         "queueing unboundedly (docs/robustness.md)")
    ap.add_argument("--ttft-deadline-ms", type=float, default=None,
                    help="time-to-first-token bound in milliseconds "
                         "(expires requests still waiting for a lane)")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="preemptions a request survives before the "
                         "terminal PREEMPTED state (default 3)")
    ap.add_argument("--no-preemption", dest="preemption",
                    action="store_false", default=True,
                    help="disable evicting lower-priority running "
                         "requests under KV-pool pressure")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy argmax "
                         "(docs/sampling.md)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest-probability tokens "
                         "(0 = no top-k filter)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: keep the smallest prefix of "
                         "tokens whose probability mass reaches p")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base RNG seed; request i samples with seed+i, "
                         "so reruns replay token-for-token")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft up to K tokens per "
                         "step via prompt-lookup and verify them in one "
                         "batched forward (0 = off; continuous scheduler "
                         "only; outputs unchanged — docs/sampling.md)")
    ap.add_argument("--spec-ngram", type=int, default=3,
                    help="longest context n-gram the prompt-lookup "
                         "drafter matches (with --spec-k)")
    ap.add_argument("--http", default="", metavar="HOST:PORT",
                    help="serve over HTTP/SSE instead of the synthetic "
                         "throughput run (PORT 0 = ephemeral; SIGTERM "
                         "drains gracefully — docs/server.md)")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="admission cap: shed (429 + Retry-After) past "
                         "this queue depth instead of queueing unboundedly")
    ap.add_argument("--admit-token-budget", type=int, default=None,
                    help="admission cap: shed when queued prompt+max_new "
                         "tokens would exceed this budget")
    ap.add_argument("--drain-timeout-s", type=float, default=30.0,
                    help="with --http: how long SIGTERM waits for "
                         "in-flight requests before cancelling stragglers")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="export a Chrome trace of the run — open in "
                         "https://ui.perfetto.dev "
                         "(docs/observability.md)")
    ap.add_argument("--metrics", action="store_true",
                    help="instrument kernel dispatches and print the "
                         "Prometheus metrics snapshot at exit")
    args = ap.parse_args()
    if args.spec_k > 0:
        args.scheduler = "continuous"  # spec decoding is continuous-only

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.core import ptq
    from repro.data import synthetic
    from repro.kernels import ops
    from repro.models import api
    from repro.obs import MetricsRegistry, Tracer
    from repro.serving.engine import Engine
    from repro.serving.policy import SchedulingPolicy, SpecConfig
    from repro.serving.sampling import SamplingParams
    from repro.training import checkpoint as ckpt

    policy = SchedulingPolicy(deadline_ms=args.deadline_ms,
                              ttft_deadline_ms=args.ttft_deadline_ms,
                              preemption=args.preemption,
                              max_retries=args.max_retries,
                              max_queue_depth=args.max_queue_depth,
                              admit_token_budget=args.admit_token_budget)
    sampling = (SamplingParams(temperature=args.temperature,
                               top_k=args.top_k, top_p=args.top_p,
                               seed=args.sample_seed)
                if (args.temperature > 0 or args.top_k > 0
                    or args.top_p < 1.0) else None)
    spec = (SpecConfig(k=args.spec_k, ngram_max=args.spec_ngram)
            if args.spec_k > 0 else None)
    tracer = Tracer() if args.trace else None
    metrics = MetricsRegistry() if args.metrics else None
    if metrics is not None:          # kernel-dispatch hooks (ops.py)
        ops.instrument(metrics, tracer)

    if args.artifact:
        t0 = time.time()
        eng = Engine.from_artifact(
            args.artifact, batch_size=args.batch,
            max_len=args.prompt_len + args.max_new + 16, eager=args.eager,
            backend=args.backend, scheduler=args.scheduler,
            eos_id=args.eos_id, kv_cache=args.kv_cache,
            kv_layout=args.kv_layout, page_size=args.page_size,
            n_pages=args.n_pages, metrics=metrics, tracer=tracer,
            policy=policy, spec=spec)
        print(f"loaded artifact {args.artifact} in {time.time()-t0:.1f}s "
              f"({'eager' if args.eager else 'packed-lazy'} weights, "
              f"backend={args.backend}, scheduler={args.scheduler}, "
              f"kv_cache={args.kv_cache}, kv_layout={args.kv_layout}, "
              f"no re-quantization)")
        if args.http:
            return _serve_http(eng, args)
        stats = eng.throughput(n_requests=args.requests,
                               prompt_len=args.prompt_len,
                               max_new=args.max_new, sampling=sampling)
        print(f"served {stats['tokens']} tokens in {stats['seconds']:.2f}s "
              f"-> {stats['tok_per_s']:.1f} tok/s "
              f"({stats['prefill_compiles']} prefill compiles, "
              f"{stats['prefill_chunk_compiles']} chunk compiles, "
              f"decode utilization {stats['decode_utilization']:.2f})")
        if args.kv_layout == "paged":
            print(f"paged KV: {stats['prefix_hit_tokens']} prefix-hit "
                  f"tokens, {stats['blocks_in_use']} blocks in use, "
                  f"{stats['blocks_evicted']} evicted, "
                  f"{eng.kv_bytes_resident()} KV bytes resident")
        _obs_finish(eng, args)
        return

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get(args.arch))
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        aparams = jax.eval_shape(
            lambda k: api.init(k, cfg), jax.random.PRNGKey(0))
        params, man = ckpt.restore(args.ckpt_dir,
                                   {"params": aparams, "opt": None})
        params = params["params"]
        print(f"loaded checkpoint step {man['step']}")
    else:
        params = api.init(jax.random.PRNGKey(0), cfg)
        print("no checkpoint — random init (demo mode)")

    src = synthetic.make_source(cfg, 8, 64, 0)
    calib = [{k: jnp.asarray(v) for k, v in src.batch(i).items()}
             for i in range(3)]
    t0 = time.time()
    res = ptq.apply_method(args.method, params, cfg, calib, fmt=args.fmt,
                           steps=args.steps)
    print(f"PTQ [{args.method} / {args.fmt}] in {time.time()-t0:.0f}s")
    if args.export:
        out = res.export(cfg, args.export)
        print(f"exported artifact -> {out}")

    eng = Engine(res.params, cfg, res.qm, batch_size=args.batch,
                 max_len=args.prompt_len + args.max_new + 16,
                 backend=args.backend, scheduler=args.scheduler,
                 eos_id=args.eos_id, kv_cache=args.kv_cache,
                 kv_layout=args.kv_layout, page_size=args.page_size,
                 n_pages=args.n_pages, metrics=metrics, tracer=tracer,
                 policy=policy, spec=spec)
    if args.http:
        return _serve_http(eng, args)
    stats = eng.throughput(n_requests=args.requests,
                           prompt_len=args.prompt_len,
                           max_new=args.max_new, sampling=sampling)
    print(f"served {stats['tokens']} tokens in {stats['seconds']:.2f}s "
          f"-> {stats['tok_per_s']:.1f} tok/s "
          f"(scheduler={stats['scheduler']}, "
          f"decode utilization {stats['decode_utilization']:.2f})")
    if args.kv_layout == "paged":
        print(f"paged KV: {stats['prefix_hit_tokens']} prefix-hit "
              f"tokens, {stats['blocks_in_use']} blocks in use, "
              f"{stats['blocks_evicted']} evicted, "
              f"{eng.kv_bytes_resident()} KV bytes resident")
    _obs_finish(eng, args)


def _serve_http(eng, args) -> None:
    """--http epilogue: run the asyncio front end until SIGTERM/SIGINT,
    then print the drain report and exit by its verdict."""
    import json as _json
    import sys as _sys

    from repro.serving.server import ServerConfig, serve

    host, _, port = args.http.rpartition(":")
    report = serve(eng, ServerConfig(
        host=host or "127.0.0.1", port=int(port or 8100),
        drain_timeout_s=args.drain_timeout_s))
    print("drain report: " + _json.dumps(report), flush=True)
    _obs_finish(eng, args)
    if not report["clean"]:
        _sys.exit(1)


def _obs_finish(eng, args) -> None:
    """--trace/--metrics epilogue: export the Chrome trace and print the
    Prometheus exposition of the engine's registry (which also carries
    the kernel-dispatch metrics when --metrics instrumented ops)."""
    if stats := eng.stats():
        if args.spec_k > 0:
            print(f"speculative decoding: "
                  f"{stats['spec_proposed_tokens']} drafted, "
                  f"{stats['spec_accepted_tokens']} accepted "
                  f"(acceptance {stats['spec_acceptance']:.2f})")
        if stats.get("ttft_p50") is not None:
            print(f"latency: ttft p50={stats['ttft_p50']*1e3:.1f}ms "
                  f"p99={stats['ttft_p99']*1e3:.1f}ms"
                  + (f", tpot p50={stats['tpot_p50']*1e3:.1f}ms"
                     if stats.get("tpot_p50") is not None else ""))
    if args.trace:
        print(f"trace -> {eng.tracer.export(args.trace)} "
              f"({len(eng.tracer.events())} events)")
    if args.metrics:
        print(eng.metrics.render_prometheus())


if __name__ == "__main__":
    main()
