"""Metrics registry: Counter / Gauge / Histogram under a labeled namespace.

Design points (the serving engine is the primary client):

* **Cheap updates.** ``Counter.inc`` / ``Gauge.set`` are one attribute
  add/store — the same cost as the plain ``self.admitted += 1`` engine
  counters they replace, so the registry can stay always-on in the
  serving hot loop without moving the benchmark.
* **Exact quantiles, bounded memory.** ``Histogram`` keeps fixed
  log-spaced bucket counts (Prometheus-style cumulative export) *and* a
  reservoir of raw samples capped at ``max_samples``. Up to the cap,
  ``quantile(q)`` is computed on the raw samples with numpy's default
  linear interpolation — bit-identical to ``np.percentile`` — which is
  what latency summaries over a serving run (thousands of requests)
  want. Past the cap the reservoir degrades to uniform random retention
  (Vitter's algorithm R) and quantiles become estimates; ``exact`` in
  the snapshot says which regime a histogram is in.
* **Labels are part of the identity.** ``registry.counter(name,
  labels)`` returns one instance per (name, sorted label items); the
  same key always returns the *same* instance. Re-registering a name as
  a different metric type, or with a different label keyset than its
  first registration, raises — silent collisions are how two call sites
  end up summing into each other's metric.
"""
from __future__ import annotations

import json
import math
import random
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


def log_buckets(lo: float, hi: float, per_decade: int = 5) -> List[float]:
    """Fixed log-spaced bucket upper bounds covering [lo, hi]."""
    if not (lo > 0 and hi > lo):
        raise ValueError(f"need 0 < lo < hi, got lo={lo}, hi={hi}")
    n = int(math.ceil(per_decade * math.log10(hi / lo))) + 1
    return [lo * (hi / lo) ** (i / max(n - 1, 1)) for i in range(n)]


# default bounds: 10 microseconds .. 1000 seconds — covers kernel
# dispatches through whole-run latencies when observing seconds
DEFAULT_BUCKETS = tuple(log_buckets(1e-5, 1e3, per_decade=4))


class _Metric:
    """Common identity fields; subclasses add the value machinery."""

    kind = "abstract"

    def __init__(self, name: str, labels: Optional[dict] = None,
                 help: str = "", unit: str = ""):
        self.name = name
        self.labels = dict(labels or {})
        self.help = help
        self.unit = unit


class Counter(_Metric):
    """Monotonically increasing count (events, tokens, cumulative
    seconds). ``inc`` with a negative amount raises."""

    kind = "counter"

    def __init__(self, name, labels=None, help="", unit=""):
        super().__init__(name, labels, help, unit)
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc({amount}))")
        self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def data(self) -> dict:
        return {"value": self._value}


class Gauge(_Metric):
    """A value that goes up and down (blocks in use, queue depth)."""

    kind = "gauge"

    def __init__(self, name, labels=None, help="", unit=""):
        super().__init__(name, labels, help, unit)
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self._value -= amount

    @property
    def value(self) -> float:
        return self._value

    def data(self) -> dict:
        return {"value": self._value}


class Histogram(_Metric):
    """Distribution of observations with exact quantiles.

    Bucket counts (fixed log-spaced upper bounds, +inf terminal) feed
    the Prometheus export; the raw-sample reservoir feeds
    :meth:`quantile`. Up to ``max_samples`` observations the reservoir
    holds *every* sample and quantiles match ``np.percentile`` exactly;
    beyond it, reservoir sampling keeps a uniform subset and quantiles
    are estimates (``exact`` flips to False in :meth:`data`).
    """

    kind = "histogram"

    def __init__(self, name, labels=None, help="", unit="",
                 buckets: Optional[Tuple[float, ...]] = None,
                 max_samples: int = 65536, seed: int = 0):
        super().__init__(name, labels, help, unit)
        bs = tuple(buckets) if buckets is not None else DEFAULT_BUCKETS
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram {name}: bucket bounds must be "
                             f"strictly increasing, got {bs}")
        self.buckets = bs
        self._counts = [0] * (len(bs) + 1)       # last = +inf overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._max_samples = max_samples
        self._samples: List[float] = []
        self._rng = random.Random(seed)          # reservoir replacement

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        # linear scan is fine: bucket lists are ~30 entries and the
        # serving engine observes per *request*, not per token
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self._counts[i] += 1
                break
        else:
            self._counts[-1] += 1
        if len(self._samples) < self._max_samples:
            self._samples.append(v)
        else:                                    # algorithm R
            j = self._rng.randrange(self.count)
            if j < self._max_samples:
                self._samples[j] = v

    @property
    def exact(self) -> bool:
        """True while the reservoir still holds every observation."""
        return self.count == len(self._samples)

    def quantile(self, q: float) -> float:
        """q-quantile (q in [0, 1]) of the retained samples — identical
        to ``np.percentile(samples, 100*q)`` (linear interpolation).
        NaN with no observations."""
        if not self._samples:
            return float("nan")
        return float(np.percentile(np.asarray(self._samples), 100.0 * q))

    def data(self) -> dict:
        d = {"count": self.count, "sum": self.sum,
             "min": self.min if self.count else float("nan"),
             "max": self.max if self.count else float("nan"),
             "exact": self.exact}
        for q in (0.5, 0.9, 0.99):
            d[f"p{int(q * 100)}"] = self.quantile(q)
        return d


def _label_key(labels: Optional[dict]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v))
                        for k, v in (labels or {}).items()))


class MetricsRegistry:
    """Namespace of labeled metrics.

    ``counter/gauge/histogram(name, labels)`` get-or-create: one
    instance per (name, labels) pair, with the *first* registration
    fixing the metric's type and label keyset — later callers asking
    for the same name with a different type or label-key shape raise
    ``ValueError`` (per-series label *values* vary freely). Thread-safe
    at registration; updates on the returned metric objects are plain
    attribute arithmetic (the GIL is their lock — all engine counters
    are updated from the scheduler thread anyway).
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, tuple], _Metric] = {}
        self._schema: Dict[str, Tuple[str, tuple]] = {}  # name->(kind,keys)
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: Optional[dict], kwargs):
        lk = _label_key(labels)
        keyset = tuple(sorted((labels or {}).keys()))
        with self._lock:
            sch = self._schema.get(name)
            if sch is not None and sch != (cls.kind, keyset):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{sch[0]} with label keys {list(sch[1])}; cannot "
                    f"re-register as {cls.kind} with label keys "
                    f"{list(keyset)}")
            m = self._metrics.get((name, lk))
            if m is None:
                m = cls(name, labels, **kwargs)
                self._metrics[(name, lk)] = m
                self._schema.setdefault(name, (cls.kind, keyset))
            return m

    def counter(self, name: str, labels: Optional[dict] = None,
                help: str = "", unit: str = "") -> Counter:
        return self._get(Counter, name, labels,
                         {"help": help, "unit": unit})

    def gauge(self, name: str, labels: Optional[dict] = None,
              help: str = "", unit: str = "") -> Gauge:
        return self._get(Gauge, name, labels,
                         {"help": help, "unit": unit})

    def histogram(self, name: str, labels: Optional[dict] = None,
                  help: str = "", unit: str = "",
                  buckets: Optional[Tuple[float, ...]] = None,
                  max_samples: int = 65536) -> Histogram:
        return self._get(Histogram, name, labels,
                         {"help": help, "unit": unit, "buckets": buckets,
                          "max_samples": max_samples})

    def get(self, name: str, labels: Optional[dict] = None):
        """Existing metric instance or None (no creation)."""
        return self._metrics.get((name, _label_key(labels)))

    def __iter__(self):
        return iter(self._metrics.values())

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> dict:
        """``{name: [{labels, kind, unit, ...values}, ...]}`` — every
        series of every metric, JSON-serializable."""
        out: Dict[str, list] = {}
        for m in self._metrics.values():
            out.setdefault(m.name, []).append(
                {"labels": dict(m.labels), "kind": m.kind,
                 "unit": m.unit, **m.data()})
        return out

    def render_prometheus(self) -> str:
        """Text exposition format (one HELP/TYPE block per metric name;
        histograms emit cumulative ``_bucket`` series plus
        ``_sum``/``_count``)."""
        by_name: Dict[str, List[_Metric]] = {}
        for m in self._metrics.values():
            by_name.setdefault(m.name, []).append(m)
        lines: List[str] = []
        for name in sorted(by_name):
            series = by_name[name]
            head = series[0]
            if head.help:
                lines.append(f"# HELP {name} {head.help}")
            lines.append(f"# TYPE {name} {head.kind}")
            for m in series:
                lab = _render_labels(m.labels)
                if isinstance(m, Histogram):
                    cum = 0
                    for ub, c in zip(m.buckets, m._counts):
                        cum += c
                        lines.append(
                            f"{name}_bucket"
                            f"{_render_labels({**m.labels, 'le': _fmt(ub)})}"
                            f" {cum}")
                    lines.append(
                        f"{name}_bucket"
                        f"{_render_labels({**m.labels, 'le': '+Inf'})}"
                        f" {m.count}")
                    lines.append(f"{name}_sum{lab} {_fmt(m.sum)}")
                    lines.append(f"{name}_count{lab} {m.count}")
                else:
                    lines.append(f"{name}{lab} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"

    def render_json(self) -> str:
        return json.dumps(self.snapshot(), indent=1, sort_keys=True,
                          default=str)


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _render_labels(labels: dict) -> str:
    if not labels:
        return ""
    items = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + items + "}"
