"""Span tracer exporting Chrome ``trace_event`` JSON.

``Tracer.span("decode_step", ...)`` context managers record wall-clock
intervals (``time.perf_counter`` — monotonic) onto *track buffers*:
by default the calling thread's track, or a named logical track
(``track="req-3"`` — the serving engine gives every request its own
track so lifecycle spans render as one lane per request). ``export``
writes the standard ``{"traceEvents": [...]}`` JSON that opens directly
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

Event vocabulary (the subset of the trace-event format we emit):

* ``ph: "X"`` — complete span: ``ts`` (start, microseconds since the
  tracer's epoch) + ``dur`` (microseconds), from :meth:`Tracer.span`.
* ``ph: "i"`` — instant event (zero duration, e.g. ``first_token``,
  ``compile:decode``), from :meth:`Tracer.instant`.
* ``ph: "M"`` — track-name metadata, synthesized at export.

Spans on one track follow stack discipline (a span entered inside
another ends before it) — :func:`validate_trace` checks exactly that,
and is what the schema test and the CI smoke step run against an
exported file.

Overhead: recording one span is two ``perf_counter`` calls and one
list append; nothing is flushed or synced until :meth:`export`. When no
tracer is installed the serving engine skips even that (``None`` check,
no context manager is created).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, List, Optional, Union

__all__ = ["Tracer", "validate_trace"]


class _SpanCtx:
    """Context manager for one complete ('X') event."""

    __slots__ = ("tracer", "name", "tid", "cat", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, tid: int, cat: str,
                 args: Optional[dict]):
        self.tracer, self.name, self.tid = tracer, name, tid
        self.cat, self.args = cat, args
        self.t0 = 0.0

    def __enter__(self) -> "_SpanCtx":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        ev = {"ph": "X", "name": self.name, "cat": self.cat,
              "ts": self.tracer._us(self.t0),
              "dur": round((t1 - self.t0) * 1e6, 3),
              "pid": self.tracer.pid, "tid": self.tid}
        if self.args:
            ev["args"] = self.args
        self.tracer._events.append(ev)


class Tracer:
    """Collects span/instant events onto per-thread and named tracks.

    All timestamps come from one ``perf_counter`` epoch captured at
    construction, so tracks from different threads line up. The event
    buffer only grows; :meth:`export` may be called repeatedly (each
    call writes the full buffer).
    """

    def __init__(self, pid: int = 0):
        self.pid = pid
        self._epoch = time.perf_counter()
        self._events: List[dict] = []            # appends are GIL-atomic
        self._tracks: Dict[str, int] = {}        # track name -> tid
        self._seq: Dict[str, int] = {}           # next_index counters
        self._lock = threading.Lock()

    def next_index(self, key: str = "") -> int:
        """Monotone per-key counter — clients naming their own tracks
        (e.g. one per request) stay collision-free even when several
        producers share one tracer."""
        with self._lock:
            i = self._seq.get(key, 0)
            self._seq[key] = i + 1
            return i

    def _us(self, t: float) -> float:
        return round((t - self._epoch) * 1e6, 3)

    def _tid(self, track: Optional[str]) -> int:
        if track is None:
            t = threading.current_thread()
            track = f"thread:{t.name}"
        with self._lock:
            tid = self._tracks.get(track)
            if tid is None:
                tid = len(self._tracks)
                self._tracks[track] = tid
            return tid

    def span(self, name: str, track: Optional[str] = None,
             cat: str = "engine", **args) -> _SpanCtx:
        """``with tracer.span("decode_step", batch=4): ...`` records a
        complete event covering the block. ``track=None`` uses the
        calling thread's track; a string names a logical track (created
        on first use). Keyword args land in the event's ``args``."""
        return _SpanCtx(self, name, self._tid(track), cat, args or None)

    def complete(self, name: str, t0: float, t1: float,
                 track: Optional[str] = None, cat: str = "engine",
                 **args) -> None:
        """Record a span retroactively from two ``perf_counter``
        readings (for intervals whose start/end straddle many calls —
        e.g. a request's submit→done lifetime, closed at finish)."""
        ev = {"ph": "X", "name": name, "cat": cat,
              "ts": self._us(t0), "dur": round((t1 - t0) * 1e6, 3),
              "pid": self.pid, "tid": self._tid(track)}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, name: str, track: Optional[str] = None,
                cat: str = "engine", **args) -> None:
        """Zero-duration marker (compile events, first_token)."""
        ev = {"ph": "i", "name": name, "cat": cat, "s": "t",
              "ts": self._us(time.perf_counter()),
              "pid": self.pid, "tid": self._tid(track)}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def events(self) -> List[dict]:
        """Copy of the recorded events (no metadata rows)."""
        return list(self._events)

    def export(self, path) -> str:
        """Write Chrome trace-event JSON to ``path``; returns the path.
        Prepends thread_name metadata so Perfetto labels each track."""
        meta = [{"ph": "M", "name": "thread_name", "pid": self.pid,
                 "tid": tid, "args": {"name": name}}
                for name, tid in sorted(self._tracks.items(),
                                        key=lambda kv: kv[1])]
        doc = {"traceEvents": meta + self._events,
               "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return str(path)


def _load_events(src: Union[str, dict, list]) -> List[dict]:
    if isinstance(src, str):
        with open(src) as f:
            src = json.load(f)
    if isinstance(src, dict):
        src = src.get("traceEvents", [])
    if not isinstance(src, list):
        raise ValueError("trace must be a list of events or a dict with "
                         "a 'traceEvents' list")
    return src


def validate_trace(src: Union[str, dict, list]) -> List[dict]:
    """Validate Chrome trace-event JSON (path, parsed dict, or event
    list). Checks:

    * every event has ``ph``/``name``/``ts``/``pid``/``tid`` (metadata
      ``M`` rows need ``ph``/``name`` only), ``X`` events also ``dur``;
    * timestamps and durations are non-negative numbers;
    * per (pid, tid) track, ``X`` spans follow stack discipline —
      sorted by start, each span is either fully inside the enclosing
      open span or starts at/after its end (no partial overlap).

    Returns the non-metadata events; raises ``ValueError`` with the
    offending event on violation.
    """
    events = _load_events(src)
    out: List[dict] = []
    spans: Dict[tuple, List[dict]] = {}
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            raise ValueError(f"event missing ph/name: {ev!r}")
        if ev["ph"] == "M":
            continue
        for field in ("ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event missing {field!r}: {ev!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"bad ts: {ev!r}")
        if ev["ph"] == "X":
            if "dur" not in ev or not isinstance(ev["dur"], (int, float)) \
                    or ev["dur"] < 0:
                raise ValueError(f"X event missing/bad dur: {ev!r}")
            spans.setdefault((ev["pid"], ev["tid"]), []).append(ev)
        out.append(ev)
    eps = 1e-3   # exported timestamps are rounded to 3 decimals (ns)
    for track, evs in spans.items():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: List[dict] = []
        for ev in evs:
            end = ev["ts"] + ev["dur"]
            while stack and ev["ts"] >= stack[-1]["ts"] \
                    + stack[-1]["dur"] - eps:
                stack.pop()
            if stack and end > stack[-1]["ts"] + stack[-1]["dur"] + eps:
                raise ValueError(
                    f"span {ev['name']!r} on track {track} partially "
                    f"overlaps {stack[-1]['name']!r}: "
                    f"[{ev['ts']}, {end}] vs "
                    f"[{stack[-1]['ts']}, "
                    f"{stack[-1]['ts'] + stack[-1]['dur']}]")
            stack.append(ev)
    return out
