"""Serving telemetry: metrics registry + request-lifecycle tracing.

Two small, dependency-free primitives that every serving-stack layer
reports through (see ``docs/observability.md``):

:mod:`repro.obs.metrics`
    ``Counter`` / ``Gauge`` / ``Histogram`` behind a labeled-metric
    :class:`MetricsRegistry` with ``snapshot()`` (nested dict) and
    ``render_prometheus()`` (text exposition format) exports.
    Histograms keep fixed log-spaced buckets *and* a bounded sample
    reservoir, so p50/p90/p99 are exact (numpy-identical) until the
    reservoir cap and bucket-interpolated beyond it.

:mod:`repro.obs.tracing`
    ``Tracer.span("decode_step", ...)`` context managers recording
    wall-clock intervals onto per-thread (and per-request) track
    buffers, exported as Chrome ``trace_event`` JSON
    (``Tracer.export(path)``) that opens directly in Perfetto /
    ``chrome://tracing``.

The serving engine always carries a registry (counter updates cost the
same as the plain Python attributes they replaced); tracing is opt-in
(``Engine(tracer=...)``) and strictly zero-cost when absent — no spans,
no timestamps, no host syncs are added to the hot loop.
"""
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracing import Tracer, validate_trace

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "Tracer", "validate_trace"]
