"""Mixture-of-Experts transformer (Qwen1.5-MoE / Moonlight style):
GQA attention + top-k routed experts with capacity-based dispatch and
optional shared experts.

Routing is grouped (``cfg.moe_groups``): tokens are split into G groups,
each with its own capacity buffer — G is set to the data-parallel degree at
production scale so dispatch stays group-local and the expert all-to-all is
the only cross-device traffic (GShard discipline). Dispatch/combine are
static-shaped scatter/gathers (capacity-dropped overflow), so the whole
block is pjit-compatible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import folding as fold_lib
from repro.core.quantize import QuantMode, qeinsum, qlinear
from repro.kernels.packing import PackedKV
from repro.launch import pcontext as pctx
from .layers import dense_init, gated_mlp, rms_norm, scan_layers
from . import transformer as dense


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32):
    L, d, fe = cfg.n_layers, cfg.d_model, cfg.d_ff
    E, ns = cfg.n_experts, cfg.n_shared_experts
    params = dense.init(key, cfg, dtype)
    b = dict(params["blocks"])
    # replace the dense FFN with router + experts (+ shared fused FFN)
    for k in ("wg", "wu", "wd"):
        del b[k]
    ks = jax.random.split(jax.random.fold_in(key, 17), 8)
    std_in = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    std_out = 1.0 / jnp.sqrt(jnp.asarray(fe, jnp.float32)) / jnp.sqrt(2.0 * L)
    b["router"] = (jax.random.normal(ks[0], (L, d, E), jnp.float32)
                   * 0.02).astype(dtype)
    b["eg"] = (jax.random.normal(ks[1], (L, E, d, fe), jnp.float32)
               * std_in).astype(dtype)
    b["eu"] = (jax.random.normal(ks[2], (L, E, d, fe), jnp.float32)
               * std_in).astype(dtype)
    b["ed"] = (jax.random.normal(ks[3], (L, E, fe, d), jnp.float32)
               * std_out).astype(dtype)
    if ns:
        fs = ns * fe  # shared experts fused into one wide FFN
        b["sg"] = (jax.random.normal(ks[4], (L, d, fs), jnp.float32)
                   * std_in).astype(dtype)
        b["su"] = (jax.random.normal(ks[5], (L, d, fs), jnp.float32)
                   * std_in).astype(dtype)
        b["sd"] = (jax.random.normal(ks[6], (L, fs, d), jnp.float32)
                   * std_out).astype(dtype)
    params["blocks"] = b
    return params


# ---------------------------------------------------------------------------
# Routed FFN
# ---------------------------------------------------------------------------

def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(cfg.capacity_factor * tokens_per_group * cfg.top_k
            / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_ffn(x, p, cfg: ArchConfig, qm: QuantMode):
    """x: (B, S, d) -> (B, S, d) routed expert mix (+ shared experts).

    Expert weights may be expert-stacked PackedWeight leaves ((E, d, f)
    after the layer scan slices L away): under ``qm.backend='fused'`` the
    qeinsum dispatcher maps the packed-native GEMM kernel over the expert
    axis, so expert weights stay 4-bit end to end.

    Returns (y, aux) with aux = (load_balance_loss, router_z_loss)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = min(cfg.moe_groups, T)
    while T % G != 0:
        G -= 1
    Tg = T // G
    C = capacity(cfg, Tg)

    xt = x.reshape(G, Tg, d)
    logits = qlinear(xt, p["router"], p.get("brouter"), qm,
                     "router").astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, Tg, E)
    top_p, top_i = jax.lax.top_k(probs, K)                     # (G, Tg, K)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)

    # --- aux losses (Switch LBL + z-loss) ---
    dense_mask = jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=2)
    frac_tokens = jnp.mean(dense_mask, axis=1)                 # (G, E)
    frac_probs = jnp.mean(probs, axis=1)                       # (G, E)
    lbl = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, -1))
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- capacity positions: rank of each (token, slot) inside its expert ---
    flat_e = top_i.reshape(G, Tg * K)                          # (G, TK)
    flat_p = top_p.reshape(G, Tg * K).astype(x.dtype)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (G, TK, E)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=1) - 1,
                              flat_e[..., None], axis=-1)[..., 0]  # (G, TK)
    keep = (pos < C).astype(x.dtype)
    pos_c = jnp.clip(pos, 0, C - 1)
    tok_idx = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32)[None, :], G, 0)
    tok_idx = jnp.repeat(tok_idx[..., None], K, axis=-1).reshape(G, Tg * K)

    # --- dispatch: (G, E, C, d) buffers ---
    # sharding discipline (§Perf): the scatter runs with the expert axis
    # REPLICATED and only the group axis sharded (each device builds full
    # expert buffers for its own token groups — purely local); the
    # transition to expert-parallel layout afterwards is a plain slice /
    # all-to-all-shaped reshard instead of GSPMD falling back to full
    # replication of the updates.
    src = jnp.take_along_axis(xt, tok_idx[..., None], axis=1)  # (G, TK, d)
    src = pctx.shard(src * keep[..., None], "batch", None, None)
    buf = jnp.zeros((G, E, C, d), x.dtype)
    gidx = jnp.repeat(jnp.arange(G, dtype=jnp.int32)[:, None], Tg * K, 1)
    buf = buf.at[gidx, flat_e, pos_c].add(src)
    buf = pctx.shard(buf, "batch", None, None, None)   # scatter stays local
    buf = pctx.shard(buf, "batch", "model", None, None)  # -> EP layout

    # --- expert compute (EP over the E axis when divisible) ---
    g = qeinsum("gecd,edf->gecf", buf, p["eg"], qm, "ffn_in")
    u = qeinsum("gecd,edf->gecf", buf, p["eu"], qm, "ffn_in")
    if "beg" in p:  # folded-transform biases (per expert)
        g = g + p["beg"][None, :, None, :].astype(g.dtype)
        u = u + p["beu"][None, :, None, :].astype(u.dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    eo = qeinsum("gecf,efd->gecd", h, p["ed"], qm, "ffn_down")
    eo = pctx.shard(eo, "batch", "model", None, None)
    eo = pctx.shard(eo, "batch", None, None, None)     # gather for combine

    # --- combine (local per group once eo is expert-replicated) ---
    gathered = eo[gidx, flat_e, pos_c]                         # (G, TK, d)
    contrib = gathered * (flat_p * keep)[..., None]
    out = jnp.zeros((G, Tg, d), x.dtype).at[gidx, tok_idx].add(contrib)
    out = pctx.shard(out, "batch", None, None)
    return out.reshape(B, S, d), (lbl, zloss)


def ffn_sublayer(x, p, cfg: ArchConfig, qm: QuantMode):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux = moe_ffn(h, p, cfg, qm)
    if "sg" in p:
        y = y + gated_mlp(h, p["sg"], p["su"], p["sd"], qm,
                          bg=p.get("bsg"), bu=p.get("bsu"))
    return x + y, aux


# ---------------------------------------------------------------------------
# Forward / prefill / decode
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, inputs, qm: QuantMode = QuantMode.off(),
            return_aux: bool = False):
    x = dense.embed_inputs(params, cfg, inputs)
    S = x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)

    def body(carry, pl):
        xc, lbl, zl = carry
        xc, _, _ = dense.attn_sublayer(xc, pl, cfg, qm, pos)
        xc, (l1, z1) = ffn_sublayer(xc, pl, cfg, qm)
        xc = pctx.shard(xc, "batch", "seq", None)
        return (xc, lbl + l1, zl + z1), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, lbl, zl), _ = scan_layers(
        body, (x, jnp.float32(0), jnp.float32(0)), params["blocks"],
        cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = dense.head_out(x, params, cfg, qm)
    if return_aux:
        return logits, (lbl / cfg.n_layers, zl / cfg.n_layers)
    return logits


init_cache = dense.init_cache
init_cache_paged = dense.init_cache_paged


def prefill(params, cfg: ArchConfig, inputs, qm: QuantMode = QuantMode.off(),
            max_len: int | None = None, kv_quant=None):
    x = dense.embed_inputs(params, cfg, inputs)
    B, S = x.shape[0], x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)

    def body(xc, pl):
        xc, k, v = dense.attn_sublayer(xc, pl, cfg, qm, pos)
        xc, _ = ffn_sublayer(xc, pl, cfg, qm)
        return pctx.shard(xc, "batch", "seq", None), (k, v)

    x, (ks, vs) = scan_layers(body, x, params["blocks"], cfg.scan_layers)
    x = rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = dense.head_out(x[:, 0], params, cfg, qm)
    if max_len is not None and max_len > S:
        pad = jnp.zeros((cfg.n_layers, B, max_len - S, cfg.kv_dim), ks.dtype)
        ks = jnp.concatenate([ks, pad], axis=2)
        vs = jnp.concatenate([vs, pad], axis=2)
    if kv_quant is not None:
        ks = PackedKV.from_dense(ks, kv_quant.fmt)
        vs = PackedKV.from_dense(vs, kv_quant.fmt)
    return logits, {"k": ks, "v": vs}


def prefill_chunk(params, cfg: ArchConfig, cache, inputs, start, last_idx,
                  qm: QuantMode = QuantMode.off()):
    """Chunked prefill (see :func:`transformer.prefill_chunk`): C tokens
    at positions start..start+C-1 against a partially filled cache; router
    aux losses are dropped (serving path). Note the expert-capacity
    buffers are sized from the *chunk's* token count, so capacity-dropped
    tokens can differ from full-sequence prefill under extreme routing
    imbalance — with ample capacity (the served regime) both paths are
    value-identical."""
    x = dense.embed_inputs(params, cfg, inputs)
    pos = start + jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = dense.attn_sublayer_chunk(xc, pl, cfg, qm, ck, cv,
                                               pos, start + x.shape[1])
        xc, _ = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    xl = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    xl = rms_norm(xl, params["ln_f"], cfg.norm_eps)
    logits = dense.head_out(xl[:, 0], params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def prefill_chunk_paged(params, cfg: ArchConfig, cache, block_tables,
                        inputs, start, last_idx,
                        qm: QuantMode = QuantMode.off()):
    """Chunked prefill against a paged pool (see
    :func:`transformer.prefill_chunk_paged` — including (B,) vector
    ``start`` / ``last_idx`` for batched prefill admission); router aux
    losses are dropped (serving path), with the same expert-capacity
    caveat as :func:`prefill_chunk`."""
    x = dense.embed_inputs(params, cfg, inputs)
    C = x.shape[1]
    st = jnp.asarray(start, jnp.int32)
    if st.ndim == 1:        # (B,) per-lane chunk starts
        pos = st[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    else:
        pos = st + jnp.arange(C, dtype=jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = dense.attn_sublayer_chunk_paged(
            xc, pl, cfg, qm, ck, cv, bt, pos, st + C)
        xc, _ = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    li = jnp.asarray(last_idx, jnp.int32)
    if li.ndim == 1:        # (B,) per-lane last-token indices
        xl = jnp.take_along_axis(x, li[:, None, None], axis=1)
    else:
        xl = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    xl = rms_norm(xl, params["ln_f"], cfg.norm_eps)
    logits = dense.head_out(xl[:, 0], params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def decode_paged(params, cfg: ArchConfig, cache, inputs, cur_len,
                 block_tables, qm: QuantMode = QuantMode.off()):
    """One decode step over a paged pool (see
    :func:`transformer.decode_paged`)."""
    x = jnp.take(params["embed"], inputs[:, None], axis=0)
    x = pctx.shard(x.astype(jnp.dtype(cache["k"].dtype)),
                   "batch", None, None)
    bt = jnp.asarray(block_tables, jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = dense.attn_sublayer_decode_paged(
            xc, pl, cfg, qm, ck, cv, bt, cur_len)
        xc, _ = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = dense.head_out(x[:, 0], params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def decode(params, cfg: ArchConfig, cache, inputs, cur_len,
           qm: QuantMode = QuantMode.off()):
    x = jnp.take(params["embed"], inputs[:, None], axis=0)
    x = pctx.shard(x.astype(cache["k"].dtype), "batch", None, None)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = dense.attn_sublayer_decode(xc, pl, cfg, qm, ck, cv,
                                                cur_len)
        xc, _ = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = dense.head_out(x[:, 0], params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def verify(params, cfg: ArchConfig, cache, inputs, pos, n_valid,
           qm: QuantMode = QuantMode.off()):
    """Speculative verify step (see :func:`transformer.verify`)."""
    x = jnp.take(params["embed"], inputs, axis=0)
    x = pctx.shard(x.astype(cache["k"].dtype), "batch", None, None)
    pv = jnp.asarray(pos, jnp.int32)
    nv = jnp.asarray(n_valid, jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = dense.attn_sublayer_verify(xc, pl, cfg, qm, ck, cv,
                                                pv, nv)
        xc, _ = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = dense.head_out(x, params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def verify_paged(params, cfg: ArchConfig, cache, inputs, pos, n_valid,
                 block_tables, qm: QuantMode = QuantMode.off()):
    """Speculative verify step over a paged pool (see
    :func:`transformer.verify_paged`)."""
    x = jnp.take(params["embed"], inputs, axis=0)
    x = pctx.shard(x.astype(jnp.dtype(cache["k"].dtype)),
                   "batch", None, None)
    pv = jnp.asarray(pos, jnp.int32)
    nv = jnp.asarray(n_valid, jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = dense.attn_sublayer_verify_paged(
            xc, pl, cfg, qm, ck, cv, bt, pv, nv)
        xc, _ = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = dense.head_out(x, params, cfg, qm)
    return logits, {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# PTQ integration
# ---------------------------------------------------------------------------

def fold_norms(params, cfg: ArchConfig):
    p = dict(params)
    b = dict(p["blocks"])
    # expert weights read h through ln2; they carry an extra E axis
    b["ln1"], (b["wq"], b["wk"], b["wv"]) = fold_lib.fold_norm_into(
        b["ln1"], b["wq"], b["wk"], b["wv"])
    g2 = b["ln2"]
    b["router"] = b["router"] * g2[:, :, None].astype(b["router"].dtype)
    b["eg"] = b["eg"] * g2[:, None, :, None].astype(b["eg"].dtype)
    b["eu"] = b["eu"] * g2[:, None, :, None].astype(b["eu"].dtype)
    if "sg" in b:
        b["sg"] = b["sg"] * g2[:, :, None].astype(b["sg"].dtype)
        b["su"] = b["su"] * g2[:, :, None].astype(b["su"].dtype)
    b["ln2"] = jnp.ones_like(g2)
    head = dense.head_matrix(params, cfg)
    lnf, (head,) = fold_lib.fold_norm_into(p["ln_f"], head)
    p["ln_f"], p["head"] = lnf, head
    p["blocks"] = b
    return p


def fold(params, cfg: ArchConfig, tset: fold_lib.TransformSet):
    p = dict(params)
    b = dict(p["blocks"])
    a1i = tset.a1_inv
    a2i = tset.a2_inv()

    b["wq"], b["bq"] = fold_lib.fold_read(b["wq"], b.get("bq"), a1i, tset.v1)
    b["wk"], b["bk"] = fold_lib.fold_read(b["wk"], b.get("bk"), a1i, tset.v1)
    b["wv"], b["bv"] = fold_lib.fold_value(
        b["wv"], b.get("bv", jnp.zeros_like(b["wk"][..., 0, :])), a1i,
        tset.v1, tset.a2, tset.v2, cfg.n_kv_heads)
    b["wo"], b["bo"] = fold_lib.fold_attn_out(
        b["wo"], None, tset.a1, a2i, tset.v2, cfg.n_heads)
    b["router"], b["brouter"] = fold_lib.fold_read(
        b["router"], None, a1i, tset.v1)
    # experts: vmap the read-fold over the E axis
    b["eg"], b["beg"] = fold_lib.fold_read(b["eg"], None, a1i, tset.v1)
    b["eu"], b["beu"] = fold_lib.fold_read(b["eu"], None, a1i, tset.v1)
    ed, _ = fold_lib.fold_write(b["ed"], None, tset.a1)
    if tset.t3_block:
        ed = fold_lib.fold_t3(ed, tset.t3_block)
    b["ed"] = ed
    if "sg" in b:
        b["sg"], b["bsg"] = fold_lib.fold_read(b["sg"], None, a1i, tset.v1)
        b["su"], b["bsu"] = fold_lib.fold_read(b["su"], None, a1i, tset.v1)
        sd, _ = fold_lib.fold_write(b["sd"], None, tset.a1)
        if tset.t3_block:
            sd = fold_lib.fold_t3(sd, tset.t3_block)
        b["sd"] = sd

    p["embed"] = fold_lib.fold_embed(p["embed"], tset.a1, tset.v1)
    head, bh = fold_lib.fold_read(dense.head_matrix(params, cfg), None,
                                  a1i, tset.v1)
    p["head"], p["bhead"] = head, bh
    p["blocks"] = b
    return p
