"""Mamba2 — State Space Duality (SSD), chunked matmul form (Dao & Gu 2024).

Block layout follows the reference Mamba2 block:
  in_proj: d -> [z (d_inner), xBC (d_inner + 2·G·N), dt (H)]
  depthwise causal conv over xBC, SiLU
  SSD recurrence  h_t = exp(dt·A) h_{t-1} + dt·B_t ⊗ x_t ;  y_t = C_t·h_t + D·x_t
  gated RMSNorm(y · silu(z)), out_proj: d_inner -> d

The chunked algorithm expresses everything as chunk-local matmuls (MXU
friendly) plus a cheap inter-chunk scan — linear in sequence length, which
is what makes the 500k-token decode/train shapes feasible.

LATMiX applicability: T1 folds into in_proj (read) and out_proj (write);
there is no value path so T2 does not apply (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import folding as fold_lib
from repro.core.quantize import QuantMode, qlinear
from repro.launch import pcontext as pctx
from .layers import causal_conv1d, conv1d_step, dense_init, rms_norm, rms_norm_gated, scan_layers


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32):
    L, d = cfg.n_layers, cfg.d_model
    di, H = cfg.d_inner, cfg.ssm_nheads
    G, N, K = cfg.ssm_ngroups, cfg.ssm_state, cfg.conv_kernel
    proj_out = 2 * di + 2 * G * N + H
    ks = jax.random.split(key, 8)

    def stack(k, din, dout, scale=1.0):
        keys = jax.random.split(k, L)
        return jnp.stack([dense_init(keys[i], din, dout, dtype, scale)
                          for i in range(L)])

    blocks = {
        "ln": jnp.ones((L, d), dtype),
        "in_proj": stack(ks[0], d, proj_out),
        "conv_w": (jax.random.normal(ks[1], (L, cfg.conv_dim, K), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((L, cfg.conv_dim), dtype),
        "A_log": jnp.log(jnp.tile(
            jnp.linspace(1.0, 16.0, H)[None], (L, 1))).astype(jnp.float32),
        "D": jnp.ones((L, H), jnp.float32),
        "dt_bias": jnp.log(jnp.tile(
            jnp.linspace(1e-3, 1e-1, H)[None] / (1 - jnp.linspace(1e-3, 1e-1, H)[None]),
            (L, 1))).astype(jnp.float32),
        "norm": jnp.ones((L, di), dtype),
        "out_proj": stack(ks[2], di, d, scale=1.0 / jnp.sqrt(2.0 * L)),
    }
    params = {
        "blocks": blocks,
        "ln_f": jnp.ones((d,), dtype),
        "embed": (jax.random.normal(ks[3], (cfg.vocab_size, d), jnp.float32)
                  * 0.02).astype(dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[4], d, cfg.vocab_size, dtype)
    return params


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------

def _segsum(x):
    """(..., T) -> (..., T, T) lower-triangular cumulative segment sums:
    out[i, j] = sum_{k=j+1..i} x[k], -inf above the diagonal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dA, B, C, chunk: int, init_state=None):
    """SSD in chunked matmul form.

    x:  (b, l, h, p)  — inputs already scaled by dt
    dA: (b, l, h)     — log-decay per step (dt * A, A < 0)
    B:  (b, l, h, n)  — input projections (groups already broadcast to heads)
    C:  (b, l, h, n)  — output projections
    Returns (y (b, l, h, p), final_state (b, h, p, n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, l)
    while l % q != 0:
        q //= 2
    nc = l // q

    xr = x.reshape(b, nc, q, h, p)
    Br = B.reshape(b, nc, q, h, n)
    Cr = C.reshape(b, nc, q, h, n)
    Ar = jnp.moveaxis(dA.reshape(b, nc, q, h), -1, -2)  # (b, nc, h, q)
    A_cum = jnp.cumsum(Ar, axis=-1)                      # (b, nc, h, q)

    # 1) intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(Ar))                          # (b, nc, h, q, q)
    Ydiag = jnp.einsum("bcqhn,bcshn,bchqs,bcshp->bcqhp", Cr, Br, Lmat, xr)

    # 2) per-chunk end states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)      # (b, nc, h, q)
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn", Br, decay_states, xr)

    # 3) inter-chunk recurrence (scan over chunks) — f32 carry (stable and
    # dtype-invariant under bf16 inputs)
    chunk_decay = jnp.exp(A_cum[..., -1])                # (b, nc, h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(s, inp):
        st, dec = inp
        s_new = s * dec[..., None, None] + st.astype(jnp.float32)
        return s_new, s  # emit the state *entering* this chunk

    (s_final, prev_states) = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b, nc, h, p, n)

    # 4) inter-chunk (off-diagonal) contribution
    state_decay = jnp.exp(A_cum)                         # (b, nc, h, q)
    Yoff = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", Cr, prev_states, state_decay)

    y = (Ydiag + Yoff).reshape(b, l, h, p).astype(x.dtype)
    return y, s_final


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------

def _split_proj(zxbcdt, cfg: ArchConfig):
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim:]
    return z, xBC, dt


def _ssm_inputs(xBC, dt_raw, p, cfg: ArchConfig):
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    P = cfg.ssm_headdim
    xs = xBC[..., :di]
    Bs = xBC[..., di:di + G * N]
    Cs = xBC[..., di + G * N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))          # (H,), negative
    lead = xs.shape[:-1]
    xh = xs.reshape(*lead, H, P)
    rep = H // G
    Bh = jnp.repeat(Bs.reshape(*lead, G, N), rep, axis=-2)
    Ch = jnp.repeat(Cs.reshape(*lead, G, N), rep, axis=-2)
    return xh, Bh, Ch, dt, a


def block(x, p, cfg: ArchConfig, qm: QuantMode, init_state=None,
          return_state: bool = False):
    """x: (B, L, d). Returns (x', (final_ssm_state, conv_tail))."""
    Bb, Lq, d = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = qlinear(h, p["in_proj"], p.get("b_in"), qm, "ssm_in")
    z, xBC, dt_raw = _split_proj(zxbcdt, cfg)
    conv_tail = xBC[:, -(cfg.conv_kernel - 1):, :]         # pre-conv inputs
    xBC = causal_conv1d(xBC, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xh, Bh, Ch, dt, a = _ssm_inputs(xBC, dt_raw, p, cfg)
    dA = dt * a[None, None, :]                             # (B, L, H)
    xin = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y, s_final = ssd_chunked(xin, dA, Bh.astype(x.dtype), Ch.astype(x.dtype),
                             cfg.ssm_chunk, init_state)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bb, Lq, cfg.d_inner)
    y = rms_norm_gated(y, z, p["norm"], cfg.norm_eps)
    out = qlinear(y, p["out_proj"], p.get("b_out"), qm, "ssm_out")
    state = (s_final, jnp.moveaxis(conv_tail, 1, 2))       # (B, conv_dim, K-1)
    return x + out.astype(x.dtype), state


def block_decode(x, p, cfg: ArchConfig, qm: QuantMode, ssm_state, conv_state):
    """One token. x: (B, 1, d); ssm_state: (B, H, P, N);
    conv_state: (B, conv_dim, K-1)."""
    Bb = x.shape[0]
    h = rms_norm(x[:, 0], p["ln"], cfg.norm_eps)
    zxbcdt = qlinear(h, p["in_proj"], p.get("b_in"), qm, "ssm_in")
    z, xBC_t, dt_raw = _split_proj(zxbcdt, cfg)
    xBC_t, conv_state = conv1d_step(conv_state, xBC_t, p["conv_w"],
                                    p["conv_b"])
    xBC_t = jax.nn.silu(xBC_t.astype(jnp.float32)).astype(x.dtype)
    xh, Bh, Ch, dt, a = _ssm_inputs(xBC_t, dt_raw, p, cfg)   # (B, H, P) etc.
    dA = jnp.exp(dt * a[None, :])                            # (B, H)
    upd = jnp.einsum("bhn,bhp->bhpn", Bh.astype(jnp.float32),
                     xh.astype(jnp.float32) * dt[..., None])
    ssm_state = ssm_state * dA[..., None, None] + upd.astype(ssm_state.dtype)
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32),
                   ssm_state.astype(jnp.float32)).astype(x.dtype)
    y = y + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(Bb, cfg.d_inner)
    y = rms_norm_gated(y, z, p["norm"], cfg.norm_eps)
    out = qlinear(y, p["out_proj"], p.get("b_out"), qm, "ssm_out")
    return x + out[:, None, :], ssm_state, conv_state


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

def head_matrix(params, cfg):
    return params["head"] if "head" in params else params["embed"].T


def head_out(x, params, cfg, qm):
    return qlinear(x, head_matrix(params, cfg), params.get("bhead"),
                   qm, "head")


def forward(params, cfg: ArchConfig, inputs, qm: QuantMode = QuantMode.off()):
    x = jnp.take(params["embed"], inputs, axis=0)
    x = pctx.shard(x, "batch", None, None)

    def body(xc, pl):
        xc, _ = block(xc, pl, cfg, qm)
        return pctx.shard(xc, "batch", "seq", None), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = scan_layers(body, x, params["blocks"], cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return head_out(x, params, cfg, qm)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32):
    L, H, P, N = (cfg.n_layers, cfg.ssm_nheads, cfg.ssm_headdim,
                  cfg.ssm_state)
    return {
        "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((L, batch, cfg.conv_dim, cfg.conv_kernel - 1),
                          dtype),
    }


def prefill(params, cfg: ArchConfig, inputs, qm: QuantMode = QuantMode.off(),
            max_len: int | None = None):
    del max_len  # state-space cache is O(1) in sequence length
    x = jnp.take(params["embed"], inputs, axis=0)
    x = pctx.shard(x, "batch", None, None)

    def body(xc, pl):
        xc, (s, c) = block(xc, pl, cfg, qm)
        return pctx.shard(xc, "batch", "seq", None), (s, c)

    x, (ss, cs) = scan_layers(body, x, params["blocks"], cfg.scan_layers)
    x = rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = head_out(x[:, 0], params, cfg, qm)
    return logits, {"ssm": ss.astype(jnp.float32), "conv": cs}


def decode(params, cfg: ArchConfig, cache, inputs, cur_len,
           qm: QuantMode = QuantMode.off()):
    del cur_len  # state-space cache is position-free
    x = jnp.take(params["embed"], inputs[:, None], axis=0)
    x = pctx.shard(x.astype(cache["conv"].dtype), "batch", None, None)

    def body(xc, inp):
        pl, s, c = inp
        xc, s, c = block_decode(xc, pl, cfg, qm, s, c)
        return xc, (s, c)

    x, (ss, cs) = scan_layers(body, x, (params["blocks"], cache["ssm"],
                               cache["conv"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = head_out(x[:, 0], params, cfg, qm)
    return logits, {"ssm": ss, "conv": cs}


# ---------------------------------------------------------------------------
# PTQ integration — T1 only (no value path; see DESIGN.md)
# ---------------------------------------------------------------------------

def fold_norms(params, cfg: ArchConfig):
    p = dict(params)
    b = dict(p["blocks"])
    b["ln"], (b["in_proj"],) = fold_lib.fold_norm_into(b["ln"], b["in_proj"])
    b["norm"], (b["out_proj"],) = fold_lib.fold_norm_into(
        b["norm"], b["out_proj"])
    head = head_matrix(params, cfg)
    lnf, (head,) = fold_lib.fold_norm_into(p["ln_f"], head)
    p["ln_f"], p["head"] = lnf, head
    p["blocks"] = b
    return p


def fold(params, cfg: ArchConfig, tset: fold_lib.TransformSet):
    p = dict(params)
    b = dict(p["blocks"])
    a1i = tset.a1_inv
    b["in_proj"], b["b_in"] = fold_lib.fold_read(
        b["in_proj"], None, a1i, tset.v1)
    b["out_proj"], b["b_out"] = fold_lib.fold_write(
        b["out_proj"], jnp.zeros((cfg.n_layers, cfg.d_model),
                                 b["out_proj"].dtype), tset.a1)
    p["embed"] = fold_lib.fold_embed(p["embed"], tset.a1, tset.v1)
    head, bh = fold_lib.fold_read(head_matrix(params, cfg), None, a1i,
                                  tset.v1)
    p["head"], p["bhead"] = head, bh
    p["blocks"] = b
    return p
