"""Shared neural-net layers: norms, RoPE, GQA attention (online-softmax
chunked), gated MLPs. Pure functions; params are plain dict pytrees."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantMode, qlinear
from repro.kernels import ops
from repro.kernels.packing import PackedKV, PagedKV, kv_encode
from repro.launch import pcontext as pctx

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# KV-cache leaves: dense (B, S, kv_dim) arrays or MX-packed ``PackedKV``
# (codes + E8M0 scale bytes). The write helpers quantize at append time —
# the only lossy point of the quantized-cache path; reads decode in place
# (ref) or in-kernel (fused flash-decode).
# ---------------------------------------------------------------------------

def kv_write_rows(cache, new: jnp.ndarray, rows: jnp.ndarray):
    """Scatter one token per lane: lane b writes row ``rows[b]``.
    cache: (B, S, kv_dim) dense or PackedKV; new: (B, 1, kv_dim) dense."""
    bidx = jnp.arange(new.shape[0], dtype=jnp.int32)
    if isinstance(cache, PackedKV):
        c, s = kv_encode(new, cache.fmt)
        return PackedKV(cache.codes.at[bidx, rows].set(c[:, 0]),
                        cache.scales.at[bidx, rows].set(s[:, 0]),
                        cache.fmt, cache.dtype)
    return cache.at[bidx, rows].set(new[:, 0])


def kv_write_slice(cache, new: jnp.ndarray, start):
    """Contiguous write of ``new`` (B, C, kv_dim) at row ``start`` (traced
    scalar) across all lanes — the scalar-decode / chunked-prefill path."""
    if isinstance(cache, PackedKV):
        c, s = kv_encode(new, cache.fmt)
        return PackedKV(
            jax.lax.dynamic_update_slice(cache.codes, c, (0, start, 0)),
            jax.lax.dynamic_update_slice(cache.scales, s, (0, start, 0)),
            cache.fmt, cache.dtype)
    return jax.lax.dynamic_update_slice(cache, new, (0, start, 0))


# ---------------------------------------------------------------------------
# Paged-cache writes: every position goes through the block-table
# indirection — logical position t of lane b lives at pool page
# ``block_tables[b, t // P]``, row ``t % P`` (see ``packing.PagedKV`` and
# ``docs/paged-kv.md``). The engine guarantees writable pages are private
# to their lane (shared prefix pages are read-only), so the scatters below
# never race across lanes.
# ---------------------------------------------------------------------------

def kv_write_token_paged(pool: PagedKV, new: jnp.ndarray,
                         pages: jnp.ndarray, offs: jnp.ndarray) -> PagedKV:
    """Scatter one token per lane into a layer-sliced page pool.
    pool: PagedKV (N, P, ·); new: (B, 1, D) dense; pages/offs: (B,) i32 —
    lane b writes pool[pages[b], offs[b]]. Quantizes at append time when
    the pool is MX-packed (the decode scatter path, page-relative)."""
    if pool.fmt == "none":
        return PagedKV(pool.codes.at[pages, offs].set(
            new[:, 0].astype(pool.codes.dtype)), None, "none", pool.dtype)
    c, s = kv_encode(new, pool.fmt)
    return PagedKV(pool.codes.at[pages, offs].set(c[:, 0]),
                   pool.scales.at[pages, offs].set(s[:, 0]),
                   pool.fmt, pool.dtype)


def kv_write_chunk_paged(pool: PagedKV, new: jnp.ndarray,
                         block_tables: jnp.ndarray, start) -> PagedKV:
    """Write a C-token chunk at absolute positions start..start+C-1
    through the block tables (the chunked-prefill append path).
    pool: PagedKV (N, P, ·); new: (B, C, D) dense; block_tables:
    (B, maxp) i32; start: traced i32 scalar shared by all lanes, or a
    (B,) vector of per-lane starts (batched prefill admission). Each
    token lands at its page-relative row — chunks may straddle page
    boundaries."""
    B, C = new.shape[0], new.shape[1]
    pages, offs = _chunk_pages_offs(block_tables, B, C, pool.page_size,
                                    start)
    if pool.fmt == "none":
        return PagedKV(pool.codes.at[pages, offs].set(
            new.astype(pool.codes.dtype)), None, "none", pool.dtype)
    c, s = kv_encode(new, pool.fmt)
    return PagedKV(pool.codes.at[pages, offs].set(c),
                   pool.scales.at[pages, offs].set(s),
                   pool.fmt, pool.dtype)


def _chunk_pages_offs(block_tables, B: int, C: int, P: int, start):
    """(pages, offs) (B, C) i32 for a C-token chunk at ``start`` (traced
    scalar, broadcast — or (B,) per-lane vector) through the tables."""
    st = jnp.asarray(start, jnp.int32)
    if st.ndim == 1:                         # per-lane starts
        pos = st[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        return jnp.take_along_axis(block_tables, pos // P, axis=1), pos % P
    pos = st + jnp.arange(C, dtype=jnp.int32)               # (C,)
    pages = jnp.take_along_axis(
        block_tables, jnp.broadcast_to((pos // P)[None, :], (B, C)),
        axis=1)                                             # (B, C)
    return pages, jnp.broadcast_to((pos % P)[None, :], (B, C))


def kv_scatter_chunk_paged(pool: PagedKV, codes: jnp.ndarray,
                           scales: jnp.ndarray, block_tables: jnp.ndarray,
                           start) -> PagedKV:
    """Scatter *pre-encoded* chunk bytes into a packed page pool — the
    commit half of the fused prefill kernel's quantize-on-append:
    ``ops.mx_flash_prefill`` returns the chunk's MX code + E8M0 scale
    bytes (bit-identical to ``packing.kv_encode``), and this placement is
    byte-identical to :func:`kv_write_chunk_paged` of the dense chunk.
    pool: PagedKV (N, P, ·), quantized fmt; codes: (B, C, D*bits/8) u8;
    scales: (B, C, D//32) u8; start: scalar or (B,) per-lane i32."""
    if pool.fmt == "none":
        raise ValueError("kv_scatter_chunk_paged commits packed bytes; a "
                         "dense (fmt='none') pool has none — use "
                         "kv_write_chunk_paged")
    B, C = codes.shape[0], codes.shape[1]
    pages, offs = _chunk_pages_offs(block_tables, B, C, pool.page_size,
                                    start)
    return PagedKV(pool.codes.at[pages, offs].set(codes),
                   pool.scales.at[pages, offs].set(scales),
                   pool.fmt, pool.dtype)


def kv_write_spec(cache, new: jnp.ndarray, rows: jnp.ndarray):
    """Per-lane multi-token scatter for the speculative verify step:
    lane b token j writes row ``rows[b, j]``; rows >= S drop (the masked
    write of slots past a lane's draft count — ``mode='drop'`` because a
    clamped index would corrupt a live row instead).
    cache: (B, S, kv_dim) dense or PackedKV; new: (B, C, kv_dim) dense."""
    bidx = jnp.arange(new.shape[0], dtype=jnp.int32)[:, None]
    if isinstance(cache, PackedKV):
        c, s = kv_encode(new, cache.fmt)
        return PackedKV(cache.codes.at[bidx, rows].set(c, mode="drop"),
                        cache.scales.at[bidx, rows].set(s, mode="drop"),
                        cache.fmt, cache.dtype)
    return cache.at[bidx, rows].set(new, mode="drop")


def kv_write_spec_paged(pool: PagedKV, new: jnp.ndarray,
                        block_tables: jnp.ndarray, pos: jnp.ndarray,
                        n_valid: jnp.ndarray) -> PagedKV:
    """Per-lane multi-token write through block tables: lane b token j
    lands at logical position ``pos[b] + j`` when ``j < n_valid[b]``.
    Invalid slots are dropped by forcing their page offset to P (out of
    the page, ``mode='drop'``); their page *gather* index is clipped
    instead, because gathers clamp rather than drop and an unclipped
    ``t // P`` could read past a short lane's table row.
    pool: PagedKV (N, P, ·); new: (B, C, D); pos/n_valid: (B,) i32."""
    B, C = new.shape[0], new.shape[1]
    P = pool.page_size
    t = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (B, C)
    valid = jnp.arange(C, dtype=jnp.int32)[None, :] < n_valid[:, None]
    maxp = block_tables.shape[1]
    pages = jnp.take_along_axis(
        block_tables, jnp.clip(t // P, 0, maxp - 1), axis=1)     # (B, C)
    offs = jnp.where(valid, t % P, P)
    if pool.fmt == "none":
        return PagedKV(pool.codes.at[pages, offs].set(
            new.astype(pool.codes.dtype), mode="drop"), None, "none",
            pool.dtype)
    c, s = kv_encode(new, pool.fmt)
    return PagedKV(pool.codes.at[pages, offs].set(c, mode="drop"),
                   pool.scales.at[pages, offs].set(s, mode="drop"),
                   pool.fmt, pool.dtype)


def attention_paged(q: jnp.ndarray, k_pool: PagedKV, v_pool: PagedKV,
                    block_tables: jnp.ndarray, *, causal: bool,
                    q_pos: jnp.ndarray, window: int = 0,
                    kv_len: Optional[jnp.ndarray] = None,
                    chunk: int = 1024, backend: str = "ref") -> jnp.ndarray:
    """Attention over a paged KV pool addressed through block tables.

    Under ``backend='fused'`` the single-token decode contract (Sq == 1,
    a quantized pool, a known per-lane fill) dispatches to the paged
    Pallas flash-decode kernel, which resolves the block-table
    indirection in its grid — pages stream from HBM without a contiguous
    copy. Everything else (chunked prefill with Sq > 1, dense pools, the
    'ref' backend) gathers each lane's pages into the logical contiguous
    layout and runs the existing :func:`attention` on the same values,
    so the paged path is value-identical position-for-position to the
    contiguous cache."""
    B, Sq, H, Dh = q.shape
    if (backend == "fused" and Sq == 1 and causal
            and k_pool.fmt != "none" and kv_len is not None):
        qp = jnp.asarray(q_pos, jnp.int32)
        qpv = qp[:, 0] if qp.ndim == 2 else qp.reshape(-1)
        out = ops.mx_flash_decode_paged(
            q.reshape(B, H, Dh), k_pool.codes, k_pool.scales,
            v_pool.codes, v_pool.scales, block_tables, qpv,
            jnp.asarray(kv_len, jnp.int32).reshape(-1), k_pool.fmt,
            window=window)
        return out.reshape(B, Sq, H, Dh).astype(q.dtype)
    kvh = k_pool.feature_dim // Dh
    # gather in the pool's storage dtype — identical read semantics to the
    # contiguous cache (attention casts q to the cache dtype, not vice
    # versa), which is what keeps paged/contiguous bitwise-equal
    kd = kv_heads_view(k_pool.gather_dense(block_tables), kvh, Dh)
    vd = kv_heads_view(v_pool.gather_dense(block_tables), kvh, Dh)
    return attention(q, kd, vd, causal=causal, q_pos=q_pos, window=window,
                     kv_len=kv_len, chunk=chunk)


def shard_kv(c, *names):
    """pctx.shard over a cache leaf; a PackedKV shards its children (the
    divisibility guard drops axes the packed widths cannot honor)."""
    if isinstance(c, PackedKV):
        return PackedKV(pctx.shard(c.codes, *names),
                        pctx.shard(c.scales, *names), c.fmt, c.dtype)
    return pctx.shard(c, *names)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(x.dtype)


def rms_norm_gated(x, z, gamma, eps: float = 1e-5):
    """Mamba2 gated norm: rmsnorm(x * silu(z)) * gamma."""
    return rms_norm(x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    gamma, eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _rope_inv_freq(theta: float, half: int) -> np.ndarray:
    """Cached RoPE inverse-frequency table keyed on (theta, head_dim/2) —
    a host constant, so every trace folds the same array instead of
    re-deriving the power series per call (the ``hadamard_matrix``
    treatment)."""
    return (1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
            ).astype(np.float32)


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, N, Dh); pos: (S,) int32 positions shared across the batch,
    or (B, S) per-row positions (continuous-batching decode, where each
    batch lane sits at its own sequence position). Rotates pairs
    (x[..., :half], x[..., half:]) — llama convention. Per-row positions
    compute the identical rotation a shared-position call with that row's
    position would."""
    dh = x.shape[-1]
    half = dh // 2
    inv_freq = jnp.asarray(_rope_inv_freq(float(theta), half))
    if pos.ndim == 2:  # (B, S) per-row positions
        freqs = pos.astype(jnp.float32)[:, :, None] * inv_freq[None, None, :]
        cos = jnp.cos(freqs)[:, :, None, :]            # (B, S, 1, half)
        sin = jnp.sin(freqs)[:, :, None, :]
    else:
        freqs = pos.astype(jnp.float32)[:, None] * inv_freq[None, :]
        cos = jnp.cos(freqs)[None, :, None, :]         # (1, S, 1, half)
        sin = jnp.sin(freqs)[None, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — grouped-query, online-softmax over KV chunks.
# ---------------------------------------------------------------------------

def kv_heads_view(c, kvh: int, dh: int):
    """(B, S, kv_dim) cache leaf -> the (B, S, K, Dh) view ``attention``
    expects. A ``PackedKV`` passes through unsplit — attention derives
    the head view from q and dispatches on the packed layout."""
    if isinstance(c, PackedKV):
        return c
    return c.reshape(c.shape[0], c.shape[1], kvh, dh)


def _attention_packed(q, k: PackedKV, v: PackedKV, *, causal, q_pos,
                      k_start, window, kv_len, k_positions, chunk,
                      backend):
    """Attention over an MX-quantized KV cache (see ``docs/kv-cache.md``).

    Under ``backend='fused'`` the single-token decode contract (Sq == 1,
    contiguous keys, a known fill) dispatches to the Pallas flash-decode
    kernel, which consumes the packed codes + E8M0 scale bytes straight
    from HBM. Everything else — chunked prefill (Sq > 1), ring-buffer
    caches (k_positions), the 'ref' backend — decodes the cache in place
    (one LUT gather, the PackedWeight fallback posture) and runs the
    dense jnp path on the same values."""
    B, Sq, H, Dh = q.shape
    qp = jnp.asarray(q_pos, jnp.int32)
    if (backend == "fused" and Sq == 1 and causal and k_positions is None
            and k_start == 0 and kv_len is not None):
        qpv = qp[:, 0] if qp.ndim == 2 else qp.reshape(-1)
        out = ops.mx_flash_decode(
            q.reshape(B, H, Dh), k.codes, k.scales, v.codes, v.scales,
            qpv, jnp.asarray(kv_len, jnp.int32).reshape(-1), k.fmt,
            window=window)
        return out.reshape(B, Sq, H, Dh).astype(q.dtype)
    kvh = k.shape[-1] // Dh
    kd = kv_heads_view(k.to_dense(), kvh, Dh)
    vd = kv_heads_view(v.to_dense(), kvh, Dh)
    return attention(q, kd, vd, causal=causal, q_pos=q_pos,
                     k_start=k_start, window=window, kv_len=kv_len,
                     k_positions=k_positions, chunk=chunk)


def attention(q: jnp.ndarray, k, v, *,
              causal: bool, q_pos: jnp.ndarray, k_start: int = 0,
              window: int = 0, kv_len: Optional[jnp.ndarray] = None,
              k_positions: Optional[jnp.ndarray] = None,
              chunk: int = 1024, backend: str = "ref") -> jnp.ndarray:
    """Memory-bounded attention.

    q: (B, Sq, H, Dh);  k, v: (B, Sk, K, Dh) with H % K == 0 — or
    ``PackedKV`` leaves of logical shape (B, Sk, K*Dh) (MX-quantized
    cache; see :func:`_attention_packed` for the dispatch rules —
    ``backend='fused'`` engages the Pallas flash-decode kernel on the
    single-token decode contract).
    q_pos: (Sq,) absolute positions of the queries, shared across the
            batch — or (B, Sq) per-row positions (continuous-batching
            decode, every lane at its own position).
    k_start: absolute position of k[:, 0] (keys are contiguous).
    window: if > 0, keys with pos <= q_pos - window are masked (local attn).
    kv_len: optional traced scalar — keys at index >= kv_len are invalid
            (decode with a partially-filled cache). May be a (B,) vector
            when q_pos is per-row (each lane has its own cache fill).
    k_positions: optional (Sk,) explicit key positions (ring-buffer caches);
            overrides k_start, and entries < 0 are invalid.
    Output: (B, Sq, H, Dh).

    Every mask variant selects the same key set a shared-position call
    would select per row, so per-row calls are value-identical per lane to
    the scalar path (the engine's scheduler-parity tests pin this down).
    """
    if isinstance(k, PackedKV):
        return _attention_packed(q, k, v, causal=causal, q_pos=q_pos,
                                 k_start=k_start, window=window,
                                 kv_len=kv_len, k_positions=k_positions,
                                 chunk=chunk, backend=backend)
    B, Sq, H, Dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, Dh).astype(k.dtype)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))

    if Sk % chunk != 0 or Sk <= chunk:
        chunk = Sk
    nc = Sk // chunk

    qp = q_pos.astype(jnp.int32)  # (Sq,) shared or (B, Sq) per-row
    per_row = qp.ndim == 2

    def mask_for(kp):
        if per_row:                      # (B, Sq, chunk) boolean
            kpb = kp[None, None, :]
            ok = kpb >= 0
            if causal:
                ok = ok & (kpb <= qp[:, :, None])
            if window:
                ok = ok & (kpb > qp[:, :, None] - window)
            if kv_len is not None:
                kl = jnp.reshape(jnp.asarray(kv_len, jnp.int32), (-1, 1, 1))
                ok = ok & (kpb - k_start < kl)
            return ok
        ok = kp[None, :] >= 0
        if causal:
            ok &= kp[None, :] <= qp[:, None]
        if window:
            ok &= kp[None, :] > qp[:, None] - window
        if kv_len is not None:
            ok &= kp[None, :] - k_start < kv_len
        return ok

    # fori_loop + dynamic_slice (not scan over a moveaxis'd copy): the
    # cache is read in place, once, in its storage dtype — no hoisted f32
    # conversion and no reordered copy of the whole KV cache (§Perf).
    def body(i, carry):
        m, l, acc = carry
        kci = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, 1)
        vci = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, 1)
        if jax.default_backend() == "cpu":
            # block XLA-CPU from hoisting its f32-emulation converts of
            # bf16 dots above the loop (whole-cache phantom copies)
            kci, vci = jax.lax.optimization_barrier((kci, vci))
        if k_positions is not None:
            kp = jax.lax.dynamic_slice_in_dim(
                k_positions.astype(jnp.int32), i * chunk, chunk, 0)
        else:
            kp = (k_start + i * chunk
                  + jnp.arange(chunk, dtype=jnp.int32))
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kci,
                       preferred_element_type=jnp.float32) * scale
        okm = mask_for(kp)
        ok = (okm[:, :, None, None, :] if per_row
              else okm[None, :, None, None, :])
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(k.dtype), vci,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc)

    m0 = jnp.full((B, Sq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, Dh), jnp.float32)
    if nc == 1:
        m, l, acc = body(0, (m0, l0, a0))
    else:
        m, l, acc = jax.lax.fori_loop(0, nc, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash-style differentiable attention (custom VJP).
#
# A plain lax.scan over KV chunks is memory-efficient *forward*, but its
# backward stacks the per-chunk score residuals — the full S×S attention
# matrix in f32. The custom VJP below recomputes scores per chunk from the
# saved (q, k, v, out, lse), which keeps the training-time footprint at
# O(S·d) like FlashAttention.
# ---------------------------------------------------------------------------

def _fa_masks(q_pos, k_pos, causal, window):
    # no in-place ops: operands may be host numpy constants
    ok = k_pos[None, :] >= 0
    if causal:
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    if window:
        ok = ok & (k_pos[None, :] > q_pos[:, None] - window)
    return ok


def _fa_forward(qg, kc, vc, kpos_c, q_pos, causal, window, scale):
    """qg: (B,Sq,K,G,D); kc/vc: (nc,B,chunk,K,D). Returns (out, lse)."""
    B, Sq, K, G, Dh = qg.shape
    m0 = jnp.full((B, Sq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, Dh), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        kci, vci, kp = inp
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg.astype(jnp.float32),
                       kci.astype(jnp.float32)) * scale
        ok = _fa_masks(q_pos, kp, causal, window)[None, :, None, None, :]
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vci.astype(jnp.float32))
        return (m_new, l, acc), None

    if kc.shape[0] == 1:
        (m, l, acc), _ = body((m0, l0, a0), (kc[0], vc[0], kpos_c[0]))
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (kc, vc, kpos_c))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, lse


def flash_attention(q, k, v, *, causal, window, chunk, q_pos=None):
    """Differentiable memory-efficient attention. Keys are contiguous from
    position 0; positions are host-side numpy constants (a custom_vjp may
    not close over tracers), so this path is for full-sequence train /
    prefill — decode uses :func:`attention`."""
    del q_pos  # positions are always 0..Sq-1 here
    B, Sq, H, Dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    if Sk % chunk != 0 or Sk <= chunk:
        chunk = Sk
    nc = Sk // chunk
    scale = float(1.0 / np.sqrt(Dh))
    q_pos = np.arange(Sq, dtype=np.int32)
    kpos_c = (np.arange(nc, dtype=np.int32)[:, None] * chunk
              + np.arange(chunk, dtype=np.int32)[None, :])

    @jax.custom_vjp
    def fa(qg, kk, vv):
        kc = jnp.moveaxis(kk.reshape(B, nc, chunk, K, Dh), 1, 0)
        vc = jnp.moveaxis(vv.reshape(B, nc, chunk, K, Dh), 1, 0)
        out, _ = _fa_forward(qg, kc, vc, kpos_c, q_pos, causal, window,
                             scale)
        return out

    def fa_fwd(qg, kk, vv):
        kc = jnp.moveaxis(kk.reshape(B, nc, chunk, K, Dh), 1, 0)
        vc = jnp.moveaxis(vv.reshape(B, nc, chunk, K, Dh), 1, 0)
        out, lse = _fa_forward(qg, kc, vc, kpos_c, q_pos, causal, window,
                               scale)
        return out, (qg, kk, vv, out, lse)

    def fa_bwd(res, dout):
        qg, kk, vv, out, lse = res
        qf = qg.astype(jnp.float32)
        do = dout.astype(jnp.float32)
        delta = jnp.sum(do * out, axis=-1)            # (B,Sq,K,G)
        kc = jnp.moveaxis(kk.reshape(B, nc, chunk, K, Dh), 1, 0)
        vc = jnp.moveaxis(vv.reshape(B, nc, chunk, K, Dh), 1, 0)

        def body(dq, inp):
            kci, vci, kp = inp
            s = jnp.einsum("bqkgd,bckd->bqkgc", qf,
                           kci.astype(jnp.float32)) * scale
            ok = _fa_masks(q_pos, kp, causal, window)[None, :, None,
                                                      None, :]
            p = jnp.where(ok, jnp.exp(s - lse[..., None]), 0.0)
            dv_c = jnp.einsum("bqkgc,bqkgd->bckd", p, do)
            dp = jnp.einsum("bqkgd,bckd->bqkgc", do,
                            vci.astype(jnp.float32))
            ds = p * (dp - delta[..., None]) * scale
            dq = dq + jnp.einsum("bqkgc,bckd->bqkgd", ds,
                                 kci.astype(jnp.float32))
            dk_c = jnp.einsum("bqkgc,bqkgd->bckd", ds, qf)
            return dq, (dk_c, dv_c)

        dq0 = jnp.zeros(qg.shape, jnp.float32)
        if nc == 1:
            dq, (dk_c, dv_c) = body(dq0, (kc[0], vc[0], kpos_c[0]))
            dk, dv = dk_c, dv_c
        else:
            dq, (dks, dvs) = jax.lax.scan(body, dq0, (kc, vc, kpos_c))
            dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, K, Dh)
            dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, K, Dh)
        return (dq.astype(qg.dtype), dk.astype(kk.dtype),
                dv.astype(vv.dtype))

    fa.defvjp(fa_fwd, fa_bwd)
    out = fa(q.reshape(B, Sq, K, G, Dh), k, v)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Gated MLPs
# ---------------------------------------------------------------------------

def gated_mlp(x: jnp.ndarray, wg, wu, wd, qm: QuantMode,
              act: str = "silu", bg=None, bu=None, bd=None) -> jnp.ndarray:
    """SwiGLU / GeGLU: down( act(x@wg) * (x@wu) ). Optional biases appear
    after transformation folding (Eq. 30).

    Weights may be PackedWeight leaves: under ``qm.backend='fused'`` all
    three projections run packed-native, and the down projection's online
    T3 block-Hadamard is folded into the GEMM kernel's activation-quantize
    prologue instead of a separate rotate pass over the d_ff stream."""
    g = qlinear(x, wg, bg, qm, "ffn_in")
    u = qlinear(x, wu, bu, qm, "ffn_in")
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = fn(g.astype(jnp.float32)).astype(x.dtype) * u
    return qlinear(h, wd, bd, qm, "ffn_down")


# ---------------------------------------------------------------------------
# Depthwise causal conv (Mamba/Griffin temporal conv)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  b: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x: (B, L, C); w: (C, K) depthwise; left-pad K-1 (causal)."""
    K = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # gather K shifted views and contract — avoids conv lowering pitfalls
    views = jnp.stack([xp[:, i:i + x.shape[1], :] for i in range(K)], axis=-1)
    y = jnp.einsum("blck,ck->blc", views, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def conv1d_step(conv_state: jnp.ndarray, x_t: jnp.ndarray, w: jnp.ndarray,
                b: Optional[jnp.ndarray]):
    """Single decode step. conv_state: (B, C, K-1) previous inputs,
    x_t: (B, C). Returns (y_t (B, C), new_state)."""
    K = w.shape[-1]
    full = jnp.concatenate([conv_state, x_t[:, :, None]], axis=-1)  # (B,C,K)
    y = jnp.einsum("bck,ck->bc", full, w.astype(x_t.dtype))
    if b is not None:
        y = y + b.astype(x_t.dtype)
    return y, full[:, :, 1:]


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def shard_batch(x, *rest):
    """Annotate (B, ...) activation with batch sharding."""
    return pctx.shard(x, "batch", *rest)


@jax.custom_jvp
def _grad_transparent_barrier(xs):
    return jax.lax.optimization_barrier(xs)


@_grad_transparent_barrier.defjvp
def _barrier_jvp(primals, tangents):
    # identity semantics: the barrier only pins XLA scheduling, so the
    # tangent passes straight through (optimization_barrier itself has no
    # differentiation rule, which would break transform learning on CPU)
    return _grad_transparent_barrier(primals[0]), tangents[0]


def scan_layers(body, carry, xs, use_scan: bool = True):
    """lax.scan or an unrolled python loop (identical semantics).

    The unrolled form exists for roofline analysis: XLA's cost_analysis
    counts a while-loop body once, so per-layer FLOPs/bytes/collectives are
    measured from unrolled 1- and 2-layer lowerings and extrapolated.

    On the CPU backend the per-layer slices are wrapped in an
    optimization_barrier: XLA-CPU emulates bf16 dots by converting operands
    to f32 and hoists the converts above the while loop, materializing
    f32 copies of *all* layers' weights/caches — phantom buffers that do
    not exist on TPU (native bf16 MXU). The barrier keeps the dry-run
    memory_analysis faithful to the TPU target."""
    if use_scan:
        if jax.default_backend() == "cpu":
            def body_b(c, x):
                return body(c, _grad_transparent_barrier(x))
            return jax.lax.scan(body_b, carry, xs)
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *e: jnp.stack(e), *ys)
    else:
        stacked = None
    return carry, stacked
