"""Unified model API — dispatches on ``cfg.family``.

Every family module exposes:
  init(key, cfg, dtype) -> params
  forward(params, cfg, inputs, qm) -> logits (B, S, V)
  prefill(params, cfg, inputs, qm) -> (last_logits (B, V), cache)
  decode(params, cfg, cache, inputs, cur_len, qm) -> (logits, cache)
  init_cache(cfg, batch, max_len, dtype) -> cache pytree
  fold_norms(params, cfg) / fold(params, cfg, tset)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from . import griffin, moe, ssd, transformer

_FAMILY = {
    "dense": transformer,
    "encoder": transformer,
    "vlm": transformer,
    "moe": moe,
    "hybrid": griffin,
    "ssm": ssd,
}


def module_for(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def init(key, cfg: ArchConfig, dtype=jnp.float32):
    return module_for(cfg).init(key, cfg, dtype)


def forward(params, cfg: ArchConfig, inputs, qm: QuantMode = QuantMode.off()):
    return module_for(cfg).forward(params, cfg, inputs, qm)


def prefill(params, cfg: ArchConfig, inputs, qm: QuantMode = QuantMode.off(),
            max_len: int | None = None, kv_quant=None):
    """Run the prompt, return (last logits, cache). ``kv_quant`` — an
    optional :class:`repro.core.quantize.KVCacheQuant` — returns the KV
    cache MX-quantized (``PackedKV`` leaves; attention-cache families
    only, see ``docs/kv-cache.md``)."""
    if cfg.family == "encoder":
        raise ValueError("encoder-only arch has no decode/prefill step")
    if kv_quant is None:
        return module_for(cfg).prefill(params, cfg, inputs, qm,
                                       max_len=max_len)
    if cfg.family == "ssm":
        raise ValueError("ssm family has no attention KV cache to "
                         "quantize; serve it with kv_cache='none'")
    return module_for(cfg).prefill(params, cfg, inputs, qm,
                                   max_len=max_len, kv_quant=kv_quant)


def decode(params, cfg: ArchConfig, cache, inputs, cur_len,
           qm: QuantMode = QuantMode.off()):
    """One decode step. ``cur_len`` may be a traced scalar (shared cache
    fill) or a (B,) vector of per-slot fills — the vector form backs the
    serving engine's continuous-batching scheduler (KV-cache families
    only)."""
    if cfg.family == "encoder":
        raise ValueError("encoder-only arch has no decode step")
    return module_for(cfg).decode(params, cfg, cache, inputs, cur_len, qm)


def prefill_chunk(params, cfg: ArchConfig, cache, inputs, start, last_idx,
                  qm: QuantMode = QuantMode.off()):
    """Chunked prefill: run a fixed-width token chunk at traced positions
    against a partially filled cache (one jit signature for every prompt
    length — the continuous scheduler's admission path). Supported by the
    KV-cache families (dense/vlm/moe); recurrent families raise."""
    mod = module_for(cfg)
    if not hasattr(mod, "prefill_chunk"):
        raise ValueError(
            f"family {cfg.family!r} has no chunked-prefill step "
            f"(recurrent state caches); serve it with the wave scheduler")
    return mod.prefill_chunk(params, cfg, cache, inputs, start, last_idx, qm)


def prefill_chunk_paged(params, cfg: ArchConfig, cache, block_tables,
                        inputs, start, last_idx,
                        qm: QuantMode = QuantMode.off()):
    """Chunked prefill against a paged KV pool addressed through block
    tables (the paged engine's admission path; ``docs/paged-kv.md``).
    ``start`` / ``last_idx`` may be traced i32 scalars (all lanes share
    one chunk offset) or (B,) vectors — batched prefill admission, where
    each lane runs a chunk of its own prompt at its own offset in one
    forward. KV-cache families (dense/moe) only — recurrent ring-buffer
    families raise."""
    mod = module_for(cfg)
    if not hasattr(mod, "prefill_chunk_paged"):
        raise ValueError(
            f"family {cfg.family!r} has no paged-cache step (recurrent "
            f"ring-buffer state cannot be paged); serve it with "
            f"kv_layout='contiguous'")
    return mod.prefill_chunk_paged(params, cfg, cache, block_tables,
                                   inputs, start, last_idx, qm)


def decode_paged(params, cfg: ArchConfig, cache, inputs, cur_len,
                 block_tables, qm: QuantMode = QuantMode.off()):
    """One decode step over a paged KV pool: per-lane (B,) fills and
    (B, maxp) block tables. KV-cache families (dense/moe) only."""
    mod = module_for(cfg)
    if not hasattr(mod, "decode_paged"):
        raise ValueError(
            f"family {cfg.family!r} has no paged-cache step (recurrent "
            f"ring-buffer state cannot be paged); serve it with "
            f"kv_layout='contiguous'")
    return mod.decode_paged(params, cfg, cache, inputs, cur_len,
                            block_tables, qm)


def verify(params, cfg: ArchConfig, cache, inputs, pos, n_valid,
           qm: QuantMode = QuantMode.off()):
    """Multi-token speculative verify step over the contiguous cache:
    each lane scores its current token plus up to C - 1 draft tokens in
    one forward, returning per-slot next-token logits (B, C, V).
    KV-cache families (dense/moe) only — recurrent state advances one
    token at a time and cannot rewind, so those families raise."""
    mod = module_for(cfg)
    if not hasattr(mod, "verify"):
        raise ValueError(
            f"family {cfg.family!r} has no multi-token verify step "
            f"(recurrent state cannot rewind rejected drafts); serve it "
            f"without speculative decoding")
    return mod.verify(params, cfg, cache, inputs, pos, n_valid, qm)


def verify_paged(params, cfg: ArchConfig, cache, inputs, pos, n_valid,
                 block_tables, qm: QuantMode = QuantMode.off()):
    """Multi-token speculative verify step over a paged KV pool (same
    contract as :func:`verify`, rows resolved through block tables).
    KV-cache families (dense/moe) only."""
    mod = module_for(cfg)
    if not hasattr(mod, "verify_paged"):
        raise ValueError(
            f"family {cfg.family!r} has no multi-token verify step "
            f"(recurrent state cannot rewind rejected drafts); serve it "
            f"without speculative decoding")
    return mod.verify_paged(params, cfg, cache, inputs, pos, n_valid,
                            block_tables, qm)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32,
               kv_quant=None):
    """Allocate the decode cache. ``kv_quant`` stores attention KV as MX
    codes + E8M0 scale bytes (quantize-on-append; ``docs/kv-cache.md``)."""
    if kv_quant is None:
        return module_for(cfg).init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "ssm":
        raise ValueError("ssm family has no attention KV cache to "
                         "quantize; serve it with kv_cache='none'")
    return module_for(cfg).init_cache(cfg, batch, max_len, dtype,
                                      kv_quant=kv_quant)


def init_cache_paged(cfg: ArchConfig, n_pages: int, page_size: int,
                     dtype=jnp.float32, kv_quant=None):
    """Allocate a paged KV pool (N pages of P tokens per layer; see
    ``docs/paged-kv.md``). KV-cache families (dense/moe) only."""
    mod = module_for(cfg)
    if not hasattr(mod, "init_cache_paged"):
        raise ValueError(
            f"family {cfg.family!r} has no paged-cache layout (recurrent "
            f"ring-buffer state cannot be paged); serve it with "
            f"kv_layout='contiguous'")
    return mod.init_cache_paged(cfg, n_pages, page_size, dtype,
                                kv_quant=kv_quant)


def fold_norms(params, cfg: ArchConfig):
    return module_for(cfg).fold_norms(params, cfg)


def fold(params, cfg: ArchConfig, tset):
    return module_for(cfg).fold(params, cfg, tset)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def _ce_mean_impl(logits, labels):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
              == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    return jnp.mean(lse - gold)


@jax.custom_vjp
def _ce_mean(logits, labels):
    return _ce_mean_impl(logits, labels)


def _ce_fwd(logits, labels):
    # save only the compact residuals — the f32 softmax is *recomputed* in
    # the backward, which keeps the (tokens × vocab) f32 buffers transient
    # (≈8 GB/device saved on the 100k-vocab training cells).
    return _ce_mean_impl(logits, labels), (logits, labels)


def _ce_bwd(res, g):
    logits, labels = res
    lf = logits.astype(jnp.float32)
    p = jax.nn.softmax(lf, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
              == labels[..., None])
    n = 1
    for s in labels.shape:
        n *= s
    d = (p - onehot.astype(jnp.float32)) * (g / n)
    return d.astype(logits.dtype), None


_ce_mean.defvjp(_ce_fwd, _ce_bwd)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token-level CE. logits (..., V); labels (...) int.

    The gold logit is picked with an iota-compare masked reduce (not
    take_along_axis): it fuses into one pass and — crucially — keeps the
    vocab axis sharded under GSPMD (a gather over a sharded axis would
    all-gather the logits). The unmasked path is a custom-VJP that
    recomputes the softmax in the backward."""
    if mask is None:
        return _ce_mean(logits, labels)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
              == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - gold
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(params, cfg: ArchConfig, batch: dict,
            qm: QuantMode = QuantMode.off(),
            aux_coefs=(0.01, 1e-3)) -> jnp.ndarray:
    """Next-token loss for causal families; per-frame CE for encoders.

    batch: {"inputs": tokens (B,S) or embeds (B,S,d), "labels": (B,S)}.
    """
    inputs, labels = batch["inputs"], batch["labels"]
    if cfg.family == "moe":
        logits, (lbl, zl) = moe.forward(params, cfg, inputs, qm,
                                        return_aux=True)
        ce = cross_entropy(logits, labels, batch.get("mask"))
        return ce + aux_coefs[0] * lbl + aux_coefs[1] * zl
    logits = forward(params, cfg, inputs, qm)
    return cross_entropy(logits, labels, batch.get("mask"))


def kl_divergence(teacher_logits: jnp.ndarray, student_logits: jnp.ndarray,
                  temperature: float = 1.0) -> jnp.ndarray:
    """KL(teacher || student) averaged over tokens (Eq. 8)."""
    t = teacher_logits.astype(jnp.float32) / temperature
    s = student_logits.astype(jnp.float32) / temperature
    pt = jax.nn.softmax(t, axis=-1)
    return jnp.mean(jnp.sum(pt * (jax.nn.log_softmax(t, axis=-1)
                                  - jax.nn.log_softmax(s, axis=-1)),
                            axis=-1))


def perplexity(params, cfg: ArchConfig, tokens: jnp.ndarray,
               qm: QuantMode = QuantMode.off(), chunk: int = 0) -> float:
    """exp(mean NLL) of next-token prediction over a (B, S) token batch."""
    logits = forward(params, cfg, tokens[:, :-1], qm)
    nll = cross_entropy(logits, tokens[:, 1:])
    return float(jnp.exp(nll))
