"""Dense decoder-only / encoder-only transformer (llama-style: pre-RMSNorm,
GQA + RoPE, SwiGLU). Serves families: 'dense', 'encoder' (causal=False, no
decode path), 'vlm' (embed_inputs=False — stub frontend provides embeddings).

Layers are stacked along a leading L axis and executed with ``lax.scan`` so
95-layer configs compile as one block body (small HLO, fast compiles).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import folding as fold_lib
from repro.core.quantize import QuantMode, qlinear
from repro.kernels.packing import PackedKV, PagedKV
from repro.launch import pcontext as pctx
from repro.kernels import ops
from .layers import (apply_rope, attention, attention_paged, dense_init,
                     flash_attention, gated_mlp, kv_heads_view,
                     kv_scatter_chunk_paged, kv_write_chunk_paged,
                     kv_write_rows, kv_write_slice, kv_write_spec,
                     kv_write_spec_paged, kv_write_token_paged, rms_norm,
                     scan_layers, shard_kv)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32):
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    qd, kd = cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 12)

    def stack(k, din, dout, scale=1.0):
        keys = jax.random.split(k, L)
        return jnp.stack([dense_init(keys[i], din, dout, dtype, scale)
                          for i in range(L)])

    blocks = {
        "ln1": jnp.ones((L, d), dtype),
        "wq": stack(ks[0], d, qd),
        "wk": stack(ks[1], d, kd),
        "wv": stack(ks[2], d, kd),
        "wo": stack(ks[3], qd, d, scale=1.0 / jnp.sqrt(2.0 * L)),
        "ln2": jnp.ones((L, d), dtype),
        "wg": stack(ks[4], d, f),
        "wu": stack(ks[5], d, f),
        "wd": stack(ks[6], f, d, scale=1.0 / jnp.sqrt(2.0 * L)),
    }
    if cfg.qkv_bias:
        blocks["bq"] = jnp.zeros((L, qd), dtype)
        blocks["bk"] = jnp.zeros((L, kd), dtype)
        blocks["bv"] = jnp.zeros((L, kd), dtype)

    params = {"blocks": blocks, "ln_f": jnp.ones((d,), dtype)}
    if cfg.embed_inputs:
        params["embed"] = (jax.random.normal(ks[7], (cfg.vocab_size, d),
                                             jnp.float32) * 0.02).astype(dtype)
        if not cfg.tie_embeddings:
            params["head"] = dense_init(ks[8], d, cfg.vocab_size, dtype)
    else:
        params["head"] = dense_init(ks[8], d, cfg.vocab_size, dtype)
    return params


def head_matrix(params, cfg: ArchConfig):
    if "head" in params:
        return params["head"]
    return params["embed"].T  # tied


def head_out(x, params, cfg: ArchConfig, qm: QuantMode):
    y = qlinear(x, head_matrix(params, cfg), params.get("bhead"), qm, "head")
    return y


# ---------------------------------------------------------------------------
# Block sublayers
# ---------------------------------------------------------------------------

def _qkv(x, p, cfg: ArchConfig, qm: QuantMode, pos):
    B, S, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = qlinear(h, p["wq"], p.get("bq"), qm, "qkv")
    k = qlinear(h, p["wk"], p.get("bk"), qm, "qkv")
    v = qlinear(h, p["wv"], p.get("bv"), qm, "qkv")
    q = pctx.shard(q, "batch", None, "model")
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    kh = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, pos, cfg.rope_theta)
    kh = apply_rope(kh, pos, cfg.rope_theta)
    return q, kh.reshape(B, S, cfg.kv_dim), v


def attn_sublayer(x, p, cfg: ArchConfig, qm: QuantMode, pos,
                  window: int = 0):
    """Full-sequence attention (train / prefill). Returns (x', k, v)."""
    B, S, _ = x.shape
    q, k, v = _qkv(x, p, cfg, qm, pos)
    kh = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    vh = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.attn_repeat_kv:
        # materialize kv to H heads: every attention tensor then carries a
        # TP-divisible head axis, so GSPMD keeps the whole attention (fwd
        # and custom-vjp bwd) head-sharded instead of replicating (§Perf)
        g = cfg.n_heads // cfg.n_kv_heads
        kh = jnp.repeat(kh, g, axis=2)
        vh = jnp.repeat(vh, g, axis=2)
        q = pctx.shard(q, "batch", None, "model", None)
        kh = pctx.shard(kh, "batch", None, "model", None)
        vh = pctx.shard(vh, "batch", None, "model", None)
    out = flash_attention(
        q, kh, vh,
        causal=cfg.causal, q_pos=pos, window=window, chunk=cfg.attn_chunk)
    if cfg.attn_repeat_kv:
        out = pctx.shard(out, "batch", None, "model", None)
    out = out.reshape(B, S, cfg.q_dim)
    out = qlinear(out, p["wo"], p.get("bo"), qm, "attn_out")
    return x + out, k, v


def attn_sublayer_decode(x, p, cfg: ArchConfig, qm: QuantMode,
                         cache_k, cache_v, cur_len, window: int = 0):
    """One-token attention against a cache. x: (B, 1, d);
    cache_k/v: (B, Smax, kv_dim). Writes the new kv at index cur_len.

    ``cur_len`` is a traced int32 scalar (all rows share one position —
    the wave scheduler) or a (B,) vector (continuous batching: each row
    writes and attends at its own position). The vector path is
    value-identical per row to the scalar path at that row's position.

    ``cache_k``/``cache_v`` may be MX-packed ``PackedKV`` leaves
    (``Engine(kv_cache=...)``): the new token's k/v are quantized at
    append time and attention consumes the packed cache — in-kernel
    under the fused backend, decode-in-place otherwise."""
    B = x.shape[0]
    cl = jnp.asarray(cur_len)
    if cl.ndim == 1:                                   # per-slot positions
        pos = cl.astype(jnp.int32)[:, None]            # (B, 1)
        q, k, v = _qkv(x, p, cfg, qm, pos)
        cache_k = kv_write_rows(cache_k, k, cl)
        cache_v = kv_write_rows(cache_v, v, cl)
        kv_len = cl.astype(jnp.int32) + 1              # (B,)
    else:
        pos = jnp.reshape(cur_len, (1,)).astype(jnp.int32)
        q, k, v = _qkv(x, p, cfg, qm, pos)
        cache_k = kv_write_slice(cache_k, k, cur_len)
        cache_v = kv_write_slice(cache_v, v, cur_len)
        kv_len = cur_len + 1
    cache_k = shard_kv(cache_k, "batch", None, "model")
    cache_v = shard_kv(cache_v, "batch", None, "model")
    out = attention(q,
                    kv_heads_view(cache_k, cfg.n_kv_heads, cfg.head_dim),
                    kv_heads_view(cache_v, cfg.n_kv_heads, cfg.head_dim),
                    causal=True, q_pos=pos, kv_len=kv_len,
                    window=window, chunk=cfg.attn_chunk,
                    backend=qm.backend)
    out = out.reshape(B, 1, cfg.q_dim)
    out = qlinear(out, p["wo"], p.get("bo"), qm, "attn_out")
    return x + out, cache_k, cache_v


def attn_sublayer_chunk(x, p, cfg: ArchConfig, qm: QuantMode,
                        cache_k, cache_v, pos, kv_len, window: int = 0):
    """Chunked-prefill attention: C prompt tokens attend against a
    partially filled cache. x: (B, C, d); cache_k/v: (B, Smax, kv_dim);
    pos: (C,) absolute positions (contiguous, traced start); kv_len:
    traced scalar — cache fill after this chunk's writes (pos[-1] + 1).
    Writes the chunk's kv at pos[0]..pos[-1] and returns (x', ck, cv).

    Together with the online-softmax chunking inside :func:`attention`
    this accumulates over exactly the same KV-chunk sequence as the
    full-sequence prefill, so chunked prefill is value-identical to
    :func:`prefill` for f32 models (masked trailing chunks are exact
    no-ops of the streaming softmax)."""
    B, C = x.shape[0], x.shape[1]
    q, k, v = _qkv(x, p, cfg, qm, pos)
    start = pos[0]
    cache_k = kv_write_slice(cache_k, k, start)
    cache_v = kv_write_slice(cache_v, v, start)
    cache_k = shard_kv(cache_k, "batch", None, "model")
    cache_v = shard_kv(cache_v, "batch", None, "model")
    out = attention(q,
                    kv_heads_view(cache_k, cfg.n_kv_heads, cfg.head_dim),
                    kv_heads_view(cache_v, cfg.n_kv_heads, cfg.head_dim),
                    causal=True, q_pos=pos, kv_len=kv_len,
                    window=window, chunk=cfg.attn_chunk,
                    backend=qm.backend)
    out = out.reshape(B, C, cfg.q_dim)
    out = qlinear(out, p["wo"], p.get("bo"), qm, "attn_out")
    return x + out, cache_k, cache_v


def attn_sublayer_decode_paged(x, p, cfg: ArchConfig, qm: QuantMode,
                               cache_k: PagedKV, cache_v: PagedKV,
                               block_tables, cur_len, window: int = 0):
    """One-token attention against a *paged* KV pool. x: (B, 1, d);
    cache_k/v: layer-sliced ``PagedKV`` pools (N, P, ·); block_tables:
    (B, maxp) i32; cur_len: (B,) i32 per-lane fills (paged serving is
    continuous-batching only, so the vector form is the only form).

    The new token's k/v are scattered at the page-relative position
    ``(block_tables[b, cur_len[b] // P], cur_len[b] % P)`` and attention
    reads the pool through the same table — the paged Pallas kernel
    under the fused backend, a gather + dense jnp attention otherwise.
    Value-identical per lane to :func:`attn_sublayer_decode` at that
    lane's position."""
    B = x.shape[0]
    cl = jnp.asarray(cur_len).astype(jnp.int32)            # (B,)
    pos = cl[:, None]                                      # (B, 1)
    q, k, v = _qkv(x, p, cfg, qm, pos)
    P = cache_k.page_size
    pages = jnp.take_along_axis(block_tables, (cl // P)[:, None],
                                axis=1)[:, 0]
    offs = cl % P
    cache_k = kv_write_token_paged(cache_k, k, pages, offs)
    cache_v = kv_write_token_paged(cache_v, v, pages, offs)
    out = attention_paged(q, cache_k, cache_v, block_tables, causal=True,
                          q_pos=pos, kv_len=cl + 1, window=window,
                          chunk=cfg.attn_chunk, backend=qm.backend)
    out = out.reshape(B, 1, cfg.q_dim)
    out = qlinear(out, p["wo"], p.get("bo"), qm, "attn_out")
    return x + out, cache_k, cache_v


def attn_sublayer_verify(x, p, cfg: ArchConfig, qm: QuantMode,
                         cache_k, cache_v, pos, n_valid, window: int = 0):
    """Multi-token verify attention for speculative decoding: each lane
    carries C = K + 1 tokens — its current token plus K draft tokens —
    written at per-lane positions ``pos[b] .. pos[b] + C - 1`` (slots at
    or past ``n_valid[b]`` are dropped, not clamped) and attending with
    per-row query positions against its own causal prefix.  The masked
    key set of row j (keys 0..pos+j) equals what a sequential
    :func:`attn_sublayer_decode` step at position pos+j would see, so the
    verify step is value-identical per (lane, slot) to replaying the
    drafts one decode step at a time."""
    B, C = x.shape[0], x.shape[1]
    S = cache_k.shape[1]
    cl = jnp.asarray(pos).astype(jnp.int32)                  # (B,)
    iota = jnp.arange(C, dtype=jnp.int32)[None, :]           # (1, C)
    qpos = cl[:, None] + iota                                # (B, C)
    q, k, v = _qkv(x, p, cfg, qm, qpos)
    nv = jnp.asarray(n_valid).astype(jnp.int32)              # (B,)
    rows = jnp.where(iota < nv[:, None], qpos, S)
    cache_k = kv_write_spec(cache_k, k, rows)
    cache_v = kv_write_spec(cache_v, v, rows)
    cache_k = shard_kv(cache_k, "batch", None, "model")
    cache_v = shard_kv(cache_v, "batch", None, "model")
    out = attention(q,
                    kv_heads_view(cache_k, cfg.n_kv_heads, cfg.head_dim),
                    kv_heads_view(cache_v, cfg.n_kv_heads, cfg.head_dim),
                    causal=True, q_pos=qpos, kv_len=cl + nv,
                    window=window, chunk=cfg.attn_chunk,
                    backend=qm.backend)
    out = out.reshape(B, C, cfg.q_dim)
    out = qlinear(out, p["wo"], p.get("bo"), qm, "attn_out")
    return x + out, cache_k, cache_v


def attn_sublayer_verify_paged(x, p, cfg: ArchConfig, qm: QuantMode,
                               cache_k: PagedKV, cache_v: PagedKV,
                               block_tables, pos, n_valid,
                               window: int = 0):
    """Paged form of :func:`attn_sublayer_verify`: the C tokens write
    through the block tables (invalid slots dropped via an out-of-page
    offset) and attention reads the pool via the gather + dense path
    (the fused paged kernel is Sq == 1 only; the gather is
    value-identical, see :func:`attention_paged`)."""
    B, C = x.shape[0], x.shape[1]
    cl = jnp.asarray(pos).astype(jnp.int32)                  # (B,)
    nv = jnp.asarray(n_valid).astype(jnp.int32)              # (B,)
    qpos = cl[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    q, k, v = _qkv(x, p, cfg, qm, qpos)
    cache_k = kv_write_spec_paged(cache_k, k, block_tables, cl, nv)
    cache_v = kv_write_spec_paged(cache_v, v, block_tables, cl, nv)
    out = attention_paged(q, cache_k, cache_v, block_tables, causal=True,
                          q_pos=qpos, kv_len=cl + nv, window=window,
                          chunk=cfg.attn_chunk, backend=qm.backend)
    out = out.reshape(B, C, cfg.q_dim)
    out = qlinear(out, p["wo"], p.get("bo"), qm, "attn_out")
    return x + out, cache_k, cache_v


def attn_sublayer_chunk_paged(x, p, cfg: ArchConfig, qm: QuantMode,
                              cache_k: PagedKV, cache_v: PagedKV,
                              block_tables, pos, kv_len, window: int = 0):
    """Chunked-prefill attention against a paged pool: C prompt tokens
    write through the block tables and attend the partially filled
    logical sequence. Same contract as :func:`attn_sublayer_chunk` with
    the cache rows resolved per page. ``pos`` is (C,) contiguous
    positions shared by all lanes, or (B, C) per-lane positions (batched
    prefill admission — each lane's chunk starts at its own offset);
    ``kv_len`` is then a (B,) vector.

    Dispatch: with a quantized pool under the fused backend the whole
    step runs through ``ops.mx_flash_prefill`` — the kernel reads prefix
    pages via the block-table grid, quantizes the chunk's K/V in-tile,
    and returns the packed bytes, which :func:`kv_scatter_chunk_paged`
    commits to the pool (byte-identical to the fallback's
    quantize-then-write, so both paths stay bit-identical end to end).
    Everything else (dense pools, the 'ref' backend) quantizes on append
    and runs the gather + dense jnp path; either way the chunk grid
    matches the contiguous path (extra fully-masked trailing pages are
    exact no-ops of the online softmax)."""
    B, C = x.shape[0], x.shape[1]
    q, k, v = _qkv(x, p, cfg, qm, pos)
    posm = jnp.asarray(pos, jnp.int32)
    start = posm[:, 0] if posm.ndim == 2 else posm[0]
    if (qm.backend == "fused" and cache_k.fmt != "none"
            and kv_len is not None):
        startv = jnp.broadcast_to(jnp.reshape(start, (-1,)), (B,))
        klv = jnp.broadcast_to(
            jnp.reshape(jnp.asarray(kv_len, jnp.int32), (-1,)), (B,))
        out, kc, ksb, vc, vsb = ops.mx_flash_prefill(
            q, k, v, cache_k.codes, cache_k.scales, cache_v.codes,
            cache_v.scales, block_tables, startv, klv, cache_k.fmt,
            window=window)
        cache_k = kv_scatter_chunk_paged(cache_k, kc, ksb, block_tables,
                                         startv)
        cache_v = kv_scatter_chunk_paged(cache_v, vc, vsb, block_tables,
                                         startv)
        out = out.astype(x.dtype)
    else:
        cache_k = kv_write_chunk_paged(cache_k, k, block_tables, start)
        cache_v = kv_write_chunk_paged(cache_v, v, block_tables, start)
        out = attention_paged(q, cache_k, cache_v, block_tables,
                              causal=True, q_pos=pos, kv_len=kv_len,
                              window=window, chunk=cfg.attn_chunk,
                              backend=qm.backend)
    out = out.reshape(B, C, cfg.q_dim)
    out = qlinear(out, p["wo"], p.get("bo"), qm, "attn_out")
    return x + out, cache_k, cache_v


def ffn_sublayer(x, p, cfg: ArchConfig, qm: QuantMode):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + gated_mlp(h, p["wg"], p["wu"], p["wd"], qm,
                         bg=p.get("bg"), bu=p.get("bu"), bd=p.get("bd"))


# ---------------------------------------------------------------------------
# Forward / prefill / decode
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ArchConfig, inputs):
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], inputs, axis=0)
    else:
        x = inputs  # (B, S, d) stub-frontend embeddings
        if "input_transform" in params:  # folded T1 for stub-frontend archs
            t = params["input_transform"]
            x = x @ t["a"].astype(x.dtype) + t["v"].astype(x.dtype)
    return pctx.shard(x, "batch", None, None)


def forward(params, cfg: ArchConfig, inputs, qm: QuantMode = QuantMode.off()):
    """inputs: (B, S) int tokens or (B, S, d) embeddings -> (B, S, V)."""
    x = embed_inputs(params, cfg, inputs)
    S = x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)

    def body(xc, pl):
        xc, _, _ = attn_sublayer(xc, pl, cfg, qm, pos, window=cfg.window)
        xc = ffn_sublayer(xc, pl, cfg, qm)
        return pctx.shard(xc, "batch", "seq", None), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = scan_layers(body, x, params["blocks"], cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = head_out(x, params, cfg, qm)
    return pctx.shard(logits, "batch", None, "model")


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32,
               kv_quant=None):
    shape = (cfg.n_layers, batch, max_len, cfg.kv_dim)
    if kv_quant is not None:
        return {"k": PackedKV.zeros(shape, kv_quant.fmt, dtype),
                "v": PackedKV.zeros(shape, kv_quant.fmt, dtype)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_cache_paged(cfg: ArchConfig, n_pages: int, page_size: int,
                     dtype=jnp.float32, kv_quant=None):
    """Allocate a paged KV pool: N pages of P tokens per layer, shared by
    every batch lane and addressed through per-request block tables
    (``docs/paged-kv.md``). ``kv_quant`` stores the pages MX-packed
    (codes + E8M0 scale bytes); otherwise pages are dense ``dtype``."""
    fmt = kv_quant.fmt if kv_quant is not None else "none"
    shape = (cfg.n_layers, n_pages, page_size, cfg.kv_dim)
    return {"k": PagedKV.zeros(shape, fmt, dtype),
            "v": PagedKV.zeros(shape, fmt, dtype)}


def prefill(params, cfg: ArchConfig, inputs,
            qm: QuantMode = QuantMode.off(), max_len: int | None = None,
            kv_quant=None):
    """Run the prompt, return (last-position logits (B, V), cache).
    ``max_len`` sizes the cache for subsequent decode steps. ``kv_quant``
    stores the returned cache MX-quantized (the prompt attends its own
    dense k/v — quantization applies to what decode reads back)."""
    x = embed_inputs(params, cfg, inputs)
    B, S = x.shape[0], x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)

    def body(xc, pl):
        xc, k, v = attn_sublayer(xc, pl, cfg, qm, pos, window=cfg.window)
        xc = ffn_sublayer(xc, pl, cfg, qm)
        return pctx.shard(xc, "batch", "seq", None), (k, v)

    x, (ks, vs) = scan_layers(body, x, params["blocks"], cfg.scan_layers)
    x = rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = head_out(x[:, 0], params, cfg, qm)
    if max_len is not None and max_len > S:
        L = cfg.n_layers
        pad = jnp.zeros((L, B, max_len - S, cfg.kv_dim), ks.dtype)
        ks = jnp.concatenate([ks, pad], axis=2)
        vs = jnp.concatenate([vs, pad], axis=2)
    if kv_quant is not None:
        ks = PackedKV.from_dense(ks, kv_quant.fmt)
        vs = PackedKV.from_dense(vs, kv_quant.fmt)
    cache = {"k": shard_kv(ks, None, "batch", None, "model"),
             "v": shard_kv(vs, None, "batch", None, "model")}
    return logits, cache


def prefill_chunk(params, cfg: ArchConfig, cache, inputs, start, last_idx,
                  qm: QuantMode = QuantMode.off()):
    """Chunked prefill: run C prompt tokens at absolute positions
    start..start+C-1 against a partially filled cache.

    inputs: (B, C) int32 tokens; start: traced int32 scalar (a multiple of
    the attention chunk keeps the online-softmax chunk grid aligned with
    full-sequence prefill); last_idx: traced int32 — index *within the
    chunk* of the last real prompt token (trailing pad tokens in the final
    chunk write cache entries beyond the prompt, which stay masked until
    decode overwrites them). Returns (logits (B, V) at last_idx, cache).

    Because start/last_idx are traced and C is fixed, every prompt length
    shares one jit signature — the continuous-batching scheduler admits
    any request without recompiling."""
    x = embed_inputs(params, cfg, inputs)
    B, C = x.shape[0], x.shape[1]
    pos = start + jnp.arange(C, dtype=jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = attn_sublayer_chunk(xc, pl, cfg, qm, ck, cv, pos,
                                         start + C, window=cfg.window)
        xc = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    xl = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    xl = rms_norm(xl, params["ln_f"], cfg.norm_eps)
    logits = head_out(xl[:, 0], params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def prefill_chunk_paged(params, cfg: ArchConfig, cache, block_tables,
                        inputs, start, last_idx,
                        qm: QuantMode = QuantMode.off()):
    """Chunked prefill against a paged pool: C tokens at absolute
    positions start..start+C-1 write through ``block_tables`` (B, maxp).
    Same one-jit-signature contract as :func:`prefill_chunk` — start /
    last_idx traced, C fixed — with the cache rows resolved per page.

    ``start`` / ``last_idx`` are traced i32 scalars shared by all lanes,
    or (B,) vectors (batched prefill admission: each lane runs its own
    chunk of its own prompt in one forward — per-lane RoPE positions,
    per-lane table rows, per-lane last-token readout). Every per-lane op
    on the path is row-independent, so lane b of a batched call is
    value-identical to a scalar-start call with lane b's offsets.
    Returns (logits (B, V) at last_idx, cache)."""
    x = embed_inputs(params, cfg, inputs)
    C = x.shape[1]
    st = jnp.asarray(start, jnp.int32)
    if st.ndim == 1:        # (B,) per-lane chunk starts
        pos = st[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    else:
        pos = st + jnp.arange(C, dtype=jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = attn_sublayer_chunk_paged(xc, pl, cfg, qm, ck, cv,
                                               bt, pos, st + C,
                                               window=cfg.window)
        xc = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    li = jnp.asarray(last_idx, jnp.int32)
    if li.ndim == 1:        # (B,) per-lane last-token indices
        xl = jnp.take_along_axis(x, li[:, None, None], axis=1)
    else:
        xl = jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1)
    xl = rms_norm(xl, params["ln_f"], cfg.norm_eps)
    logits = head_out(xl[:, 0], params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def decode_paged(params, cfg: ArchConfig, cache, inputs, cur_len,
                 block_tables, qm: QuantMode = QuantMode.off()):
    """One decode step over a paged pool. inputs: (B,) int32 tokens;
    cur_len: (B,) i32 per-lane fills; block_tables: (B, maxp) i32.
    Returns (logits (B, V) float, cache). Value-identical per lane to
    :func:`decode` at that lane's position — the paged-vs-contiguous
    parity tests pin it bitwise for dense pools."""
    x = jnp.take(params["embed"], inputs[:, None], axis=0)
    x = pctx.shard(x.astype(jnp.dtype(cache["k"].dtype)),
                   "batch", None, None)
    bt = jnp.asarray(block_tables, jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = attn_sublayer_decode_paged(xc, pl, cfg, qm, ck, cv,
                                                bt, cur_len,
                                                window=cfg.window)
        xc = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = head_out(x[:, 0], params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def decode(params, cfg: ArchConfig, cache, inputs, cur_len,
           qm: QuantMode = QuantMode.off()):
    """One decode step. inputs: (B,) int32 tokens or (B, d) embeddings;
    cur_len: traced int32 — current cache fill, a scalar shared by all
    rows (wave scheduler) or a (B,) vector of per-slot fills (continuous
    scheduler). Returns (logits (B, V) float, cache)."""
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], inputs[:, None], axis=0)
    else:
        x = inputs[:, None, :]
    x = pctx.shard(x.astype(cache["k"].dtype), "batch", None, None)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = attn_sublayer_decode(xc, pl, cfg, qm, ck, cv, cur_len,
                                          window=cfg.window)
        xc = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = head_out(x[:, 0], params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def verify(params, cfg: ArchConfig, cache, inputs, pos, n_valid,
           qm: QuantMode = QuantMode.off()):
    """Speculative verify step over the contiguous cache.

    inputs: (B, C) int32 — each lane's current token followed by C - 1
    draft tokens; pos: (B,) i32 per-lane write starts (the lane's next
    cache row); n_valid: (B,) i32 real token counts per lane (1 + draft
    count; 0 idles the lane — nothing is written).  Returns
    (logits (B, C, V), cache): logits[:, j] is the next-token
    distribution after input token j, value-identical to the logits a
    sequential :func:`decode` replay of the same tokens would produce."""
    x = jnp.take(params["embed"], inputs, axis=0)
    x = pctx.shard(x.astype(cache["k"].dtype), "batch", None, None)
    pv = jnp.asarray(pos, jnp.int32)
    nv = jnp.asarray(n_valid, jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = attn_sublayer_verify(xc, pl, cfg, qm, ck, cv, pv, nv,
                                          window=cfg.window)
        xc = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = head_out(x, params, cfg, qm)
    return logits, {"k": ks, "v": vs}


def verify_paged(params, cfg: ArchConfig, cache, inputs, pos, n_valid,
                 block_tables, qm: QuantMode = QuantMode.off()):
    """Speculative verify step over a paged pool — same contract as
    :func:`verify` with the cache rows resolved through ``block_tables``
    (B, maxp).  The engine preallocates every page a request can reach
    at admission, so a rejected draft rolls back by rewinding the lane's
    position only: the stale rows stay masked (causal + kv_len) until
    the next verify step overwrites them in place."""
    x = jnp.take(params["embed"], inputs, axis=0)
    x = pctx.shard(x.astype(jnp.dtype(cache["k"].dtype)),
                   "batch", None, None)
    pv = jnp.asarray(pos, jnp.int32)
    nv = jnp.asarray(n_valid, jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)

    def body(xc, inp):
        pl, ck, cv = inp
        xc, ck, cv = attn_sublayer_verify_paged(xc, pl, cfg, qm, ck, cv,
                                                bt, pv, nv,
                                                window=cfg.window)
        xc = ffn_sublayer(xc, pl, cfg, qm)
        return xc, (ck, cv)

    x, (ks, vs) = scan_layers(body, x, (params["blocks"],
                               cache["k"], cache["v"]), cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = head_out(x, params, cfg, qm)
    return logits, {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# PTQ integration: norm folding + transform folding (Appendix C)
# ---------------------------------------------------------------------------

def fold_norms(params, cfg: ArchConfig):
    """Fold RMSNorm γ's into adjacent linears (exact)."""
    p = dict(params)
    b = dict(p["blocks"])
    b["ln1"], (b["wq"], b["wk"], b["wv"]) = fold_lib.fold_norm_into(
        b["ln1"], b["wq"], b["wk"], b["wv"])
    b["ln2"], (b["wg"], b["wu"]) = fold_lib.fold_norm_into(
        b["ln2"], b["wg"], b["wu"])
    head = head_matrix(params, cfg)
    lnf, (head,) = fold_lib.fold_norm_into(p["ln_f"], head)
    p["ln_f"] = lnf
    p["head"] = head  # unties if tied
    p["blocks"] = b
    return p


def fold(params, cfg: ArchConfig, tset: fold_lib.TransformSet):
    """Fold T1/T2 (+T3 inverse) into the weights. Differentiable — the
    LATMiX student runs this inside its loss. Requires fold_norms first."""
    p = dict(params)
    b = dict(p["blocks"])
    a1i = tset.a1_inv
    a2i = tset.a2_inv()

    b["wq"], b["bq"] = fold_lib.fold_read(b["wq"], b.get("bq"), a1i, tset.v1)
    b["wk"], b["bk"] = fold_lib.fold_read(b["wk"], b.get("bk"), a1i, tset.v1)
    b["wv"], b["bv"] = fold_lib.fold_value(
        b["wv"], b.get("bv", jnp.zeros_like(b["wk"][..., 0, :])), a1i,
        tset.v1, tset.a2, tset.v2, cfg.n_kv_heads)
    b["wo"], b["bo"] = fold_lib.fold_attn_out(
        b["wo"], None, tset.a1, a2i, tset.v2, cfg.n_heads)
    b["wg"], b["bg"] = fold_lib.fold_read(b["wg"], None, a1i, tset.v1)
    b["wu"], b["bu"] = fold_lib.fold_read(b["wu"], None, a1i, tset.v1)
    wd, bd = fold_lib.fold_write(b["wd"], None, tset.a1)
    if tset.t3_block:
        wd = fold_lib.fold_t3(wd, tset.t3_block)
    b["wd"] = wd

    if cfg.embed_inputs:
        p["embed"] = fold_lib.fold_embed(p["embed"], tset.a1, tset.v1)
    else:
        p["input_transform"] = {"a": tset.a1, "v": tset.v1}
    head, bh = fold_lib.fold_read(head_matrix(params, cfg), None, a1i, tset.v1)
    p["head"], p["bhead"] = head, bh
    p["blocks"] = b
    return p
