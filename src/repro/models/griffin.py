"""Griffin / RecurrentGemma hybrid: RG-LRU recurrent blocks + local (MQA)
attention in a (rec, rec, attn) pattern, each followed by a GeGLU MLP.

26 layers = 8 scanned super-blocks of (rec, rec, attn) + 2 trailing
recurrent layers — the 1:2 attention:recurrence ratio of the paper.

RG-LRU:  r_t = σ(w_a ⊙ u_t + b_a);  i_t = σ(w_x ⊙ u_t + b_x)
         log a_t = −c · softplus(Λ) · r_t           (c = 8)
         h_t = a_t h_{t−1} + √(1 − a_t²) · (i_t ⊙ u_t)
computed with an associative scan (log-depth over sequence length; the
diagonal recurrence is what makes the 500k-token shapes linear-time).
Gates are per-channel (diagonal) — a documented simplification of the
block-diagonal gates in the original (DESIGN.md §7).

Decode uses a **ring-buffer** KV cache of window size for attention layers
and O(1) recurrent state for RG-LRU layers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import folding as fold_lib
from repro.core.quantize import QuantMode, qlinear
from repro.launch import pcontext as pctx
from repro.kernels.packing import PackedKV
from .layers import (apply_rope, attention, causal_conv1d, conv1d_step,
                     dense_init, flash_attention, gated_mlp, kv_heads_view,
                     kv_write_slice, rms_norm, scan_layers)

C_RGLRU = 8.0


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _rec_layer(key, cfg: ArchConfig, dtype):
    d, lru, K = cfg.d_model, cfg.lru_width, cfg.conv_kernel
    ks = jax.random.split(key, 8)
    # init Λ so that a^(c·r) with r≈0.5 sits in [0.9, 0.999]
    a0 = jax.random.uniform(ks[3], (lru,), minval=0.9, maxval=0.999)
    sp = -jnp.log(a0) / (C_RGLRU * 0.5)
    lam = jnp.log(jnp.expm1(sp))
    return {
        "ln1": jnp.ones((d,), dtype),
        "wx": dense_init(ks[0], d, lru, dtype),
        "wy": dense_init(ks[1], d, lru, dtype),
        "conv_w": (jax.random.normal(ks[2], (lru, K), jnp.float32) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((lru,), dtype),
        "lam": lam.astype(jnp.float32),
        "ga_w": jnp.full((lru,), 1.0, jnp.float32),
        "ga_b": jnp.zeros((lru,), jnp.float32),
        "gx_w": jnp.full((lru,), 1.0, jnp.float32),
        "gx_b": jnp.zeros((lru,), jnp.float32),
        "wor": dense_init(ks[4], lru, d, dtype,
                          scale=1.0 / jnp.sqrt(2.0 * cfg.n_layers)),
        "ln2": jnp.ones((d,), dtype),
        "wg": dense_init(ks[5], d, cfg.d_ff, dtype),
        "wu": dense_init(ks[6], d, cfg.d_ff, dtype),
        "wd": dense_init(ks[7], cfg.d_ff, d, dtype,
                         scale=1.0 / jnp.sqrt(2.0 * cfg.n_layers)),
    }


def _attn_layer(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "ln1": jnp.ones((d,), dtype),
        "wq": dense_init(ks[0], d, cfg.q_dim, dtype),
        "wk": dense_init(ks[1], d, cfg.kv_dim, dtype),
        "wv": dense_init(ks[2], d, cfg.kv_dim, dtype),
        "wo": dense_init(ks[3], cfg.q_dim, d, dtype,
                         scale=1.0 / jnp.sqrt(2.0 * cfg.n_layers)),
        "ln2": jnp.ones((d,), dtype),
        "wg": dense_init(ks[4], d, cfg.d_ff, dtype),
        "wu": dense_init(ks[5], d, cfg.d_ff, dtype),
        "wd": dense_init(ks[6], cfg.d_ff, d, dtype,
                         scale=1.0 / jnp.sqrt(2.0 * cfg.n_layers)),
    }


def _stack(maker, key, n, cfg, dtype):
    keys = jax.random.split(key, n)
    layers = [maker(keys[i], cfg, dtype) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32):
    ns, nt = cfg.n_super_blocks, cfg.n_tail_rec
    ks = jax.random.split(key, 8)
    params = {
        "super": {
            "r1": _stack(_rec_layer, ks[0], ns, cfg, dtype),
            "r2": _stack(_rec_layer, ks[1], ns, cfg, dtype),
            "at": _stack(_attn_layer, ks[2], ns, cfg, dtype),
        },
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "embed": (jax.random.normal(ks[3], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[4], cfg.d_model, cfg.vocab_size, dtype)
    if nt:
        params["tail"] = _stack(_rec_layer, ks[5], nt, cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# RG-LRU sublayer
# ---------------------------------------------------------------------------

def _rglru_gates(u, p):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["ga_w"] + p["ga_b"])
    i = jax.nn.sigmoid(uf * p["gx_w"] + p["gx_b"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * uf)
    return a, b


def rec_sublayer(x, p, cfg: ArchConfig, qm: QuantMode, h0=None):
    """x: (B, S, d). Returns (x', (h_last, conv_tail))."""
    K = cfg.conv_kernel
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    u = qlinear(h, p["wx"], p.get("bx"), qm, "rec_in")
    gate = jax.nn.gelu(qlinear(h, p["wy"], p.get("by"), qm,
                               "rec_in").astype(jnp.float32))
    conv_tail = jnp.moveaxis(u[:, -(K - 1):, :], 1, 2)     # (B, lru, K-1)
    u = causal_conv1d(u, p["conv_w"], p["conv_b"])
    a, b = _rglru_gates(u, p)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, hs = jax.lax.associative_scan(op, (a, b), axis=1)
    out = (hs * gate).astype(x.dtype)
    out = qlinear(out, p["wor"], p.get("bor"), qm, "rec_out")
    return x + out, (hs[:, -1], conv_tail)


def rec_sublayer_decode(x, p, cfg: ArchConfig, qm: QuantMode, h_state,
                        conv_state):
    """x: (B, 1, d); h_state: (B, lru) f32; conv_state: (B, lru, K-1)."""
    h = rms_norm(x[:, 0], p["ln1"], cfg.norm_eps)
    u = qlinear(h, p["wx"], p.get("bx"), qm, "rec_in")
    gate = jax.nn.gelu(qlinear(h, p["wy"], p.get("by"), qm,
                               "rec_in").astype(jnp.float32))
    u, conv_state = conv1d_step(conv_state, u, p["conv_w"], p["conv_b"])
    a, b = _rglru_gates(u, p)
    h_new = a * h_state + b
    out = (h_new * gate).astype(x.dtype)
    out = qlinear(out, p["wor"], p.get("bor"), qm, "rec_out")
    return x + out[:, None, :], h_new, conv_state


def mlp_sublayer(x, p, cfg: ArchConfig, qm: QuantMode):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + gated_mlp(h, p["wg"], p["wu"], p["wd"], qm, act="gelu",
                         bg=p.get("bg"), bu=p.get("bu"))


# ---------------------------------------------------------------------------
# Local attention sublayer (MQA, windowed) — full-seq and ring-decode
# ---------------------------------------------------------------------------

def attn_sublayer(x, p, cfg: ArchConfig, qm: QuantMode, pos):
    B, S, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = qlinear(h, p["wq"], p.get("bq"), qm, "qkv")
    k = qlinear(h, p["wk"], p.get("bk"), qm, "qkv")
    v = qlinear(h, p["wv"], p.get("bv"), qm, "qkv")
    q = apply_rope(q.reshape(B, S, cfg.n_heads, cfg.head_dim), pos,
                   cfg.rope_theta)
    kh = apply_rope(k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim), pos,
                    cfg.rope_theta)
    out = flash_attention(
        q, kh, v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
        causal=True, q_pos=pos, window=cfg.window, chunk=cfg.attn_chunk)
    out = qlinear(out.reshape(B, S, cfg.q_dim), p["wo"], p.get("bo"), qm,
                  "attn_out")
    return x + out, kh.reshape(B, S, cfg.kv_dim), v


def attn_sublayer_decode(x, p, cfg: ArchConfig, qm: QuantMode,
                         ck, cv, cur_len):
    """Ring-buffer decode. ck/cv: (B, A, kv_dim) dense or MX-packed
    ``PackedKV`` (quantize-on-append); slot = cur_len % A. The ring
    buffer carries explicit key positions, which keeps packed caches on
    the decode-in-place attention fallback (the flash-decode kernel
    contract wants contiguous keys)."""
    B = x.shape[0]
    A = ck.shape[1]
    pos = jnp.reshape(cur_len, (1,)).astype(jnp.int32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = qlinear(h, p["wq"], p.get("bq"), qm, "qkv")
    k = qlinear(h, p["wk"], p.get("bk"), qm, "qkv")
    v = qlinear(h, p["wv"], p.get("bv"), qm, "qkv")
    q = apply_rope(q.reshape(B, 1, cfg.n_heads, cfg.head_dim), pos,
                   cfg.rope_theta)
    kh = apply_rope(k.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim), pos,
                    cfg.rope_theta).reshape(B, 1, cfg.kv_dim)
    slot = jnp.mod(cur_len, A)
    ck = kv_write_slice(ck, kh, slot)
    cv = kv_write_slice(cv, v, slot)
    # slot s holds absolute position: cur_len - ((cur_len - s) mod A)
    s_idx = jnp.arange(A, dtype=jnp.int32)
    k_pos = cur_len - jnp.mod(cur_len - s_idx, A)
    k_pos = jnp.where(k_pos >= 0, k_pos, -1)
    out = attention(q, kv_heads_view(ck, cfg.n_kv_heads, cfg.head_dim),
                    kv_heads_view(cv, cfg.n_kv_heads, cfg.head_dim),
                    causal=True, q_pos=pos, window=cfg.window,
                    k_positions=k_pos, chunk=cfg.attn_chunk,
                    backend=qm.backend)
    out = qlinear(out.reshape(B, 1, cfg.q_dim), p["wo"], p.get("bo"), qm,
                  "attn_out")
    return x + out, ck, cv


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

def head_matrix(params, cfg):
    return params["head"] if "head" in params else params["embed"].T


def head_out(x, params, cfg, qm):
    return qlinear(x, head_matrix(params, cfg), params.get("bhead"), qm,
                   "head")


def _super_fwd(x, pl, cfg, qm, pos, collect: bool):
    x, _ = rec_sublayer(x, pl["r1"], cfg, qm)
    x = mlp_sublayer(x, pl["r1"], cfg, qm)
    x, _ = rec_sublayer(x, pl["r2"], cfg, qm)
    x = mlp_sublayer(x, pl["r2"], cfg, qm)
    x, k, v = attn_sublayer(x, pl["at"], cfg, qm, pos)
    x = mlp_sublayer(x, pl["at"], cfg, qm)
    return x, (k, v)


def forward(params, cfg: ArchConfig, inputs,
            qm: QuantMode = QuantMode.off()):
    x = jnp.take(params["embed"], inputs, axis=0)
    x = pctx.shard(x, "batch", None, None)
    S = x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)

    def body(xc, pl):
        xc, _ = _super_fwd(xc, pl, cfg, qm, pos, False)
        return pctx.shard(xc, "batch", "seq", None), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = scan_layers(body, x, params["super"], cfg.scan_layers)

    if "tail" in params:
        def tail_body(xc, pl):
            xc, _ = rec_sublayer(xc, pl, cfg, qm)
            xc = mlp_sublayer(xc, pl, cfg, qm)
            return xc, None
        x, _ = scan_layers(tail_body, x, params["tail"], cfg.scan_layers)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return head_out(x, params, cfg, qm)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32,
               kv_quant=None):
    ns, nt = cfg.n_super_blocks, cfg.n_tail_rec
    A = min(max_len, cfg.window)
    lru, K = cfg.lru_width, cfg.conv_kernel
    kv_shape = (ns, batch, A, cfg.kv_dim)
    if kv_quant is not None:
        ck = PackedKV.zeros(kv_shape, kv_quant.fmt, dtype)
        cv = PackedKV.zeros(kv_shape, kv_quant.fmt, dtype)
    else:
        ck = jnp.zeros(kv_shape, dtype)
        cv = jnp.zeros(kv_shape, dtype)
    cache = {
        "attn_k": ck,
        "attn_v": cv,
        "rec_h": jnp.zeros((ns, 2, batch, lru), jnp.float32),
        "rec_conv": jnp.zeros((ns, 2, batch, lru, K - 1), dtype),
    }
    if nt:
        cache["tail_h"] = jnp.zeros((nt, batch, lru), jnp.float32)
        cache["tail_conv"] = jnp.zeros((nt, batch, lru, K - 1), dtype)
    return cache


def prefill(params, cfg: ArchConfig, inputs,
            qm: QuantMode = QuantMode.off(), max_len: int | None = None,
            kv_quant=None):
    x = jnp.take(params["embed"], inputs, axis=0)
    x = pctx.shard(x, "batch", None, None)
    B, S = x.shape[0], x.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    A = min(max(S, max_len or S), cfg.window)

    def body(xc, pl):
        xc, (h1, c1) = rec_sublayer(xc, pl["r1"], cfg, qm)
        xc = mlp_sublayer(xc, pl["r1"], cfg, qm)
        xc, (h2, c2) = rec_sublayer(xc, pl["r2"], cfg, qm)
        xc = mlp_sublayer(xc, pl["r2"], cfg, qm)
        xc, k, v = attn_sublayer(xc, pl["at"], cfg, qm, pos)
        xc = mlp_sublayer(xc, pl["at"], cfg, qm)
        xc = pctx.shard(xc, "batch", "seq", None)
        # ring-pack the last min(S, A) keys: slot = pos % A
        W = min(S, A)
        sel = jnp.arange(S - W, S, dtype=jnp.int32)
        slots = jnp.mod(sel, A)
        ck = jnp.zeros((B, A, cfg.kv_dim), k.dtype).at[:, slots].set(
            k[:, S - W:])
        cv = jnp.zeros((B, A, cfg.kv_dim), v.dtype).at[:, slots].set(
            v[:, S - W:])
        if kv_quant is not None:
            ck = PackedKV.from_dense(ck, kv_quant.fmt)
            cv = PackedKV.from_dense(cv, kv_quant.fmt)
        xc = pctx.shard(xc, "batch", None, None)
        return xc, (ck, cv, jnp.stack([h1, h2]), jnp.stack([c1, c2]))

    x, (cks, cvs, hs, cs) = scan_layers(body, x, params["super"],
                                        cfg.scan_layers)
    cache = {"attn_k": cks, "attn_v": cvs, "rec_h": hs.astype(jnp.float32),
             "rec_conv": cs}

    if "tail" in params:
        def tail_body(xc, pl):
            xc, (h, c) = rec_sublayer(xc, pl, cfg, qm)
            xc = mlp_sublayer(xc, pl, cfg, qm)
            return xc, (h, c)
        x, (th, tc) = scan_layers(tail_body, x, params["tail"],
                                  cfg.scan_layers)
        cache["tail_h"] = th.astype(jnp.float32)
        cache["tail_conv"] = tc

    x = rms_norm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return head_out(x[:, 0], params, cfg, qm), cache


def decode(params, cfg: ArchConfig, cache, inputs, cur_len,
           qm: QuantMode = QuantMode.off()):
    x = jnp.take(params["embed"], inputs[:, None], axis=0)
    x = pctx.shard(x.astype(cache["attn_k"].dtype), "batch", None, None)

    def body(xc, inp):
        pl, ck, cv, hs, cs = inp
        xc, h1, c1 = rec_sublayer_decode(xc, pl["r1"], cfg, qm, hs[0], cs[0])
        xc = mlp_sublayer(xc, pl["r1"], cfg, qm)
        xc, h2, c2 = rec_sublayer_decode(xc, pl["r2"], cfg, qm, hs[1], cs[1])
        xc = mlp_sublayer(xc, pl["r2"], cfg, qm)
        xc, ck, cv = attn_sublayer_decode(xc, pl["at"], cfg, qm, ck, cv,
                                          cur_len)
        xc = mlp_sublayer(xc, pl["at"], cfg, qm)
        return xc, (ck, cv, jnp.stack([h1, h2]), jnp.stack([c1, c2]))

    x, (cks, cvs, hs, cs) = scan_layers(
        body, x, (params["super"], cache["attn_k"], cache["attn_v"],
                  cache["rec_h"], cache["rec_conv"]), cfg.scan_layers)
    new_cache = {"attn_k": cks, "attn_v": cvs, "rec_h": hs, "rec_conv": cs}

    if "tail" in params:
        def tail_body(xc, inp):
            pl, h, c = inp
            xc, h, c = rec_sublayer_decode(xc, pl, cfg, qm, h, c)
            xc = mlp_sublayer(xc, pl, cfg, qm)
            return xc, (h, c)
        x, (th, tc) = scan_layers(
            tail_body, x, (params["tail"], cache["tail_h"],
                           cache["tail_conv"]), cfg.scan_layers)
        new_cache["tail_h"], new_cache["tail_conv"] = th, tc

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return head_out(x[:, 0], params, cfg, qm), new_cache


# ---------------------------------------------------------------------------
# PTQ integration
# ---------------------------------------------------------------------------

def _fold_norms_rec(p):
    p = dict(p)
    p["ln1"], (p["wx"], p["wy"]) = fold_lib.fold_norm_into(
        p["ln1"], p["wx"], p["wy"])
    p["ln2"], (p["wg"], p["wu"]) = fold_lib.fold_norm_into(
        p["ln2"], p["wg"], p["wu"])
    return p


def _fold_norms_attn(p):
    p = dict(p)
    p["ln1"], (p["wq"], p["wk"], p["wv"]) = fold_lib.fold_norm_into(
        p["ln1"], p["wq"], p["wk"], p["wv"])
    p["ln2"], (p["wg"], p["wu"]) = fold_lib.fold_norm_into(
        p["ln2"], p["wg"], p["wu"])
    return p


def fold_norms(params, cfg: ArchConfig):
    p = dict(params)
    sup = dict(p["super"])
    sup["r1"] = _fold_norms_rec(sup["r1"])
    sup["r2"] = _fold_norms_rec(sup["r2"])
    sup["at"] = _fold_norms_attn(sup["at"])
    p["super"] = sup
    if "tail" in p:
        p["tail"] = _fold_norms_rec(p["tail"])
    lnf, (head,) = fold_lib.fold_norm_into(p["ln_f"], head_matrix(p, cfg))
    p["ln_f"], p["head"] = lnf, head
    return p


def _fold_rec(p, a1, a1i, v1, t3_block):
    p = dict(p)
    p["wx"], p["bx"] = fold_lib.fold_read(p["wx"], None, a1i, v1)
    p["wy"], p["by"] = fold_lib.fold_read(p["wy"], None, a1i, v1)
    p["wor"], p["bor"] = fold_lib.fold_write(
        p["wor"], jnp.zeros(p["wor"].shape[:-2] + (p["wor"].shape[-1],),
                            p["wor"].dtype), a1)
    return _fold_mlp(p, a1, a1i, v1, t3_block)


def _fold_mlp(p, a1, a1i, v1, t3_block):
    p["wg"], p["bg"] = fold_lib.fold_read(p["wg"], None, a1i, v1)
    p["wu"], p["bu"] = fold_lib.fold_read(p["wu"], None, a1i, v1)
    wd, _ = fold_lib.fold_write(p["wd"], None, a1)
    if t3_block:
        wd = fold_lib.fold_t3(wd, t3_block)
    p["wd"] = wd
    return p


def _fold_attn(p, cfg, a1, a1i, v1, a2, v2, a2i, t3_block):
    p = dict(p)
    p["wq"], p["bq"] = fold_lib.fold_read(p["wq"], None, a1i, v1)
    p["wk"], p["bk"] = fold_lib.fold_read(p["wk"], None, a1i, v1)
    p["wv"], p["bv"] = fold_lib.fold_value(
        p["wv"], jnp.zeros(p["wk"].shape[:-2] + (p["wk"].shape[-1],),
                           p["wk"].dtype), a1i, v1, a2, v2, cfg.n_kv_heads)
    p["wo"], p["bo"] = fold_lib.fold_attn_out(
        p["wo"], None, a1, a2i, v2, cfg.n_heads)
    return _fold_mlp(p, a1, a1i, v1, t3_block)


def fold(params, cfg: ArchConfig, tset: fold_lib.TransformSet):
    """T1 everywhere; T2 on the attention layers (a2 stacked over the
    n_super attention layers)."""
    p = dict(params)
    a1i = tset.a1_inv
    a2i = tset.a2_inv()
    sup = dict(p["super"])
    sup["r1"] = _fold_rec(sup["r1"], tset.a1, a1i, tset.v1, tset.t3_block)
    sup["r2"] = _fold_rec(sup["r2"], tset.a1, a1i, tset.v1, tset.t3_block)
    sup["at"] = _fold_attn(sup["at"], cfg, tset.a1, a1i, tset.v1,
                           tset.a2, tset.v2, a2i, tset.t3_block)
    p["super"] = sup
    if "tail" in p:
        p["tail"] = _fold_rec(dict(p["tail"]), tset.a1, a1i, tset.v1,
                              tset.t3_block)
    head0 = head_matrix(p, cfg)
    p["embed"] = fold_lib.fold_embed(p["embed"], tset.a1, tset.v1)
    head, bh = fold_lib.fold_read(head0, None, a1i, tset.v1)
    p["head"], p["bhead"] = head, bh
    return p
