"""Pallas TPU kernel: MX quantization (codes + power-of-two block scales).

Tiling: grid over (M/BM, K/BK) with BK a multiple of the MX block (32).
Each kernel instance loads a (BM, BK) tile of x into VMEM, computes the
per-32-element-block max, derives the shared exponent (Eq. 1), snaps the
scaled elements to the FP4/INT4 grid by midpoint comparison (7 VPU compares
— exact, no transcendental rounding), and writes uint8 codes plus f32
scales.

VMEM budget per instance (defaults BM=256, BK=512, f32):
  in 512 KiB + codes 128 KiB + scales 16 KiB  « 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import mx as mxlib

MXBLOCK = 32


def _format_consts(fmt: str):
    el = mxlib.FORMATS[fmt]
    grid = np.asarray(el.grid, np.float32)
    mids = (grid[1:] + grid[:-1]) / 2.0
    return grid, mids, el.r_max, len(el.grid) - 1  # center code


def _decode_tile(codes, grid, center):
    """uint8 symmetric code -> float value, via static compares (the grid
    has <= 8 magnitudes; Pallas forbids captured jnp LUT constants).
    Shared by every GEMM kernel variant that dequantizes codes in-tile."""
    rel = codes.astype(jnp.int32) - center
    sign = jnp.where(rel < 0, -1.0, 1.0).astype(jnp.float32)
    k = jnp.abs(rel)
    val = jnp.zeros(codes.shape, jnp.float32)
    for i, g in enumerate(grid):                  # static python loop
        val += jnp.where(k == i, float(g), 0.0)
    return sign * val


def _quant_tile(xb, grid, mids, r_max, center):
    """xb: (BM, nb, 32) f32 -> (codes int32, scales f32 (BM, nb))."""
    amax = jnp.max(jnp.abs(xb), axis=-1)
    safe = jnp.where(amax > 0, amax, 1.0)
    e = jnp.floor(jnp.log2(safe))
    scale = jnp.where(amax > 0, jnp.exp2(e - r_max), 1.0)
    z = xb / scale[..., None]
    mag = jnp.abs(z)
    idx = jnp.zeros(z.shape, jnp.int32)
    for m in mids:                      # len(grid)-1 static compares
        idx += (mag >= m).astype(jnp.int32)
    codes = center + jnp.where(z < 0, -idx, idx)
    return codes, scale


def _mx_quant_kernel(x_ref, codes_ref, scales_ref, *, fmt):
    grid, mids, r_max, center = _format_consts(fmt)
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    xb = x.reshape(bm, bk // MXBLOCK, MXBLOCK)
    codes, scale = _quant_tile(xb, grid, mids, r_max, center)
    codes_ref[...] = codes.reshape(bm, bk).astype(jnp.uint8)
    scales_ref[...] = scale.astype(jnp.float32)


def mx_quant(x: jnp.ndarray, fmt: str = "mxfp4", *, bm: int = 256,
             bk: int = 512, interpret: bool = True):
    """x: (M, K), K % 32 == 0 -> (codes uint8 (M, K), scales (M, K//32))."""
    M, K = x.shape
    bm = min(bm, M)
    bk = min(bk, K)
    while M % bm:
        bm //= 2
    while K % bk:
        bk //= 2
    assert bk % MXBLOCK == 0
    out_shapes = (
        jax.ShapeDtypeStruct((M, K), jnp.uint8),
        jax.ShapeDtypeStruct((M, K // MXBLOCK), jnp.float32),
    )
    kern = functools.partial(_mx_quant_kernel, fmt=fmt)
    return pl.pallas_call(
        kern,
        grid=(M // bm, K // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // MXBLOCK), lambda i, j: (i, j)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x)
