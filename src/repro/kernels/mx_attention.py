"""Pallas TPU kernel: flash-decode attention over an MX-quantized KV cache.

The serving decode hot path after PR-2 moved every GEMM onto packed
weights: one query token per lane attends against the whole KV cache, so
decode cost is dominated by *streaming the cache out of HBM*. Storing the
cache as MX codes (per-32-block E8M0 scales along the feature axis, see
``packing.PackedKV``) cuts that traffic ~2x (mxfp8/mxint8) or ~4x
(mxfp4/mxint4) vs bf16 — and this kernel consumes the packed bytes
*directly*: codes + scale bytes are DMA'd to VMEM per KV chunk, decoded
in-tile, and fed to an online-softmax accumulation. No dense fp cache is
ever materialized.

Shape contract (the dispatch wrapper ``ops.mx_flash_decode`` enforces it
and falls back to the jnp reference off-contract):

  q         (B, H, Dh) float      — one decode token per lane
  k/v codes (B, S, D*bits/8) u8   — D = kvh*Dh, nibble-packed when 4-bit
  k/v scales(B, S, D//32)    u8   — E8M0 bytes
  q_pos     (B,) i32              — absolute query positions (per lane)
  kv_len    (B,) i32              — cache fill per lane (rows >= kv_len
                                    are stale and masked)
  window    static int            — sliding-window size (0 = full causal)

Grid: (B, S/BS) with the KV-chunk axis innermost, so the (H, Dh) fp32
accumulator plus the (H,) running max / normalizer stay resident in VMEM
across the KV sweep (the GEMM kernels' K-innermost discipline). GQA runs
natively: q is viewed (kvh, G, Dh) and scores contract against the
decoded (BS, kvh, Dh) tile per kv-head.

Masking is per *row* (lane): causal ``kp <= q_pos``, fill ``kp < kv_len``
and window ``kp > q_pos - window`` — identical key selection to
``models.layers.attention``, so the kernel slots under the model's decode
step with no semantic change. Odd tails (kv_len not a multiple of BS) are
masked chunks, which are exact no-ops of the online softmax.

VMEM per instance (BS=512, D=4096, mxfp8): codes 2x 2 MiB + scales 2x
64 KiB + q/acc « 16 MiB. On CPU the kernel runs in interpret mode
(correctness only); the TPU story is the roofline rows in
``benchmarks/kernels_bench.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .mx_quant import MXBLOCK, _decode_tile, _format_consts, _quant_tile
from . import packing

NEG_INF = -1e30
E8M0_BIAS = 127


def _pick_chunk(S: int, bs: int, explicit: bool = False) -> int:
    """KV-chunk width: shrink ``bs`` (halving) until it divides S.

    ``explicit=True`` marks a caller-chosen width: it is honored as-is
    (clamped only to S) and a non-dividing width raises instead of being
    silently halved — the override that lets tests drive the
    multi-chunk / block-table grid in CPU interpret mode, where the
    *default* collapses to a single chunk (the chunk grid exists for
    TPU VMEM)."""
    if explicit:
        bs = min(bs, S)
        if bs < 1 or S % bs:
            raise ValueError(
                f"explicit KV chunk width bs={bs} does not divide the "
                f"cache length S={S}; pick a divisor of S (or leave bs "
                f"unset for the backend default)")
        return bs
    bs = min(bs, S)
    while S % bs:
        bs //= 2
    return max(bs, 1)


def _decode_codes(codes, fmt, grid, center):
    """Symmetric code -> float value. The 4-bit grids decode with the
    shared 8-compare loop (``_decode_tile``); the 8-bit grids would cost
    ~128 VPU compares per element that way, so they decode
    *arithmetically* — their half-grids are closed-form:

      int8:      v(k) = k                      (k = |code - center|)
      fp8 e4m3:  v(k) = k * 2^-9                      for k < 8
                 v(k) = (1 + m/8) * 2^(e-7),  e = (k-8)//8 + 1,
                                              m = (k-8) % 8   otherwise

    both exact in f32 (the values ARE f32-representable grid points), so
    this is bit-identical to the LUT decode — pinned by the kernel-vs-
    oracle tests across every format."""
    rel = codes.astype(jnp.int32) - center
    if fmt in ("mxint8", "mxfp8"):
        sign = jnp.where(rel < 0, -1.0, 1.0).astype(jnp.float32)
        k = jnp.abs(rel)
        if fmt == "mxint8":
            return sign * k.astype(jnp.float32)
        kf = k.astype(jnp.float32)
        e = jnp.floor_divide(k - 8, 8) + 1
        m = jnp.remainder(k - 8, 8).astype(jnp.float32)
        norm = (1.0 + m / 8.0) * jnp.exp2(e.astype(jnp.float32) - 7.0)
        return sign * jnp.where(k < 8, kf * jnp.float32(2.0 ** -9), norm)
    return _decode_tile(codes, grid, center)


def _decode_kv_tile(codes, scales, fmt, grid, center, bits, kvh, dh):
    """(BS, D*bits/8) codes + (BS, D//32) E8M0 bytes -> (BS, kvh, dh) f32."""
    if bits == 4:
        # canonical nibble unpack (pack_codes order: even index in the
        # low nibble) — pure jnp, so it traces inside the kernel body
        codes = packing.unpack_codes(codes)
    vals = _decode_codes(codes, fmt, grid, center)          # (BS, D)
    s = jnp.exp2(scales.astype(jnp.float32) - 127.0)        # (BS, D//32)
    bs, d = vals.shape
    out = (vals.reshape(bs, d // MXBLOCK, MXBLOCK) * s[..., None])
    return out.reshape(bs, kvh, dh)


def _flash_decode_kernel(q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                         pos_ref, len_ref, o_ref, m_ref, l_ref, *,
                         fmt, bits, window, kvh, dh, n_chunks):
    grid, _, _, center = _format_consts(fmt)
    c = pl.program_id(1)
    bs = kc_ref.shape[1]

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                        # (H, Dh)
    H = q.shape[0]
    G = H // kvh
    qg = q.reshape(kvh, G, dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    k = _decode_kv_tile(kc_ref[0], ks_ref[0], fmt, grid, center, bits,
                        kvh, dh)
    v = _decode_kv_tile(vc_ref[0], vs_ref[0], fmt, grid, center, bits,
                        kvh, dh)

    s = jnp.einsum("kgd,skd->kgs", qg, k,
                   preferred_element_type=jnp.float32) * scale

    kp = (c * bs
          + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0])  # (bs,)
    qp = pos_ref[0, 0]
    ok = (kp <= qp) & (kp < len_ref[0, 0])
    if window:
        ok = ok & (kp > qp - window)
    okb = ok[None, None, :]                                  # (1, 1, bs)
    s = jnp.where(okb, s, NEG_INF)

    m_prev = m_ref[0].reshape(kvh, G)
    l_prev = l_ref[0].reshape(kvh, G)
    acc_prev = o_ref[0].reshape(kvh, G, dh)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(okb, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc = acc_prev * corr[..., None] + jnp.einsum(
        "kgs,skd->kgd", p, v, preferred_element_type=jnp.float32)

    m_ref[...] = m_new.reshape(1, H)
    l_ref[...] = l_new.reshape(1, H)

    @pl.when(c < n_chunks - 1)
    def _stash():
        o_ref[...] = acc.reshape(1, H, dh)

    @pl.when(c == n_chunks - 1)
    def _finalize():
        o_ref[...] = (acc / jnp.maximum(l_new, 1e-30)[..., None]
                      ).reshape(1, H, dh)


def mx_flash_decode(q: jnp.ndarray, k_codes: jnp.ndarray,
                    k_scales: jnp.ndarray, v_codes: jnp.ndarray,
                    v_scales: jnp.ndarray, q_pos: jnp.ndarray,
                    kv_len: jnp.ndarray, fmt: str = "mxfp8", *,
                    window: int = 0, bs: int = 512,
                    explicit_bs: bool = False,
                    interpret: bool = True) -> jnp.ndarray:
    """Flash-decode attention over packed MX KV. Returns (B, H, Dh) f32.

    See the module docstring for the shape contract. ``bs`` is the KV
    chunk width (shrunk to divide S; ``explicit_bs=True`` honors it
    exactly and raises when it cannot divide S)."""
    B, H, Dh = q.shape
    bits = packing.kv_fmt_bits(fmt)
    S = k_codes.shape[1]
    D = k_codes.shape[2] * 8 // bits
    kvh = D // Dh
    assert H % kvh == 0 and kvh * Dh == D, (q.shape, k_codes.shape)
    assert D % MXBLOCK == 0, (D,)
    assert k_scales.shape == (B, S, D // MXBLOCK), k_scales.shape
    bs = _pick_chunk(S, bs, explicit=explicit_bs)
    n_chunks = S // bs
    pos2 = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32).reshape(-1),
                            (B,)).reshape(B, 1)
    len2 = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                            (B,)).reshape(B, 1)
    kern = functools.partial(_flash_decode_kernel, fmt=fmt, bits=bits,
                             window=window, kvh=kvh, dh=Dh,
                             n_chunks=n_chunks)
    db = k_codes.shape[2]
    ns = D // MXBLOCK
    out, _, _ = pl.pallas_call(
        kern,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, H, Dh), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, bs, db), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, bs, ns), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, bs, db), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, bs, ns), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, H, Dh), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, H), lambda i, c: (i, 0)),
            pl.BlockSpec((1, H), lambda i, c: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ),
        interpret=interpret,
    )(q, k_codes, k_scales, v_codes, v_scales, pos2, len2)
    return out


# ---------------------------------------------------------------------------
# Paged flash decode: block-table indirection over a shared page pool
# ---------------------------------------------------------------------------
#
# Same online-softmax body as the contiguous kernel — the only change is
# WHERE a KV chunk comes from. The contiguous grid slices lane b's own
# (S, ·) cache at chunk c; the paged grid reads page ``block_tables[b, c]``
# of one pool shared by every lane. The block table rides in as a
# *scalar-prefetch* operand (``pltpu.PrefetchScalarGridSpec``), so the
# BlockSpec index maps can address pages before the body runs — the DMA
# engine gathers the right page per grid step and no dense, contiguous
# copy of the cache is ever materialized. Chunk width == page size: a page
# holds positions [c*P, (c+1)*P) of its lane, so the position iota, the
# per-lane masks, and the accumulator discipline carry over unchanged.
# Table slots past a lane's fill may hold any valid page id (the engine
# parks them on the scrap page); their rows are masked by ``kv_len``
# exactly like the contiguous kernel's stale tail.


def _flash_decode_paged_kernel(bt_ref, q_ref, kc_ref, ks_ref, vc_ref,
                               vs_ref, pos_ref, len_ref, o_ref, m_ref,
                               l_ref, *, fmt, bits, window, kvh, dh,
                               n_chunks):
    # bt_ref (the prefetched block table) is consumed by the index maps;
    # the body is position-identical to the contiguous kernel because a
    # page IS chunk c of its lane's logical cache.
    del bt_ref
    _flash_decode_kernel(q_ref, kc_ref, ks_ref, vc_ref, vs_ref, pos_ref,
                         len_ref, o_ref, m_ref, l_ref, fmt=fmt, bits=bits,
                         window=window, kvh=kvh, dh=dh, n_chunks=n_chunks)


def mx_flash_decode_paged(q: jnp.ndarray, k_codes: jnp.ndarray,
                          k_scales: jnp.ndarray, v_codes: jnp.ndarray,
                          v_scales: jnp.ndarray,
                          block_tables: jnp.ndarray, q_pos: jnp.ndarray,
                          kv_len: jnp.ndarray, fmt: str = "mxfp8", *,
                          window: int = 0,
                          interpret: bool = True) -> jnp.ndarray:
    """Flash-decode attention over a *paged* packed MX KV pool.

    q          (B, H, Dh) float    — one decode token per lane
    k/v codes  (N, P, D*bits/8) u8 — page pool shared by all lanes
    k/v scales (N, P, D//32)    u8 — E8M0 bytes
    block_tables (B, maxp) i32     — page id of lane b's chunk c
    q_pos/kv_len (B,) i32          — per-lane positions / fills

    Returns (B, H, Dh) f32. Grid (B, maxp) with the page axis innermost;
    page ``block_tables[b, c]`` supplies logical positions
    [c*P, (c+1)*P) of lane b."""
    B, H, Dh = q.shape
    bits = packing.kv_fmt_bits(fmt)
    N, P, db = k_codes.shape
    D = db * 8 // bits
    kvh = D // Dh
    maxp = block_tables.shape[1]
    assert H % kvh == 0 and kvh * Dh == D, (q.shape, k_codes.shape)
    assert D % MXBLOCK == 0, (D,)
    ns = D // MXBLOCK
    assert k_scales.shape == (N, P, ns), k_scales.shape
    pos2 = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32).reshape(-1),
                            (B,)).reshape(B, 1)
    len2 = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                            (B,)).reshape(B, 1)
    kern = functools.partial(_flash_decode_paged_kernel, fmt=fmt,
                             bits=bits, window=window, kvh=kvh, dh=Dh,
                             n_chunks=maxp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, maxp),
        in_specs=[
            pl.BlockSpec((1, H, Dh), lambda i, c, bt: (i, 0, 0)),
            pl.BlockSpec((1, P, db), lambda i, c, bt: (bt[i, c], 0, 0)),
            pl.BlockSpec((1, P, ns), lambda i, c, bt: (bt[i, c], 0, 0)),
            pl.BlockSpec((1, P, db), lambda i, c, bt: (bt[i, c], 0, 0)),
            pl.BlockSpec((1, P, ns), lambda i, c, bt: (bt[i, c], 0, 0)),
            pl.BlockSpec((1, 1), lambda i, c, bt: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, c, bt: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, H, Dh), lambda i, c, bt: (i, 0, 0)),
            pl.BlockSpec((1, H), lambda i, c, bt: (i, 0)),
            pl.BlockSpec((1, H), lambda i, c, bt: (i, 0)),
        ),
    )
    out, _, _ = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), q, k_codes, k_scales,
      v_codes, v_scales, pos2, len2)
    return out


# ---------------------------------------------------------------------------
# Paged flash prefill: (q_block x kv_block) grid + fused quantize-on-append
# ---------------------------------------------------------------------------
#
# Chunked prefill attends a (B, C) token chunk against (a) the lane's
# committed prefix, which lives as packed MX pages in the pool, and (b) the
# chunk itself (causal self-attention). The jnp path pays for that twice:
# it quantizes the chunk, scatters it into the pool, then gathers + decodes
# the WHOLE logical cache densely. This kernel reads the prefix pages
# through the same scalar-prefetch block-table ABI as
# ``mx_flash_decode_paged`` (decoded in-tile by ``_decode_kv_tile``) and
# handles the chunk itself by quantizing the dense K/V tile *inside the
# kernel* (``_quant_kv_tile`` — the ``mx_quant`` tile body followed by the
# packed-byte layout of ``packing.kv_encode``): the packed bytes stream out
# as extra kernel outputs for the caller to scatter into the pool, and the
# decode of those same bytes feeds the attention tile. Dense chunk K/V
# never round-trips HBM, and attending the roundtripped values keeps the
# kernel bit-identical to write-then-read of the fallback path.
#
# Grid (B, C/qb, maxp + C/kvb), KV axis innermost so the per-(lane, q-block)
# f32 accumulator + running max / normalizer stay VMEM-resident across the
# sweep. KV steps c < maxp read page ``block_tables[b, c]`` (positions
# [c*P, (c+1)*P), valid iff ``kp < start`` — the committed prefix — so a
# mid-page prefix-cache resume never double-counts rows the chunk re-fills);
# steps c >= maxp read kv-block c - maxp of the dense chunk at positions
# ``start + (c - maxp)*kvb + iota``. Causal / fill / window masks apply to
# both sources exactly as in ``models.layers.attention``.


def _quant_kv_tile(x, fmt, grid, mids, r_max, center, bits):
    """In-kernel MX encode of a dense (bs, D) f32 tile.

    Returns (code bytes (bs, D*bits/8) u8, E8M0 scale bytes (bs, D//32)
    u8, roundtrip values (bs, D) f32). The bytes are bit-identical to
    ``packing.kv_encode`` (same ``_quant_tile`` snap, same nibble order,
    same E8M0 bias) and the roundtrip is computed by decoding those very
    bytes, so attending the roundtrip == writing the bytes to the pool
    and reading them back."""
    bs, d = x.shape
    xb = x.reshape(bs, d // MXBLOCK, MXBLOCK)
    codes, scale = _quant_tile(xb, grid, mids, r_max, center)
    sbyte = (jnp.round(jnp.log2(scale)).astype(jnp.int32)
             + E8M0_BIAS)                          # == pack_scales_e8m0
    codes = codes.reshape(bs, d)
    vals = _decode_codes(codes, fmt, grid, center)
    s = jnp.exp2(sbyte.astype(jnp.float32) - E8M0_BIAS)
    rt = (vals.reshape(bs, d // MXBLOCK, MXBLOCK) * s[..., None]
          ).reshape(bs, d)
    if bits == 4:
        cb = codes.reshape(bs, d // 2, 2)          # pack_codes nibble order
        cbytes = (cb[..., 0] | (cb[..., 1] << 4)).astype(jnp.uint8)
    else:
        cbytes = codes.astype(jnp.uint8)
    return cbytes, sbyte.astype(jnp.uint8), rt


def _flash_prefill_kernel(bt_ref, q_ref, kcp_ref, ksp_ref, vcp_ref,
                          vsp_ref, kd_ref, vd_ref, start_ref, len_ref,
                          o_ref, m_ref, l_ref, kc_ref, ks_ref, vc_ref,
                          vs_ref, *, fmt, bits, window, kvh, dh, maxp,
                          n_cb, qb, kvb, page):
    del bt_ref          # consumed by the index maps (scalar prefetch)
    grid, mids, r_max, center = _format_consts(fmt)
    j = pl.program_id(1)
    c = pl.program_id(2)
    n_kv = maxp + n_cb

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)               # (qb, H, Dh)
    H = q.shape[1]
    G = H // kvh
    qg = q.reshape(qb, kvh, G, dh)
    sm = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    start = start_ref[0, 0]
    kl = len_ref[0, 0]
    qp = (start + j * qb
          + jax.lax.broadcasted_iota(jnp.int32, (1, qb), 1)[0])   # (qb,)

    def _update(k, v, kp, src_ok):
        # One online-softmax step over an (s, kvh, dh) KV tile at logical
        # positions kp, with src_ok masking rows the source doesn't own.
        s = jnp.einsum("qkgd,skd->qkgs", qg, k,
                       preferred_element_type=jnp.float32) * sm
        ok = src_ok & (kp < kl)
        okb = ok[None, :] & (kp[None, :] <= qp[:, None])
        if window:
            okb = okb & (kp[None, :] > qp[:, None] - window)
        okb = okb[:, None, None, :]                # (qb, 1, 1, s)
        s = jnp.where(okb, s, NEG_INF)
        m_prev = m_ref[0].reshape(qb, kvh, G)
        l_prev = l_ref[0].reshape(qb, kvh, G)
        acc_prev = o_ref[0].reshape(qb, kvh, G, dh)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(okb, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc_prev * corr[..., None] + jnp.einsum(
            "qkgs,skd->qkgd", p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new.reshape(1, qb, H)
        l_ref[...] = l_new.reshape(1, qb, H)
        o_ref[...] = acc.reshape(1, qb, H, dh)

    @pl.when(c < maxp)
    def _prefix_page():
        k = _decode_kv_tile(kcp_ref[0], ksp_ref[0], fmt, grid, center,
                            bits, kvh, dh)
        v = _decode_kv_tile(vcp_ref[0], vsp_ref[0], fmt, grid, center,
                            bits, kvh, dh)
        kp = (c * page
              + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)[0])
        _update(k, v, kp, kp < start)

    @pl.when(c >= maxp)
    def _chunk_block():
        cc = c - maxp
        kb, ksb, krt = _quant_kv_tile(kd_ref[0].astype(jnp.float32), fmt,
                                      grid, mids, r_max, center, bits)
        vb, vsb, vrt = _quant_kv_tile(vd_ref[0].astype(jnp.float32), fmt,
                                      grid, mids, r_max, center, bits)
        kc_ref[...] = kb[None]
        ks_ref[...] = ksb[None]
        vc_ref[...] = vb[None]
        vs_ref[...] = vsb[None]
        kp = (start + cc * kvb
              + jax.lax.broadcasted_iota(jnp.int32, (1, kvb), 1)[0])
        _update(krt.reshape(kvb, kvh, dh), vrt.reshape(kvb, kvh, dh), kp,
                jnp.full((kvb,), True))

    @pl.when(c == n_kv - 1)
    def _finalize():
        l = l_ref[0].reshape(qb, kvh, G)
        acc = o_ref[0].reshape(qb, kvh, G, dh)
        o_ref[...] = (acc / jnp.maximum(l, 1e-30)[..., None]
                      ).reshape(1, qb, H, dh)


def mx_flash_prefill(q: jnp.ndarray, k_chunk: jnp.ndarray,
                     v_chunk: jnp.ndarray, k_codes: jnp.ndarray,
                     k_scales: jnp.ndarray, v_codes: jnp.ndarray,
                     v_scales: jnp.ndarray, block_tables: jnp.ndarray,
                     q_start: jnp.ndarray, kv_len: jnp.ndarray,
                     fmt: str = "mxfp8", *, window: int = 0,
                     qb: int | None = None, kvb: int | None = None,
                     explicit_qb: bool = False, explicit_kvb: bool = False,
                     interpret: bool = True):
    """Flash-prefill attention over a paged packed MX KV pool, fused with
    the quantize-on-append of the current chunk.

    q          (B, C, H, Dh) float  — chunk queries (C tokens per lane)
    k/v chunk  (B, C, D) float      — dense chunk K/V (D = kvh*Dh)
    k/v codes  (N, P, D*bits/8) u8  — page pool shared by all lanes
    k/v scales (N, P, D//32)    u8  — E8M0 bytes
    block_tables (B, maxp) i32      — page id of lane b's page c
    q_start    (B,) i32             — chunk start position per lane (pool
                                      rows ``kp < q_start`` are the
                                      committed prefix; rows the chunk
                                      covers come from the in-tile encode)
    kv_len     (B,) i32             — valid-key bound per lane (typically
                                      q_start + C)

    Returns ``(out (B, C, H, Dh) f32, k_code_bytes (B, C, D*bits/8) u8,
    k_scale_bytes (B, C, D//32) u8, v_code_bytes, v_scale_bytes)`` — the
    byte outputs are exactly ``packing.kv_encode`` of the chunk, for the
    caller to scatter into the pool. ``qb``/``kvb`` tile the chunk's query
    and self-KV axes (``explicit_*=True`` honors them exactly and raises
    on non-divisors — the override that drives the multi-block grid in
    CPU interpret mode)."""
    B, C, H, Dh = q.shape
    bits = packing.kv_fmt_bits(fmt)
    N, P, db = k_codes.shape
    D = db * 8 // bits
    kvh = D // Dh
    maxp = block_tables.shape[1]
    assert H % kvh == 0 and kvh * Dh == D, (q.shape, k_codes.shape)
    assert D % MXBLOCK == 0, (D,)
    ns = D // MXBLOCK
    assert k_scales.shape == (N, P, ns), k_scales.shape
    assert k_chunk.shape == (B, C, D), (k_chunk.shape, (B, C, D))
    assert maxp >= 1, "prefill needs at least one table slot per lane"
    qb = _pick_chunk(C, C if qb is None else qb, explicit=explicit_qb)
    kvb = _pick_chunk(C, C if kvb is None else kvb, explicit=explicit_kvb)
    n_qb = C // qb
    n_cb = C // kvb
    start2 = jnp.broadcast_to(jnp.asarray(q_start, jnp.int32).reshape(-1),
                              (B,)).reshape(B, 1)
    len2 = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                            (B,)).reshape(B, 1)
    kern = functools.partial(_flash_prefill_kernel, fmt=fmt, bits=bits,
                             window=window, kvh=kvh, dh=Dh, maxp=maxp,
                             n_cb=n_cb, qb=qb, kvb=kvb, page=P)
    # Index-map clamps: pool specs only matter on steps c < maxp (chunk
    # steps clamp to the last table slot — any valid page id, rows unused);
    # chunk specs only matter on steps c >= maxp (pool steps clamp to
    # chunk block 0, unread). The chunk-byte output blocks are fully
    # written on every chunk step, and the last grid step visiting each
    # block is a chunk step, so revisiting is flush-safe.
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, n_qb, maxp + n_cb),
        in_specs=[
            pl.BlockSpec((1, qb, H, Dh), lambda i, j, c, bt: (i, j, 0, 0)),
            pl.BlockSpec((1, P, db),
                         lambda i, j, c, bt:
                         (bt[i, jnp.minimum(c, maxp - 1)], 0, 0)),
            pl.BlockSpec((1, P, ns),
                         lambda i, j, c, bt:
                         (bt[i, jnp.minimum(c, maxp - 1)], 0, 0)),
            pl.BlockSpec((1, P, db),
                         lambda i, j, c, bt:
                         (bt[i, jnp.minimum(c, maxp - 1)], 0, 0)),
            pl.BlockSpec((1, P, ns),
                         lambda i, j, c, bt:
                         (bt[i, jnp.minimum(c, maxp - 1)], 0, 0)),
            pl.BlockSpec((1, kvb, D),
                         lambda i, j, c, bt:
                         (i, jnp.maximum(c - maxp, 0), 0)),
            pl.BlockSpec((1, kvb, D),
                         lambda i, j, c, bt:
                         (i, jnp.maximum(c - maxp, 0), 0)),
            pl.BlockSpec((1, 1), lambda i, j, c, bt: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, c, bt: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, qb, H, Dh), lambda i, j, c, bt: (i, j, 0, 0)),
            pl.BlockSpec((1, qb, H), lambda i, j, c, bt: (i, j, 0)),
            pl.BlockSpec((1, qb, H), lambda i, j, c, bt: (i, j, 0)),
            pl.BlockSpec((1, kvb, db),
                         lambda i, j, c, bt:
                         (i, jnp.maximum(c - maxp, 0), 0)),
            pl.BlockSpec((1, kvb, ns),
                         lambda i, j, c, bt:
                         (i, jnp.maximum(c - maxp, 0), 0)),
            pl.BlockSpec((1, kvb, db),
                         lambda i, j, c, bt:
                         (i, jnp.maximum(c - maxp, 0), 0)),
            pl.BlockSpec((1, kvb, ns),
                         lambda i, j, c, bt:
                         (i, jnp.maximum(c - maxp, 0), 0)),
        ),
    )
    out, _, _, kc, ks, vc, vs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, C, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, C, H), jnp.float32),
            jax.ShapeDtypeStruct((B, C, H), jnp.float32),
            jax.ShapeDtypeStruct((B, C, db), jnp.uint8),
            jax.ShapeDtypeStruct((B, C, ns), jnp.uint8),
            jax.ShapeDtypeStruct((B, C, db), jnp.uint8),
            jax.ShapeDtypeStruct((B, C, ns), jnp.uint8),
        ),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), q, k_codes, k_scales,
      v_codes, v_scales, k_chunk, v_chunk, start2, len2)
    return out, kc, ks, vc, vs
