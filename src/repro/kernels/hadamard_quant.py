"""Pallas TPU kernel: fused online T3 (block-Hadamard) + MX quantization.

The one runtime op LATMiX adds: before the FFN down projection the
activation is rotated by blockdiag(H₃₂) (inverse folded into the weights)
and immediately MX-quantized. Fusing the two saves one full HBM round-trip
of the (tokens × d_ff) tensor — the d_ff stream is the widest activation in
the network, so this is the highest-leverage fusion in the serving path.

The 32×32 Hadamard multiply maps to a single MXU pass per (BM, 32) slab:
we reshape the (BM, BK) tile to (BM·BK/32, 32) and right-multiply by H₃₂.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import transforms as tfm
from .mx_quant import MXBLOCK, _format_consts, _quant_tile


def _rotate_tile(xb, h):
    """(BM, nb, 32) blocked tile · blockdiag(H₃₂) in one MXU pass:
    reshape to (BM·nb, 32) and right-multiply by the (32, 32) block.
    Shared with the fused T3-prologue GEMM in :mod:`mx_matmul`."""
    bm, nb, b = xb.shape
    yb = jnp.dot(xb.reshape(-1, b), h, preferred_element_type=jnp.float32)
    return yb.reshape(bm, nb, b)


def _hadamard_quant_kernel(x_ref, h_ref, codes_ref, scales_ref, *, fmt):
    grid, mids, r_max, center = _format_consts(fmt)
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    h = h_ref[...].astype(jnp.float32)            # (32, 32)
    xb = x.reshape(bm, bk // MXBLOCK, MXBLOCK)
    yb = _rotate_tile(xb, h)
    codes, scale = _quant_tile(yb, grid, mids, r_max, center)
    codes_ref[...] = codes.reshape(bm, bk).astype(jnp.uint8)
    scales_ref[...] = scale.astype(jnp.float32)


def hadamard_quant(x: jnp.ndarray, fmt: str = "mxfp4", *, bm: int = 256,
                   bk: int = 512, interpret: bool = True):
    """x: (M, K) -> (codes uint8 (M, K), scales f32 (M, K//32)) of
    Q_mx(x · blockdiag(H₃₂))."""
    M, K = x.shape
    bm, bk = min(bm, M), min(bk, K)
    while M % bm:
        bm //= 2
    while K % bk:
        bk //= 2
    assert bk % MXBLOCK == 0
    h = tfm.hadamard_matrix(MXBLOCK, dtype=jnp.float32)
    kern = functools.partial(_hadamard_quant_kernel, fmt=fmt)
    return pl.pallas_call(
        kern,
        grid=(M // bm, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((MXBLOCK, MXBLOCK), lambda i, j: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // MXBLOCK), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((M, K), jnp.uint8),
            jax.ShapeDtypeStruct((M, K // MXBLOCK), jnp.float32),
        ),
        interpret=interpret,
    )(x, h)
