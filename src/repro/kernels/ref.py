"""Pure-jnp oracles for the Pallas kernels (the source of truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.core import transforms as tfm


def mx_quant_ref(x: jnp.ndarray, fmt: str = "mxfp4", block: int = 32):
    """(M, K) -> (codes uint8 (M, K), scales f32 (M, K//block))."""
    cfg = mxlib.MXConfig(fmt=fmt, block_size=block)
    return mxlib.encode(x, cfg)


def mx_dequant_ref(codes, scales, fmt: str = "mxfp4", block: int = 32,
                   dtype=jnp.float32):
    cfg = mxlib.MXConfig(fmt=fmt, block_size=block)
    return mxlib.decode(codes, scales, cfg, dtype)


def mx_matmul_ref(x: jnp.ndarray, w_codes: jnp.ndarray,
                  w_scales: jnp.ndarray, fmt: str = "mxfp4",
                  block: int = 32) -> jnp.ndarray:
    """Fused act-quant MX GEMM oracle.

    x: (M, K) float; w_codes: (K, N) uint8; w_scales: (K//block, N) f32.
    y = Q_mx(x) @ dequant(w), fp32 accumulation.
    """
    cfg = mxlib.MXConfig(fmt=fmt, block_size=block)
    xq = mxlib.quantize(x.astype(jnp.float32), cfg, ste=False)
    w = mx_dequant_ref(w_codes.T, w_scales.T, fmt, block).T
    return xq @ w


def hadamard_quant_ref(x: jnp.ndarray, fmt: str = "mxfp4",
                       block: int = 32):
    """Online T3: block-Hadamard rotate then MX-encode.
    x: (M, K) -> (codes (M, K), scales (M, K//block))."""
    h = tfm.hadamard_matrix(block, dtype=jnp.float32)
    y = tfm.apply_blockwise(x.astype(jnp.float32), h)
    return mx_quant_ref(y, fmt, block)


def mx_matmul_packed_ref(x: jnp.ndarray, w_packed: jnp.ndarray,
                         w_scales_e8m0: jnp.ndarray, fmt: str = "mxfp4",
                         t3: bool = False) -> jnp.ndarray:
    """Oracle for the packed-native fused GEMM (both kernel layouts share
    this source of truth).

    x: (M, K) float; w_packed: (K//2, N) uint8 nibble-packed codes;
    w_scales_e8m0: (K//32, N) uint8 E8M0 scale bytes. t3=True applies the
    online block-Hadamard to x before quantization (ffn_down role).
    y = Q_mx(T3?(x)) @ dequant(w), fp32 accumulation.
    """
    from repro.kernels import packing
    codes = packing.unpack_codes(jnp.swapaxes(w_packed, -1, -2))
    codes = jnp.swapaxes(codes, -1, -2)                  # (K, N)
    scales = packing.unpack_scales_e8m0(w_scales_e8m0)   # (K//32, N) f32
    xf = x.astype(jnp.float32)
    if t3:
        h = tfm.hadamard_matrix(32, dtype=jnp.float32)
        xf = tfm.apply_blockwise(xf, h)
    return mx_matmul_ref(xf, codes, scales, fmt)


def mx_attention_ref(q: jnp.ndarray, k_codes: jnp.ndarray,
                     k_scales: jnp.ndarray, v_codes: jnp.ndarray,
                     v_scales: jnp.ndarray, q_pos: jnp.ndarray,
                     kv_len: jnp.ndarray, fmt: str = "mxfp8",
                     window: int = 0) -> jnp.ndarray:
    """Golden oracle for :func:`repro.kernels.mx_attention.mx_flash_decode`.

    q: (B, H, Dh); k/v codes + E8M0 scale bytes in the ``PackedKV``
    layout (see ``packing.kv_encode``); q_pos / kv_len: (B,) int32 (or
    scalars, broadcast). Decodes the whole cache and runs one masked
    fp32 softmax — no chunking, no online accumulation — so any
    streaming/decode bug in the kernel shows up against it.
    """
    from repro.kernels import packing
    B, H, Dh = q.shape
    k = packing.kv_decode(k_codes, k_scales, fmt)        # (B, S, D)
    v = packing.kv_decode(v_codes, v_scales, fmt)
    S, D = k.shape[1], k.shape[2]
    kvh = D // Dh
    G = H // kvh
    qg = q.astype(jnp.float32).reshape(B, kvh, G, Dh)
    kh = k.reshape(B, S, kvh, Dh)
    vh = v.reshape(B, S, kvh, Dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kh) * scale
    kp = jnp.arange(S, dtype=jnp.int32)[None, :]          # (1, S)
    qp = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32).reshape(-1),
                          (B,))[:, None]
    kl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                          (B,))[:, None]
    ok = (kp <= qp) & (kp < kl)
    if window:
        ok = ok & (kp > qp - window)
    s = jnp.where(ok[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vh)
    return out.reshape(B, H, Dh)


def mx_attention_paged_ref(q: jnp.ndarray, k_codes: jnp.ndarray,
                           k_scales: jnp.ndarray, v_codes: jnp.ndarray,
                           v_scales: jnp.ndarray,
                           block_tables: jnp.ndarray, q_pos: jnp.ndarray,
                           kv_len: jnp.ndarray, fmt: str = "mxfp8",
                           window: int = 0) -> jnp.ndarray:
    """Golden oracle for
    :func:`repro.kernels.mx_attention.mx_flash_decode_paged`.

    k/v codes + scales are the (N, P, ·) page pool; ``block_tables``
    (B, maxp) int32 maps lane b's chunk c to a pool page. The oracle
    gathers each lane's pages into the contiguous logical layout and
    defers to :func:`mx_attention_ref` — so the paged kernel is pinned
    against the exact same dense softmax as the contiguous kernel, with
    the indirection resolved by a plain jnp gather."""
    bt = jnp.asarray(block_tables, jnp.int32)
    B, maxp = bt.shape
    P = k_codes.shape[1]

    def flat(pool):
        g = jnp.take(pool, bt, axis=0)               # (B, maxp, P, ·)
        return g.reshape(B, maxp * P, pool.shape[-1])

    return mx_attention_ref(q, flat(k_codes), flat(k_scales),
                            flat(v_codes), flat(v_scales), q_pos, kv_len,
                            fmt, window)


def mx_prefill_ref(q: jnp.ndarray, k_chunk: jnp.ndarray,
                   v_chunk: jnp.ndarray, k_codes: jnp.ndarray,
                   k_scales: jnp.ndarray, v_codes: jnp.ndarray,
                   v_scales: jnp.ndarray, block_tables: jnp.ndarray,
                   q_start: jnp.ndarray, kv_len: jnp.ndarray,
                   fmt: str = "mxfp8", window: int = 0):
    """Golden oracle for
    :func:`repro.kernels.mx_attention.mx_flash_prefill`.

    q: (B, C, H, Dh); k/v_chunk: (B, C, D) dense chunk K/V; k/v codes +
    scales: the (N, P, ·) page pool; block_tables (B, maxp) int32;
    q_start / kv_len: (B,) int32 (or scalars, broadcast). Encodes the
    chunk with ``packing.kv_encode`` (the write-then-read semantics the
    kernel fuses), scatters the bytes over the gathered logical cache at
    rows [q_start, q_start + C), decodes the whole thing, and runs one
    masked dense fp32 softmax per chunk query row. Returns
    ``(out (B, C, H, Dh) f32, k_code_bytes, k_scale_bytes, v_code_bytes,
    v_scale_bytes)`` — the byte outputs mirror the kernel's fused
    quantize-on-append outputs."""
    from repro.kernels import packing
    B, C, H, Dh = q.shape
    bt = jnp.asarray(block_tables, jnp.int32)
    maxp = bt.shape[1]
    P = k_codes.shape[1]
    S = maxp * P
    kc, ks = packing.kv_encode(k_chunk, fmt)
    vc, vs = packing.kv_encode(v_chunk, fmt)
    st = jnp.broadcast_to(jnp.asarray(q_start, jnp.int32).reshape(-1),
                          (B,))
    kl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                          (B,))
    rows = st[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (B, C)
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]

    def flat(pool, chunk):
        g = jnp.take(pool, bt, axis=0).reshape(B, S, pool.shape[-1])
        return g.at[bidx, rows].set(chunk)

    k = packing.kv_decode(flat(k_codes, kc), flat(k_scales, ks), fmt)
    v = packing.kv_decode(flat(v_codes, vc), flat(v_scales, vs), fmt)
    D = k.shape[-1]
    kvh = D // Dh
    G = H // kvh
    qg = q.astype(jnp.float32).reshape(B, C, kvh, G, Dh)
    kh = k.reshape(B, S, kvh, Dh)
    vh = v.reshape(B, S, kvh, Dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kh) * scale
    kp = jnp.arange(S, dtype=jnp.int32)[None, None, :]     # (1, 1, S)
    qp = rows[:, :, None]                                  # (B, C, 1)
    ok = (kp <= qp) & (kp < kl[:, None, None])
    if window:
        ok = ok & (kp > qp - window)
    s = jnp.where(ok[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, vh)
    return out.reshape(B, C, H, Dh), kc, ks, vc, vs


def quantize_weight_for_kernel(w: jnp.ndarray, fmt: str = "mxfp4",
                               block: int = 32):
    """Pre-quantize a (K, N) weight along K into kernel layout:
    (codes (K, N) uint8, scales (K//block, N) f32)."""
    cfg = mxlib.MXConfig(fmt=fmt, block_size=block)
    codes_t, scales_t = mxlib.encode(w.T, cfg)      # blocked along K
    return codes_t.T, scales_t.T
