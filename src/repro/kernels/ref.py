"""Pure-jnp oracles for the Pallas kernels (the source of truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib
from repro.core import transforms as tfm


def mx_quant_ref(x: jnp.ndarray, fmt: str = "mxfp4", block: int = 32):
    """(M, K) -> (codes uint8 (M, K), scales f32 (M, K//block))."""
    cfg = mxlib.MXConfig(fmt=fmt, block_size=block)
    return mxlib.encode(x, cfg)


def mx_dequant_ref(codes, scales, fmt: str = "mxfp4", block: int = 32,
                   dtype=jnp.float32):
    cfg = mxlib.MXConfig(fmt=fmt, block_size=block)
    return mxlib.decode(codes, scales, cfg, dtype)


def mx_matmul_ref(x: jnp.ndarray, w_codes: jnp.ndarray,
                  w_scales: jnp.ndarray, fmt: str = "mxfp4",
                  block: int = 32) -> jnp.ndarray:
    """Fused act-quant MX GEMM oracle.

    x: (M, K) float; w_codes: (K, N) uint8; w_scales: (K//block, N) f32.
    y = Q_mx(x) @ dequant(w), fp32 accumulation.
    """
    cfg = mxlib.MXConfig(fmt=fmt, block_size=block)
    xq = mxlib.quantize(x.astype(jnp.float32), cfg, ste=False)
    w = mx_dequant_ref(w_codes.T, w_scales.T, fmt, block).T
    return xq @ w


def hadamard_quant_ref(x: jnp.ndarray, fmt: str = "mxfp4",
                       block: int = 32):
    """Online T3: block-Hadamard rotate then MX-encode.
    x: (M, K) -> (codes (M, K), scales (M, K//block))."""
    h = tfm.hadamard_matrix(block, dtype=jnp.float32)
    y = tfm.apply_blockwise(x.astype(jnp.float32), h)
    return mx_quant_ref(y, fmt, block)


def mx_matmul_packed_ref(x: jnp.ndarray, w_packed: jnp.ndarray,
                         w_scales_e8m0: jnp.ndarray, fmt: str = "mxfp4",
                         t3: bool = False) -> jnp.ndarray:
    """Oracle for the packed-native fused GEMM (both kernel layouts share
    this source of truth).

    x: (M, K) float; w_packed: (K//2, N) uint8 nibble-packed codes;
    w_scales_e8m0: (K//32, N) uint8 E8M0 scale bytes. t3=True applies the
    online block-Hadamard to x before quantization (ffn_down role).
    y = Q_mx(T3?(x)) @ dequant(w), fp32 accumulation.
    """
    from repro.kernels import packing
    codes = packing.unpack_codes(jnp.swapaxes(w_packed, -1, -2))
    codes = jnp.swapaxes(codes, -1, -2)                  # (K, N)
    scales = packing.unpack_scales_e8m0(w_scales_e8m0)   # (K//32, N) f32
    xf = x.astype(jnp.float32)
    if t3:
        h = tfm.hadamard_matrix(32, dtype=jnp.float32)
        xf = tfm.apply_blockwise(xf, h)
    return mx_matmul_ref(xf, codes, scales, fmt)


def quantize_weight_for_kernel(w: jnp.ndarray, fmt: str = "mxfp4",
                               block: int = 32):
    """Pre-quantize a (K, N) weight along K into kernel layout:
    (codes (K, N) uint8, scales (K//block, N) f32)."""
    cfg = mxlib.MXConfig(fmt=fmt, block_size=block)
    codes_t, scales_t = mxlib.encode(w.T, cfg)      # blocked along K
    return codes_t.T, scales_t.T
