"""4-bit code packing — the deployable HBM layout.

The interpreter kernels address uint8 codes (one per byte); deployment
stores two 4-bit codes per byte plus one E8M0 (biased power-of-two
exponent) scale byte per 32-block. These utilities convert between the
layouts and are the source of the roofline packed-byte accounting
(`mx.packed_nbytes`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib


def pack_codes(codes: jnp.ndarray) -> jnp.ndarray:
    """uint8 codes in [0, 15] -> packed uint8, two per byte (even index in
    the low nibble). Last axis must be even."""
    *lead, d = codes.shape
    c = codes.reshape(*lead, d // 2, 2).astype(jnp.uint8)
    return (c[..., 0] | (c[..., 1] << 4)).astype(jnp.uint8)


def unpack_codes(packed: jnp.ndarray) -> jnp.ndarray:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    *lead, h = packed.shape
    out = jnp.stack([lo, hi], axis=-1).reshape(*lead, h * 2)
    return out.astype(jnp.uint8)


def pack_scales_e8m0(scales: jnp.ndarray) -> jnp.ndarray:
    """Power-of-two f32 scales -> E8M0 byte (biased exponent, OCP MX)."""
    e = jnp.round(jnp.log2(scales.astype(jnp.float32))).astype(jnp.int32)
    return (e + 127).astype(jnp.uint8)


def unpack_scales_e8m0(b: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp2(b.astype(jnp.int32) - 127).astype(jnp.float32)


def pack_weight(w: jnp.ndarray, fmt: str = "mxfp4"):
    """(K, N) float weight -> deployable bundle:
    {codes_packed (K//2, N) uint8, scales_e8m0 (K//32, N) uint8}."""
    cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
    codes_t, scales_t = mxlib.encode(w.T, cfg)     # blocked along K
    codes, scales = codes_t.T, scales_t.T          # (K, N), (K//32, N)
    packed = pack_codes(codes.T).T                 # pack along K
    return {"codes_packed": packed,
            "scales_e8m0": pack_scales_e8m0(scales),
            "fmt": fmt, "shape": w.shape}


def unpack_weight(bundle, dtype=jnp.float32) -> jnp.ndarray:
    cfg = mxlib.MXConfig(fmt=bundle["fmt"], block_size=32)
    codes = unpack_codes(bundle["codes_packed"].T).T
    scales = unpack_scales_e8m0(bundle["scales_e8m0"])
    return mxlib.decode(codes.T, scales.T, cfg, dtype).T


def packed_bundle_nbytes(bundle) -> int:
    return (bundle["codes_packed"].size
            + bundle["scales_e8m0"].size)
