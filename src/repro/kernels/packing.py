"""4-bit code packing — the deployable HBM layout.

The interpreter kernels address uint8 codes (one per byte); deployment
stores two 4-bit codes per byte plus one E8M0 (biased power-of-two
exponent) scale byte per 32-block. These utilities convert between the
layouts and are the source of the roofline packed-byte accounting
(`mx.packed_nbytes`).

``pack_weight``/``unpack_weight`` operate on the *contraction* axis
(axis -2, matching the qlinear weight orientation) and accept arbitrary
leading batch dims, so layer-stacked ``(L, K, N)`` and expert-batched
``(L, E, K, N)`` weights pack in one call. ``PackedWeight`` wraps the
packed arrays as a pytree so packed weights can live inside a params
tree: jit carries only the uint8 codes + scales in HBM and the dense
fp weight is reconstructed on the fly at each use site (layer-sliced
under ``lax.scan``, i.e. one layer dequantized at a time).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib

# Formats that fit two codes per byte. The full symmetric code range of a
# 4-bit element grid is 2*8-1 = 15 values (codes 0..14 < 16).
PACKABLE_FMTS = ("mxfp4", "mxint4")


def _check_packable(fmt: str, block_size: int = 32, scale_mode: str = "pow2"):
    if fmt not in PACKABLE_FMTS:
        raise ValueError(
            f"fmt {fmt!r} is not 4-bit packable (supported: {PACKABLE_FMTS})")
    if scale_mode != "pow2":
        raise ValueError(
            f"E8M0 scale bytes require pow2 scales, got {scale_mode!r}")
    if block_size != 32:
        raise ValueError(f"packed layout is fixed at 32-blocks, "
                         f"got block_size={block_size}")


def pack_codes(codes: jnp.ndarray) -> jnp.ndarray:
    """uint8 codes in [0, 15] -> packed uint8, two per byte (even index in
    the low nibble). Last axis must be even."""
    *lead, d = codes.shape
    if d % 2 != 0:
        raise ValueError(f"packing axis must be even, got {d}")
    c = codes.reshape(*lead, d // 2, 2).astype(jnp.uint8)
    return (c[..., 0] | (c[..., 1] << 4)).astype(jnp.uint8)


def unpack_codes(packed: jnp.ndarray) -> jnp.ndarray:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    *lead, h = packed.shape
    out = jnp.stack([lo, hi], axis=-1).reshape(*lead, h * 2)
    return out.astype(jnp.uint8)


def pack_scales_e8m0(scales: jnp.ndarray) -> jnp.ndarray:
    """Power-of-two f32 scales -> E8M0 byte (biased exponent, OCP MX)."""
    e = jnp.round(jnp.log2(scales.astype(jnp.float32))).astype(jnp.int32)
    return (e + 127).astype(jnp.uint8)


def unpack_scales_e8m0(b: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp2(b.astype(jnp.int32) - 127).astype(jnp.float32)


def pack_weight(w: jnp.ndarray, fmt: str = "mxfp4"):
    """(*lead, K, N) float weight -> deployable bundle:
    {codes_packed (*lead, K//2, N) uint8,
     scales_e8m0 (*lead, K//32, N) uint8}.

    Blocked/packed along the contraction axis K (axis -2). Exact for any
    weight already on the MX grid (pack∘unpack is the identity there);
    otherwise it quantizes (RTN) as a side effect.
    """
    _check_packable(fmt)
    cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
    wt = jnp.swapaxes(w, -1, -2)                 # (*lead, N, K)
    if wt.shape[-1] % cfg.block_size != 0:
        raise ValueError(f"contraction dim {wt.shape[-1]} not divisible by "
                         f"block size {cfg.block_size}")
    codes_t, scales_t = mxlib.encode(wt, cfg)    # blocked along K
    packed_t = pack_codes(codes_t)               # (*lead, N, K//2)
    return {"codes_packed": jnp.swapaxes(packed_t, -1, -2),
            "scales_e8m0": jnp.swapaxes(pack_scales_e8m0(scales_t), -1, -2),
            "fmt": fmt, "shape": tuple(w.shape)}


def unpack_weight(bundle, dtype=jnp.float32) -> jnp.ndarray:
    cfg = mxlib.MXConfig(fmt=bundle["fmt"], block_size=32)
    codes_t = unpack_codes(jnp.swapaxes(bundle["codes_packed"], -1, -2))
    scales_t = jnp.swapaxes(bundle["scales_e8m0"], -1, -2)
    out_t = mxlib.decode(codes_t, unpack_scales_e8m0(scales_t), cfg, dtype)
    return jnp.swapaxes(out_t, -1, -2)


def packed_bundle_nbytes(bundle) -> int:
    codes = bundle["codes_packed"]
    scales = bundle["scales_e8m0"]
    return int(codes.size) + int(scales.size)


# ---------------------------------------------------------------------------
# PackedWeight: packed bundle as a pytree leaf-group inside a params tree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight:
    """An MX-packed linear weight usable in place of a dense array.

    The codes/scales are pytree children (they flow through jit/scan and
    are layer-sliced like any stacked leaf); fmt and target dtype are
    static aux data. A params tree holding PackedWeight leaves serves
    directly: under ``QuantMode(backend='fused')`` ``qlinear``/``qeinsum``
    hand the codes/scales straight to the packed-native Pallas GEMM (no
    dense weight ever materialized); on the reference path they call
    :func:`maybe_dense`, so HBM keeps the 4-bit layout and the fp weight
    exists only transiently inside the compiled step.
    """

    codes_packed: jnp.ndarray   # (*lead, K//2, N) uint8
    scales_e8m0: jnp.ndarray    # (*lead, K//32, N) uint8
    fmt: str = "mxfp4"
    dtype: str = "float32"

    def tree_flatten(self):
        return (self.codes_packed, self.scales_e8m0), (self.fmt, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def shape(self):
        """Logical dense shape (*lead, K, N)."""
        *lead, k2, n = self.codes_packed.shape
        return tuple(lead) + (k2 * 2, n)

    @property
    def ndim(self) -> int:
        return self.codes_packed.ndim

    @property
    def nbytes_packed(self) -> int:
        return int(self.codes_packed.size) + int(self.scales_e8m0.size)

    @property
    def nbytes_dense(self) -> int:
        """Byte count of the dense fp equivalent — the HBM traffic a
        non-packed weight would cost per use (bench/roofline term)."""
        n = 1
        for s in self.shape:
            n *= int(s)
        return n * jnp.dtype(self.dtype).itemsize

    def to_dense(self, dtype=None) -> jnp.ndarray:
        return unpack_weight(
            {"codes_packed": self.codes_packed,
             "scales_e8m0": self.scales_e8m0, "fmt": self.fmt},
            dtype if dtype is not None else jnp.dtype(self.dtype))

    @classmethod
    def from_dense(cls, w: jnp.ndarray, fmt: str = "mxfp4") -> "PackedWeight":
        b = pack_weight(w, fmt)
        return cls(b["codes_packed"], b["scales_e8m0"], fmt,
                   str(jnp.asarray(w).dtype))


def maybe_dense(w):
    """Resolve a PackedWeight to its dense fp array; pass others through."""
    if isinstance(w, PackedWeight):
        return w.to_dense()
    return w
