"""4-bit code packing — the deployable HBM layout.

The interpreter kernels address uint8 codes (one per byte); deployment
stores two 4-bit codes per byte plus one E8M0 (biased power-of-two
exponent) scale byte per 32-block. These utilities convert between the
layouts and are the source of the roofline packed-byte accounting
(`mx.packed_nbytes`).

``pack_weight``/``unpack_weight`` operate on the *contraction* axis
(axis -2, matching the qlinear weight orientation) and accept arbitrary
leading batch dims, so layer-stacked ``(L, K, N)`` and expert-batched
``(L, E, K, N)`` weights pack in one call. ``PackedWeight`` wraps the
packed arrays as a pytree so packed weights can live inside a params
tree: jit carries only the uint8 codes + scales in HBM and the dense
fp weight is reconstructed on the fly at each use site (layer-sliced
under ``lax.scan``, i.e. one layer dequantized at a time).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import mx as mxlib

# Formats that fit two codes per byte. The full symmetric code range of a
# 4-bit element grid is 2*8-1 = 15 values (codes 0..14 < 16).
PACKABLE_FMTS = ("mxfp4", "mxint4")

# Formats the KV cache can be stored in: 8-bit formats keep one code per
# byte; 4-bit formats nibble-pack along the feature axis like weights.
KV_FMTS = ("mxfp8", "mxint8", "mxfp4", "mxint4")


def _check_packable(fmt: str, block_size: int = 32, scale_mode: str = "pow2"):
    if fmt not in PACKABLE_FMTS:
        raise ValueError(
            f"fmt {fmt!r} is not 4-bit packable (supported: {PACKABLE_FMTS})")
    if scale_mode != "pow2":
        raise ValueError(
            f"E8M0 scale bytes require pow2 scales, got {scale_mode!r}")
    if block_size != 32:
        raise ValueError(f"packed layout is fixed at 32-blocks, "
                         f"got block_size={block_size}")


def pack_codes(codes: jnp.ndarray) -> jnp.ndarray:
    """uint8 codes in [0, 15] -> packed uint8, two per byte (even index in
    the low nibble). Last axis must be even."""
    *lead, d = codes.shape
    if d % 2 != 0:
        raise ValueError(f"packing axis must be even, got {d}")
    c = codes.reshape(*lead, d // 2, 2).astype(jnp.uint8)
    return (c[..., 0] | (c[..., 1] << 4)).astype(jnp.uint8)


def unpack_codes(packed: jnp.ndarray) -> jnp.ndarray:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    *lead, h = packed.shape
    out = jnp.stack([lo, hi], axis=-1).reshape(*lead, h * 2)
    return out.astype(jnp.uint8)


def pack_scales_e8m0(scales: jnp.ndarray) -> jnp.ndarray:
    """Power-of-two f32 scales -> E8M0 byte (biased exponent, OCP MX)."""
    e = jnp.round(jnp.log2(scales.astype(jnp.float32))).astype(jnp.int32)
    return (e + 127).astype(jnp.uint8)


def unpack_scales_e8m0(b: jnp.ndarray) -> jnp.ndarray:
    return jnp.exp2(b.astype(jnp.int32) - 127).astype(jnp.float32)


def pack_weight(w: jnp.ndarray, fmt: str = "mxfp4"):
    """(*lead, K, N) float weight -> deployable bundle:
    {codes_packed (*lead, K//2, N) uint8,
     scales_e8m0 (*lead, K//32, N) uint8}.

    Blocked/packed along the contraction axis K (axis -2). Exact for any
    weight already on the MX grid (pack∘unpack is the identity there);
    otherwise it quantizes (RTN) as a side effect.
    """
    _check_packable(fmt)
    cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
    wt = jnp.swapaxes(w, -1, -2)                 # (*lead, N, K)
    if wt.shape[-1] % cfg.block_size != 0:
        raise ValueError(f"contraction dim {wt.shape[-1]} not divisible by "
                         f"block size {cfg.block_size}")
    codes_t, scales_t = mxlib.encode(wt, cfg)    # blocked along K
    packed_t = pack_codes(codes_t)               # (*lead, N, K//2)
    return {"codes_packed": jnp.swapaxes(packed_t, -1, -2),
            "scales_e8m0": jnp.swapaxes(pack_scales_e8m0(scales_t), -1, -2),
            "fmt": fmt, "shape": tuple(w.shape)}


def unpack_weight(bundle, dtype=jnp.float32) -> jnp.ndarray:
    cfg = mxlib.MXConfig(fmt=bundle["fmt"], block_size=32)
    codes_t = unpack_codes(jnp.swapaxes(bundle["codes_packed"], -1, -2))
    scales_t = jnp.swapaxes(bundle["scales_e8m0"], -1, -2)
    out_t = mxlib.decode(codes_t, unpack_scales_e8m0(scales_t), cfg, dtype)
    return jnp.swapaxes(out_t, -1, -2)


def packed_bundle_nbytes(bundle) -> int:
    codes = bundle["codes_packed"]
    scales = bundle["scales_e8m0"]
    return int(codes.size) + int(scales.size)


# ---------------------------------------------------------------------------
# PackedWeight: packed bundle as a pytree leaf-group inside a params tree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight:
    """An MX-packed linear weight usable in place of a dense array.

    The codes/scales are pytree children (they flow through jit/scan and
    are layer-sliced like any stacked leaf); fmt and target dtype are
    static aux data. A params tree holding PackedWeight leaves serves
    directly: under ``QuantMode(backend='fused')`` ``qlinear``/``qeinsum``
    hand the codes/scales straight to the packed-native Pallas GEMM (no
    dense weight ever materialized); on the reference path they call
    :func:`maybe_dense`, so HBM keeps the 4-bit layout and the fp weight
    exists only transiently inside the compiled step.
    """

    codes_packed: jnp.ndarray   # (*lead, K//2, N) uint8
    scales_e8m0: jnp.ndarray    # (*lead, K//32, N) uint8
    fmt: str = "mxfp4"
    dtype: str = "float32"

    def tree_flatten(self):
        return (self.codes_packed, self.scales_e8m0), (self.fmt, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def shape(self):
        """Logical dense shape (*lead, K, N)."""
        *lead, k2, n = self.codes_packed.shape
        return tuple(lead) + (k2 * 2, n)

    @property
    def ndim(self) -> int:
        return self.codes_packed.ndim

    @property
    def nbytes_packed(self) -> int:
        return int(self.codes_packed.size) + int(self.scales_e8m0.size)

    @property
    def nbytes_dense(self) -> int:
        """Byte count of the dense fp equivalent — the HBM traffic a
        non-packed weight would cost per use (bench/roofline term)."""
        n = 1
        for s in self.shape:
            n *= int(s)
        return n * jnp.dtype(self.dtype).itemsize

    def to_dense(self, dtype=None) -> jnp.ndarray:
        return unpack_weight(
            {"codes_packed": self.codes_packed,
             "scales_e8m0": self.scales_e8m0, "fmt": self.fmt},
            dtype if dtype is not None else jnp.dtype(self.dtype))

    @classmethod
    def from_dense(cls, w: jnp.ndarray, fmt: str = "mxfp4") -> "PackedWeight":
        b = pack_weight(w, fmt)
        return cls(b["codes_packed"], b["scales_e8m0"], fmt,
                   str(jnp.asarray(w).dtype))


def maybe_dense(w):
    """Resolve a PackedWeight to its dense fp array; pass others through."""
    if isinstance(w, PackedWeight):
        return w.to_dense()
    return w


# ---------------------------------------------------------------------------
# Packed KV cache: MX codes + E8M0 scale bytes along the *last* axis
# ---------------------------------------------------------------------------
#
# Weights pack along the contraction axis (-2); the KV cache packs along its
# feature axis (-1, the stored (B, S, kv_dim) layout — 32-blocks sit inside
# heads whenever head_dim % 32 == 0, i.e. every production config). 8-bit
# formats (mxfp8 / mxint8) store one code per byte; 4-bit formats
# nibble-pack two codes per byte exactly like PackedWeight.


def _kv_center(fmt: str) -> int:
    """The uint8 code that decodes to 0.0 (zero-init of a fresh cache)."""
    return len(mxlib.FORMATS[fmt].grid) - 1


def kv_fmt_bits(fmt: str) -> int:
    if fmt not in KV_FMTS:
        raise ValueError(f"fmt {fmt!r} is not a KV-cache format "
                         f"(supported: {KV_FMTS})")
    return mxlib.FORMATS[fmt].bits


def kv_encode(x: jnp.ndarray, fmt: str = "mxfp8"):
    """(..., D) float -> (codes uint8 (..., D*bits/8), scales uint8
    (..., D//32) E8M0). D % 32 == 0; pow2 scales per 32-block."""
    bits = kv_fmt_bits(fmt)
    cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
    if x.shape[-1] % 32 != 0:
        raise ValueError(f"KV feature dim {x.shape[-1]} not divisible by 32")
    codes, scales = mxlib.encode(x, cfg)
    if bits == 4:
        codes = pack_codes(codes)
    return codes, pack_scales_e8m0(scales)


def kv_decode(codes: jnp.ndarray, scales_e8m0: jnp.ndarray,
              fmt: str = "mxfp8", dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of :func:`kv_encode` -> (..., D) dense values."""
    bits = kv_fmt_bits(fmt)
    cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
    if bits == 4:
        codes = unpack_codes(codes)
    return mxlib.decode(codes, unpack_scales_e8m0(scales_e8m0), cfg, dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedKV:
    """An MX-quantized KV-cache tensor usable in place of a dense array.

    codes: (*lead, S, D*bits/8) uint8 — one code per byte (8-bit fmts) or
    nibble-packed (4-bit fmts) along the feature axis; scales: (*lead, S,
    D//32) uint8 E8M0 bytes. Registered as a pytree so a cache holding
    PackedKV leaves flows through jit / lax.scan (layer-sliced like any
    stacked leaf) and the engine's lane-merge ``tree_map`` untouched.
    ``fmt``/``dtype`` are static aux data, so dispatch on them never
    retraces."""

    codes: jnp.ndarray
    scales: jnp.ndarray
    fmt: str = "mxfp8"
    dtype: str = "float32"

    def tree_flatten(self):
        return (self.codes, self.scales), (self.fmt, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def shape(self):
        """Logical dense shape (*lead, S, D)."""
        *lead, s, db = self.codes.shape
        return tuple(lead) + (s, db * 8 // kv_fmt_bits(self.fmt))

    @property
    def ndim(self) -> int:
        return self.codes.ndim

    @property
    def nbytes_packed(self) -> int:
        return int(self.codes.size) + int(self.scales.size)

    def to_dense(self, dtype=None) -> jnp.ndarray:
        return kv_decode(self.codes, self.scales, self.fmt,
                         dtype if dtype is not None else
                         jnp.dtype(self.dtype))

    @classmethod
    def from_dense(cls, x: jnp.ndarray, fmt: str = "mxfp8") -> "PackedKV":
        c, s = kv_encode(x, fmt)
        return cls(c, s, fmt, str(jnp.asarray(x).dtype))

    @classmethod
    def zeros(cls, shape, fmt: str = "mxfp8",
              dtype=jnp.float32) -> "PackedKV":
        """Fresh cache of logical dense ``shape`` (*lead, S, D): center
        codes (which decode to 0.0) and unit E8M0 scales."""
        *lead, d = shape
        bits = kv_fmt_bits(fmt)
        if d % 32 != 0:
            raise ValueError(f"KV feature dim {d} not divisible by 32")
        center = _kv_center(fmt)
        cbyte = center | (center << 4) if bits == 4 else center
        codes = jnp.full(tuple(lead) + (d * bits // 8,), cbyte, jnp.uint8)
        scales = jnp.full(tuple(lead) + (d // 32,), 127, jnp.uint8)
        return cls(codes, scales, fmt, str(jnp.dtype(dtype)))


# ---------------------------------------------------------------------------
# Paged KV cache: a pool of fixed-size pages addressed through block tables
# ---------------------------------------------------------------------------
#
# The contiguous layouts above reserve one (max_len, D) lane per batch slot.
# The paged layout instead keeps ONE pool of N fixed-size pages (P tokens
# each) and addresses it through per-request *block tables* — (B, max_pages)
# int32 arrays of page ids — so memory tracks actual sequence lengths and
# identical prompt prefixes can share pages by reference (the serving
# engine's BlockAllocator owns the table bookkeeping; see docs/paged-kv.md).
# A page is a fixed run of MX 32-blocks whenever the cache is quantized:
# P tokens x (D * bits/8) code bytes + (D // 32) E8M0 scale bytes per token,
# exactly the PackedKV byte layout cut into page-sized runs.


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKV:
    """A paged KV pool usable in place of a contiguous cache leaf.

    codes: (*lead, N, P, D*bits/8) uint8 MX codes (one per byte for 8-bit
    fmts, nibble-packed for 4-bit fmts) — or (*lead, N, P, D) *dense
    float* pages when ``fmt == 'none'`` (the unquantized paged cache).
    scales: (*lead, N, P, D//32) uint8 E8M0 bytes, or ``None`` for dense
    pages. Registered as a pytree (``None`` scales flatten to an empty
    subtree), so a cache of PagedKV leaves flows through jit / lax.scan
    layer slicing untouched; ``fmt``/``dtype`` are static aux data.

    Logical position ``t`` of a request lives at page
    ``block_table[t // P]``, row ``t % P`` — every reader/writer goes
    through that indirection (``models.layers`` write helpers, the paged
    flash-decode kernel's block-table grid, :meth:`gather_dense`)."""

    codes: jnp.ndarray
    scales: Optional[jnp.ndarray]
    fmt: str = "none"
    dtype: str = "float32"

    def tree_flatten(self):
        return (self.codes, self.scales), (self.fmt, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def page_size(self) -> int:
        return self.codes.shape[-2]

    @property
    def n_pages(self) -> int:
        return self.codes.shape[-3]

    @property
    def feature_dim(self) -> int:
        """Logical dense feature width D."""
        if self.fmt == "none":
            return self.codes.shape[-1]
        return self.codes.shape[-1] * 8 // kv_fmt_bits(self.fmt)

    @property
    def ndim(self) -> int:
        return self.codes.ndim

    @classmethod
    def zeros(cls, shape, fmt: str = "none", dtype=jnp.float32) -> "PagedKV":
        """Fresh pool of logical dense ``shape`` (*lead, N, P, D)."""
        *lead, n, p, d = shape
        if fmt == "none":
            return cls(jnp.zeros((*lead, n, p, d), jnp.dtype(dtype)), None,
                       "none", str(jnp.dtype(dtype)))
        bits = kv_fmt_bits(fmt)
        if d % 32 != 0:
            raise ValueError(f"KV feature dim {d} not divisible by 32")
        center = _kv_center(fmt)
        cbyte = center | (center << 4) if bits == 4 else center
        codes = jnp.full((*lead, n, p, d * bits // 8), cbyte, jnp.uint8)
        scales = jnp.full((*lead, n, p, d // 32), 127, jnp.uint8)
        return cls(codes, scales, fmt, str(jnp.dtype(dtype)))

    def gather_dense(self, block_tables: jnp.ndarray,
                     dtype=None) -> jnp.ndarray:
        """Materialize the logical contiguous view of ``block_tables``
        (B, max_pages) int32: a dense (B, max_pages*P, D) array — page j
        of lane b supplies rows [j*P, (j+1)*P). The reference attention
        path reads the cache through this gather; rows past a lane's
        fill come from whatever page id sits in the unused table slot
        (the engine parks them on the scrap page) and stay masked by
        ``kv_len``. Pool must be layer-sliced (no lead dims)."""
        if self.codes.ndim != 3:
            raise ValueError("gather_dense expects a layer-sliced pool "
                             f"(N, P, ·); got ndim={self.codes.ndim}")
        B, maxp = block_tables.shape
        P = self.page_size
        dt = dtype if dtype is not None else jnp.dtype(self.dtype)
        c = jnp.take(self.codes, block_tables, axis=0)     # (B, maxp, P, ·)
        c = c.reshape(B, maxp * P, c.shape[-1])
        if self.fmt == "none":
            return c.astype(dt)
        s = jnp.take(self.scales, block_tables, axis=0)
        s = s.reshape(B, maxp * P, s.shape[-1])
        return kv_decode(c, s, self.fmt, dt)
