"""Pallas TPU kernels: fused activation-quantizing MX GEMMs.

  y = Q_mx(x) @ dequant(w)

— the deployment hot-spot after LATMiX folding: activations arrive bf16,
are MX-quantized on the fly (per-row 32-blocks along K), the weight tile is
decoded from its stored codes + power-of-two column scales, and the MXU
accumulates fp32 over the K grid axis.

Two weight layouts:

  :func:`mx_matmul`          — interpreter layout: one uint8 code per byte,
                               f32 scales ((K, N) + (K//32, N)).
  :func:`mx_matmul_packed`   — the HBM/artifact layout consumed *directly*:
                               two 4-bit codes per byte ((K//2, N) uint8)
                               + E8M0 scale bytes ((K//32, N) uint8),
                               decoded inside the kernel tile. No dense fp
                               weight is ever materialized, and the weight
                               VMEM/HBM traffic is half the uint8-per-code
                               layout (9 bits/param total vs 17).

``mx_matmul_packed(t3=True)`` additionally fuses the online T3
block-Hadamard into the activation-quantize prologue (the ``ffn_down``
call-site), saving the separate rotate pass over the widest activation
stream in the network.

Tiling: grid (M/BM, N/BN, K/BK), K innermost so the (BM, BN) fp32
accumulator tile stays resident in VMEM across the K sweep. BM/BN/BK are
multiples of 128 (MXU-aligned) when shapes allow; BK a multiple of 32 keeps
whole MX blocks inside one tile so scales never straddle instances.

VMEM per instance (BM=BN=256, BK=512, packed layout): x 512K + w codes 64K
+ w scales 4K + acc 256K ≈ 0.82 MiB « 16 MiB.

On CPU these run in interpret mode for correctness only; the roofline
memory term uses the 4-bit packed byte count (see DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import mx as mxlib
from repro.core import transforms as tfm
from .hadamard_quant import _rotate_tile
from .mx_quant import MXBLOCK, _decode_tile, _format_consts, _quant_tile

# backwards-compatible alias (the decode helper moved to mx_quant so every
# GEMM variant shares it)
_decode_codes = _decode_tile


def _pick_blocks(M: int, N: int, K: int, bm: int, bn: int, bk: int):
    """Shrink requested block sizes until they divide the problem. K is
    always a multiple of 32 for MX operands, and every halving of 512
    stays a multiple of 32, so bk lands on a whole number of MX blocks."""
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    while M % bm:
        bm //= 2
    while N % bn:
        bn //= 2
    while K % bk:
        bk //= 2
    return bm, bn, bk


def _mx_matmul_kernel(x_ref, wc_ref, ws_ref, out_ref, *, fmt, n_k):
    grid, mids, r_max, center = _format_consts(fmt)
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)            # (BM, BK)
    bm, bk = x.shape
    xb = x.reshape(bm, bk // MXBLOCK, MXBLOCK)
    codes, scale = _quant_tile(xb, grid, mids, r_max, center)
    xq = (_decode_tile(codes, grid, center)
          * scale[..., None]).reshape(bm, bk)

    wc = wc_ref[...]                              # (BK, BN) uint8
    ws = ws_ref[...]                              # (BK//32, BN) f32
    wvals = _decode_tile(wc, grid, center)
    bn = wc.shape[1]
    w = (wvals.reshape(bk // MXBLOCK, MXBLOCK, bn)
         * ws[:, None, :]).reshape(bk, bn)

    out_ref[...] += jnp.dot(xq, w, preferred_element_type=jnp.float32)


def mx_matmul(x: jnp.ndarray, w_codes: jnp.ndarray, w_scales: jnp.ndarray,
              fmt: str = "mxfp4", *, bm: int = 256, bn: int = 256,
              bk: int = 512, interpret: bool = True,
              out_dtype=jnp.float32) -> jnp.ndarray:
    """x: (M, K); w_codes: (K, N) uint8; w_scales: (K//32, N) f32."""
    M, K = x.shape
    K2, N = w_codes.shape
    assert K == K2 and w_scales.shape == (K // MXBLOCK, N)
    bm, bn, bk = _pick_blocks(M, N, K, bm, bn, bk)
    assert bk % MXBLOCK == 0, (bk,)
    kern = functools.partial(_mx_matmul_kernel, fmt=fmt, n_k=K // bk)
    out = pl.pallas_call(
        kern,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // MXBLOCK, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, w_codes, w_scales)
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# Packed-native layout: nibble-packed codes + E8M0 scale bytes in, fp out
# ---------------------------------------------------------------------------

def _unpack_tile(wp):
    """(BK//2, BN) nibble-packed uint8 -> (BK, BN) uint8 codes.

    ``pack_codes`` puts code 2i in the low nibble and 2i+1 in the high
    nibble of byte i (along the contraction axis), so the interleave is a
    sublane-axis stack+reshape — no gather."""
    lo = wp & 0xF
    hi = (wp >> 4) & 0xF
    bk2, bn = wp.shape
    return jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)


def _mx_matmul_packed_kernel(*refs, fmt, t3):
    if t3:
        x_ref, h_ref, wp_ref, ws_ref, out_ref = refs
    else:
        x_ref, wp_ref, ws_ref, out_ref = refs
    grid, mids, r_max, center = _format_consts(fmt)
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)            # (BM, BK)
    bm, bk = x.shape
    xb = x.reshape(bm, bk // MXBLOCK, MXBLOCK)
    if t3:  # fused T3 prologue: rotate each 32-block before quantizing
        xb = _rotate_tile(xb, h_ref[...].astype(jnp.float32))
    codes, scale = _quant_tile(xb, grid, mids, r_max, center)
    xq = (_decode_tile(codes, grid, center)
          * scale[..., None]).reshape(bm, bk)

    wc = _unpack_tile(wp_ref[...])                # (BK, BN) uint8 codes
    wvals = _decode_tile(wc, grid, center)
    bn = wc.shape[1]
    # E8M0 byte -> power-of-two scale: exp2 of the unbiased exponent
    ws = jnp.exp2(ws_ref[...].astype(jnp.float32) - 127.0)  # (BK//32, BN)
    w = (wvals.reshape(bk // MXBLOCK, MXBLOCK, bn)
         * ws[:, None, :]).reshape(bk, bn)

    out_ref[...] += jnp.dot(xq, w, preferred_element_type=jnp.float32)


def mx_matmul_packed(x: jnp.ndarray, w_packed: jnp.ndarray,
                     w_scales_e8m0: jnp.ndarray, fmt: str = "mxfp4", *,
                     t3: bool = False, bm: int = 256, bn: int = 256,
                     bk: int = 512, interpret: bool = True,
                     out_dtype=jnp.float32) -> jnp.ndarray:
    """Packed-native fused MX GEMM: y = Q_mx([x·blockdiag(H₃₂)]) @ deq(w).

    x: (M, K) float; w_packed: (K//2, N) uint8, two 4-bit codes per byte
    along K; w_scales_e8m0: (K//32, N) uint8 E8M0 scale bytes — i.e. the
    exact HBM/artifact layout of :class:`repro.kernels.packing.PackedWeight`.
    The dense fp weight exists only as per-tile VMEM values inside the
    kernel. ``t3=True`` applies the online block-Hadamard (T3) to each
    activation 32-block before quantization (the ``ffn_down`` role).
    """
    M, K = x.shape
    K2, N = w_packed.shape
    assert K == 2 * K2, (x.shape, w_packed.shape)
    assert w_scales_e8m0.shape == (K // MXBLOCK, N), w_scales_e8m0.shape
    assert K % MXBLOCK == 0, (K,)
    bm, bn, bk = _pick_blocks(M, N, K, bm, bn, bk)
    assert bk % MXBLOCK == 0, (bk,)
    kern = functools.partial(_mx_matmul_packed_kernel, fmt=fmt, t3=t3)
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))]
    args = [x]
    if t3:
        in_specs.append(pl.BlockSpec((MXBLOCK, MXBLOCK),
                                     lambda i, j, k: (0, 0)))
        args.append(tfm.hadamard_matrix(MXBLOCK, dtype=jnp.float32))
    in_specs += [
        pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
        pl.BlockSpec((bk // MXBLOCK, bn), lambda i, j, k: (k, j)),
    ]
    args += [w_packed, w_scales_e8m0]
    out = pl.pallas_call(
        kern,
        grid=(M // bm, N // bn, K // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(*args)
    return out.astype(out_dtype)
