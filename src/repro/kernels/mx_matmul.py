"""Pallas TPU kernel: fused activation-quantizing MX GEMM.

  y = Q_mx(x) @ dequant(w_codes, w_scales)

— the deployment hot-spot after LATMiX folding: activations arrive bf16,
are MX-quantized on the fly (per-row 32-blocks along K), the weight tile is
decoded from uint8 codes with its power-of-two column scales, and the MXU
accumulates fp32 over the K grid axis.

Tiling: grid (M/BM, N/BN, K/BK), K innermost so the (BM, BN) fp32
accumulator tile stays resident in VMEM across the K sweep. BM/BN/BK are
multiples of 128 (MXU-aligned); BK a multiple of 32 keeps whole MX blocks
inside one tile so scales never straddle instances.

VMEM per instance (BM=BN=256, BK=512): x 512K + w codes 128K + w scales 2K
+ acc 256K ≈ 0.9 MiB « 16 MiB.

On CPU this runs in interpret mode for correctness only; the roofline
memory term uses the 4-bit packed byte count (see DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import mx as mxlib
from .mx_quant import MXBLOCK, _format_consts, _quant_tile


def _decode_codes(codes, grid, center):
    """uint8 symmetric code -> float value, via static compares (the grid
    has <= 8 magnitudes; Pallas forbids captured jnp LUT constants)."""
    rel = codes.astype(jnp.int32) - center
    sign = jnp.where(rel < 0, -1.0, 1.0).astype(jnp.float32)
    k = jnp.abs(rel)
    val = jnp.zeros(codes.shape, jnp.float32)
    for i, g in enumerate(grid):                  # static python loop
        val += jnp.where(k == i, float(g), 0.0)
    return sign * val


def _mx_matmul_kernel(x_ref, wc_ref, ws_ref, out_ref, *, fmt, n_k):
    grid, mids, r_max, center = _format_consts(fmt)
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)            # (BM, BK)
    bm, bk = x.shape
    xb = x.reshape(bm, bk // MXBLOCK, MXBLOCK)
    codes, scale = _quant_tile(xb, grid, mids, r_max, center)
    xq = (_decode_codes(codes, grid, center)
          * scale[..., None]).reshape(bm, bk)

    wc = wc_ref[...]                              # (BK, BN) uint8
    ws = ws_ref[...]                              # (BK//32, BN) f32
    wvals = _decode_codes(wc, grid, center)
    bn = wc.shape[1]
    w = (wvals.reshape(bk // MXBLOCK, MXBLOCK, bn)
         * ws[:, None, :]).reshape(bk, bn)

    out_ref[...] += jnp.dot(xq, w, preferred_element_type=jnp.float32)


def mx_matmul(x: jnp.ndarray, w_codes: jnp.ndarray, w_scales: jnp.ndarray,
              fmt: str = "mxfp4", *, bm: int = 256, bn: int = 256,
              bk: int = 512, interpret: bool = True,
              out_dtype=jnp.float32) -> jnp.ndarray:
    """x: (M, K); w_codes: (K, N) uint8; w_scales: (K//32, N) f32."""
    M, K = x.shape
    K2, N = w_codes.shape
    assert K == K2 and w_scales.shape == (K // MXBLOCK, N)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    while M % bm:
        bm //= 2
    while N % bn:
        bn //= 2
    while K % bk:
        bk //= 2
    assert bk % MXBLOCK == 0, (bk,)
    kern = functools.partial(_mx_matmul_kernel, fmt=fmt, n_k=K // bk)
    out = pl.pallas_call(
        kern,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // MXBLOCK, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, w_codes, w_scales)
    return out.astype(out_dtype)
