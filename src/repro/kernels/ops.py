"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (the kernel body executes in Python
for validation); on TPU backends it defaults to False (compiled Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import hadamard_quant as _hq
from . import mx_matmul as _mm
from . import mx_quant as _mq
from . import ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def mx_quantize(x, fmt: str = "mxfp4", interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _mq.mx_quant(x, fmt, interpret=it)


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def mx_gemm(x, w_codes, w_scales, fmt: str = "mxfp4",
            interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _mm.mx_matmul(x, w_codes, w_scales, fmt, interpret=it)


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def t3_quantize(x, fmt: str = "mxfp4", interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _hq.hadamard_quant(x, fmt, interpret=it)


@functools.partial(jax.jit, static_argnames=("fmt", "t3", "interpret"))
def mx_gemm_packed(x, w_packed, w_scales_e8m0, fmt: str = "mxfp4",
                   t3: bool = False, interpret: bool | None = None):
    """Packed-native fused MX GEMM over the HBM layout (PackedWeight
    arrays): nibble-packed codes + E8M0 scale bytes in, fp32 out.

    Shapes/dtypes (2-D): x (M, K) float (f32/bf16 — quantized to ``fmt``
    on the fly in the kernel prologue); w_packed (K//2, N) uint8 (two
    4-bit codes per byte along the contraction axis); w_scales_e8m0
    (K//32, N) uint8 (one pow2 scale byte per 32-block). Returns (M, N)
    float32 — no dense fp weight is ever materialized. K must be a
    multiple of 32. Stacked (layer- or expert-batched) weights carry
    leading batch dims on all three operands and are mapped with
    ``jax.vmap`` (a leading grid axis on TPU); x must then be
    (*lead, M, K) — rank mismatches raise ValueError.

    t3=True folds the online 32-wide T3 block-Hadamard into the
    activation-quantize prologue (the ``ffn_down`` call-site). fmt must
    be a packable format ('mxfp4' | 'mxint4').

    This is the raw kernel wrapper: eligibility checks and the
    bit-identical fallback to the reference path live one level up in
    ``core.quantize.qlinear`` / ``qeinsum`` — callers that cannot meet
    the contract should go through those. Off-TPU the kernel executes in
    interpret mode (correct, slow) unless ``interpret`` is forced.
    """
    it = _default_interpret() if interpret is None else interpret
    fn = functools.partial(_mm.mx_matmul_packed, fmt=fmt, t3=t3,
                           interpret=it)
    lead = w_packed.ndim - 2
    if x.ndim != lead + 2:
        raise ValueError(f"x rank {x.ndim} does not match weight batch "
                         f"rank {w_packed.ndim}")
    for _ in range(lead):
        fn = jax.vmap(fn)
    return fn(x, w_packed, w_scales_e8m0)


# re-exported oracles
mx_quant_ref = ref.mx_quant_ref
mx_matmul_ref = ref.mx_matmul_ref
mx_matmul_packed_ref = ref.mx_matmul_packed_ref
hadamard_quant_ref = ref.hadamard_quant_ref
quantize_weight_for_kernel = ref.quantize_weight_for_kernel
