"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (the kernel body executes in Python
for validation); on TPU backends it defaults to False (compiled Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import hadamard_quant as _hq
from . import mx_matmul as _mm
from . import mx_quant as _mq
from . import ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def mx_quantize(x, fmt: str = "mxfp4", interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _mq.mx_quant(x, fmt, interpret=it)


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def mx_gemm(x, w_codes, w_scales, fmt: str = "mxfp4",
            interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _mm.mx_matmul(x, w_codes, w_scales, fmt, interpret=it)


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def t3_quantize(x, fmt: str = "mxfp4", interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _hq.hadamard_quant(x, fmt, interpret=it)


# re-exported oracles
mx_quant_ref = ref.mx_quant_ref
mx_matmul_ref = ref.mx_matmul_ref
hadamard_quant_ref = ref.hadamard_quant_ref
quantize_weight_for_kernel = ref.quantize_weight_for_kernel
