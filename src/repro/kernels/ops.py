"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (the kernel body executes in Python
for validation); on TPU backends it defaults to False (compiled Mosaic).

Dispatch instrumentation (``docs/observability.md``): after
:func:`instrument`, every public wrapper records per-op call counts and
cumulative host-side dispatch time into a ``repro.obs.MetricsRegistry``
(``kernel_dispatch_calls_total`` / ``kernel_dispatch_seconds_total``,
labeled by op), and the fused-vs-ref dispatch decisions made one level
up in ``core.quantize`` land in ``quant_dispatch_total{op,path}``. Calls
made *inside* an enclosing ``jax.jit`` trace execute once per compile,
not once per step — they are labeled ``traced="true"`` so compile-time
inlines and real dispatches never sum into each other. Uninstrumented
(the default), the wrappers add a single ``is None`` check per call.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from . import hadamard_quant as _hq
from . import mx_attention as _ma
from . import mx_matmul as _mm
from . import mx_quant as _mq
from . import packing as _pk
from . import ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------
# Dispatch instrumentation
# ----------------------------------------------------------------------

_instr = None       # (registry, tracer) when instrumented


def instrument(registry, tracer=None) -> None:
    """Start recording kernel-dispatch metrics into ``registry`` (a
    ``repro.obs.MetricsRegistry``); optionally also emit a
    ``dispatch:<op>`` span per python-level call when a
    ``repro.obs.Tracer`` is given. Global (module-level) — one
    instrumentation target at a time; :func:`uninstrument` stops."""
    global _instr
    _instr = (registry, tracer)


def uninstrument() -> None:
    global _instr
    _instr = None


def _is_traced(*xs) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in xs)


def _record(op: str, dt: float, traced: bool) -> None:
    registry, _ = _instr
    labels = {"op": op, "traced": "true" if traced else "false"}
    registry.counter(
        "kernel_dispatch_calls_total", labels,
        help="public kernel-wrapper invocations (traced=true rows ran "
             "inside an enclosing jit trace: once per compile, not per "
             "step)").inc()
    registry.counter(
        "kernel_dispatch_seconds_total", labels, unit="s",
        help="cumulative host-side dispatch wall time (async device "
             "work excluded; under interpret mode this is ~the actual "
             "kernel time)").inc(dt)


def _dispatch(op: str, fn, *args, **kwargs):
    """Call ``fn`` (the jitted implementation), timing the host-side
    dispatch when instrumented. The timer spans trace+dispatch only —
    device execution is asynchronous and deliberately NOT waited on (no
    host sync is ever added to a serving hot loop by instrumentation)."""
    ins = _instr
    if ins is None:
        return fn(*args, **kwargs)
    traced = _is_traced(*args)
    _, tracer = ins
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    dt = time.perf_counter() - t0
    _record(op, dt, traced)
    if tracer is not None and not traced:
        tracer.complete(f"dispatch:{op}", t0, t0 + dt, cat="kernel")
    return out


def record_quant_path(op: str, path: str, role: str = "") -> None:
    """Hook for ``core.quantize``: count a fused-vs-ref dispatch
    decision (``quant_dispatch_total{op, path, role}``). No-op unless
    :func:`instrument` is active. Runs at trace time for calls inside a
    jit — counts are per *compiled call site*, not per step."""
    ins = _instr
    if ins is None:
        return
    ins[0].counter(
        "quant_dispatch_total", {"op": op, "path": path, "role": role},
        help="qlinear/qeinsum execution-path decisions (per traced "
             "call site)").inc()


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def _mx_quantize_jit(x, fmt: str = "mxfp4",
                     interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _mq.mx_quant(x, fmt, interpret=it)


def mx_quantize(x, fmt: str = "mxfp4", interpret: bool | None = None):
    return _dispatch("mx_quantize", _mx_quantize_jit, x, fmt=fmt,
                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def _mx_gemm_jit(x, w_codes, w_scales, fmt: str = "mxfp4",
                 interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _mm.mx_matmul(x, w_codes, w_scales, fmt, interpret=it)


def mx_gemm(x, w_codes, w_scales, fmt: str = "mxfp4",
            interpret: bool | None = None):
    return _dispatch("mx_gemm", _mx_gemm_jit, x, w_codes, w_scales,
                     fmt=fmt, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("fmt", "interpret"))
def _t3_quantize_jit(x, fmt: str = "mxfp4",
                     interpret: bool | None = None):
    it = _default_interpret() if interpret is None else interpret
    return _hq.hadamard_quant(x, fmt, interpret=it)


def t3_quantize(x, fmt: str = "mxfp4", interpret: bool | None = None):
    return _dispatch("t3_quantize", _t3_quantize_jit, x, fmt=fmt,
                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("fmt", "t3", "interpret"))
def _mx_gemm_packed_jit(x, w_packed, w_scales_e8m0, fmt: str = "mxfp4",
                        t3: bool = False,
                        interpret: bool | None = None):
    """Packed-native fused MX GEMM over the HBM layout (PackedWeight
    arrays): nibble-packed codes + E8M0 scale bytes in, fp32 out.

    Shapes/dtypes (2-D): x (M, K) float (f32/bf16 — quantized to ``fmt``
    on the fly in the kernel prologue); w_packed (K//2, N) uint8 (two
    4-bit codes per byte along the contraction axis); w_scales_e8m0
    (K//32, N) uint8 (one pow2 scale byte per 32-block). Returns (M, N)
    float32 — no dense fp weight is ever materialized. K must be a
    multiple of 32. Stacked (layer- or expert-batched) weights carry
    leading batch dims on all three operands and are mapped with
    ``jax.vmap`` (a leading grid axis on TPU); x must then be
    (*lead, M, K) — rank mismatches raise ValueError.

    t3=True folds the online 32-wide T3 block-Hadamard into the
    activation-quantize prologue (the ``ffn_down`` call-site). fmt must
    be a packable format ('mxfp4' | 'mxint4').

    This is the raw kernel wrapper: eligibility checks and the
    bit-identical fallback to the reference path live one level up in
    ``core.quantize.qlinear`` / ``qeinsum`` — callers that cannot meet
    the contract should go through those. Off-TPU the kernel executes in
    interpret mode (correct, slow) unless ``interpret`` is forced.
    """
    it = _default_interpret() if interpret is None else interpret
    fn = functools.partial(_mm.mx_matmul_packed, fmt=fmt, t3=t3,
                           interpret=it)
    lead = w_packed.ndim - 2
    if x.ndim != lead + 2:
        raise ValueError(f"x rank {x.ndim} does not match weight batch "
                         f"rank {w_packed.ndim}")
    for _ in range(lead):
        fn = jax.vmap(fn)
    return fn(x, w_packed, w_scales_e8m0)


def mx_gemm_packed(x, w_packed, w_scales_e8m0, fmt: str = "mxfp4",
                   t3: bool = False, interpret: bool | None = None):
    return _dispatch("mx_gemm_packed", _mx_gemm_packed_jit, x, w_packed,
                     w_scales_e8m0, fmt=fmt, t3=t3, interpret=interpret)


mx_gemm_packed.__doc__ = _mx_gemm_packed_jit.__doc__


def _flash_decode_contract(q, k_codes, k_scales, v_codes,
                           v_scales, fmt: str) -> bool:
    """Does the packed KV meet the Pallas flash-decode kernel contract?"""
    if fmt not in _pk.KV_FMTS:
        return False
    if q.ndim != 3 or k_codes.ndim != 3 or k_scales.ndim != 3:
        return False
    B, H, Dh = q.shape
    bits = _pk.kv_fmt_bits(fmt)
    D = k_codes.shape[2] * 8 // bits
    if D % 32 != 0 or Dh == 0 or D % Dh != 0 or H % (D // Dh) != 0:
        return False
    return (k_codes.shape[0] == B
            and k_scales.shape == (B, k_codes.shape[1], D // 32)
            and v_codes.shape == k_codes.shape
            and v_scales.shape == k_scales.shape)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "window", "bs", "interpret"))
def _mx_flash_decode_jit(q, k_codes, k_scales, v_codes, v_scales, q_pos,
                         kv_len, fmt: str = "mxfp8", window: int = 0,
                         bs: int | None = None,
                         interpret: bool | None = None):
    """Flash-decode attention over a packed MX KV cache.

    Shapes/dtypes: q (B, H, Dh) float — one decode token per lane;
    k/v_codes (B, S, D*bits/8) uint8 and k/v_scales (B, S, D//32) uint8
    E8M0 bytes in the ``packing.PackedKV`` layout (D = n_kv_heads * Dh,
    nibble-packed along the feature axis for 4-bit fmts); q_pos / kv_len
    (B,) int32 (scalars broadcast). Keys are contiguous from position 0.
    Returns (B, H, Dh) float32. ``window`` > 0 masks keys at
    ``pos <= q_pos - window`` (sliding-window attention).

    Dispatch: the Pallas kernel consumes the packed bytes directly
    (decoded per KV chunk in VMEM, online softmax with GQA and per-lane
    masking). Anything off-contract — a non-KV format, a mismatched
    scale layout, a head count the GQA view cannot tile — is rejected
    with a ValueError: every such input is equally ill-formed for the
    jnp oracle, so there is no graceful fallback to route to. The
    *model-level* fallback lives in ``models.layers.attention``: caches
    the kernel cannot serve (ring buffers, chunked prefill, the 'ref'
    backend) are decoded in place and run the dense jnp path. Off-TPU
    the kernel executes in interpret mode (correct, slow) unless
    ``interpret`` is forced.

    ``bs`` (KV chunk width) defaults to the whole cache under interpret
    mode — the chunk grid exists for the TPU memory hierarchy, and an
    interpreted grid step is pure overhead — and to a VMEM-sized tile
    when compiled. An *explicit* ``bs`` is honored exactly (it must
    divide S, else ValueError) on every backend, so the multi-chunk grid
    is exercisable in CPU interpret mode too.
    """
    if not _flash_decode_contract(q, k_codes, k_scales, v_codes,
                                  v_scales, fmt):
        raise ValueError(
            f"mx_flash_decode contract violation: q {q.shape}, k_codes "
            f"{k_codes.shape}, k_scales {k_scales.shape}, v_codes "
            f"{v_codes.shape}, v_scales {v_scales.shape}, fmt={fmt!r}. "
            f"Expected q (B, H, Dh); codes (B, S, D*bits/8) with "
            f"D % 32 == 0, D % Dh == 0 and H divisible by the kv-head "
            f"count D/Dh; scales (B, S, D//32); V shapes matching K; "
            f"fmt one of {_pk.KV_FMTS}.")
    it = _default_interpret() if interpret is None else interpret
    explicit = bs is not None
    if bs is None:
        bs = k_codes.shape[1] if it else 512
    return _ma.mx_flash_decode(q, k_codes, k_scales, v_codes, v_scales,
                               q_pos, kv_len, fmt, window=window, bs=bs,
                               explicit_bs=explicit, interpret=it)


def mx_flash_decode(q, k_codes, k_scales, v_codes, v_scales, q_pos,
                    kv_len, fmt: str = "mxfp8", window: int = 0,
                    bs: int | None = None,
                    interpret: bool | None = None):
    return _dispatch("mx_flash_decode", _mx_flash_decode_jit, q, k_codes,
                     k_scales, v_codes, v_scales, q_pos, kv_len, fmt=fmt,
                     window=window, bs=bs, interpret=interpret)


mx_flash_decode.__doc__ = _mx_flash_decode_jit.__doc__


def _flash_decode_paged_contract(q, k_codes, k_scales, v_codes, v_scales,
                                 block_tables, fmt: str) -> bool:
    """Does the page pool meet the paged flash-decode kernel contract?"""
    if fmt not in _pk.KV_FMTS:
        return False
    if (q.ndim != 3 or k_codes.ndim != 3 or k_scales.ndim != 3
            or block_tables.ndim != 2):
        return False
    B, H, Dh = q.shape
    bits = _pk.kv_fmt_bits(fmt)
    N, P = k_codes.shape[0], k_codes.shape[1]
    D = k_codes.shape[2] * 8 // bits
    if D % 32 != 0 or Dh == 0 or D % Dh != 0 or H % (D // Dh) != 0:
        return False
    return (block_tables.shape[0] == B
            and k_scales.shape == (N, P, D // 32)
            and v_codes.shape == k_codes.shape
            and v_scales.shape == k_scales.shape)


@functools.partial(jax.jit, static_argnames=("fmt", "window", "interpret"))
def _mx_flash_decode_paged_jit(q, k_codes, k_scales, v_codes, v_scales,
                               block_tables, q_pos, kv_len,
                               fmt: str = "mxfp8", window: int = 0,
                               interpret: bool | None = None):
    """Flash-decode attention over a *paged* packed MX KV pool.

    Shapes/dtypes: q (B, H, Dh) float; k/v_codes (N, P, D*bits/8) uint8
    and k/v_scales (N, P, D//32) uint8 E8M0 bytes — the shared page pool
    in the ``packing.PagedKV`` layout (N pages of P tokens each);
    block_tables (B, maxp) int32 — lane b's chunk c reads pool page
    ``block_tables[b, c]``, which holds logical positions
    [c*P, (c+1)*P); q_pos / kv_len (B,) int32 (scalars broadcast).
    Returns (B, H, Dh) float32. ``window`` as in :func:`mx_flash_decode`.

    The block table is a scalar-prefetch operand: BlockSpec index maps
    resolve the page id before each grid step, so the kernel DMA-gathers
    pages straight from the pool — no contiguous copy of a lane's cache
    is ever materialized. Table slots past a lane's fill must still hold
    *valid* page ids (the serving engine parks them on its scrap page);
    those rows are masked by ``kv_len``. Off-contract inputs raise — the
    model-level fallback (gather + dense jnp attention) lives in
    ``models.layers.attention_paged``."""
    if not _flash_decode_paged_contract(q, k_codes, k_scales, v_codes,
                                        v_scales, block_tables, fmt):
        raise ValueError(
            f"mx_flash_decode_paged contract violation: q {q.shape}, "
            f"k_codes {k_codes.shape}, k_scales {k_scales.shape}, "
            f"v_codes {v_codes.shape}, v_scales {v_scales.shape}, "
            f"block_tables {block_tables.shape}, fmt={fmt!r}. Expected "
            f"q (B, H, Dh); a (N, P, D*bits/8) page pool with "
            f"D % 32 == 0, D % Dh == 0 and H divisible by the kv-head "
            f"count D/Dh; scales (N, P, D//32); V shapes matching K; "
            f"block_tables (B, maxp) int32; fmt one of {_pk.KV_FMTS}.")
    it = _default_interpret() if interpret is None else interpret
    return _ma.mx_flash_decode_paged(q, k_codes, k_scales, v_codes,
                                     v_scales, block_tables, q_pos,
                                     kv_len, fmt, window=window,
                                     interpret=it)


def mx_flash_decode_paged(q, k_codes, k_scales, v_codes, v_scales,
                          block_tables, q_pos, kv_len,
                          fmt: str = "mxfp8", window: int = 0,
                          interpret: bool | None = None):
    return _dispatch("mx_flash_decode_paged", _mx_flash_decode_paged_jit,
                     q, k_codes, k_scales, v_codes, v_scales,
                     block_tables, q_pos, kv_len, fmt=fmt, window=window,
                     interpret=interpret)


mx_flash_decode_paged.__doc__ = _mx_flash_decode_paged_jit.__doc__


def _flash_prefill_contract(q, k_chunk, v_chunk, k_codes, k_scales,
                            v_codes, v_scales, block_tables,
                            fmt: str) -> bool:
    """Does the input meet the paged flash-prefill kernel contract?"""
    if fmt not in _pk.KV_FMTS:
        return False
    if (q.ndim != 4 or k_chunk.ndim != 3 or k_codes.ndim != 3
            or k_scales.ndim != 3 or block_tables.ndim != 2):
        return False
    B, C, H, Dh = q.shape
    bits = _pk.kv_fmt_bits(fmt)
    N, P = k_codes.shape[0], k_codes.shape[1]
    D = k_codes.shape[2] * 8 // bits
    if D % 32 != 0 or Dh == 0 or D % Dh != 0 or H % (D // Dh) != 0:
        return False
    return (block_tables.shape[0] == B and block_tables.shape[1] >= 1
            and k_chunk.shape == (B, C, D)
            and v_chunk.shape == k_chunk.shape
            and k_scales.shape == (N, P, D // 32)
            and v_codes.shape == k_codes.shape
            and v_scales.shape == k_scales.shape)


@functools.partial(jax.jit, static_argnames=("fmt", "window", "qb", "kvb",
                                             "interpret"))
def _mx_flash_prefill_jit(q, k_chunk, v_chunk, k_codes, k_scales, v_codes,
                          v_scales, block_tables, q_start, kv_len,
                          fmt: str = "mxfp8", window: int = 0,
                          qb: int | None = None, kvb: int | None = None,
                          interpret: bool | None = None):
    """Flash-prefill attention over a *paged* packed MX KV pool, fused
    with the quantize-on-append of the current chunk.

    Shapes/dtypes: q (B, C, H, Dh) float — a C-token prefill chunk per
    lane; k/v_chunk (B, C, D) float — the chunk's dense K/V (D =
    n_kv_heads * Dh); k/v_codes (N, P, D*bits/8) uint8 and k/v_scales
    (N, P, D//32) uint8 E8M0 bytes — the shared page pool in the
    ``packing.PagedKV`` layout; block_tables (B, maxp) int32 (same
    scalar-prefetch ABI as :func:`mx_flash_decode_paged`); q_start /
    kv_len (B,) int32 (scalars broadcast) — chunk start offset and
    valid-key bound per lane.

    Returns ``(out (B, C, H, Dh) f32, k_code_bytes (B, C, D*bits/8) u8,
    k_scale_bytes (B, C, D//32) u8, v_code_bytes, v_scale_bytes)``. The
    byte outputs are bit-identical to ``packing.kv_encode`` of the chunk
    — the caller scatters them into the pool
    (``models.layers.kv_scatter_chunk_paged``) so dense chunk K/V never
    round-trips HBM; the kernel attends the decoded roundtrip of those
    same bytes, keeping it bit-identical to write-then-read. Pool rows
    ``kp < q_start`` are the committed prefix; causal / fill / window
    masks are per query row, as in ``models.layers.attention``.

    Off-contract inputs raise ValueError — every such input is equally
    ill-formed for the jnp oracle (``mx_prefill_ref``); the model-level
    fallback (quantize + scatter + gather + dense jnp attention) lives in
    ``models.transformer.attn_sublayer_chunk_paged``. ``qb``/``kvb``
    (query/self-KV tile widths over the chunk) default to the whole chunk
    under interpret mode and to VMEM-sized tiles when compiled; explicit
    values are honored exactly (must divide C, else ValueError) on every
    backend, so the multi-block grid is exercisable in CPU interpret
    mode."""
    if not _flash_prefill_contract(q, k_chunk, v_chunk, k_codes, k_scales,
                                   v_codes, v_scales, block_tables, fmt):
        raise ValueError(
            f"mx_flash_prefill contract violation: q {q.shape}, k_chunk "
            f"{k_chunk.shape}, v_chunk {v_chunk.shape}, k_codes "
            f"{k_codes.shape}, k_scales {k_scales.shape}, v_codes "
            f"{v_codes.shape}, v_scales {v_scales.shape}, block_tables "
            f"{block_tables.shape}, fmt={fmt!r}. Expected q (B, C, H, "
            f"Dh); dense chunk K/V (B, C, D) with D % 32 == 0, "
            f"D % Dh == 0 and H divisible by the kv-head count D/Dh; a "
            f"(N, P, D*bits/8) page pool with scales (N, P, D//32); V "
            f"shapes matching K; block_tables (B, maxp) int32 with "
            f"maxp >= 1; fmt one of {_pk.KV_FMTS}.")
    it = _default_interpret() if interpret is None else interpret
    C = q.shape[1]
    explicit_qb = qb is not None
    explicit_kvb = kvb is not None
    if qb is None:
        qb = C if it else 128
    if kvb is None:
        kvb = C if it else 512
    return _ma.mx_flash_prefill(q, k_chunk, v_chunk, k_codes, k_scales,
                                v_codes, v_scales, block_tables, q_start,
                                kv_len, fmt, window=window, qb=qb,
                                kvb=kvb, explicit_qb=explicit_qb,
                                explicit_kvb=explicit_kvb, interpret=it)


def mx_flash_prefill(q, k_chunk, v_chunk, k_codes, k_scales, v_codes,
                     v_scales, block_tables, q_start, kv_len,
                     fmt: str = "mxfp8", window: int = 0,
                     qb: int | None = None, kvb: int | None = None,
                     interpret: bool | None = None):
    return _dispatch("mx_flash_prefill", _mx_flash_prefill_jit, q,
                     k_chunk, v_chunk, k_codes, k_scales, v_codes,
                     v_scales, block_tables, q_start, kv_len, fmt=fmt,
                     window=window, qb=qb, kvb=kvb, interpret=interpret)


mx_flash_prefill.__doc__ = _mx_flash_prefill_jit.__doc__


# re-exported oracles
mx_quant_ref = ref.mx_quant_ref
mx_matmul_ref = ref.mx_matmul_ref
mx_matmul_packed_ref = ref.mx_matmul_packed_ref
mx_attention_ref = ref.mx_attention_ref
mx_attention_paged_ref = ref.mx_attention_paged_ref
mx_prefill_ref = ref.mx_prefill_ref
hadamard_quant_ref = ref.hadamard_quant_ref
quantize_weight_for_kernel = ref.quantize_weight_for_kernel
