"""Overload-safe asyncio HTTP/SSE front end over the serving engine.

The Engine (``repro.serving.engine``) is a library loop: blocking
``submit``/``step``/``drain`` calls on one thread. Production serving is
an async *process* — this module is the boundary layer that makes the
difference (``docs/server.md`` has the full protocol):

* :class:`EngineSupervisor` — owns the engine on a dedicated worker
  thread (every engine call goes through one lock; the asyncio loop
  never blocks on a decode step). The worker drains a thread-safe
  control queue (cancellations) *before every step* — a client
  disconnect cancels its request within one engine step — and runs the
  step under the ``failed_step`` / ``stuck_step`` server fault points.
  When a step raises, the supervisor **fails the poisoned lane**
  (terminal FAILED — re-running it would poison the restarted loop the
  same way), **requeues every bystander lane** without charging retry
  budget (recompute-resume: bit-identical under greedy decoding), and
  keeps stepping. The server-side watchdog task flags a stalled step
  and fires :meth:`EngineSupervisor.abort_current_step` — the injected
  ``stuck_step`` hang honors it cooperatively; a genuine wedged device
  computation cannot be interrupted from Python, so the watchdog's job
  there is *detection* (readiness flips, the operator restarts the
  process).

* :class:`Server` — stdlib-asyncio HTTP/1.1 server (no third-party web
  framework; one connection per request, ``Connection: close``):

  - ``POST /v1/generate`` — submit a request; ``"stream": true`` (the
    default) responds as Server-Sent Events (``event: token`` per
    flush, a final ``event: done`` carrying the terminal state),
    otherwise one JSON body at completion.
  - **Admission control**: ``Engine.submit`` sheds over-limit requests
    (``SchedulingPolicy`` caps, terminal SHED state); the server maps
    :class:`ShedError` to ``429`` with ``Retry-After`` (integer
    seconds, RFC-shaped) and ``X-Retry-After-S`` (exact float) derived
    from the policy backoff schedule. Shedding is loud by design —
    never a silent requeue.
  - **Graceful drain**: SIGTERM/SIGINT flips ``/readyz`` to 503,
    closes the listener, rejects new generates with 503 +
    ``Retry-After``, lets in-flight requests run to a terminal state
    (cancelling stragglers at ``drain_timeout_s``), then stops the
    worker and emits a drain report asserting ``sum(terminal) ==
    submitted`` and a clean ``BlockAllocator.check()`` — zero leaked
    pages is an exit-code property, not a hope.
  - **Disconnect propagation**: a dropped SSE connection (EOF on the
    socket or a failed write) enqueues ``Engine.cancel`` — the lane
    frees and its pages deref mid-stream; bystander lanes are
    untouched.
  - **Bounded streaming**: each SSE stream buffers at most
    ``stream_buffer`` pending flushes; a slower consumer degrades to
    *coalesced flushes* (one event carrying many tokens — data is
    never dropped, memory never grows past the cap) counted by
    ``serving_stream_coalesced_flushes_total``.
  - ``GET /healthz`` (process liveness), ``GET /readyz`` (load-balancer
    readiness; 503 while draining), ``GET /metrics`` (Prometheus text
    from the engine's registry), ``GET /statz`` (``Engine.stats()`` as
    JSON).

``python -m repro.serving.server`` starts a demo server on a tiny
random-init model (the chaos-harness config) — what CI's server smoke
drives over real HTTP. All request/response payloads speak token ids;
tokenization is out of scope for the reproduction.
"""
from __future__ import annotations

import argparse
import asyncio
import collections
import dataclasses
import json
import math
import signal
import sys
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import Engine, Request
from repro.serving.faults import FaultInjector
from repro.serving.policy import (RequestState, SchedulingPolicy, ShedError,
                                  TERMINAL_STATES)
from repro.serving.sampling import SamplingParams

__all__ = ["EngineSupervisor", "Server", "ServerConfig", "StuckStepError",
           "serve"]


class StuckStepError(RuntimeError):
    """An engine step exceeded the watchdog budget (injected via the
    ``stuck_step`` fault point; see module docstring for why a genuine
    device hang is detect-only)."""


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Front-end knobs (``Server(config=...)``); engine-side admission
    caps live on ``SchedulingPolicy``, not here."""

    host: str = "127.0.0.1"
    port: int = 8100                  # 0 = ephemeral (tests / CI smoke)
    stream_buffer: int = 32           # pending SSE flushes before coalescing
    drain_timeout_s: float = 30.0     # SIGTERM -> cancel stragglers
    watchdog_timeout_s: float = 10.0  # step wall-clock budget
    watchdog_poll_s: float = 0.25
    worker_poll_s: float = 0.02       # idle worker wakeup granularity
    max_body_bytes: int = 1 << 20
    retry_after_drain_s: float = 1.0  # Retry-After on 503 while draining


# ---------------------------------------------------------------------------
# Engine supervisor: worker thread + failure recovery
# ---------------------------------------------------------------------------

class EngineSupervisor:
    """Runs the engine loop on a worker thread and survives step failures.

    Thread contract: every engine touch — submit, cancel, step, stats —
    happens under ``self._lock``. The asyncio side calls :meth:`submit`
    through an executor (it can block on a running step) and
    :meth:`cancel` through the control queue (applied before the next
    step). Completion callbacks registered at submit fire on the worker
    thread *after* the lock is released — marshal back to the loop with
    ``call_soon_threadsafe`` (the server's token streams do).
    """

    def __init__(self, engine: Engine,
                 faults: Optional[FaultInjector] = None,
                 worker_poll_s: float = 0.02):
        self.engine = engine
        self.faults = faults
        self.worker_poll_s = worker_poll_s
        self._lock = threading.RLock()
        self._control: "collections.deque" = collections.deque()
        self._live: Dict[str, Tuple[Request, Optional[Callable]]] = {}
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._abort = threading.Event()      # watchdog -> stuck-step hang
        self._heartbeat = time.monotonic()
        self._in_step = False
        self._blame_lane: Optional[int] = None
        self._thread: Optional[threading.Thread] = None
        self.restarts = 0
        self._c_restarts = engine.metrics.counter(
            "serving_supervisor_restarts_total",
            help="engine loop restarts after a stuck/failed step: the "
                 "poisoned lane's request is terminal-FAILED, bystander "
                 "lanes requeue and resume bit-identically "
                 "(docs/server.md)")

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> None:
        self._thread = threading.Thread(target=self._worker,
                                        name="engine-supervisor",
                                        daemon=True)
        self._thread.start()

    def stop(self, timeout_s: float = 10.0) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout_s)

    # -- asyncio-facing API ------------------------------------------------

    def submit(self, req: Request,
               on_done: Optional[Callable] = None) -> Request:
        """Submit under the engine lock (call via an executor from the
        event loop — a decode step may hold the lock for milliseconds).
        Raises :class:`ShedError` untouched; registers ``on_done``
        atomically with the submit so a fast completion cannot race past
        the registration."""
        with self._lock:
            self.engine.submit(req)          # may raise ShedError
            self._live[req.request_id] = (req, on_done)
        self._wake.set()
        return req

    def cancel(self, request_id: str) -> None:
        """Thread-safe cancellation; applied before the next engine step
        (the within-one-step guarantee the disconnect tests pin)."""
        self._control.append(request_id)
        self._wake.set()

    def idle(self) -> bool:
        with self._lock:
            return not self.engine.busy and not self._control

    def live_ids(self) -> List[str]:
        with self._lock:
            return [rid for rid, (r, _) in self._live.items()
                    if r.state not in TERMINAL_STATES]

    def stats(self) -> dict:
        with self._lock:
            return self.engine.stats()

    def render_metrics(self) -> str:
        with self._lock:
            return self.engine.metrics.render_prometheus()

    # -- watchdog interface ------------------------------------------------

    def stalled(self, timeout_s: float) -> bool:
        return (self._in_step
                and time.monotonic() - self._heartbeat > timeout_s)

    def abort_current_step(self) -> None:
        self._abort.set()

    # -- worker ------------------------------------------------------------

    def _worker(self) -> None:
        while not self._stop.is_set():
            self._drain_control()
            with self._lock:
                busy = self.engine.busy
            if not busy:
                self._wake.wait(self.worker_poll_s)
                self._wake.clear()
                continue
            try:
                self._heartbeat = time.monotonic()
                self._in_step = True
                self._fire_step_faults()
                with self._lock:
                    done = self.engine.step()
            except Exception as exc:            # noqa: BLE001 — supervisor
                self._in_step = False
                self._recover(exc)
                continue
            self._in_step = False
            for req in done:
                self._notify_done(req)

    def _drain_control(self) -> None:
        while self._control:
            rid = self._control.popleft()
            with self._lock:
                ok = self.engine.cancel(rid)
                entry = self._live.get(rid)
            if ok and entry is not None:
                self._notify_done(entry[0])

    def _fire_step_faults(self) -> None:
        fi = self.faults
        if fi is None:
            return
        hit = fi.fire("failed_step")
        if hit is not None:
            self._blame_lane = hit.get("lane")
            raise RuntimeError(hit.get("error", "injected step failure"))
        hit = fi.fire("stuck_step")
        if hit is not None:
            self._blame_lane = hit.get("lane")
            hang_s = float(hit.get("hang_s", 30.0))
            # cooperative hang: wakes the moment the watchdog aborts, so
            # the test pins detection latency, not the full hang
            aborted = self._abort.wait(hang_s)
            raise StuckStepError(
                "step aborted by watchdog" if aborted
                else f"step stuck {hang_s:g}s (watchdog never fired)")

    def _recover(self, exc: Exception) -> None:
        """Fail the poisoned lane, requeue bystanders, keep stepping."""
        done: List[Request] = []
        with self._lock:
            lanes = [i for i, s in enumerate(self.engine._slots)
                     if s is not None]
            blame = self._blame_lane
            self._blame_lane = None
            if blame not in lanes:
                # no attribution (real failures can't name a lane):
                # deterministically blame the lowest occupied lane
                blame = lanes[0] if lanes else None
            if blame is not None:
                failed = self.engine.fail_lane(
                    blame, f"step failed under supervisor: {exc}")
                if failed is not None:
                    done.append(failed)
                for i in lanes:
                    if i != blame:
                        self.engine.requeue_lane(
                            i, "supervisor restart after failed step")
        self.restarts += 1
        self._c_restarts.inc()
        self._abort.clear()
        for req in done:
            self._notify_done(req)

    def _notify_done(self, req: Request) -> None:
        entry = self._live.pop(req.request_id, None)
        if entry is not None and entry[1] is not None:
            try:
                entry[1](req)
            except Exception:                   # noqa: BLE001 — callback
                pass                            # never kills the worker


# ---------------------------------------------------------------------------
# Bounded SSE token stream
# ---------------------------------------------------------------------------

class _TokenStream:
    """Per-connection token buffer between the worker thread and one SSE
    writer. Holds at most ``limit`` pending flush units; overflow merges
    every pending unit into one *coalesced* flush (tokens are never
    dropped — a slow consumer gets fewer, fatter events instead of
    unbounded server memory). All mutation happens on the event loop via
    ``call_soon_threadsafe``."""

    def __init__(self, loop: asyncio.AbstractEventLoop, limit: int):
        self._loop = loop
        self.limit = max(int(limit), 1)
        self._pending: "collections.deque[List[int]]" = collections.deque()
        self._event = asyncio.Event()
        self._done: Optional[Request] = None
        self.coalesced = 0

    # worker-thread side -----------------------------------------------------

    def feed_threadsafe(self, tok: int) -> None:
        self._loop.call_soon_threadsafe(self._feed, int(tok))

    def done_threadsafe(self, req: Request) -> None:
        self._loop.call_soon_threadsafe(self._finish, req)

    # event-loop side --------------------------------------------------------

    def _feed(self, tok: int) -> None:
        if len(self._pending) >= self.limit:
            merged: List[int] = []
            while self._pending:
                merged.extend(self._pending.popleft())
            merged.append(tok)
            self._pending.append(merged)
            self.coalesced += 1
        else:
            self._pending.append([tok])
        self._event.set()

    def _finish(self, req: Request) -> None:
        self._done = req
        self._event.set()

    async def next(self) -> Optional[List[int]]:
        """Next flush unit (>=1 tokens), or None once the request is
        terminal and the buffer is drained."""
        while True:
            if self._pending:
                return self._pending.popleft()
            if self._done is not None:
                return None
            self._event.clear()
            await self._event.wait()

    @property
    def result(self) -> Optional[Request]:
        return self._done


# ---------------------------------------------------------------------------
# HTTP server
# ---------------------------------------------------------------------------

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 413: "Payload Too Large",
            429: "Too Many Requests", 499: "Client Closed Request",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}

_STATE_HTTP = {RequestState.FINISHED: 200, RequestState.TIMED_OUT: 504,
               RequestState.CANCELLED: 499}


class Server:
    """See module docstring. ``Server(engine).serve_forever()`` is the
    whole lifecycle: bind, serve, drain on SIGTERM/SIGINT, report."""

    def __init__(self, engine: Engine,
                 config: ServerConfig = ServerConfig(),
                 faults: Optional[FaultInjector] = None):
        self.engine = engine
        self.config = config
        self.faults = faults
        self.sup = EngineSupervisor(engine, faults=faults,
                                    worker_poll_s=config.worker_poll_s)
        self.draining = False
        self.port: Optional[int] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._watchdog_task: Optional[asyncio.Task] = None
        self._active_streams = 0
        reg = engine.metrics
        self._reg = reg
        self._g_streams = reg.gauge(
            "http_active_streams",
            help="SSE connections currently streaming tokens")
        self._c_disconnects = reg.counter(
            "serving_client_disconnects_total",
            help="SSE connections dropped mid-stream; each cancels its "
                 "request within one engine step (docs/server.md)")
        self._c_coalesced = reg.counter(
            "serving_stream_coalesced_flushes_total",
            help="bounded-buffer overflows degraded to one multi-token "
                 "flush (slow SSE consumers; no tokens dropped)")

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> None:
        self.sup.start()
        self._server = await asyncio.start_server(
            self._handle, self.config.host, self.config.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._watchdog_task = asyncio.ensure_future(self._watchdog())

    async def serve_forever(self, install_signals: bool = True) -> dict:
        """Serve until SIGTERM/SIGINT, then drain; returns the drain
        report (also what ``__main__`` turns into the exit code)."""
        await self.start()
        loop = asyncio.get_running_loop()
        stop = asyncio.Event()
        if install_signals:
            for sig in (signal.SIGTERM, signal.SIGINT):
                loop.add_signal_handler(sig, stop.set)
        print(f"serving on http://{self.config.host}:{self.port}",
              flush=True)
        await stop.wait()
        return await self.shutdown()

    async def shutdown(self) -> dict:
        """Graceful drain (module docstring step by step)."""
        self.draining = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        loop = asyncio.get_running_loop()
        deadline = loop.time() + self.config.drain_timeout_s
        cancelled_stragglers = False
        while not (self.sup.idle() and self._active_streams == 0):
            if loop.time() >= deadline and not cancelled_stragglers:
                for rid in self.sup.live_ids():
                    self.sup.cancel(rid)
                cancelled_stragglers = True
                deadline = loop.time() + 5.0    # grace for the cancels
            elif loop.time() >= deadline:
                break                            # report the leak below
            await asyncio.sleep(0.02)
        if self._watchdog_task is not None:
            self._watchdog_task.cancel()
        self.sup.stop()
        return self.drain_report(
            cancelled_stragglers=cancelled_stragglers)

    def drain_report(self, cancelled_stragglers: bool = False) -> dict:
        """Quiescence audit: every submitted request terminal, allocator
        invariants clean. ``clean`` is the exit-code bit."""
        st = self.engine.stats()
        terminal_sum = sum(st["terminal"].values())
        allocator_clean = True
        allocator = None
        if getattr(self.engine, "kv_layout", None) == "paged":
            try:
                allocator = self.engine._alloc.check()
            except AssertionError as exc:
                allocator_clean = False
                allocator = {"error": str(exc)}
            else:
                allocator_clean = allocator["in_use"] == 0
        all_terminal = terminal_sum == st["submitted"]
        return {
            "submitted": st["submitted"],
            "terminal": st["terminal"],
            "terminal_sum": terminal_sum,
            "all_terminal": all_terminal,
            "allocator": allocator,
            "allocator_clean": allocator_clean,
            "supervisor_restarts": self.sup.restarts,
            "cancelled_stragglers": cancelled_stragglers,
            "clean": all_terminal and allocator_clean,
        }

    async def _watchdog(self) -> None:
        while True:
            await asyncio.sleep(self.config.watchdog_poll_s)
            if self.sup.stalled(self.config.watchdog_timeout_s):
                self.sup.abort_current_step()

    # -- HTTP plumbing -----------------------------------------------------

    def _count(self, route: str, code: int) -> None:
        self._reg.counter(
            "http_requests_total", {"route": route, "code": str(code)},
            help="HTTP requests by route and status code").inc()

    @staticmethod
    def _response(code: int, body: bytes,
                  content_type: str = "application/json",
                  extra: Optional[dict] = None) -> bytes:
        head = [f"HTTP/1.1 {code} {_REASONS.get(code, 'Unknown')}",
                f"Content-Type: {content_type}",
                f"Content-Length: {len(body)}",
                "Connection: close"]
        for k, v in (extra or {}).items():
            head.append(f"{k}: {v}")
        return ("\r\n".join(head) + "\r\n\r\n").encode() + body

    def _json(self, code: int, obj: dict,
              extra: Optional[dict] = None) -> bytes:
        return self._response(code, (json.dumps(obj) + "\n").encode(),
                              extra=extra)

    async def _read_request(self, reader: asyncio.StreamReader):
        """(method, path, headers, body) or an error-response bytes."""
        line = await reader.readline()
        if not line:
            return None
        try:
            method, target, _version = line.decode("latin1").split(None, 2)
        except ValueError:
            return self._json(400, {"error": "malformed request line"})
        headers: Dict[str, str] = {}
        total = len(line)
        while True:
            h = await reader.readline()
            total += len(h)
            if total > 64 * 1024:
                return self._json(400, {"error": "headers too large"})
            if h in (b"\r\n", b"\n", b""):
                break
            if b":" in h:
                k, v = h.decode("latin1").split(":", 1)
                headers[k.strip().lower()] = v.strip()
        body = b""
        n = int(headers.get("content-length", "0") or "0")
        if n > self.config.max_body_bytes:
            return self._json(413, {
                "error": f"body {n} bytes > max {self.config.max_body_bytes}"})
        if n:
            body = await reader.readexactly(n)
        return method.upper(), target.split("?", 1)[0], headers, body

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await self._read_request(reader)
            if parsed is None:
                return
            if isinstance(parsed, bytes):       # parse-level error response
                writer.write(parsed)
                await writer.drain()
                return
            method, path, headers, body = parsed
            await self._route(method, path, body, reader, writer)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _route(self, method: str, path: str, body: bytes,
                     reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> None:
        if path == "/healthz" and method == "GET":
            self._count(path, 200)
            writer.write(self._response(200, b"ok\n", "text/plain"))
        elif path == "/readyz" and method == "GET":
            if self.draining:
                self._count(path, 503)
                writer.write(self._json(
                    503, {"ready": False, "reason": "draining"},
                    extra={"Retry-After": _retry_after_header(
                        self.config.retry_after_drain_s)}))
            else:
                self._count(path, 200)
                writer.write(self._json(200, {"ready": True}))
        elif path == "/metrics" and method == "GET":
            loop = asyncio.get_running_loop()
            text = await loop.run_in_executor(None, self.sup.render_metrics)
            self._count(path, 200)
            writer.write(self._response(
                200, text.encode(), "text/plain; version=0.0.4"))
        elif path == "/statz" and method == "GET":
            loop = asyncio.get_running_loop()
            st = await loop.run_in_executor(None, self.sup.stats)
            self._count(path, 200)
            writer.write(self._json(200, st))
        elif path == "/v1/generate":
            if method != "POST":
                self._count(path, 405)
                writer.write(self._json(405, {"error": "POST only"}))
            else:
                await self._generate(body, reader, writer)
                return                           # handled its own write
        else:
            self._count(path, 404)
            writer.write(self._json(404, {"error": f"no route {path}"}))
        await writer.drain()

    # -- /v1/generate ------------------------------------------------------

    def _parse_generate(self, body: bytes):
        """Request object + stream flag, or an error-response bytes."""
        try:
            data = json.loads(body.decode() or "{}")
        except (ValueError, UnicodeDecodeError) as exc:
            return self._json(400, {"error": f"bad JSON body: {exc}"})
        prompt = data.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            return self._json(400, {
                "error": "prompt must be a non-empty list of token ids"})
        sampling = None
        if any(k in data for k in ("temperature", "top_k", "top_p", "seed")):
            try:
                sampling = SamplingParams(
                    temperature=float(data.get("temperature", 0.0)),
                    top_k=int(data.get("top_k", 0)),
                    top_p=float(data.get("top_p", 1.0)),
                    seed=int(data.get("seed", 0)))
            except (TypeError, ValueError) as exc:
                return self._json(400, {"error": f"bad sampling: {exc}"})
        try:
            req = Request(
                prompt=np.asarray(prompt, np.int32),
                max_new=int(data.get("max_new", 16)),
                priority=int(data.get("priority", 0)),
                deadline_ms=(float(data["deadline_ms"])
                             if data.get("deadline_ms") is not None else None),
                ttft_deadline_ms=(float(data["ttft_deadline_ms"])
                                  if data.get("ttft_deadline_ms") is not None
                                  else None),
                sampling=sampling)
        except (TypeError, ValueError) as exc:
            return self._json(400, {"error": f"bad request: {exc}"})
        return req, bool(data.get("stream", True))

    async def _generate(self, body: bytes, reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter) -> None:
        route = "/v1/generate"
        if self.draining:
            self._count(route, 503)
            writer.write(self._json(
                503, {"error": "draining: not accepting new work",
                      "retry_after_s": self.config.retry_after_drain_s},
                extra={"Retry-After": _retry_after_header(
                    self.config.retry_after_drain_s)}))
            await writer.drain()
            return
        parsed = self._parse_generate(body)
        if isinstance(parsed, bytes):
            self._count(route, 400)
            writer.write(parsed)
            await writer.drain()
            return
        req, stream = parsed
        loop = asyncio.get_running_loop()
        if stream:
            tstream = _TokenStream(loop, self.config.stream_buffer)
            req.on_token = tstream.feed_threadsafe
            on_done = tstream.done_threadsafe
        else:
            fut: "asyncio.Future[Request]" = loop.create_future()

            def on_done(r, _fut=fut, _loop=loop):
                _loop.call_soon_threadsafe(
                    lambda: None if _fut.done() else _fut.set_result(r))
        try:
            await loop.run_in_executor(None, self.sup.submit, req, on_done)
        except ShedError as exc:
            self._count(route, 429)
            writer.write(self._json(
                429, {"error": "shed", "reason": exc.reason,
                      "retry_after_s": exc.retry_after_s,
                      "request_id": exc.request.request_id},
                extra={"Retry-After": _retry_after_header(exc.retry_after_s),
                       "X-Retry-After-S": f"{exc.retry_after_s:g}"}))
            await writer.drain()
            return
        if stream:
            await self._stream_response(req, tstream, reader, writer)
        else:
            done = await fut
            code = _STATE_HTTP.get(done.state, 500)
            self._count(route, code)
            writer.write(self._json(code, _result_json(done)))
            await writer.drain()

    async def _stream_response(self, req: Request, tstream: _TokenStream,
                               reader: asyncio.StreamReader,
                               writer: asyncio.StreamWriter) -> None:
        self._count("/v1/generate", 200)
        self._active_streams += 1
        self._g_streams.set(self._active_streams)
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-cache\r\n"
            b"Connection: close\r\n\r\n")
        disc = asyncio.ensure_future(_watch_disconnect(reader))
        fi = self.faults
        events = 0
        emitted = 0
        disconnected = False
        try:
            await writer.drain()
            while True:
                nxt = asyncio.ensure_future(tstream.next())
                done_set, _ = await asyncio.wait(
                    {nxt, disc}, return_when=asyncio.FIRST_COMPLETED)
                if disc in done_set:
                    nxt.cancel()
                    disconnected = True
                    break
                toks = nxt.result()
                if toks is None:
                    break
                if fi is not None:
                    hit = fi.fire("slow_consumer")
                    if hit is not None:
                        await asyncio.sleep(float(hit.get("delay_s", 0.05)))
                    # fire() counts per flush: inject("disconnect", at=N)
                    # drops the connection before the (N+1)-th event
                    if fi.fire("disconnect") is not None:
                        writer.transport.abort()
                        disconnected = True
                        break
                payload = json.dumps({"tokens": toks, "i": emitted,
                                      "coalesced": len(toks) > 1})
                writer.write(f"event: token\ndata: {payload}\n\n".encode())
                await writer.drain()
                events += 1
                emitted += len(toks)
            if not disconnected:
                done = tstream.result
                payload = json.dumps(_result_json(
                    done, coalesced_flushes=tstream.coalesced))
                writer.write(f"event: done\ndata: {payload}\n\n".encode())
                await writer.drain()
        except (ConnectionError, OSError):
            disconnected = True
        finally:
            disc.cancel()
            if disconnected:
                self._c_disconnects.inc()
                self.sup.cancel(req.request_id)
            if tstream.coalesced:
                self._c_coalesced.inc(tstream.coalesced)
            self._active_streams -= 1
            self._g_streams.set(self._active_streams)


async def _watch_disconnect(reader: asyncio.StreamReader) -> None:
    """Resolves when the peer closes its end (EOF). Extra request bytes
    on an SSE connection are drained and ignored (Connection: close —
    there is no pipelining to honor)."""
    while True:
        chunk = await reader.read(4096)
        if not chunk:
            return


def _retry_after_header(seconds: float) -> int:
    """RFC 9110 Retry-After is integer seconds; round sub-second backoff
    up so a compliant client never retries early. The exact float rides
    in ``X-Retry-After-S``."""
    return max(int(math.ceil(seconds)), 1)


def _result_json(req: Optional[Request], **extra) -> dict:
    if req is None:                              # disconnect before done
        return {"state": None, **extra}
    return {"request_id": req.request_id,
            "state": req.state.value,
            "error": req.error,
            "n_tokens": 0 if req.out is None else int(len(req.out)),
            "tokens": [] if req.out is None else
                      [int(t) for t in req.out],
            **extra}


# ---------------------------------------------------------------------------
# Entry point: demo server on a tiny random-init model
# ---------------------------------------------------------------------------

def demo_engine(max_queue_depth: Optional[int] = None,
                admit_token_budget: Optional[int] = None,
                deadline_ms: Optional[float] = None,
                batch_size: int = 4, max_len: int = 128,
                faults: Optional[FaultInjector] = None) -> Engine:
    """Tiny random-init paged engine (the chaos-harness config) — demo /
    CI-smoke backing for the server; no artifact required."""
    import jax
    from repro.configs.base import ArchConfig
    from repro.core.quantize import QuantMode
    from repro.models import api
    cfg = ArchConfig(name="demo", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                     attn_chunk=16)
    params = api.init(jax.random.PRNGKey(0), cfg)
    policy = SchedulingPolicy(max_queue_depth=max_queue_depth,
                              admit_token_budget=admit_token_budget,
                              deadline_ms=deadline_ms)
    return Engine(params, cfg, QuantMode.off(), batch_size=batch_size,
                  max_len=max_len, scheduler="continuous",
                  kv_layout="paged", page_size=32, policy=policy,
                  faults=faults)


def serve(engine: Engine, config: ServerConfig = ServerConfig(),
          faults: Optional[FaultInjector] = None) -> dict:
    """Blocking convenience: run the server until SIGTERM/SIGINT and
    return the drain report (what ``launch/serve.py --http`` calls)."""
    return asyncio.run(Server(engine, config=config,
                              faults=faults).serve_forever())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="demo HTTP/SSE server on a tiny random-init model "
                    "(docs/server.md; real checkpoints go through "
                    "launch/serve.py --http)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8100,
                    help="0 picks an ephemeral port (printed at startup)")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="admission cap: shed (429) past this queue depth")
    ap.add_argument("--admit-token-budget", type=int, default=None,
                    help="admission cap: shed when queued prompt+max_new "
                         "tokens would exceed this")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="default end-to-end deadline for requests")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--drain-timeout-s", type=float, default=30.0)
    args = ap.parse_args(argv)
    eng = demo_engine(max_queue_depth=args.max_queue_depth,
                      admit_token_budget=args.admit_token_budget,
                      deadline_ms=args.deadline_ms,
                      batch_size=args.batch_size, max_len=args.max_len)
    report = serve(eng, ServerConfig(host=args.host, port=args.port,
                                     drain_timeout_s=args.drain_timeout_s))
    print("drain report: " + json.dumps(report), flush=True)
    return 0 if report["clean"] else 1


if __name__ == "__main__":
    sys.exit(main())
