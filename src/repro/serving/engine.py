"""Batched serving engine with two schedulers over the MX-quantized
prefill/decode steps (the paper's deployment mode: LATMiX-folded weights +
online T3 + quantized matmuls).

Schedulers (``Engine(..., scheduler=...)``, see ``docs/serving.md``):

``"wave"``
    Static batching: up to B requests prefill together (prompts left-padded
    to a common chunk-bucketed length) and the whole wave decodes until its
    *slowest* member finishes. Simple, minimal host/device traffic — but on
    mixed-length traffic most decode slot-steps are spent on requests that
    already finished.

``"continuous"``
    Continuous batching: a fixed pool of B decode slots backed by one
    persistent KV cache allocated at (B, max_len). Slots are recycled
    ring-style — the step a slot's request emits EOS (or exhausts its
    budget) the next queued request is chunk-prefilled into the freed lane
    while the other lanes keep decoding. Prefill is *chunked*: every prompt
    is processed in fixed attn_chunk-wide pieces with traced start/length
    indices, so all prompt lengths share ONE jit signature and slot swaps
    never recompile. Decode runs with per-slot positions ((B,) ``cur_len``
    vector) and is value-identical per lane to the wave engine's step, so
    each request's tokens are bit-identical across schedulers.

Common posture:
  * cache allocated once at (B, max_len) rounded to the attention chunk,
  * greedy (argmax) sampling by default; per-request temperature /
    top-k / top-p with a replayable seed via ``Request.sampling``
    (``docs/sampling.md``) — temperature 0 stays bit-identical to the
    greedy closures. Per-slot sampling state is (last token, position,
    remaining budget, emitted count = the RNG step index),
  * optional self-drafting speculative decoding (``Engine(spec=...)``,
    continuous scheduler): prompt-lookup drafts + one batched verify
    step per engine step; rejected drafts roll back by rewinding lane
    positions (paged rollback is a pointer rewind — pages were
    preallocated at admission and stale rows stay masked),
  * optional ``eos_id`` — outputs stop at (and include) the first EOS,
  * per-request latency + decode-utilization accounting for the serving
    benchmark (``benchmarks/serving_bench.py``).

Telemetry (``docs/observability.md``): every engine counter lives in a
``repro.obs.MetricsRegistry`` (pass one via ``Engine(metrics=...)`` to
share/export it, else a private one is created) — :meth:`Engine.stats`
is a view over it, including TTFT/TPOT latency histograms. Request
lifecycle and engine-step spans are recorded when a ``repro.obs.Tracer``
is passed (``Engine(tracer=...)``) and exported as Chrome trace-event
JSON; with no tracer the hot loop records nothing.

Clocks: *intervals* (TTFT/TPOT, throughput, span timestamps) are always
measured with ``time.perf_counter()`` (monotonic — wall clock can step
backwards under NTP); ``time.time()`` survives only as the *absolute*
``Request.t_submit``/``t_first``/``t_done`` timestamps.

Lifecycle (``docs/robustness.md``): every request ends in exactly one
terminal :class:`~repro.serving.policy.RequestState` — ``FINISHED``,
``CANCELLED`` (:meth:`Engine.cancel`), ``TIMED_OUT`` (per-request TTFT /
end-to-end deadlines, checked while queued and between decode bursts),
``FAILED`` (the per-lane non-finite-logit guard, or a request that can
*never* fit the pool), or ``PREEMPTED`` (evicted under pressure with the
retry budget exhausted). Admission is priority-ordered; under pool
exhaustion the lowest-priority running request is preempted (pages
deref'd, request requeued with bounded retries + exponential backoff —
re-prefill is cheap under the paged prefix cache) instead of blocking
admission behind it. All of it is driven by a
:class:`~repro.serving.policy.SchedulingPolicy` and observable through
terminal-state counters and lifecycle trace spans; a seeded
:class:`~repro.serving.faults.FaultInjector` (``Engine(faults=...)``)
can deterministically force every one of these paths for chaos tests.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.quantize import KVCacheQuant, QuantMode
from repro.models import api
from repro.obs import MetricsRegistry, Tracer
from repro.serving.faults import FaultInjector
from repro.serving.policy import (RequestQueue, RequestState,
                                  SchedulingPolicy, ShedError, SpecConfig,
                                  TERMINAL_STATES, pick_victim)
from repro.serving.sampling import GREEDY, SamplingParams, propose_ngram
from repro.serving import sampling

SCHEDULERS = ("wave", "continuous")
KV_LAYOUTS = ("contiguous", "paged")


class BlockAllocator:
    """Ref-counted allocator over the paged KV pool's page ids.

    Pages ids live in [reserved, n_pages) (ids below ``reserved`` are
    engine scrap pages that dead lanes park their block tables on). A
    page is in exactly one of three states:

      * **free** — on the free list, content garbage;
      * **referenced** — ``ref > 0`` block tables point at it;
      * **cached** — ``ref == 0`` but registered under a prefix hash
        (:meth:`register`): its KV bytes are a reusable prompt-prefix
        page, parked in an LRU and reclaimed (evicted + unregistered)
        only when the free list runs dry.

    :meth:`alloc` hands out ``ref == 1`` pages, preferring free pages and
    LRU-evicting cached ones under pressure; it returns ``None`` when
    even eviction cannot cover the request (the engine's admission
    backpressure). :meth:`lookup`/:meth:`incref` revive a cached page
    into the referenced state — that is the prefix *hit* path. All
    bookkeeping is host-side and O(1) per page transition."""

    def __init__(self, n_pages: int, page_size: int, reserved: int = 0):
        if n_pages - reserved < 1:
            raise ValueError(f"pool needs at least one allocatable page "
                             f"(n_pages={n_pages}, reserved={reserved})")
        self.n_pages, self.page_size = n_pages, page_size
        self.reserved = reserved
        self._free = collections.deque(range(reserved, n_pages))
        self._ref = {p: 0 for p in range(reserved, n_pages)}
        self._page_of: dict = {}                # prefix hash -> page id
        self._hash_of: dict = {}                # page id -> prefix hash
        self._lru: collections.OrderedDict = collections.OrderedDict()
        self.evicted = 0                        # cumulative LRU evictions

    @property
    def capacity(self) -> int:
        """Total allocatable pages."""
        return self.n_pages - self.reserved

    @property
    def available(self) -> int:
        """Pages obtainable right now (free + evictable cached)."""
        return len(self._free) + len(self._lru)

    @property
    def in_use(self) -> int:
        """Pages referenced by at least one block table."""
        return self.capacity - self.available

    @property
    def cached(self) -> int:
        """Pages parked for prefix reuse (ref == 0, registered)."""
        return len(self._lru)

    @property
    def free(self) -> int:
        """Pages on the free list (content garbage)."""
        return len(self._free)

    @property
    def resident(self) -> int:
        """Pages holding live KV bytes (referenced or cached)."""
        return self.capacity - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n fresh pages at ref == 1, or None (caller applies
        backpressure). Eviction order is least-recently-cached first."""
        if n > self.available:
            return None
        out = []
        for _ in range(n):
            if self._free:
                p = self._free.popleft()
            else:
                p, _ = self._lru.popitem(last=False)
                del self._page_of[self._hash_of.pop(p)]
                self.evicted += 1
            self._ref[p] = 1
            out.append(p)
        return out

    def incref(self, p: int) -> None:
        if self._ref[p] == 0:
            self._lru.pop(p, None)              # cached -> referenced
        self._ref[p] += 1

    def decref(self, p: int) -> None:
        if self._ref[p] <= 0:
            raise ValueError(f"decref of unreferenced page {p}")
        self._ref[p] -= 1
        if self._ref[p] == 0:
            if p in self._hash_of:
                self._lru[p] = True             # cached: evictable
            else:
                self._free.append(p)

    def register(self, h, p: int) -> Optional[int]:
        """Publish page ``p`` as the cached copy of prefix hash ``h``.
        First registration wins: if ``h`` is already served by another
        page (or ``p`` already carries a hash) nothing changes and the
        existing mapping is returned."""
        if h in self._page_of or p in self._hash_of:
            return self._page_of.get(h)
        self._page_of[h] = p
        self._hash_of[p] = h
        return p

    def lookup(self, h) -> Optional[int]:
        """Page cached under prefix hash ``h`` (refreshing its LRU
        recency), or None."""
        p = self._page_of.get(h)
        if p is not None and self._ref[p] == 0:
            self._lru.move_to_end(p)
        return p

    def flush_cache(self) -> int:
        """Evict every cached (unreferenced, registered) page back to
        the free list; returns how many were reclaimed. The forced-
        eviction chaos hook (``FaultInjector`` point ``evict_cache``) —
        referenced pages are untouched."""
        n = 0
        while self._lru:
            p, _ = self._lru.popitem(last=False)
            del self._page_of[self._hash_of.pop(p)]
            self._free.append(p)
            self.evicted += 1
            n += 1
        return n

    def check(self) -> dict:
        """Verify the allocator's internal invariants; raises
        AssertionError on any violation, else returns the accounting
        ``{"free", "cached", "in_use", "evicted"}``. The chaos /
        property tests call this after every interleaved operation:
        free + cached + referenced must partition [reserved, n_pages)
        exactly — a page leak or double-free shows up here."""
        if any(r < 0 for r in self._ref.values()):
            raise AssertionError("negative refcount")
        fs = set(self._free)
        cs = set(self._lru)
        rs = {p for p, r in self._ref.items() if r > 0}
        if len(fs) != len(self._free):
            raise AssertionError("duplicate page on the free list")
        for a, b, what in ((fs, cs, "free/cached"), (fs, rs, "free/ref"),
                           (cs, rs, "cached/ref")):
            if a & b:
                raise AssertionError(f"page in two states: {what} "
                                     f"{sorted(a & b)}")
        allp = set(range(self.reserved, self.n_pages))
        if fs | cs | rs != allp:
            raise AssertionError(
                f"pages unaccounted for: missing {sorted(allp - fs - cs - rs)}"
                f" extra {sorted((fs | cs | rs) - allp)}")
        for p in cs:
            if p not in self._hash_of:
                raise AssertionError(f"cached page {p} has no hash")
        if len(self._page_of) != len(self._hash_of):
            raise AssertionError("hash<->page maps out of sync")
        for h, p in self._page_of.items():
            if self._hash_of.get(p) != h:
                raise AssertionError(f"hash map mismatch on page {p}")
        if self.in_use + self.free + self.cached != self.capacity:
            raise AssertionError(
                f"in_use {self.in_use} + free {self.free} + cached "
                f"{self.cached} != capacity {self.capacity}")
        return {"free": self.free, "cached": self.cached,
                "in_use": self.in_use, "evicted": self.evicted}


@dataclasses.dataclass(eq=False)       # identity eq/hash: a request is
class Request:                         # a handle, not a value
    """One generation request.

    prompt: (S,) int32 token ids. max_new: decode budget (the output is
    shorter only if ``Engine(eos_id=...)`` is hit first). ``on_token`` is
    an optional streaming callback invoked with each emitted int token as
    it becomes available (per step under the continuous scheduler; at wave
    end under the wave scheduler). ``out`` is filled with the emitted
    int32 token array when the request completes.

    Timestamps: ``t_submit``/``t_first``/``t_done`` are *absolute* wall
    clock (``time.time()``, for logs); the ``m_*`` mirrors are
    ``time.perf_counter()`` readings — monotonic, the ones every
    duration (TTFT = ``m_first - m_submit``, TPOT =
    ``(m_done - m_first)/(len(out) - 1)``) is computed from. Under the
    wave scheduler all tokens are delivered at wave end, so
    ``m_first == m_done`` and only TTFT (== wave latency) is
    meaningful.

    Lifecycle (``docs/robustness.md``): ``state`` walks
    QUEUED -> RUNNING -> one terminal :class:`RequestState`; ``error``
    carries the human-readable reason for any non-FINISHED end.
    ``priority`` orders admission (higher first) and gates preemption —
    only strictly lower-priority running requests can be evicted for
    this one. ``deadline_ms`` (submit -> done) and ``ttft_deadline_ms``
    (submit -> first token) override the engine policy's defaults; None
    defers to the policy. ``request_id`` keys :meth:`Engine.cancel`
    (auto-assigned at submit when None). ``retries``/``preemptions``/
    ``not_before`` are preemption bookkeeping (engine-managed), and
    ``_gen`` accumulates emitted tokens across preemptions so a resumed
    request re-prefills prompt+_gen and continues bit-identically."""

    prompt: np.ndarray                  # (S,) int32
    max_new: int = 16
    out: Optional[np.ndarray] = None
    t_submit: float = 0.0               # wall clock (absolute)
    t_first: float = 0.0
    t_done: float = 0.0
    m_submit: float = 0.0               # perf_counter (durations)
    m_first: float = 0.0
    m_done: float = 0.0
    on_token: Optional[Callable[[int], None]] = None
    trace_track: Optional[str] = None   # tracer track name (engine-set)
    # --- lifecycle (docs/robustness.md) ---
    priority: int = 0                   # higher admits (and evicts) first
    deadline_ms: Optional[float] = None          # submit -> done TTL
    ttft_deadline_ms: Optional[float] = None     # submit -> first token
    request_id: Optional[str] = None    # cancel() handle (engine-set)
    state: RequestState = RequestState.QUEUED
    error: Optional[str] = None         # reason for a non-FINISHED end
    retries: int = 0                    # re-admissions after preemption
    preemptions: int = 0                # times evicted from a lane
    not_before: float = 0.0             # backoff hold (perf_counter)
    # sampling: None (or temperature<=0) decodes greedily, bit-identical
    # to an engine without sampling at all. Otherwise temperature/top-k/
    # top-p with a per-request seed: token i is drawn from
    # PRNGKey(seed) folded with its emission index, so a run is
    # replayable and a preemption-resume re-seeds from len(_gen) and
    # replays its own tail deterministically (docs/sampling.md).
    sampling: Optional[SamplingParams] = None
    _gen: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Slot:
    """Per-slot decode state (continuous scheduler)."""

    req: Request
    toks: List[int]          # emitted tokens (greedy sampling state)
    pos: int                 # cache fill == next write position
    remaining: int           # decode budget left


class Engine:
    """Serving engine over ``api.prefill``/``api.decode``.

    ``params`` may hold dense arrays or packed-HBM ``PackedWeight``
    leaves (artifact serving, see :meth:`from_artifact`): the quantized
    execution path dequantizes packed weights lazily inside the compiled
    prefill/decode steps — or, with ``backend='fused'``, consumes the
    packed layout directly in the Pallas MX GEMM kernels (see
    ``core.quantize``). Both schedulers work with both backends.

    Streaming API: :meth:`submit` enqueues a request, :meth:`step` runs
    one scheduler step and returns the requests completed by it,
    :meth:`drain` steps until idle. :meth:`generate` = submit-all + drain,
    returning the input list (mutated in place, original order).
    """

    # counters that reset_stats() windows; compile counters are
    # deliberately absent (cumulative for the engine lifetime — the jit
    # cache never resets)
    _WINDOW_KEYS = ("admitted", "decode_steps", "slot_steps",
                    "useful_decode_tokens", "prefill_chunk_steps",
                    "prefill_batched_steps", "prefill_lane_steps",
                    "prefix_hit_tokens", "blocks_evicted",
                    "spec_proposed_tokens", "spec_accepted_tokens")

    def __init__(self, params, cfg: ArchConfig, qm: QuantMode,
                 batch_size: int = 4, max_len: int = 256,
                 backend: str | None = None,
                 bucket_prompts: bool = True,
                 scheduler: str = "wave",
                 eos_id: Optional[int] = None,
                 kv_cache: "str | KVCacheQuant | None" = None,
                 kv_layout: str = "contiguous",
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 policy: Optional[SchedulingPolicy] = None,
                 faults: Optional[FaultInjector] = None,
                 spec: Optional[SpecConfig] = None):
        """bucket_prompts=True rounds prompt lengths up to the attention
        chunk so distinct lengths reuse one prefill compile (wave) / keep
        the chunk grid aligned (continuous). Bucketed pads are left-pad
        tokens and are attended like the engine's existing ragged-wave
        pads (static batching, no per-row masks) — pass False for
        unpadded, per-length compiles.

        scheduler='continuous' requires a token-embedding KV-cache family
        (dense/moe); recurrent families (hybrid/ssm) serve with 'wave'.

        kv_cache: 'mxfp8' | 'mxint8' | 'mxfp4' | 'mxint4' stores the KV
        cache MX-quantized (codes + E8M0 scale bytes per 32-block along
        kv_dim; see ``docs/kv-cache.md``) — keys/values are quantized at
        append time and decode attention reads the packed bytes (the
        Pallas flash-decode kernel under ``backend='fused'``, decode-in-
        place otherwise). Greedy outputs match the dense cache within a
        small tolerance; 'none'/None (default) keeps the dense fp cache
        bit-identical to previous behavior. Attention-cache families
        only (dense/moe/hybrid), and kv_dim must divide into 32-blocks.

        kv_layout: 'contiguous' (default) reserves one (max_len, kv_dim)
        lane per slot; 'paged' allocates one pool of fixed-size pages
        addressed through per-request block tables, with ref-counted
        hash-based prefix caching — a shared prompt prefix is prefilled
        once and reused by reference (see ``docs/paged-kv.md``). Paged
        serving requires scheduler='continuous' and a KV-cache family
        (dense/moe); it places prompts unpadded at position 0 (prompt
        bucketing does not apply — identical token placement is what
        makes prefixes shareable). page_size (tokens per page; default
        the smallest multiple of attn_chunk >= 64) must be a multiple of
        32 (the MX block) and of cfg.attn_chunk (so prefix-resume
        positions stay chunk-aligned); n_pages sizes the pool (default:
        one scrap page + batch_size * ceil(max_len/page_size), the same
        budget as the contiguous pool).

        metrics: a ``repro.obs.MetricsRegistry`` to report into (shared
        across engines / exported by the caller); None creates a private
        one. The registry is always on — counter updates cost what the
        plain attributes they replaced cost. tracer: a
        ``repro.obs.Tracer`` recording request-lifecycle and engine-step
        spans (Chrome trace-event export, ``docs/observability.md``);
        None (default) records nothing — no timestamps or host syncs are
        added to the serving loop.

        policy: a ``repro.serving.policy.SchedulingPolicy`` — default
        deadlines, the preemption switch, retry budget/backoff
        (``docs/robustness.md``); None uses the policy defaults (no
        deadlines, preemption on). faults: a seeded
        ``repro.serving.faults.FaultInjector`` whose rules fire at the
        engine's injection points (chaos tests only; None — the
        default — adds zero work to the serving loop).

        spec: a ``repro.serving.policy.SpecConfig`` turns on
        self-drafting speculative decoding (``docs/sampling.md``):
        every engine step proposes up to ``spec.k`` draft tokens per
        lane by prompt lookup and verifies them in one batched
        multi-token forward; rejected drafts roll back by rewinding the
        lane's position (paged: a pointer rewind inside the pages the
        request already owns). Continuous scheduler + KV-cache families
        only. Outputs are unchanged: greedy spec decoding is
        token-bit-identical to non-spec greedy and sampled spec
        preserves the sampling distribution — spec trades draft +
        verify cost against tokens per step."""
        if cfg.family == "encoder":
            raise ValueError("encoder archs are not served autoregressively")
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r} "
                             f"(expected one of {SCHEDULERS})")
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(f"unknown kv_layout {kv_layout!r} "
                             f"(expected one of {KV_LAYOUTS})")
        if kv_layout == "paged":
            # checked before the generic scheduler/family gating so the
            # error names the actual conflict (ring buffers cannot page)
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"kv_layout='paged' pages an attention KV cache "
                    f"through block tables; family {cfg.family!r} keeps "
                    f"recurrent ring-buffer state (griffin/ssm hybrids) "
                    f"that cannot be paged — serve it with "
                    f"kv_layout='contiguous'")
            if scheduler != "continuous":
                raise ValueError(
                    "kv_layout='paged' requires scheduler='continuous'; "
                    "the wave scheduler keeps the existing contiguous "
                    "per-wave cache")
        if scheduler == "continuous" and (
                cfg.family not in ("dense", "moe") or not cfg.embed_inputs):
            raise ValueError(
                "continuous scheduler requires a token-embedding KV-cache "
                "family (dense/moe); recurrent-state families must use "
                "scheduler='wave'")
        if spec is not None and scheduler != "continuous":
            raise ValueError(
                "speculative decoding (spec=...) requires "
                "scheduler='continuous': drafts are proposed per slot "
                "from each request's own emitted tokens")
        self.policy = policy if policy is not None else SchedulingPolicy()
        self.spec = spec
        self._faults = faults
        self.kv_quant = KVCacheQuant.parse(kv_cache)
        if self.kv_quant is not None:
            if cfg.family == "ssm":
                raise ValueError("kv_cache quantization requires an "
                                 "attention KV cache; ssm serves with "
                                 "kv_cache='none'")
            if cfg.kv_dim % 32 != 0:
                raise ValueError(
                    f"kv_cache quantization needs kv_dim % 32 == 0 (one "
                    f"E8M0 scale per 32-block along the cache feature "
                    f"axis), got kv_dim={cfg.kv_dim} for {cfg.name!r} — "
                    f"serve this model with kv_cache='none', or pick an "
                    f"arch whose n_kv_heads*head_dim is a multiple of 32")
        if backend is not None:
            qm = qm.with_backend(backend)
        self.params, self.cfg, self.qm = params, cfg, qm
        self.B = batch_size
        self.bucket_prompts = bucket_prompts
        self.scheduler = scheduler
        self.eos_id = eos_id
        chunk = cfg.attn_chunk
        self.max_len = (max_len + chunk - 1) // chunk * chunk

        self.kv_layout = kv_layout
        self.page_size = 0
        self.pages_per_slot = 0
        self._alloc: Optional[BlockAllocator] = None
        if kv_layout == "paged":
            if page_size is None:
                page_size = chunk * max(1, -(-64 // chunk))
            if page_size % 32 != 0:
                raise ValueError(
                    f"page_size must be a multiple of the MX 32-block "
                    f"(a page is a fixed run of MX blocks), got "
                    f"{page_size}")
            if page_size % chunk != 0:
                raise ValueError(
                    f"page_size must be a whole number of attention "
                    f"chunks so prefix-resume positions stay "
                    f"chunk-aligned; got page_size={page_size}, "
                    f"attn_chunk={chunk}")
            self.page_size = page_size
            self.pages_per_slot = -(-self.max_len // page_size)
            if n_pages is None:
                n_pages = 1 + self.B * self.pages_per_slot
            if n_pages < 1 + self.pages_per_slot:
                raise ValueError(
                    f"n_pages={n_pages} cannot hold one scrap page plus "
                    f"a full-length request "
                    f"({self.pages_per_slot} pages for max_len="
                    f"{self.max_len})")
            # page 0 is the scrap page: dead lanes' block tables park on
            # it, so their idle decode writes never touch live pages
            self._alloc = BlockAllocator(n_pages, page_size, reserved=1)
            self._tables = np.zeros((self.B, self.pages_per_slot),
                                    np.int32)
            self._tables_dev = None
            self._slot_pages: List[Optional[List[int]]] = [None] * self.B

        # --- telemetry: every counter lives in the metrics registry;
        # stats() is a view over it (docs/observability.md has the
        # catalog). Compile accounting: one prefill compile per distinct
        # (B, S) wave shape (bucketing in _wave keeps this set small);
        # the continuous scheduler's chunked prefill and vector decode
        # each compile once.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        reg = self.metrics
        self._prefill_shapes: set = set()
        self._chunk_shapes: set = set()
        self._decode_shapes: set = set()
        self._c_compiles = {
            kind: reg.counter("serving_compiles_total", {"step": kind},
                              help="jit signatures compiled (cumulative "
                                   "over the engine lifetime; never "
                                   "reset — the jit cache is an "
                                   "engine-lifetime property)")
            for kind in ("prefill", "prefill_chunk", "decode")}
        self._c_admitted = reg.counter(
            "serving_requests_admitted_total",
            help="requests admitted into a scheduler lane")
        self._c_decode_steps = reg.counter(
            "serving_decode_steps_total",
            help="batched decode steps dispatched")
        self._c_slot_steps = reg.counter(
            "serving_slot_steps_total",
            help="decode steps x lanes (utilization denominator)")
        self._c_useful = reg.counter(
            "serving_useful_decode_tokens_total",
            help="decoded tokens that made it into a request's output")
        self._c_chunk_steps = reg.counter(
            "serving_prefill_chunk_steps_total",
            help="chunked-prefill invocations (drops under prefix hits)")
        self._c_prefill_batched = reg.counter(
            "serving_prefill_batched_steps_total",
            help="chunked-prefill invocations that carried >=2 lanes "
                 "(paged batched admission — "
                 "policy.max_prefill_lanes_per_step)")
        self._c_prefill_lane_steps = reg.counter(
            "serving_prefill_lane_steps_total",
            help="chunked-prefill invocations x active lanes; "
                 "lane_steps / chunk_steps is the mean prefill batch "
                 "occupancy (1.0 == strictly serial admission)")
        self._h_prefill_batch = reg.histogram(
            "serving_prefill_batch_size", unit="lanes",
            help="active lanes per chunked-prefill invocation (serial "
                 "admission observes 1 per chunk)")
        self._c_prefix_hit_toks = reg.counter(
            "serving_prefix_hit_tokens_total", unit="tokens",
            help="prompt tokens served from cached prefix pages")
        self._c_prefix_hits = reg.counter(
            "serving_prefix_cache_hits_total",
            help="paged admissions that reused >=1 cached prefix page")
        self._c_prefix_misses = reg.counter(
            "serving_prefix_cache_misses_total",
            help="paged admissions with no cached prefix page")
        self._c_evicted = reg.counter(
            "serving_blocks_evicted_total",
            help="cached prefix pages reclaimed by LRU eviction")
        self._g_blocks_in_use = reg.gauge(
            "serving_blocks_in_use", unit="pages",
            help="pages referenced by live block tables")
        self._g_blocks_cached = reg.gauge(
            "serving_blocks_cached", unit="pages",
            help="unreferenced pages parked for prefix reuse")
        self._g_queue_depth = reg.gauge(
            "serving_queue_depth", unit="requests",
            help="requests waiting for a lane")
        self._h_ttft = reg.histogram(
            "serving_ttft_seconds", unit="s",
            help="time to first token (submit -> first token available; "
                 "wave scheduler: == wave latency, tokens are delivered "
                 "at wave end)")
        self._h_tpot = reg.histogram(
            "serving_tpot_seconds", unit="s",
            help="time per output token after the first (continuous "
                 "scheduler only — the wave scheduler delivers all "
                 "tokens at once)")
        self._h_latency = reg.histogram(
            "serving_request_latency_seconds", unit="s",
            help="submit -> done")
        self._h_queue_wait = reg.histogram(
            "serving_queue_wait_seconds", unit="s",
            help="submit -> admission start (continuous scheduler)")
        self._c_submitted = reg.counter(
            "serving_requests_submitted_total",
            help="requests accepted by submit()")
        self._c_terminal = {
            s: reg.counter("serving_requests_terminal_total",
                           {"state": s.value},
                           help="requests reaching this terminal "
                                "lifecycle state (docs/robustness.md); "
                                "the series sum equals submitted "
                                "requests at quiescence")
            for s in (RequestState.FINISHED, RequestState.CANCELLED,
                      RequestState.TIMED_OUT, RequestState.FAILED,
                      RequestState.PREEMPTED, RequestState.SHED)}
        self._c_preempt = reg.counter(
            "serving_preemptions_total",
            help="running requests evicted from a lane (priority "
                 "inversion or page pressure); each is requeued with "
                 "backoff until its retry budget runs out")
        self._c_nan = reg.counter(
            "serving_nan_guard_trips_total",
            help="requests failed by the per-lane non-finite-logit "
                 "guard (the rest of the decode batch continues)")
        self._c_never_fit = reg.counter(
            "serving_rejected_never_fit_total",
            help="requests rejected at admission because prompt+budget "
                 "can never fit the pool (terminal FAILED, not requeued)")
        self._c_shed = reg.counter(
            "serving_requests_shed_total",
            help="requests rejected by admission control at submit() "
                 "(queue depth / per-priority / token-budget caps — "
                 "docs/server.md); terminal SHED, never requeued")
        self._c_spec_proposed = reg.counter(
            "serving_spec_proposed_total", unit="tokens",
            help="draft tokens proposed by the prompt-lookup drafter "
                 "and scored by a verify step")
        self._c_spec_accepted = reg.counter(
            "serving_spec_accepted_total", unit="tokens",
            help="proposed draft tokens accepted by the verify step "
                 "(acceptance rate = accepted / proposed)")
        self._evicted_seen = 0       # allocator.evicted -> counter delta
        # windowed-vs-cumulative split (see stats()/reset_stats())
        self._window_base = {k: 0 for k in self._WINDOW_KEYS}

        def prefill(params, toks):
            return api.prefill(params, cfg, toks, qm, max_len=self.max_len,
                               kv_quant=self.kv_quant)

        def prefill_chunk(params, cache, toks, start, last_idx):
            return api.prefill_chunk(params, cfg, cache, toks, start,
                                     last_idx, qm)

        def decode(params, cache, toks, cur_len, poison_lane):
            logits, cache = api.decode(params, cfg, cache, toks, cur_len, qm)
            # per-lane NaN/Inf guard: `ok` rides back with the sampled
            # tokens (fetched in the existing burst sync — no extra host
            # round trip). poison_lane is the nan_logits chaos hook; -1
            # (the always case outside chaos tests) makes the where a
            # bitwise identity.
            lanes = jnp.arange(logits.shape[0], dtype=jnp.int32)
            logits = jnp.where((lanes == poison_lane)[:, None],
                               jnp.float32(jnp.nan).astype(logits.dtype),
                               logits)
            ok = jnp.isfinite(logits).all(axis=-1)
            return (jnp.argmax(logits, axis=-1).astype(jnp.int32), ok,
                    cache)

        def merge_slot(cache, slot_cache, i):
            def upd(c, s):
                idx = (jnp.int32(0), i) + (jnp.int32(0),) * (c.ndim - 2)
                return jax.lax.dynamic_update_slice(c, s, idx)
            return jax.tree.map(upd, cache, slot_cache)

        def prefill_chunk_paged(params, cache, toks, tables, start,
                                last_idx):
            return api.prefill_chunk_paged(params, cfg, cache, tables,
                                           toks, start, last_idx, qm)

        def decode_paged(params, cache, toks, cur_len, tables,
                         poison_lane):
            logits, cache = api.decode_paged(params, cfg, cache, toks,
                                             cur_len, tables, qm)
            lanes = jnp.arange(logits.shape[0], dtype=jnp.int32)
            logits = jnp.where((lanes == poison_lane)[:, None],
                               jnp.float32(jnp.nan).astype(logits.dtype),
                               logits)
            ok = jnp.isfinite(logits).all(axis=-1)
            return (jnp.argmax(logits, axis=-1).astype(jnp.int32), ok,
                    cache)

        def copy_page(cache, src, dst):
            # clone one pool page (all layers, k and v, codes and
            # scales): the admission copy-on-write of a partially
            # reused prefix page
            return jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), cache)

        # sampled decode variants: same forward + NaN guard as the
        # greedy closures (which stay byte-identical — their compile
        # counts are pinned by tests), with the argmax replaced by the
        # per-lane seeded sampler. Dispatched only when a live lane is
        # actually non-greedy, so greedy-only traffic never compiles or
        # pays for them.
        def decode_sampled(params, cache, toks, cur_len, poison_lane,
                           temps, top_ks, top_ps, seeds, steps):
            logits, cache = api.decode(params, cfg, cache, toks, cur_len,
                                       qm)
            lanes = jnp.arange(logits.shape[0], dtype=jnp.int32)
            logits = jnp.where((lanes == poison_lane)[:, None],
                               jnp.float32(jnp.nan).astype(logits.dtype),
                               logits)
            ok = jnp.isfinite(logits).all(axis=-1)
            nxt = sampling.sample_tokens(logits, temps, top_ks, top_ps,
                                         seeds, steps)
            return nxt, ok, cache

        def decode_paged_sampled(params, cache, toks, cur_len, tables,
                                 poison_lane, temps, top_ks, top_ps,
                                 seeds, steps):
            logits, cache = api.decode_paged(params, cfg, cache, toks,
                                             cur_len, tables, qm)
            lanes = jnp.arange(logits.shape[0], dtype=jnp.int32)
            logits = jnp.where((lanes == poison_lane)[:, None],
                               jnp.float32(jnp.nan).astype(logits.dtype),
                               logits)
            ok = jnp.isfinite(logits).all(axis=-1)
            nxt = sampling.sample_tokens(logits, temps, top_ks, top_ps,
                                         seeds, steps)
            return nxt, ok, cache

        # speculative verify: one multi-token forward scores the current
        # token + drafts, then the acceptance rule picks the emitted run
        # — all fused in one jit so a verify step is a single dispatch +
        # a single host sync, like a decode step.
        # The host-varying per-step state rides in ONE packed int32
        # array (one device_put per verify step instead of five):
        #   packed[:, :C]  = verify inputs [cur, d_1..d_K]
        #   packed[:, C]   = per-lane write position
        #   packed[:, C+1] = per-lane valid-slot count (1 + draft len)
        #   packed[:, C+2] = per-lane emission index (the RNG step)
        #   packed[:, C+3] = poisoned lane id, broadcast (-1 = none)
        # and the three results come back as one packed int32 array
        # [out | n_emit | ok] — one blocking fetch per step instead of
        # three. The drafts spec_accept needs are exactly toks[:, 1:],
        # sliced inside the jit rather than committed separately.
        def _verify_unpack(packed):
            C = packed.shape[1] - 4
            return (packed[:, :C], packed[:, C], packed[:, C + 1],
                    packed[:, C + 2], packed[0, C + 3])

        def _verify_accept(logits, toks, n_valid, steps, poison_lane,
                           temps, top_ks, top_ps, seeds):
            lanes = jnp.arange(logits.shape[0], dtype=jnp.int32)
            logits = jnp.where((lanes == poison_lane)[:, None, None],
                               jnp.float32(jnp.nan).astype(logits.dtype),
                               logits)
            out, n_emit, okrow = sampling.spec_accept(
                logits, toks[:, 1:], n_valid - 1, temps, top_ks, top_ps,
                seeds, steps)
            return jnp.concatenate(
                [out, n_emit[:, None], okrow.astype(jnp.int32)], axis=1)

        def verify_step(params, cache, packed, temps, top_ks, top_ps,
                        seeds):
            toks, pos, n_valid, steps, poison_lane = _verify_unpack(
                packed)
            logits, cache = api.verify(params, cfg, cache, toks, pos,
                                       n_valid, qm)
            res = _verify_accept(logits, toks, n_valid, steps,
                                 poison_lane, temps, top_ks, top_ps,
                                 seeds)
            return res, cache

        def verify_step_paged(params, cache, packed, tables, temps,
                              top_ks, top_ps, seeds):
            toks, pos, n_valid, steps, poison_lane = _verify_unpack(
                packed)
            logits, cache = api.verify_paged(params, cfg, cache, toks,
                                             pos, n_valid, tables, qm)
            res = _verify_accept(logits, toks, n_valid, steps,
                                 poison_lane, temps, top_ks, top_ps,
                                 seeds)
            return res, cache

        self._prefill = jax.jit(prefill)
        self._prefill_chunk = jax.jit(prefill_chunk)
        self._decode = jax.jit(decode)
        self._merge = jax.jit(merge_slot)
        self._prefill_chunk_paged = jax.jit(prefill_chunk_paged)
        self._decode_paged = jax.jit(decode_paged)
        self._copy_page = jax.jit(copy_page)
        self._decode_sampled = jax.jit(decode_sampled)
        self._decode_paged_sampled = jax.jit(decode_paged_sampled)
        self._verify = jax.jit(verify_step)
        self._verify_paged = jax.jit(verify_step_paged)
        self._sample_tokens = jax.jit(sampling.sample_tokens)

        # streaming state
        self._queue = RequestQueue(       # priority + backoff admission
            max_depth=self.policy.max_queue_depth)
        self._shed_streak = 0             # consecutive sheds -> Retry-After
        self._by_id: dict = {}            # request_id -> live Request
        self._next_id = 0                 # request_id autonumber
        self._slots: List[Optional[_Slot]] = [None] * self.B
        self._admit_cursor = 0            # ring rotation over the lanes
        self._cache = None                # persistent (B, max_len) KV pool
        self._slot_cache = None           # (1, max_len) admission scratch
        self._home = None                 # canonical input sharding (lazy)
        self._greedy_vecs: dict = {}      # batch -> constant samp vectors

    # ------------------------------------------------------------------
    # Telemetry helpers + legacy counter attributes (registry views)
    # ------------------------------------------------------------------

    @property
    def admitted(self) -> int:
        return int(self._c_admitted.value)

    @property
    def decode_steps(self) -> int:
        return int(self._c_decode_steps.value)

    @property
    def slot_steps(self) -> int:
        return int(self._c_slot_steps.value)

    @property
    def useful_decode_tokens(self) -> int:
        return int(self._c_useful.value)

    @property
    def prefill_chunk_steps(self) -> int:
        return int(self._c_chunk_steps.value)

    @property
    def prefix_hit_tokens(self) -> int:
        return int(self._c_prefix_hit_toks.value)

    @property
    def prefill_compiles(self) -> int:
        return int(self._c_compiles["prefill"].value)

    @property
    def prefill_chunk_compiles(self) -> int:
        return int(self._c_compiles["prefill_chunk"].value)

    @property
    def decode_compiles(self) -> int:
        return int(self._c_compiles["decode"].value)

    def _span(self, name: str, **args):
        """Engine-track span, or a no-op when tracing is off."""
        if self.tracer is None:
            return contextlib.nullcontext()
        return self.tracer.span(name, **args)

    def _count_compile(self, kind: str, key) -> None:
        """First sighting of a jit signature: bump the cumulative
        compile counter and drop a distinctly-marked trace event."""
        shapes = {"prefill": self._prefill_shapes,
                  "prefill_chunk": self._chunk_shapes,
                  "decode": self._decode_shapes}[kind]
        if key in shapes:
            return
        shapes.add(key)
        self._c_compiles[kind].inc()
        if self.tracer is not None:
            self.tracer.instant(f"compile:{kind}", cat="compile",
                                signature=str(key))

    def _sync_alloc_metrics(self) -> None:
        """Mirror BlockAllocator state into gauges/counters (paged)."""
        if self._alloc is None:
            return
        self._g_blocks_in_use.set(self._alloc.in_use)
        self._g_blocks_cached.set(self._alloc.cached)
        if self._alloc.evicted > self._evicted_seen:
            self._c_evicted.inc(self._alloc.evicted - self._evicted_seen)
            self._evicted_seen = self._alloc.evicted

    def _home_sharding(self):
        """Canonical replicated sharding for fresh host-built inputs (the
        pool cache, a burst's first cur/pos). Uncommitted arrays are a
        different jit cache key than the committed outputs the steps
        produce — without this, the chunk-prefill/decode/merge functions
        each compile twice (fresh-input signature + steady state), a
        multi-second hit that landed inside the timed serving run and was
        most of the continuous scheduler's tok/s gap. Scope: this matches
        the steps' output shardings on single-replica serving (the tested
        posture); under a live multi-device mesh whose steps constrain
        the cache to batch/model axes, the first step after a fresh input
        can still compile separately."""
        if self._home is None:
            home = jax.sharding.SingleDeviceSharding(jax.devices()[0])
            for leaf in jax.tree.leaves(self.params):
                s = getattr(leaf, "sharding", None)
                if isinstance(s, jax.sharding.NamedSharding):
                    home = jax.sharding.NamedSharding(
                        s.mesh, jax.sharding.PartitionSpec())
                    break
            self._home = home
        return self._home

    def _commit(self, tree):
        """device_put a fresh pytree onto the canonical sharding."""
        return jax.device_put(tree, self._home_sharding())

    @classmethod
    def from_artifact(cls, path, batch_size: int = 4, max_len: int = 256,
                      eager: bool = False, verify: bool = True,
                      backend: str | None = None,
                      scheduler: str = "wave",
                      eos_id: Optional[int] = None,
                      kv_cache: "str | KVCacheQuant | None" = None,
                      kv_layout: str = "contiguous",
                      page_size: Optional[int] = None,
                      n_pages: Optional[int] = None,
                      metrics: Optional[MetricsRegistry] = None,
                      tracer: Optional[Tracer] = None,
                      policy: Optional[SchedulingPolicy] = None,
                      faults: Optional[FaultInjector] = None,
                      spec: Optional[SpecConfig] = None) -> "Engine":
        """Serve directly from an exported artifact directory: no
        calibration, no re-quantization — load packed bytes and go.

        eager=False keeps quantized weights 4-bit packed in HBM
        (dequantized per layer inside the compiled step); eager=True
        materializes dense fp weights once at load. backend='fused'
        routes the quantized matmuls through the packed-native Pallas
        kernels (requires eager=False to have any effect — eager loads
        are dense and fall back to the reference path). scheduler/eos_id/
        kv_cache/kv_layout/page_size/n_pages/metrics/tracer/policy/
        faults/spec are forwarded to :class:`Engine`."""
        from repro.artifacts import load_artifact
        params, cfg, qm = load_artifact(path, eager=eager, verify=verify,
                                        backend=backend)
        return cls(params, cfg, qm, batch_size=batch_size, max_len=max_len,
                   scheduler=scheduler, eos_id=eos_id, kv_cache=kv_cache,
                   kv_layout=kv_layout, page_size=page_size,
                   n_pages=n_pages, metrics=metrics, tracer=tracer,
                   policy=policy, faults=faults, spec=spec)

    # ------------------------------------------------------------------
    # Streaming API
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> Request:
        """Enqueue a request. It starts executing on the next step().

        Assigns a ``request_id`` (for :meth:`cancel`) when the request
        has none, applies the engine policy's default deadlines to
        requests that don't carry their own, and moves the request into
        the QUEUED lifecycle state.

        **Admission control** (``policy.max_queue_depth`` /
        ``max_queue_depth_per_priority`` / ``admit_token_budget``): an
        over-limit request is *shed*, not silently requeued — it lands
        in the terminal SHED state (still counted toward submitted, so
        ``sum(terminal) == submitted`` holds) and :class:`ShedError` is
        raised with a ``retry_after_s`` that grows along the policy's
        backoff schedule for each *consecutive* shed (reset on the next
        successful admission, capped at ``backoff_s(6)``) — sustained
        overload pushes clients further out instead of inviting an
        immediate retry storm."""
        req.t_submit = time.time()             # absolute (logs)
        req.m_submit = time.perf_counter()     # durations
        if req.request_id is None:
            req.request_id = f"req-{self._next_id}"
            self._next_id += 1
        if req.deadline_ms is None:
            req.deadline_ms = self.policy.deadline_ms
        if req.ttft_deadline_ms is None:
            req.ttft_deadline_ms = self.policy.ttft_deadline_ms
        self._c_submitted.inc()
        reason = self.policy.shed_reason(self._queue, req)
        if reason is not None:
            self._shed_streak += 1
            retry_after = self.policy.backoff_s(min(self._shed_streak, 6))
            self._c_shed.inc()
            if self.tracer is not None:
                self.tracer.instant("shed", track="engine", cat="request",
                                    request=req.request_id, reason=reason)
            self._finish(req, req._gen, state=RequestState.SHED,
                         error=f"shed by admission control: {reason}")
            raise ShedError(req, reason, retry_after)
        self._shed_streak = 0
        req.state = RequestState.QUEUED
        self._by_id[req.request_id] = req
        if self.tracer is not None and req.trace_track is None:
            # Index comes from the tracer, not the engine, so request
            # tracks stay unique when several engines share one tracer.
            req.trace_track = f"req-{self.tracer.next_index('req')}"
        self._queue.push(req)
        self._g_queue_depth.set(len(self._queue))
        return req

    def cancel(self, request_id: str) -> bool:
        """Client-side cancellation: stop ``request_id`` wherever it is.

        Queued requests are dropped (the queue skips non-QUEUED entries
        lazily); a running request's lane is freed and its pages
        deref'd mid-flight. The request lands in the terminal CANCELLED
        state with any tokens emitted so far in ``out``. Returns False
        when the id is unknown or the request already reached a
        terminal state (cancellation is idempotent, not an error)."""
        req = self._by_id.get(request_id)
        if req is None or req.state in TERMINAL_STATES:
            return False
        for i, sl in enumerate(self._slots):
            if sl is not None and sl.req is req:
                self._slots[i] = None
                if self.kv_layout == "paged":
                    self._release_paged(i)
                    self._sync_alloc_metrics()
                break
        if self.tracer is not None and req.trace_track is not None:
            self.tracer.instant("cancel", track=req.trace_track,
                                cat="request")
        self._finish(req, req._gen, state=RequestState.CANCELLED,
                     error="cancelled by client")
        self._g_queue_depth.set(len(self._queue))
        return True

    def fail_lane(self, lane: int, error: str):
        """Supervisor hook: terminal-FAIL the request on ``lane`` and
        free the lane + its pages. Used after a stuck/failed engine step
        to remove the poisoned request — re-running it would poison the
        restarted loop the same way. Returns the failed request, or
        None for an empty lane."""
        sl = self._slots[lane]
        if sl is None:
            return None
        req = sl.req
        self._slots[lane] = None
        if self.kv_layout == "paged":
            self._release_paged(lane)
            self._sync_alloc_metrics()
        if self.tracer is not None and req.trace_track is not None:
            self.tracer.instant("fail_lane", track=req.trace_track,
                                cat="request", lane=lane, reason=error)
        self._finish(req, req._gen, state=RequestState.FAILED, error=error)
        return req

    def requeue_lane(self, lane: int, reason: str):
        """Supervisor hook: return ``lane``'s request to the queue
        *without* charging its preemption retry budget — bystander lanes
        of a failed step did nothing wrong. The lane and its pages are
        freed; tokens emitted so far stay in ``_gen``, so re-admission
        re-prefills prompt+gen and resumes bit-identically under greedy
        decoding (the recompute-resume path preemption uses). Returns
        the requeued request, or None for an empty lane."""
        sl = self._slots[lane]
        if sl is None:
            return None
        req = sl.req
        self._slots[lane] = None
        if self.kv_layout == "paged":
            self._release_paged(lane)
            self._sync_alloc_metrics()
        if self.tracer is not None and req.trace_track is not None:
            self.tracer.instant("requeue", track=req.trace_track,
                                cat="request", lane=lane, reason=reason)
        req.state = RequestState.QUEUED
        self._queue.push_front(req)
        self._g_queue_depth.set(len(self._queue))
        return req

    def step(self) -> List[Request]:
        """Run one scheduler step; return the requests it completed.

        Continuous: admit queued requests into free slots (chunked
        prefill), then one batched decode step over all live slots.
        Wave: serve one full wave of up to B queued requests.

        Both schedulers first honor the ``slow_step`` fault point and
        expire queued requests whose deadlines already passed."""
        if self._faults is not None:
            hit = self._faults.fire("slow_step")
            if hit is not None:
                time.sleep(float(hit.get("delay_s", 0.01)))
        if self.scheduler == "continuous":
            return self._step_continuous()
        done: List[Request] = []
        self._expire_queued(done)
        reqs = []
        now = time.perf_counter()
        while len(reqs) < self.B:
            req = self._queue.pop(now)
            if req is None:
                break
            err = self._never_fits(req)
            if err is not None:
                self._reject_never_fit(req, err, done)
                continue
            reqs.append(req)
        self._g_queue_depth.set(len(self._queue))
        return (self._wave(reqs) if reqs else []) + done

    @property
    def busy(self) -> bool:
        """True while any request is queued or occupies a slot (i.e.
        :meth:`step` still has work — the load generator's poll)."""
        return bool(len(self._queue)) or any(
            s is not None for s in self._slots)

    def drain(self) -> List[Request]:
        """Step until the queue and every slot are empty; return all
        requests completed while draining (completion order)."""
        done: List[Request] = []
        while self.busy:
            done.extend(self.step())
        return done

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests; returns the same list (original
        order) with ``out``/latency fields filled."""
        for r in requests:
            self.submit(r)
        self.drain()
        return requests

    # ------------------------------------------------------------------
    # Shared helpers
    # ------------------------------------------------------------------

    def _bucket_len(self, s: int, max_new: int) -> int:
        """Round a prompt length up to the attention chunk so the jitted
        prefill compiles once per bucket, not once per distinct prompt
        length. Buckets only when the decode budget still fits in the
        cache (otherwise the raw length is kept — old behavior).

        Bucketed prompts are left-padded further than strictly needed;
        pads share the engine's existing ragged-wave semantics (left-pad
        tokens are attended — static batching, no per-row masks). Disable
        with ``Engine(..., bucket_prompts=False)``."""
        if not self.bucket_prompts:
            return s
        chunk = self.cfg.attn_chunk
        sb = (s + chunk - 1) // chunk * chunk
        while sb > s and sb + max_new > self.max_len:
            sb -= chunk
        return max(sb, s)

    def _trim_eos(self, toks: np.ndarray) -> np.ndarray:
        if self.eos_id is None:
            return toks
        hits = np.flatnonzero(toks == self.eos_id)
        return toks[:hits[0] + 1] if hits.size else toks

    def _finish(self, req: Request, toks,
                state: RequestState = RequestState.FINISHED,
                error: Optional[str] = None) -> None:
        """Move ``req`` into terminal ``state`` with output ``toks``
        (possibly partial for the failure states). Every terminal
        transition funnels through here: it owns the terminal-state
        counter, the latency histograms, and the lifecycle span, so the
        counters sum to submitted requests at quiescence."""
        req.out = np.asarray(toks, np.int32)
        req.state = state
        if error is not None:
            req.error = error
        req.t_done = time.time()
        req.m_done = time.perf_counter()
        if not req.m_first and state is RequestState.FINISHED:
            req.m_first = req.m_done         # wave / empty-budget path:
            req.t_first = req.t_done         # tokens delivered at once
        self._c_terminal[state].inc()
        self._c_useful.inc(max(len(req.out) - 1, 0))
        if req.m_submit and state is not RequestState.SHED:
            # shed requests never ran — a ~0 latency sample would fake
            # great percentiles exactly when the server is overloaded
            self._h_latency.observe(req.m_done - req.m_submit)
            if req.m_first:
                # no first token (expired in queue, failed prefill):
                # nothing to observe — a zero would fake a great TTFT
                self._h_ttft.observe(req.m_first - req.m_submit)
        if len(req.out) > 1 and req.m_done > req.m_first > 0:
            self._h_tpot.observe((req.m_done - req.m_first)
                                 / (len(req.out) - 1))
        if self.tracer is not None and req.trace_track is not None:
            if req.m_first and req.m_done > req.m_first:
                self.tracer.complete("decode", req.m_first, req.m_done,
                                     track=req.trace_track, cat="request")
            self.tracer.complete("request", req.m_submit or req.m_done,
                                 req.m_done, track=req.trace_track,
                                 cat="request", tokens=len(req.out),
                                 prompt=len(req.prompt),
                                 state=state.value,
                                 **({"error": req.error}
                                    if req.error else {}))
        self._by_id.pop(req.request_id, None)

    # ------------------------------------------------------------------
    # Lifecycle policy: deadlines, never-fit rejection, preemption
    # ------------------------------------------------------------------

    def _never_fits(self, req: Request) -> Optional[str]:
        """Reason this request can NEVER be served (even by evicting
        every cached page), or None. Checked once at admission pop —
        requeueing such a request would block the head of the queue
        forever (the pre-lifecycle engine's failure mode)."""
        s = len(req.prompt)
        if self.scheduler == "continuous" and self.kv_layout == "paged":
            if s + req.max_new > self.max_len:
                return (f"prompt {s} + max_new {req.max_new} > max_len "
                        f"{self.max_len}")
            pages = -(-(s + req.max_new) // self.page_size)
            if pages > self._alloc.capacity:
                return (f"needs {pages} pages but the pool holds only "
                        f"{self._alloc.capacity} even after evicting "
                        f"every cached page")
            return None
        sb = self._bucket_len(s, req.max_new)
        if sb + req.max_new > self.max_len:
            return (f"prompt {s} (bucketed {sb}) + max_new {req.max_new}"
                    f" > max_len {self.max_len}")
        return None

    def _reject_never_fit(self, req: Request, err: str,
                          done: List[Request]) -> None:
        self._c_never_fit.inc()
        self._finish(req, req._gen, state=RequestState.FAILED,
                     error=f"request can never fit the KV pool: {err} — "
                           f"raise max_len/n_pages or lower max_new")
        done.append(req)

    def _deadline_reason(self, req: Request, now: float,
                         where: str) -> Optional[str]:
        """Which deadline (if any) ``req`` has blown at ``now``."""
        if not req.m_submit:
            return None
        waited_ms = (now - req.m_submit) * 1e3
        if req.deadline_ms is not None and waited_ms >= req.deadline_ms:
            return (f"end-to-end deadline {req.deadline_ms:g}ms exceeded "
                    f"{where} ({waited_ms:.0f}ms elapsed)")
        if (req.ttft_deadline_ms is not None and not req.m_first
                and waited_ms >= req.ttft_deadline_ms):
            return (f"TTFT deadline {req.ttft_deadline_ms:g}ms exceeded "
                    f"{where} ({waited_ms:.0f}ms elapsed)")
        return None

    def _timeout(self, req: Request, reason: str,
                 done: List[Request]) -> None:
        if self.tracer is not None and req.trace_track is not None:
            self.tracer.instant("timeout", track=req.trace_track,
                                cat="request", reason=reason)
        self._finish(req, req._gen, state=RequestState.TIMED_OUT,
                     error=reason)
        done.append(req)

    def _expire_queued(self, done: List[Request]) -> None:
        """Time out queued requests whose TTFT / end-to-end deadline
        already passed — they would waste prefill work and then time out
        anyway. The queue drops the now-terminal entries lazily."""
        now = time.perf_counter()
        for req in list(self._queue):
            reason = self._deadline_reason(req, now, "while queued")
            if reason is not None:
                self._timeout(req, reason, done)

    def _expire_running(self, done: List[Request], paged: bool) -> None:
        """Time out running requests (end-to-end deadline only — a
        running request has its first token by definition). Called
        between decode bursts; ``policy.deadline_burst_cap`` bounds how
        stale this check can get."""
        now = time.perf_counter()
        for i in range(self.B):
            sl = self._slots[i]
            if sl is None:
                continue
            reason = self._deadline_reason(sl.req, now, "while decoding")
            if reason is not None:
                self._slots[i] = None
                if paged:
                    self._release_paged(i)
                self._timeout(sl.req, reason, done)

    def _preempt(self, lane: int, done: List[Request],
                 reason: str) -> None:
        """Evict lane ``lane``: free the lane + deref its pages, then
        requeue the request with backoff (tokens emitted so far are kept
        in ``_gen``; re-admission re-prefills prompt+gen, cheap under
        the prefix cache, and continues bit-identically). A request out
        of retry budget lands in the terminal PREEMPTED state."""
        sl = self._slots[lane]
        req = sl.req
        self._slots[lane] = None
        if self.kv_layout == "paged":
            self._release_paged(lane)
        self._c_preempt.inc()
        req.preemptions += 1
        req.retries += 1
        if self.tracer is not None and req.trace_track is not None:
            self.tracer.instant("preempt", track=req.trace_track,
                                cat="request", lane=lane, reason=reason,
                                retry=req.retries)
        if req.retries > self.policy.max_retries:
            self._finish(
                req, req._gen, state=RequestState.PREEMPTED,
                error=f"preempted {req.preemptions}x ({reason}); retry "
                      f"budget {self.policy.max_retries} exhausted")
            done.append(req)
            return
        req.state = RequestState.QUEUED
        req.not_before = (time.perf_counter()
                          + self.policy.backoff_s(req.retries))
        self._queue.push_front(req)

    def _victim_lanes(self):
        return ((i, s.req) for i, s in enumerate(self._slots)
                if s is not None)

    def _maybe_preempt_priority(self, done: List[Request]) -> None:
        """Priority-inversion trigger: every lane is busy and a
        strictly higher-priority request waits — evict the worst lane
        (at most one per step; admission picks up the freed lane this
        same step)."""
        if not self.policy.preemption:
            return
        if any(s is None for s in self._slots):
            return
        head = self._queue.peek(time.perf_counter())
        if head is None:
            return
        lane = pick_victim(self._victim_lanes(),
                           max_priority=head.priority)
        if lane is not None:
            self._preempt(lane, done, "priority")

    @staticmethod
    def _effective_prompt(req: Request) -> np.ndarray:
        """What (re-)admission prefills: the prompt plus every token
        already emitted before a preemption. Greedy sampling makes the
        resumed continuation bit-identical to the uninterrupted run."""
        prompt = np.asarray(req.prompt, np.int32)
        if not req._gen:
            return prompt
        return np.concatenate(
            [prompt, np.asarray(req._gen, np.int32)])

    def _cache_dtype(self):
        emb = self.params.get("embed") if isinstance(self.params, dict) \
            else None
        return emb.dtype if emb is not None else jnp.float32

    def _count_decode_compile(self, b: int, kind: str) -> None:
        self._count_compile("decode", (b, kind))

    # ------------------------------------------------------------------
    # Wave scheduler (static batching)
    # ------------------------------------------------------------------

    def _wave(self, reqs: List[Request]) -> List[Request]:
        t0 = time.time()
        B = len(reqs)
        max_new = max(r.max_new for r in reqs)
        S = self._bucket_len(max(len(r.prompt) for r in reqs), max_new)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad

        # greedy waves keep the untouched argmax + greedy-decode path
        # (bit-identical, same compile keys); a wave with any sampled
        # request switches the whole wave to the sampled closures —
        # greedy members still argmax inside them (per-lane temp 0)
        sampled = any(r.sampling is not None and not r.sampling.greedy
                      for r in reqs)
        self._count_compile("prefill", (B, S))
        self._count_decode_compile(
            B, "scalar-sampled" if sampled else "scalar")
        if sampled:
            # wave requests never resume, so every lane's first emission
            # index is 0; the loop then advances all lanes in lockstep
            temps_d, tks_d, tps_d, seeds_d, steps_d = self._samp_vectors(
                list(reqs), [0] * B)
        for r in reqs:
            r.state = RequestState.RUNNING
        with self._span("wave", batch=B, prompt_len=S, max_new=max_new):
            with self._span("prefill", batch=B, prompt_len=S):
                last_logits, cache = self._prefill(self.params,
                                                   jnp.asarray(toks))
                if sampled:
                    nxt = self._sample_tokens(last_logits, temps_d,
                                              tks_d, tps_d, seeds_d,
                                              steps_d)
                    steps_d = steps_d + 1
                else:
                    nxt = jnp.argmax(last_logits, axis=-1) \
                             .astype(jnp.int32)
                ok = jnp.isfinite(last_logits).all(axis=-1)
            # accumulate sampled tokens on device; one host transfer at
            # the end (a per-step np.asarray would sync the dispatch
            # pipeline every decode step)
            toks_dev = [nxt]
            oks_dev = [ok]
            pos = S
            with self._span("decode_loop", steps=max(max_new - 1, 0)):
                for _ in range(max_new - 1):
                    poison = -1
                    if self._faults is not None:
                        hit = self._faults.fire("nan_logits")
                        if hit is not None:
                            poison = int(hit.get("lane", 0))
                    if sampled:
                        nxt, ok, cache = self._decode_sampled(
                            self.params, cache, nxt, jnp.int32(pos),
                            jnp.int32(poison), temps_d, tks_d, tps_d,
                            seeds_d, steps_d)
                        steps_d = steps_d + 1
                    else:
                        nxt, ok, cache = self._decode(
                            self.params, cache, nxt, jnp.int32(pos),
                            jnp.int32(poison))
                    toks_dev.append(nxt)
                    oks_dev.append(ok)
                    pos += 1
            with self._span("host_sync", tokens=B * max_new):
                host = np.asarray(jnp.stack(toks_dev, axis=1))
                okh = np.asarray(jnp.stack(oks_dev, axis=1))
        t1 = time.time()
        self._c_admitted.inc(B)
        self._c_decode_steps.inc(max(max_new - 1, 0))  # max_new=0: none
        self._c_slot_steps.inc(B * max(max_new - 1, 0))
        for i, r in enumerate(reqs):
            bad = np.flatnonzero(~okh[i, :r.max_new])
            if bad.size:
                # the guard fails only this lane: its output stops just
                # before the first poisoned step, neighbors are untouched
                out = self._trim_eos(host[i, :bad[0]].astype(np.int32))
                self._c_nan.inc()
                if self.tracer is not None and r.trace_track is not None:
                    self.tracer.instant("nan_guard", track=r.trace_track,
                                        cat="request", lane=i,
                                        step=int(bad[0]))
                self._finish(r, out, state=RequestState.FAILED,
                             error=f"non-finite logits in lane {i} at "
                                   f"wave step {int(bad[0])}")
            else:
                out = self._trim_eos(host[i, :r.max_new].astype(np.int32))
                self._finish(r, out)
            r.t_submit, r.t_done = t0, t1
            if r.on_token is not None:
                for t in out:
                    r.on_token(int(t))
        return reqs

    # ------------------------------------------------------------------
    # Continuous scheduler (slot pool + chunked prefill)
    # ------------------------------------------------------------------

    def _ensure_pool(self) -> None:
        if self._cache is not None:
            return
        dt = self._cache_dtype()
        if self.kv_layout == "paged":
            self._cache = self._commit(
                api.init_cache_paged(self.cfg, self._alloc.n_pages,
                                     self.page_size, dt,
                                     kv_quant=self.kv_quant))
            return
        self._cache = self._commit(
            api.init_cache(self.cfg, self.B, self.max_len, dt,
                           kv_quant=self.kv_quant))
        self._slot_cache = self._commit(
            api.init_cache(self.cfg, 1, self.max_len, dt,
                           kv_quant=self.kv_quant))

    def _admit(self, slot: int, req: Request) -> tuple:
        """Chunk-prefill ``req`` into lane ``slot`` of the persistent
        cache. Returns (bucketed prompt length, first sampled token).

        The prompt is left-padded to its chunk bucket (same semantics as
        the wave engine) and processed in fixed attn_chunk-wide pieces —
        the final piece right-pads to the chunk width and passes the index
        of the last real token, so every prompt length reuses the single
        compiled chunk step. Pad writes land at cache positions beyond
        the prompt where they stay masked until decode overwrites them.

        A preempted request re-admits with its emitted tokens appended
        to the prompt (``_effective_prompt``) and the remaining budget;
        greedy decode then continues bit-identically."""
        prompt = self._effective_prompt(req)
        s = len(prompt)
        max_new = req.max_new - len(req._gen)
        C = self.cfg.attn_chunk
        sb = self._bucket_len(s, max_new)
        if sb + max_new > self.max_len:
            raise ValueError(
                f"request does not fit the KV pool: prompt {s} (bucketed "
                f"{sb}) + max_new {max_new} > max_len {self.max_len}")
        n_chunks = -(-sb // C)
        buf = np.zeros(n_chunks * C, np.int32)
        buf[sb - s:sb] = prompt
        self._count_compile("prefill_chunk", (1, C))
        logits = None
        for ci in range(n_chunks):
            width = min(sb - ci * C, C)
            with self._span("prefill_chunk", chunk=ci, slot=slot):
                logits, self._slot_cache = self._prefill_chunk(
                    self.params, self._slot_cache,
                    jnp.asarray(buf[None, ci * C:(ci + 1) * C]),
                    jnp.int32(ci * C), jnp.int32(width - 1))
            self._c_chunk_steps.inc()
            self._c_prefill_lane_steps.inc()
            self._h_prefill_batch.observe(1)
        with self._span("merge", slot=slot):
            self._cache = self._merge(self._cache, self._slot_cache,
                                      jnp.int32(slot))
        row = np.asarray(logits)[0]
        tok = self._first_token(req, row)
        return sb, tok, bool(np.isfinite(row).all())

    def _first_token(self, req: Request, row: np.ndarray) -> int:
        """Sample the admission token from a (V,) prefill-logits row.

        Greedy requests keep the host argmax (bit-identical to the
        pre-sampling engine). Sampled requests draw through the same
        jitted per-lane sampler the decode burst uses, on a (1, V)
        batch: the draw depends only on (seed, emission index), so the
        admission token equals what a decode-batch draw at the same
        index would produce — including after a preemption-resume,
        where the emission index restarts at ``len(req._gen)``."""
        sp = req.sampling
        if sp is None or sp.greedy:
            return int(row.argmax())
        return int(np.asarray(self._sample_tokens(
            jnp.asarray(row[None]),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            jnp.asarray([sp.seed], jnp.uint32),
            jnp.asarray([len(req._gen)], jnp.int32)))[0])

    def _samp_vectors(self, reqs: List[Optional[Request]],
                      steps: List[int]) -> tuple:
        """Build the per-lane sampling argument vectors for a batch.

        ``reqs[i]`` may be None (idle lane — greedy no-op args);
        ``steps[i]`` is the lane's next emission index.  All-greedy
        batches reuse one cached constant tuple: greedy lanes take the
        argmax branch, so none of these vectors (steps included) affect
        the output, and committing five fresh arrays per spec step is
        pure host overhead."""
        n = len(reqs)
        if all(r is None or r.sampling is None or r.sampling.greedy
               for r in reqs):
            cached = self._greedy_vecs.get(n)
            if cached is None:
                z = self._commit(jnp.zeros(n, jnp.float32))
                cached = self._greedy_vecs[n] = (
                    z, self._commit(jnp.zeros(n, jnp.int32)),
                    self._commit(jnp.ones(n, jnp.float32)),
                    self._commit(jnp.zeros(n, jnp.uint32)),
                    self._commit(jnp.zeros(n, jnp.int32)))
            return cached
        temps = np.zeros(n, np.float32)
        tks = np.zeros(n, np.int32)
        tps = np.ones(n, np.float32)
        seeds = np.zeros(n, np.uint32)
        for i, r in enumerate(reqs):
            sp = r.sampling if r is not None and r.sampling is not None \
                else GREEDY
            temps[i] = sp.temperature
            tks[i] = sp.top_k
            tps[i] = sp.top_p
            seeds[i] = sp.seed
        return (self._commit(jnp.asarray(temps)),
                self._commit(jnp.asarray(tks)),
                self._commit(jnp.asarray(tps)),
                self._commit(jnp.asarray(seeds)),
                self._commit(jnp.asarray(np.asarray(steps, np.int32))))

    def _emit(self, req: Request, tok: int) -> None:
        if req.on_token is not None:
            req.on_token(tok)

    # ------------------------------------------------------------------
    # Paged admission: block tables + ref-counted prefix caching
    # ------------------------------------------------------------------

    def _page_hashes(self, prompt: np.ndarray) -> List[bytes]:
        """Chained content hashes of the prompt's *full* pages: hash j
        commits to tokens [0, (j+1)*P) — page content alone is not
        enough, because a page's KV depends on everything before it."""
        P = self.page_size
        hs: List[bytes] = []
        h = hashlib.sha256(b"mx-paged-kv")
        for j in range(len(prompt) // P):
            h = hashlib.sha256(
                h.digest()
                + np.ascontiguousarray(prompt[j * P:(j + 1) * P],
                                       np.int32).tobytes())
            hs.append(h.digest())
        return hs

    def _tables_committed(self):
        if self._tables_dev is None:
            self._tables_dev = self._commit(jnp.asarray(self._tables))
        return self._tables_dev

    def _release_paged(self, slot: int) -> None:
        """Drop lane ``slot``'s page references and park its block table
        on the scrap page (dead-lane decode writes must not touch live
        pages). Registered pages whose refcount hits zero stay cached
        for future prefix hits until LRU eviction reclaims them."""
        pages = self._slot_pages[slot]
        if pages is not None:
            for p in pages:
                self._alloc.decref(p)
            self._slot_pages[slot] = None
        self._tables[slot, :] = 0
        self._tables_dev = None

    def _admit_paged(self, slot: int, req: Request) -> Optional[tuple]:
        """Admit ``req`` into lane ``slot`` of the paged pool. Returns
        (prompt length, first sampled token), or ``None`` when the pool
        cannot supply the pages right now (backpressure — the caller
        requeues the request and stops admitting this step).

        Prefix caching: the prompt's full pages are chain-hashed and
        matched against the allocator's registry. Matched pages are
        reused *by reference* (refcount bump, zero prefill work);
        chunked prefill resumes at the first unmatched chunk. At least
        the chunk holding the last prompt token always re-runs — the
        admission needs its logits to sample the first output token —
        and when that rewrite would land inside a shared page, the page
        is copied into a private one first (copy-on-write), preserving
        the cached bytes for other requests. After prefill, this
        prompt's own full pages are registered for future sharing.

        Prompts are placed unpadded at position 0 (no bucketing): page
        content is position-dependent (RoPE), so identical placement is
        what makes equal prefixes shareable.

        A preempted request re-admits with prompt+emitted tokens
        (``_effective_prompt``): its original prompt's registered pages
        are prefix-cache hits, so the retry re-prefills only the tail."""
        plan = self._admit_paged_prep(slot, req)
        if plan is None:
            return None
        return self._prefill_plan_serial(plan)

    def _admit_paged_prep(self, slot: int, req: Request,
                          in_flight: bool = False) -> Optional[dict]:
        """Host-side half of a paged admission: page accounting, prefix
        matching, copy-on-write, and the lane's block-table write —
        everything up to (not including) the prefill chunk loop.
        Returns a *plan* dict consumed by :meth:`_prefill_plan_serial`
        or the batched admission loop, or ``None`` on backpressure
        (every page reference taken here has been released).

        ``in_flight`` marks that other admissions hold pages but do not
        occupy a slot yet (earlier plans of the same batched-admission
        step) — it suppresses the exhausted-with-idle-pool error, which
        would otherwise misread their reservations as a permanently
        unsatisfiable request."""
        prompt = self._effective_prompt(req)
        s = len(prompt)
        max_new = req.max_new - len(req._gen)
        C = self.cfg.attn_chunk
        P = self.page_size
        if s + max_new > self.max_len:
            raise ValueError(
                f"request does not fit the KV pool: prompt {s} + "
                f"max_new {max_new} > max_len {self.max_len}")
        n_req_pages = -(-(s + max_new) // P)
        hashes = self._page_hashes(prompt)
        matched: List[int] = []
        for h in hashes:
            p = self._alloc.lookup(h)
            if p is None:
                break
            matched.append(p)
        # resume point: whole matched pages, capped so the chunk holding
        # the last prompt token is always re-run (its logits seed decode)
        resume = max(0, min(len(matched) * P, (s - 1) // C * C))
        m_full = resume // P
        cow_src = matched[m_full] if resume % P else None
        for p in matched[:m_full]:
            self._alloc.incref(p)
        if cow_src is not None:
            self._alloc.incref(cow_src)     # pin across alloc + copy
        forced = (self._faults is not None and
                  self._faults.fire("alloc_exhausted",
                                    need=n_req_pages - m_full) is not None)
        fresh = (None if forced
                 else self._alloc.alloc(n_req_pages - m_full))
        if fresh is None:
            for p in matched[:m_full]:
                self._alloc.decref(p)
            if cow_src is not None:
                self._alloc.decref(cow_src)
            if (not forced and not in_flight
                    and not any(sl is not None for sl in self._slots)):
                raise ValueError(
                    f"KV page pool exhausted with no requests in "
                    f"flight: request needs {n_req_pages - m_full} "
                    f"fresh pages but only {self._alloc.available} of "
                    f"{self._alloc.capacity} are obtainable — raise "
                    f"n_pages or lower max_new")
            return None
        pages = matched[:m_full] + fresh
        if cow_src is not None:
            with self._span("copy_page", src=cow_src, dst=fresh[0]):
                self._cache = self._copy_page(self._cache,
                                              jnp.int32(cow_src),
                                              jnp.int32(fresh[0]))
            self._alloc.decref(cow_src)
        self._c_prefix_hit_toks.inc(resume)
        (self._c_prefix_hits if m_full else self._c_prefix_misses).inc()
        self._tables[slot, :] = 0
        self._tables[slot, :len(pages)] = pages
        self._tables_dev = None

        n_chunks = -(-(s - resume) // C)
        buf = np.zeros(n_chunks * C, np.int32)
        buf[:s - resume] = prompt[resume:]
        return {"slot": slot, "req": req, "s": s, "resume": resume,
                "n_chunks": n_chunks, "buf": buf, "pages": pages,
                "hashes": hashes}

    def _prefill_plan_serial(self, plan: dict) -> tuple:
        """Run one admission plan's chunked prefill serially (one lane
        per dispatch — the pre-batching jit signature) and finish it."""
        slot, s, resume = plan["slot"], plan["s"], plan["resume"]
        C = self.cfg.attn_chunk
        buf = plan["buf"]
        table_row = self._commit(jnp.asarray(self._tables[slot:slot + 1]))
        self._count_compile("prefill_chunk", ("paged", 1, C))
        logits = None
        for ci in range(plan["n_chunks"]):
            width = min(s - resume - ci * C, C)
            with self._span("prefill_chunk", chunk=ci, slot=slot,
                            paged=True, prefill_batch=1):
                logits, self._cache = self._prefill_chunk_paged(
                    self.params, self._cache,
                    jnp.asarray(buf[None, ci * C:(ci + 1) * C]), table_row,
                    jnp.int32(resume + ci * C), jnp.int32(width - 1))
            self._c_chunk_steps.inc()
            self._c_prefill_lane_steps.inc()
            self._h_prefill_batch.observe(1)
        return self._admit_paged_finish(plan, np.asarray(logits)[0])

    def _admit_paged_finish(self, plan: dict, row: np.ndarray) -> tuple:
        """Post-prefill bookkeeping of a paged admission: register the
        prompt's full pages for prefix sharing, pin the lane's page
        list, sample the first token from the last chunk's logits row."""
        slot, req, s = plan["slot"], plan["req"], plan["s"]
        for j in range(s // self.page_size):
            self._alloc.register(plan["hashes"][j], plan["pages"][j])
        self._slot_pages[slot] = plan["pages"]
        tok = self._first_token(req, row)
        return s, tok, bool(np.isfinite(row).all())

    def _admit_one(self, i: int, req: Request, paged: bool):
        """Admit ``req`` into lane ``i`` with lifecycle telemetry.
        Returns the (sb, tok, ok) admission result, or None on paged
        backpressure (nothing was recorded for the request). TTFT /
        queue-wait are observed only on the *first* admission — a
        preempted request's retry is not a new first token."""
        t_a0 = time.perf_counter()
        with self._span("admit", slot=i, prompt=len(req.prompt),
                        req=req.trace_track or ""):
            if paged:
                res = self._admit_paged(i, req)
                if res is None:
                    return None
            else:
                res = self._admit(i, req)
        self._record_admission(req, t_a0, time.perf_counter(), res[2])
        return res

    def _record_admission(self, req: Request, t_a0: float, t_a1: float,
                          ok: bool) -> None:
        """Admission lifecycle telemetry, shared by serial and batched
        admission: admitted counter, RUNNING transition, first-token /
        queue-wait observations, request-track trace events. ``t_a0``
        is when admission work started for this request, ``t_a1`` when
        its first token became available on the host."""
        self._c_admitted.inc()
        req.state = RequestState.RUNNING
        first = not req.m_first
        if first and ok:
            req.m_first = t_a1
            req.t_first = time.time()
            if req.m_submit:
                self._h_queue_wait.observe(t_a0 - req.m_submit)
        if self.tracer is not None and req.trace_track is not None:
            if req.m_submit and first:
                self.tracer.complete("queued", req.m_submit, t_a0,
                                     track=req.trace_track, cat="request")
            self.tracer.complete("prefill", t_a0, t_a1,
                                 track=req.trace_track, cat="request",
                                 prompt=len(req.prompt), resumed=not first)
            if first and ok:
                self.tracer.instant("first_token", track=req.trace_track,
                                    cat="request")

    def _post_admission(self, i: int, req: Request, res: tuple,
                        paged: bool, done: List[Request]) -> bool:
        """Shared admission epilogue: NaN guard, first-token emission,
        same-step completion, or lane occupancy. Returns True when the
        lane is now occupied (False: it stays free for the next
        queued request)."""
        sb, tok, ok = res
        if not ok:
            # prefill produced non-finite logits: fail this request
            # alone, the lane stays free for the next
            self._c_nan.inc()
            if (self.tracer is not None
                    and req.trace_track is not None):
                self.tracer.instant("nan_guard", track=req.trace_track,
                                    cat="request", lane=i, step=-1)
            if paged:
                self._release_paged(i)
            self._finish(req, req._gen, state=RequestState.FAILED,
                         error=f"non-finite logits at prefill "
                               f"(lane {i})")
            done.append(req)
            return False
        req._gen.append(tok)
        self._emit(req, tok)
        if req.max_new - len(req._gen) == 0 or tok == self.eos_id:
            self._finish(req, req._gen)  # lane freed same step
            done.append(req)
            if paged:
                self._release_paged(i)
            return False
        self._slots[i] = _Slot(req, req._gen, sb,
                               req.max_new - len(req._gen))
        return True

    def _admit_batched(self, done: List[Request], knob: int) -> None:
        """Paged admission with prefill batching: admit up to ``knob``
        queued requests per engine step through ONE chunked-prefill
        loop whose dispatches carry every candidate lane at once —
        per-lane block tables, start offsets, and last-token indices
        stacked on the batch axis under a single jit signature
        (``("paged", B, C)``). The loop runs ``max(n_chunks)`` steps;
        a lane whose prompt ran out simply goes inactive (its row
        rides along on the scrap table, see below).

        Semantics match serial admission exactly: candidates are
        collected in admit-cursor ring order with the same pop /
        never-fits / zero-budget / preempt-retry / backpressure
        handling, and the fused or fallback prefill is row-independent,
        so each lane's tokens are bit-identical to admitting it alone.
        The one cross-request interaction serial admission has — a
        later request prefix-hitting pages a *just-admitted* earlier
        request registered — cannot happen mid-batch, so a candidate
        whose prompt pages collide with hashes this batch is about to
        register is deferred (pushed back to the queue front, stopping
        collection to preserve queue order); it admits next step with
        its prefix hit intact.

        Non-candidate lanes (and candidates past their last chunk) run
        on an all-zeros table row: their writes land on the scrap page
        (page 0) and their logits rows are never read — rows are
        independent, so garbage lanes cannot perturb live ones."""
        C = self.cfg.attn_chunk
        P = self.page_size
        plans: List[dict] = []
        pending: set = set()     # page hashes this batch will register
        stop = False
        for off in range(self.B):
            if stop or len(plans) >= knob:
                break
            i = (self._admit_cursor + off) % self.B
            if self._slots[i] is not None:
                continue
            while True:
                req = self._queue.pop(time.perf_counter())
                if req is None:
                    stop = True
                    break
                err = self._never_fits(req)
                if err is not None:
                    self._reject_never_fit(req, err, done)
                    continue
                if req.max_new - len(req._gen) <= 0:
                    self._c_admitted.inc()
                    self._finish(req, req._gen)
                    done.append(req)
                    continue
                if plans and any(
                        h in pending
                        for h in self._page_hashes(
                            self._effective_prompt(req))):
                    # would prefix-hit a page an earlier candidate in
                    # this batch registers only *after* its prefill —
                    # defer so the hit is not silently skipped
                    self._queue.push_front(req)
                    stop = True
                    break
                t_a0 = time.perf_counter()
                plan = self._admit_paged_prep(i, req,
                                              in_flight=bool(plans))
                while plan is None and self.policy.preemption:
                    # page pressure: same victim/retry dance as serial
                    lane = pick_victim(self._victim_lanes(),
                                       max_priority=req.priority)
                    if lane is None:
                        break
                    self._preempt(lane, done, "page pressure")
                    plan = self._admit_paged_prep(i, req,
                                                  in_flight=bool(plans))
                if plan is None:
                    self._queue.push_front(req)
                    stop = True
                    break
                plan["t0"] = t_a0
                pending.update(plan["hashes"][:plan["s"] // P])
                plans.append(plan)
                break
        if not plans:
            return
        if len(plans) == 1:
            # a batch of one IS the serial path — same jit signature,
            # same spans, same counters
            p = plans[0]
            req = p["req"]
            with self._span("admit", slot=p["slot"],
                            prompt=len(req.prompt),
                            req=req.trace_track or ""):
                res = self._prefill_plan_serial(p)
            self._record_admission(req, p["t0"], time.perf_counter(),
                                   res[2])
            self._post_admission(p["slot"], req, res, True, done)
            return

        B = self.B
        maxp = self._tables.shape[1]
        tables = np.zeros((B, maxp), np.int32)
        for p in plans:
            tables[p["slot"]] = self._tables[p["slot"]]
        # committed as a COPY: `tables` is mutated between steps while
        # earlier dispatches are still in flight, and jnp.asarray of a
        # host array can be zero-copy on CPU backends — aliasing it
        # would let the mutation reach computations already enqueued
        tables_d = self._commit(jnp.asarray(tables.copy()))
        n_steps = max(p["n_chunks"] for p in plans)
        self._count_compile("prefill_chunk", ("paged", B, C))
        lane_logits: dict = {}   # slot -> device logits, its last chunk
        with self._span("admit", lanes=len(plans), batched=True):
            for ci in range(n_steps):
                active = [p for p in plans if ci < p["n_chunks"]]
                if ci and any(p["n_chunks"] == ci for p in plans):
                    # a lane just ran out of chunks: park it on the
                    # scrap table BEFORE the next dispatch, or its
                    # ride-along garbage rows would overwrite the real
                    # KV it just finished writing
                    for p in plans:
                        if p["n_chunks"] <= ci:
                            tables[p["slot"]] = 0
                    tables_d = self._commit(jnp.asarray(tables.copy()))
                toks = np.zeros((B, C), np.int32)
                starts = np.zeros(B, np.int32)
                last = np.zeros(B, np.int32)
                for p in active:
                    toks[p["slot"]] = p["buf"][ci * C:(ci + 1) * C]
                    starts[p["slot"]] = p["resume"] + ci * C
                    last[p["slot"]] = min(
                        p["s"] - p["resume"] - ci * C, C) - 1
                with self._span("prefill_chunk", chunk=ci, paged=True,
                                prefill_batch=len(active)):
                    logits, self._cache = self._prefill_chunk_paged(
                        self.params, self._cache, jnp.asarray(toks),
                        tables_d, jnp.asarray(starts),
                        jnp.asarray(last))
                self._c_chunk_steps.inc()
                self._c_prefill_lane_steps.inc(len(active))
                if len(active) > 1:
                    self._c_prefill_batched.inc()
                self._h_prefill_batch.observe(len(active))
                for p in active:
                    if ci == p["n_chunks"] - 1:
                        lane_logits[p["slot"]] = logits
            t_a1 = time.perf_counter()
            for p in plans:
                # one host fetch per lane, after every dispatch is in
                # flight — the sync cost is paid once per admission,
                # exactly like the serial path's trailing fetch
                row = np.asarray(lane_logits[p["slot"]])[p["slot"]]
                res = self._admit_paged_finish(p, row)
                self._record_admission(p["req"], p["t0"], t_a1, res[2])
                self._post_admission(p["slot"], p["req"], res, True,
                                     done)

    def _step_continuous(self) -> List[Request]:
        self._ensure_pool()
        paged = self.kv_layout == "paged"
        done: List[Request] = []
        with self._span("engine_step"):
            done = self._step_continuous_inner(paged, done)
        if paged:
            self._sync_alloc_metrics()
        self._g_queue_depth.set(len(self._queue))
        if not done and not any(s is not None for s in self._slots):
            # nothing ran and nothing finished: every queued request is
            # in a backoff hold — sleep toward the nearest release so
            # drain() doesn't spin the host
            d = self._queue.next_eligible_delay(time.perf_counter())
            if d:
                time.sleep(min(d, 0.02))
        return done

    def _step_continuous_inner(self, paged: bool,
                               done: List[Request]) -> List[Request]:
        # --- lifecycle pre-pass: forced eviction fault, queued-deadline
        # expiry, then the priority-inversion preemption trigger ---
        if (self._faults is not None and paged
                and self._faults.fire("evict_cache") is not None):
            n = self._alloc.flush_cache()
            if self.tracer is not None:
                self.tracer.instant("fault:evict_cache", cat="fault",
                                    evicted=n)
        self._expire_queued(done)
        self._maybe_preempt_priority(done)

        # --- admission: fill free lanes from the queue (ring order).
        # Paged admission batches up to max_prefill_lanes_per_step
        # requests into one chunked-prefill loop; knob 1 (and the
        # contiguous layout, whose admission runs in a single-lane
        # scratch cache) keeps the serial path bit-identical to the
        # pre-batching engine. ---
        knob = (max(1, self.policy.max_prefill_lanes_per_step)
                if paged else 1)
        if knob > 1:
            self._admit_batched(done, knob)
        else:
            blocked = False
            for off in range(self.B):
                i = (self._admit_cursor + off) % self.B
                if self._slots[i] is not None:
                    continue
                while True:
                    req = self._queue.pop(time.perf_counter())
                    if req is None:
                        break
                    err = self._never_fits(req)
                    if err is not None:
                        self._reject_never_fit(req, err, done)
                        continue
                    if req.max_new - len(req._gen) <= 0:
                        self._c_admitted.inc()
                        self._finish(req, req._gen)
                        done.append(req)
                        continue
                    res = self._admit_one(i, req, paged)
                    while res is None and self.policy.preemption:
                        # page pressure: evict a strictly lower-priority
                        # running request and retry this admission — its
                        # freed pages (plus cache evictions) cover us
                        lane = pick_victim(self._victim_lanes(),
                                           max_priority=req.priority)
                        if lane is None:
                            break
                        self._preempt(lane, done, "page pressure")
                        res = self._admit_one(i, req, paged)
                    if res is None:
                        # pool pressure with nothing evictable: requeue
                        # at the front and stop admitting — pages free
                        # up as lanes finish
                        self._queue.push_front(req)
                        blocked = True
                        break
                    if self._post_admission(i, req, res, paged, done):
                        break
                if blocked:
                    break
        self._admit_cursor = (self._admit_cursor + 1) % self.B

        live = [i for i in range(self.B) if self._slots[i] is not None]
        if not live:
            return done

        if self.spec is not None:
            # speculative decoding replaces the decode burst: one verify
            # step per engine step (drafts depend on the tokens the
            # previous step emitted, so steps are inherently host-paced
            # — each one can emit up to k+1 tokens per lane instead)
            self._spec_decode_step(live, paged, done)
            self._expire_running(done, paged)
            return done

        # --- decode burst over every lane (dead lanes idle; their
        # sampled tokens are discarded, their stale cache rows are
        # overwritten wholesale at the next admission merge).
        #
        # With no eos_id the slot schedule is deterministic on the host:
        # every lane runs exactly `remaining` more steps. All steps up to
        # the next lane completion are therefore dispatched back-to-back
        # with the sampled-token array fed straight back on device — the
        # device->host fetch (needed only for on_token emission and
        # bookkeeping) is batched ONCE per burst instead of syncing the
        # dispatch pipeline every step, which is what let the wave
        # scheduler out-run continuous on tok/s. With an eos_id any step
        # can free a lane, so the burst degenerates to one step (EOS must
        # be observed before the next input token is chosen... it is the
        # next input token, so the pipeline is inherently serialized).
        burst = 1 if self.eos_id is not None else min(
            self._slots[i].remaining for i in live)
        if any(self._slots[i].req.deadline_ms is not None for i in live):
            # deadlines are only observable between bursts; cap the
            # burst so enforcement granularity stays bounded (deadline-
            # free traffic keeps the full burst and its single sync)
            burst = min(burst, max(1, self.policy.deadline_burst_cap))
        cur = np.zeros(self.B, np.int32)
        pos = np.zeros(self.B, np.int32)
        for i in live:
            cur[i] = self._slots[i].toks[-1]
            pos[i] = self._slots[i].pos
        # the greedy closures are dispatched untouched whenever every
        # live lane is greedy, so greedy traffic (and its compile
        # counts) is bit-identical to an engine without sampling
        sampled = any(self._slots[i].req.sampling is not None
                      and not self._slots[i].req.sampling.greedy
                      for i in live)
        self._count_decode_compile(
            self.B, ("vector-paged" if paged else "vector") +
                    ("-sampled" if sampled else ""))
        # committed onto the canonical sharding so the burst's first step
        # shares one jit signature with the steady-state steps (whose
        # cur/pos are the previous step's committed outputs)
        cur_d = self._commit(jnp.asarray(cur))
        pos_d = self._commit(jnp.asarray(pos))
        tables_d = self._tables_committed() if paged else None
        if sampled:
            # steps[i] = the lane's next emission index: sl.toks already
            # includes the admission token (emission 0), so index =
            # len(toks). Idle lanes get greedy no-op args.
            temps_d, tks_d, tps_d, seeds_d, steps_d = self._samp_vectors(
                [self._slots[i].req if self._slots[i] is not None else None
                 for i in range(self.B)],
                [len(self._slots[i].toks) if self._slots[i] is not None
                 else 0 for i in range(self.B)])
        toks_dev = []
        oks_dev = []
        with self._span("decode_burst", steps=burst, lanes=len(live)):
            for _ in range(burst):
                poison = -1
                if self._faults is not None:
                    hit = self._faults.fire("nan_logits")
                    if hit is not None:
                        poison = int(hit.get("lane", live[0]))
                # spans time the *dispatch* (device work is async; the
                # device wait shows up in host_sync below) — no per-step
                # host sync is ever introduced by tracing
                with self._span("decode_step", paged=paged):
                    if paged and sampled:
                        cur_d, ok_d, self._cache = \
                            self._decode_paged_sampled(
                                self.params, self._cache, cur_d, pos_d,
                                tables_d, jnp.int32(poison), temps_d,
                                tks_d, tps_d, seeds_d, steps_d)
                    elif paged:
                        cur_d, ok_d, self._cache = self._decode_paged(
                            self.params, self._cache, cur_d, pos_d,
                            tables_d, jnp.int32(poison))
                    elif sampled:
                        cur_d, ok_d, self._cache = self._decode_sampled(
                            self.params, self._cache, cur_d, pos_d,
                            jnp.int32(poison), temps_d, tks_d, tps_d,
                            seeds_d, steps_d)
                    else:
                        cur_d, ok_d, self._cache = self._decode(
                            self.params, self._cache, cur_d, pos_d,
                            jnp.int32(poison))
                toks_dev.append(cur_d)
                oks_dev.append(ok_d)
                pos_d = pos_d + 1
                if sampled:
                    steps_d = steps_d + 1
                self._c_decode_steps.inc()
                self._c_slot_steps.inc(self.B)
            with self._span("host_sync", steps=burst):
                host = np.asarray(jnp.stack(toks_dev, axis=1))  # 1 sync
                okh = np.asarray(jnp.stack(oks_dev, axis=1))
        for step in range(burst):
            for i in live:
                sl = self._slots[i]
                if sl is None:
                    continue
                if not okh[i, step]:
                    # per-lane failure isolation: only the poisoned
                    # lane's request fails; its private cache rows are
                    # garbage now but nothing shared was written (decode
                    # writes land past the registered prefix pages) and
                    # the lane's next admission overwrites them
                    req = sl.req
                    self._c_nan.inc()
                    if (self.tracer is not None
                            and req.trace_track is not None):
                        self.tracer.instant("nan_guard",
                                            track=req.trace_track,
                                            cat="request", lane=i,
                                            step=step)
                    self._slots[i] = None
                    if paged:
                        self._release_paged(i)
                    self._finish(req, sl.toks,
                                 state=RequestState.FAILED,
                                 error=f"non-finite logits in lane {i} "
                                       f"at decode position {sl.pos}")
                    done.append(req)
                    continue
                tok = int(host[i, step])
                sl.toks.append(tok)
                sl.pos += 1
                sl.remaining -= 1
                self._emit(sl.req, tok)
                if sl.remaining == 0 or tok == self.eos_id:
                    self._finish(sl.req, sl.toks)
                    done.append(sl.req)
                    self._slots[i] = None
                    if paged:
                        self._release_paged(i)
        self._expire_running(done, paged)
        return done

    def _spec_decode_step(self, live: List[int], paged: bool,
                          done: List[Request]) -> None:
        """One speculative decode step over every live lane.

        Host side proposes up to ``spec.k`` draft tokens per lane by
        prompt lookup over (prompt + emitted tokens); one batched verify
        forward scores current-token + drafts at the lane's positions
        and the fused acceptance rule emits 1..k+1 tokens per lane
        (accepted draft prefix, then the rejection resample or the
        bonus sample). Rollback is a pure position rewind: ``sl.pos``
        advances only by the emitted count, so rejected slots' cache
        rows stay masked (causal + kv_len) until the next verify step
        overwrites them in place — under the paged layout the pages
        were preallocated at admission, so no page is allocated,
        dereffed, or leaked by acceptance or rejection."""
        K = self.spec.k
        C = K + 1
        # the whole host-varying step state in one packed array — see
        # the verify_step closure for the column layout
        packed = np.zeros((self.B, C + 4), np.int32)
        steps = [0] * self.B
        reqs: List[Optional[Request]] = [None] * self.B
        n_prop = 0
        for i in live:
            sl = self._slots[i]
            reqs[i] = sl.req
            # drafts come from the request's own history; d_len is
            # capped at remaining-1 so the emitted run (<= d_len+1)
            # never overruns the decode budget — which also keeps every
            # verify write inside the rows/pages admission reserved
            ctx = np.concatenate([np.asarray(sl.req.prompt, np.int64),
                                  np.asarray(sl.toks, np.int64)])
            d = propose_ngram(ctx, K, self.spec.ngram_max,
                              self.spec.ngram_min)
            dn = max(0, min(len(d), sl.remaining - 1))
            packed[i, 0] = sl.toks[-1]
            packed[i, 1:1 + dn] = d[:dn]
            packed[i, C] = sl.pos
            packed[i, C + 1] = dn + 1
            packed[i, C + 2] = len(sl.toks)
            steps[i] = len(sl.toks)
            n_prop += dn
        self._c_spec_proposed.inc(n_prop)
        temps_d, tks_d, tps_d, seeds_d, _ = self._samp_vectors(
            reqs, steps)
        self._count_decode_compile(
            self.B, "verify-paged" if paged else "verify")
        poison = -1
        if self._faults is not None:
            hit = self._faults.fire("nan_logits")
            if hit is not None:
                poison = int(hit.get("lane", live[0]))
        packed[:, C + 3] = poison
        packed_d = self._commit(jnp.asarray(packed))
        with self._span("verify_step", lanes=len(live), k=K,
                        proposed=n_prop, paged=paged):
            if paged:
                res_d, self._cache = self._verify_paged(
                    self.params, self._cache, packed_d,
                    self._tables_committed(),
                    temps_d, tks_d, tps_d, seeds_d)
            else:
                res_d, self._cache = self._verify(
                    self.params, self._cache, packed_d,
                    temps_d, tks_d, tps_d, seeds_d)
            with self._span("host_sync", steps=1):
                res = np.asarray(res_d)
        out = res[:, :C]
        ne = res[:, C]
        okh = res[:, C + 1:].astype(bool)
        self._c_decode_steps.inc()
        self._c_slot_steps.inc(self.B)
        for i in live:
            sl = self._slots[i]
            if sl is None:
                continue
            n = int(ne[i])
            self._c_spec_accepted.inc(n - 1)
            row = out[i]
            bad = np.flatnonzero(~okh[i, :n])
            if bad.size:
                # poisoned verify: the lane keeps the tokens before the
                # first non-finite slot, then fails alone (a NaN'd lane
                # accepts nothing, so n == 1 and nothing garbage is
                # emitted); neighbors are untouched
                k0 = int(bad[0])
                for t in row[:k0]:
                    sl.toks.append(int(t))
                    self._emit(sl.req, int(t))
                sl.pos += k0
                req = sl.req
                self._c_nan.inc()
                if (self.tracer is not None
                        and req.trace_track is not None):
                    self.tracer.instant("nan_guard",
                                        track=req.trace_track,
                                        cat="request", lane=i, step=k0)
                self._slots[i] = None
                if paged:
                    self._release_paged(i)
                self._finish(req, sl.toks, state=RequestState.FAILED,
                             error=f"non-finite logits in lane {i} at "
                                   f"verify position {sl.pos}")
                done.append(req)
                continue
            emitted = row[:n]
            if self.eos_id is not None:
                hits = np.flatnonzero(emitted == self.eos_id)
                if hits.size:
                    # stop at (and include) the first EOS: later
                    # accepted drafts are discarded, their stale cache
                    # rows die with the lane
                    emitted = emitted[:int(hits[0]) + 1]
            kept = len(emitted)
            for t in emitted:
                sl.toks.append(int(t))
                self._emit(sl.req, int(t))
            sl.pos += kept
            sl.remaining -= kept
            if (sl.remaining == 0
                    or (kept and int(emitted[-1]) == self.eos_id)):
                self._finish(sl.req, sl.toks)
                done.append(sl.req)
                self._slots[i] = None
                if paged:
                    self._release_paged(i)

    # ------------------------------------------------------------------
    # Accounting
    # ------------------------------------------------------------------

    def _counter_values(self) -> dict:
        """Current cumulative values of the windowable counters."""
        self._sync_alloc_metrics()
        return {"admitted": self.admitted,
                "decode_steps": self.decode_steps,
                "slot_steps": self.slot_steps,
                "useful_decode_tokens": self.useful_decode_tokens,
                "prefill_chunk_steps": self.prefill_chunk_steps,
                "prefill_batched_steps": int(
                    self._c_prefill_batched.value),
                "prefill_lane_steps": int(
                    self._c_prefill_lane_steps.value),
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "blocks_evicted": int(self._c_evicted.value),
                "spec_proposed_tokens": int(self._c_spec_proposed.value),
                "spec_accepted_tokens": int(self._c_spec_accepted.value)}

    def reset_stats(self) -> None:
        """Start a new stats *window*: ``stats()['window']`` counts from
        here. Explicitly NOT reset: the cumulative (flat) counters, the
        compile counters (the jit cache is an engine-lifetime property —
        a "window" of compiles is meaningless), the latency histograms,
        and the gauges (they describe current state, not a period)."""
        self._window_base = self._counter_values()

    @staticmethod
    def _quantiles(h) -> dict:
        """{p50, p99} of a histogram in seconds; None before any
        observation (JSON-safe, unlike NaN)."""
        if h.count == 0:
            return {"p50": None, "p99": None}
        return {"p50": h.quantile(0.5), "p99": h.quantile(0.99)}

    def stats(self) -> dict:
        """Serving counters, as a view over the metrics registry
        (``Engine.metrics`` holds the full catalog; see
        ``docs/observability.md``).

        Key classes — the cumulative/window split is explicit:

        * flat counter keys (``admitted`` ... ``blocks_evicted``) —
          **cumulative since construction** (bit-compatible with every
          pre-telemetry release);
        * ``window`` — the same counters **since the last**
          :meth:`reset_stats` (plus the window's decode_utilization);
        * ``cumulative_compiles`` — compile counts, never windowed (the
          jit cache is an engine-lifetime property; the flat
          ``*_compiles`` keys alias these);
        * ``ttft_p50/p99`` / ``tpot_p50/p99`` — seconds, from the
          registry's latency histograms (None before any completion;
          TPOT needs a multi-token continuous-scheduler completion).

        decode_utilization is the fraction of decode slot-steps that
        produced a token which made it into a request's output — the
        wave scheduler burns slot-steps on requests shorter than their
        wave; the continuous scheduler only idles lanes when the queue
        runs dry.

        Paged-layout counters (zero under 'contiguous'):
        ``prefix_hit_tokens`` — prompt tokens served from cached prefix
        pages instead of being re-prefilled; ``blocks_in_use`` — pages
        currently referenced by live block tables (a gauge);
        ``blocks_evicted`` — cached prefix pages reclaimed by LRU
        eviction under pool pressure (cumulative).
        ``prefill_chunk_steps`` counts chunked-prefill invocations under
        both layouts — with prefix hits it drops below the no-sharing
        chunk count, which is how tests prove a shared prefix is
        prefilled exactly once. Batched paged admission
        (``policy.max_prefill_lanes_per_step`` > 1) folds several
        lanes into each invocation: ``prefill_batched_steps`` counts
        the invocations that carried >=2 lanes, ``prefill_lane_steps``
        counts invocations x active lanes, and
        ``prefill_lanes_per_step`` (= lane_steps / chunk_steps) is the
        mean prefill batch occupancy — 1.0 under strictly serial
        admission.

        Lifecycle keys (``docs/robustness.md``): ``submitted`` —
        requests accepted by submit(); ``terminal`` — dict of terminal-
        state counts (finished/cancelled/timed_out/failed/preempted;
        sums to ``submitted`` at quiescence); ``preemptions`` — lane
        evictions (each either requeued or terminal-PREEMPTED);
        ``nan_guard_trips`` — requests failed by the non-finite-logit
        guard; ``rejected_never_fit`` — admissions rejected because
        prompt+budget can never fit. All cumulative (not windowed) —
        ``admitted`` counts every admission *including* preemption
        retries, so ``admitted >= submitted`` under preemption."""
        cum = self._counter_values()
        util = (cum["useful_decode_tokens"] / cum["slot_steps"]
                if cum["slot_steps"] else 0.0)
        window = {k: cum[k] - self._window_base[k]
                  for k in self._WINDOW_KEYS}
        window["decode_utilization"] = (
            window["useful_decode_tokens"] / window["slot_steps"]
            if window["slot_steps"] else 0.0)
        window["spec_acceptance"] = (
            window["spec_accepted_tokens"] / window["spec_proposed_tokens"]
            if window["spec_proposed_tokens"] else 0.0)
        compiles = {"prefill": self.prefill_compiles,
                    "prefill_chunk": self.prefill_chunk_compiles,
                    "decode": self.decode_compiles}
        ttft = self._quantiles(self._h_ttft)
        tpot = self._quantiles(self._h_tpot)
        return {"scheduler": self.scheduler, "backend": self.qm.backend,
                "kv_cache": (self.kv_quant.fmt if self.kv_quant else "none"),
                "kv_layout": self.kv_layout,
                "admitted": cum["admitted"],
                "prefill_compiles": compiles["prefill"],
                "prefill_chunk_compiles": compiles["prefill_chunk"],
                "decode_compiles": compiles["decode"],
                "decode_steps": cum["decode_steps"],
                "slot_steps": cum["slot_steps"],
                "useful_decode_tokens": cum["useful_decode_tokens"],
                "decode_utilization": util,
                "prefill_chunk_steps": cum["prefill_chunk_steps"],
                "prefill_batched_steps": cum["prefill_batched_steps"],
                "prefill_lane_steps": cum["prefill_lane_steps"],
                "prefill_lanes_per_step": (
                    cum["prefill_lane_steps"]
                    / max(cum["prefill_chunk_steps"], 1)),
                "prefix_hit_tokens": cum["prefix_hit_tokens"],
                "spec_proposed_tokens": cum["spec_proposed_tokens"],
                "spec_accepted_tokens": cum["spec_accepted_tokens"],
                "spec_acceptance": (
                    cum["spec_accepted_tokens"]
                    / cum["spec_proposed_tokens"]
                    if cum["spec_proposed_tokens"] else 0.0),
                "blocks_in_use": (self._alloc.in_use if self._alloc
                                  else 0),
                "blocks_evicted": (self._alloc.evicted if self._alloc
                                   else 0),
                "ttft_p50": ttft["p50"], "ttft_p99": ttft["p99"],
                "tpot_p50": tpot["p50"], "tpot_p99": tpot["p99"],
                "submitted": int(self._c_submitted.value),
                "terminal": {s.value: int(c.value)
                             for s, c in self._c_terminal.items()},
                "preemptions": int(self._c_preempt.value),
                "nan_guard_trips": int(self._c_nan.value),
                "rejected_never_fit": int(self._c_never_fit.value),
                "window": window,
                "cumulative_compiles": compiles}

    def kv_bytes_resident(self) -> int:
        """Bytes of KV cache currently holding data the engine may read.

        Contiguous layouts reserve the full (B, max_len) pool up front,
        so the whole allocation is resident regardless of traffic. The
        paged layout counts only pages that are referenced by a live
        block table or cached for prefix reuse (plus the scrap page) —
        the number the serving benchmark tracks to show paging's memory
        win on short/mixed traffic."""
        if self._cache is None:
            return 0
        leaves = jax.tree.leaves(self._cache)
        total = sum(int(a.size) * a.dtype.itemsize for a in leaves)
        if self.kv_layout != "paged":
            # the admission scratch lane is part of the contiguous
            # engine's standing KV allocation
            leaves = jax.tree.leaves(self._slot_cache)
            return total + sum(int(a.size) * a.dtype.itemsize
                               for a in leaves)
        live = self._alloc.resident + self._alloc.reserved
        return total * live // self._alloc.n_pages

    def throughput(self, n_requests: int = 8, prompt_len: int = 32,
                   max_new: int = 32, seed: int = 0,
                   sampling: Optional[SamplingParams] = None) -> dict:
        """Tokens/second over a synthetic request wave (Fig. 4 metric),
        plus the scheduler counters from :meth:`stats`.

        The flat step/token counters and decode_utilization describe
        *this run* only (deltas against the engine's cumulative
        counters; ``window`` is overwritten with the same per-run
        values); compile counts stay cumulative — the jit cache is an
        engine-lifetime property. Timed with ``time.perf_counter()``
        (wall clock is not monotonic)."""
        rng = np.random.default_rng(seed)
        reqs = [Request(prompt=rng.integers(
            0, self.cfg.vocab_size, prompt_len).astype(np.int32),
            max_new=max_new,
            sampling=(dataclasses.replace(sampling, seed=sampling.seed + i)
                      if sampling is not None else None))
            for i in range(n_requests)]
        before = self.stats()
        t0 = time.perf_counter()
        done = self.generate(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        rate = toks / dt if dt > 0 else float("inf")  # clock can tick 0
        run = self.stats()
        for k in self._WINDOW_KEYS:
            run[k] -= before[k]
        run["decode_utilization"] = (
            run["useful_decode_tokens"] / run["slot_steps"]
            if run["slot_steps"] else 0.0)
        run["spec_acceptance"] = (
            run["spec_accepted_tokens"] / run["spec_proposed_tokens"]
            if run["spec_proposed_tokens"] else 0.0)
        run["window"] = {k: run[k] for k in self._WINDOW_KEYS}
        run["window"]["decode_utilization"] = run["decode_utilization"]
        run["window"]["spec_acceptance"] = run["spec_acceptance"]
        return {"tokens": toks, "seconds": dt, "tok_per_s": rate, **run}
