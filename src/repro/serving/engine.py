"""Batched serving engine: continuous-batching-lite request loop over the
prefill/decode steps, with MX-quantized execution (the paper's deployment
mode: LATMiX-folded weights + online T3 + quantized matmuls).

Design notes (large-scale posture):
  * slot-based batch: fixed B decode lanes; finished sequences are refilled
    from the queue (continuous batching) — one compiled decode step serves
    the whole lifetime,
  * cache allocated once at (B, max_len) rounded to the attention chunk,
  * greedy or temperature sampling,
  * per-request latency accounting for the Fig. 4 throughput benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.models import api


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 16
    out: Optional[np.ndarray] = None
    t_submit: float = 0.0
    t_done: float = 0.0


class Engine:
    """``params`` may hold dense arrays or packed-HBM ``PackedWeight``
    leaves (artifact serving, see :meth:`from_artifact`): the quantized
    execution path dequantizes packed weights lazily inside the compiled
    prefill/decode steps — or, with ``backend='fused'``, consumes the
    packed layout directly in the Pallas MX GEMM kernels (see
    ``core.quantize``)."""

    def __init__(self, params, cfg: ArchConfig, qm: QuantMode,
                 batch_size: int = 4, max_len: int = 256,
                 backend: str | None = None,
                 bucket_prompts: bool = True):
        """bucket_prompts=True rounds each wave's prompt length up to the
        attention chunk so distinct lengths reuse one prefill compile.
        Bucketed pads are left-pad tokens and are attended like the
        engine's existing ragged-wave pads (static batching, no per-row
        masks) — pass False for unpadded, per-length compiles."""
        if cfg.family == "encoder":
            raise ValueError("encoder archs are not served autoregressively")
        if backend is not None:
            qm = qm.with_backend(backend)
        self.params, self.cfg, self.qm = params, cfg, qm
        self.B = batch_size
        self.bucket_prompts = bucket_prompts
        chunk = cfg.attn_chunk
        self.max_len = (max_len + chunk - 1) // chunk * chunk
        # compile accounting: one prefill compile per distinct (B, S)
        # wave shape — bucketing in _wave keeps this set small
        self._prefill_shapes: set = set()
        self.prefill_compiles = 0

        def prefill(params, toks):
            return api.prefill(params, cfg, toks, qm, max_len=self.max_len)

        def decode(params, cache, toks, cur_len):
            logits, cache = api.decode(params, cfg, cache, toks, cur_len, qm)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    @classmethod
    def from_artifact(cls, path, batch_size: int = 4, max_len: int = 256,
                      eager: bool = False, verify: bool = True,
                      backend: str | None = None) -> "Engine":
        """Serve directly from an exported artifact directory: no
        calibration, no re-quantization — load packed bytes and go.

        eager=False keeps quantized weights 4-bit packed in HBM
        (dequantized per layer inside the compiled step); eager=True
        materializes dense fp weights once at load. backend='fused'
        routes the quantized matmuls through the packed-native Pallas
        kernels (requires eager=False to have any effect — eager loads
        are dense and fall back to the reference path)."""
        from repro.artifacts import load_artifact
        params, cfg, qm = load_artifact(path, eager=eager, verify=verify,
                                        backend=backend)
        return cls(params, cfg, qm, batch_size=batch_size, max_len=max_len)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests with static batching per wave (prompts
        padded to a common length)."""
        out = []
        for i in range(0, len(requests), self.B):
            out.extend(self._wave(requests[i:i + self.B]))
        return out

    def _bucket_len(self, s: int, max_new: int) -> int:
        """Round a wave's prompt length up to the attention chunk so the
        jitted prefill compiles once per bucket, not once per distinct
        prompt length. Buckets only when the decode budget still fits in
        the cache (otherwise the raw length is kept — old behavior).

        Bucketed waves are left-padded further than strictly needed; pads
        share the engine's existing ragged-wave semantics (left-pad tokens
        are attended — static batching, no per-row masks). Disable with
        ``Engine(..., bucket_prompts=False)``."""
        if not self.bucket_prompts:
            return s
        chunk = self.cfg.attn_chunk
        sb = (s + chunk - 1) // chunk * chunk
        while sb > s and sb + max_new > self.max_len:
            sb -= chunk
        return max(sb, s)

    def _wave(self, reqs: List[Request]) -> List[Request]:
        t0 = time.time()
        B = len(reqs)
        max_new = max(r.max_new for r in reqs)
        S = self._bucket_len(max(len(r.prompt) for r in reqs), max_new)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad

        if (B, S) not in self._prefill_shapes:
            self._prefill_shapes.add((B, S))
            self.prefill_compiles += 1
        last_logits, cache = self._prefill(self.params, jnp.asarray(toks))
        nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
        # accumulate sampled tokens on device; one host transfer at the end
        # (a per-step np.asarray would sync the dispatch pipeline every
        # decode step)
        toks_dev = [nxt]
        pos = S
        for _ in range(max_new - 1):
            nxt, cache = self._decode(self.params, cache, nxt,
                                      jnp.int32(pos))
            toks_dev.append(nxt)
            pos += 1
        host = np.asarray(jnp.stack(toks_dev, axis=1))  # (B, max_new)
        t1 = time.time()
        for i, r in enumerate(reqs):
            r.out = host[i, :r.max_new].astype(np.int32)
            r.t_submit, r.t_done = t0, t1
        return reqs

    def throughput(self, n_requests: int = 8, prompt_len: int = 32,
                   max_new: int = 32, seed: int = 0) -> dict:
        """Tokens/second over a synthetic request wave (Fig. 4 metric)."""
        rng = np.random.default_rng(seed)
        reqs = [Request(prompt=rng.integers(
            0, self.cfg.vocab_size, prompt_len).astype(np.int32),
            max_new=max_new) for _ in range(n_requests)]
        t0 = time.time()
        done = self.generate(reqs)
        dt = time.time() - t0
        toks = sum(len(r.out) for r in done)
        rate = toks / dt if dt > 0 else float("inf")  # clock can tick 0
        return {"tokens": toks, "seconds": dt, "tok_per_s": rate,
                "prefill_compiles": self.prefill_compiles,
                "backend": self.qm.backend}
