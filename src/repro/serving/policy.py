"""Request-lifecycle policy layer for the serving engine.

The engine (``repro.serving.engine``) executes requests; this module
decides *which* request runs and *when one must stop*:

* :class:`RequestState` — the lifecycle state machine. Every submitted
  request ends in exactly one terminal state (``docs/robustness.md`` has
  the full diagram):

  .. code-block:: text

      submit ─▶ QUEUED ──admit──▶ RUNNING ──▶ FINISHED  (EOS / budget)
        │         │  ▲               │ ├────▶ CANCELLED (Engine.cancel)
        │         │  └──requeue──────┤ ├────▶ TIMED_OUT (deadline)
        │         │  (retry+backoff) │ └────▶ FAILED    (NaN / never fits)
        │         ├──▶ CANCELLED     └─────▶ PREEMPTED  (retries spent)
        │         └──▶ TIMED_OUT
        └──▶ SHED   (admission control: queue/token caps — docs/server.md)

* :class:`SchedulingPolicy` — the knobs: default TTFT / end-to-end
  deadlines, the preemption switch, the retry budget and backoff for
  preempted requests, how often a decode burst is interrupted to check
  running deadlines, and the **admission-control caps**
  (``max_queue_depth`` / ``max_queue_depth_per_priority`` /
  ``admit_token_budget``) that turn overload into descriptive
  :class:`ShedError` rejections instead of unbounded queue growth.

* :class:`RequestQueue` — the admission queue: strict priority order
  (higher ``Request.priority`` first), FIFO within a priority level,
  re-admissions (preempted requests) ahead of their peers, and
  *backoff holds* — a requeued request is invisible to :meth:`pop`
  until its ``not_before`` stamp passes, so a preemption storm cannot
  thrash the same pages every step. Cancelled / expired entries are
  dropped lazily (the engine flips ``Request.state``; the queue skips
  anything no longer ``QUEUED``). ``max_depth`` bounds how many live
  entries :meth:`push` accepts (``push_front`` — the preemption
  requeue — is exempt: work already admitted once must be able to
  return).

* :class:`ShedError` — raised by ``Engine.submit`` when admission
  control rejects a request. Carries the (now terminal-``SHED``)
  request, the human-readable reason, and ``retry_after_s`` derived
  from the backoff schedule — the HTTP front end maps it to a 429
  with a ``Retry-After`` header (``docs/server.md``).

* :func:`pick_victim` — the preemption choice: among running requests
  below the admission's priority, evict the one with the least progress
  (fewest emitted tokens — cheapest to re-prefill, especially with the
  paged prefix cache), ties broken by lane for determinism.

Everything here is host-side, deterministic, and engine-agnostic — the
chaos tests drive it directly.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import math
from typing import Iterable, List, Optional, Tuple

__all__ = ["RequestState", "TERMINAL_STATES", "SchedulingPolicy",
           "RequestQueue", "ShedError", "pick_victim"]


class RequestState(enum.Enum):
    """Lifecycle states. ``value`` doubles as the metrics label."""

    QUEUED = "queued"
    RUNNING = "running"
    FINISHED = "finished"        # budget exhausted / EOS — the good end
    CANCELLED = "cancelled"      # client called Engine.cancel()
    TIMED_OUT = "timed_out"      # TTFT or end-to-end deadline exceeded
    FAILED = "failed"            # non-finite logits / can never fit
    PREEMPTED = "preempted"      # evicted and out of retry budget
    SHED = "shed"                # rejected at submit by admission control

    @property
    def terminal(self) -> bool:
        return self in TERMINAL_STATES


TERMINAL_STATES = frozenset({
    RequestState.FINISHED, RequestState.CANCELLED, RequestState.TIMED_OUT,
    RequestState.FAILED, RequestState.PREEMPTED, RequestState.SHED})


class ShedError(RuntimeError):
    """``Engine.submit`` rejected the request (admission control).

    The request is already terminal (``SHED``, counted in
    ``stats()["terminal"]`` so ``sum(terminal) == submitted`` holds);
    the caller must not retry before ``retry_after_s`` — the HTTP front
    end surfaces it as ``Retry-After`` on a 429 response."""

    def __init__(self, request, reason: str, retry_after_s: float):
        super().__init__(reason)
        self.request = request
        self.reason = reason
        self.retry_after_s = retry_after_s


@dataclasses.dataclass(frozen=True)
class SchedulingPolicy:
    """Engine-wide lifecycle policy (``Engine(policy=...)``).

    ``deadline_ms`` / ``ttft_deadline_ms`` are *defaults* applied at
    :meth:`Engine.submit` to requests that do not carry their own; None
    means no deadline. The TTFT deadline runs from submit until the
    first token is sampled (it can only expire while queued / during
    prefill admission); the end-to-end deadline runs submit → done and
    is also checked between decode bursts.

    ``preemption`` gates both preemption triggers (pool exhaustion and
    priority inversion). A preempted request is requeued with
    exponential backoff (``backoff_base_s * 2**(retries-1)``) at most
    ``max_retries`` times; the next eviction lands it in the terminal
    ``PREEMPTED`` state. Retries are *cheap*, not free: re-prefill reuses
    cached prefix pages under the paged layout.

    ``deadline_burst_cap`` bounds how many decode steps the continuous
    scheduler dispatches back-to-back while any running request carries
    a deadline — deadlines are only observable between bursts, so the
    cap is the enforcement granularity (in steps). Deadline-free traffic
    keeps the unbounded burst (one host sync per lane completion).

    ``max_queue_depth`` / ``max_queue_depth_per_priority`` /
    ``admit_token_budget`` are the **admission-control caps** checked by
    ``Engine.submit`` *before* a request enters the queue; an over-limit
    request is shed (terminal ``SHED`` state + :class:`ShedError`) with
    a ``Retry-After`` from the same backoff schedule that paces
    preemption re-admissions. All three default to None — never shed —
    so library users are unaffected unless they opt in. The token budget
    counts ``len(prompt) + max_new`` over queued requests: the worst
    case KV/compute debt admission would take on. Preemption requeues
    (``RequestQueue.push_front``) bypass submit and are exempt — work
    admitted once must always be able to return.

    ``max_prefill_lanes_per_step`` caps how many queued requests the
    continuous scheduler's *paged* admission prefills together in one
    batched chunk loop per engine step (docs/serving.md). Each chunked-
    prefill dispatch then carries up to that many lanes — per-lane
    block tables and start offsets stacked on the batch axis under one
    jit signature — instead of one lane per dispatch. ``1`` restores
    strictly serial admission (the pre-batching behavior, bit-
    identical); the contiguous layout always admits serially (its
    admission runs in a single-lane scratch cache). Batched and serial
    admission emit token-identical outputs — the knob trades host
    dispatch count against per-step latency, never results."""

    deadline_ms: Optional[float] = None
    ttft_deadline_ms: Optional[float] = None
    preemption: bool = True
    max_retries: int = 3
    backoff_base_s: float = 0.02
    deadline_burst_cap: int = 4
    max_queue_depth: Optional[int] = None
    max_queue_depth_per_priority: Optional[int] = None
    admit_token_budget: Optional[int] = None
    max_prefill_lanes_per_step: int = 4

    def backoff_s(self, retries: int) -> float:
        """Hold time before a request's ``retries``-th re-admission."""
        return self.backoff_base_s * (2.0 ** max(retries - 1, 0))

    def shed_reason(self, queue: "RequestQueue", req) -> Optional[str]:
        """Why ``req`` must be shed given the queue's current load, or
        None to admit. Checked at submit time only — never re-applied to
        requeued (already-admitted) work."""
        depth = len(queue)
        if self.max_queue_depth is not None and depth >= self.max_queue_depth:
            return (f"queue full: depth {depth} >= "
                    f"max_queue_depth {self.max_queue_depth}")
        if self.max_queue_depth_per_priority is not None:
            pdepth = queue.depth(priority=req.priority)
            if pdepth >= self.max_queue_depth_per_priority:
                return (f"priority {req.priority} lane full: depth {pdepth}"
                        f" >= max_queue_depth_per_priority "
                        f"{self.max_queue_depth_per_priority}")
        if self.admit_token_budget is not None:
            load = queue.token_load()
            cost = len(req.prompt) + req.max_new
            if load + cost > self.admit_token_budget:
                return (f"token budget exhausted: queued load {load} + "
                        f"request cost {cost} > admit_token_budget "
                        f"{self.admit_token_budget}")
        return None


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs (``Engine(spec=...)``; docs/sampling.md).

    Passing a SpecConfig turns self-drafting speculative decoding on for
    every request served by the engine (continuous scheduler only):
    each engine step, an n-gram prompt-lookup draft proposes up to ``k``
    tokens per lane and one batched verify forward scores them all.

    ``k`` is the draft length — each verify step scores ``k + 1``
    positions (current token + drafts) and emits 1..k+1 tokens.
    ``ngram_max`` / ``ngram_min`` bound the context-suffix n-gram the
    prompt-lookup drafter matches (longest match wins; the most recent
    earlier occurrence supplies the continuation). Outputs are unchanged
    by any of these knobs — greedy spec decoding is token-bit-identical
    to non-spec greedy, and sampled spec preserves the sampling
    distribution; they trade only draft cost against acceptance rate."""

    k: int = 4
    ngram_max: int = 3
    ngram_min: int = 1

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec k must be >= 1, got {self.k}")
        if not 1 <= self.ngram_min <= self.ngram_max:
            raise ValueError(
                f"need 1 <= ngram_min <= ngram_max, got "
                f"ngram_min={self.ngram_min}, ngram_max={self.ngram_max}")


class RequestQueue:
    """Priority admission queue with lazy removal and backoff holds.

    Orders by (priority desc, arrival seq asc). ``push_front`` re-admits
    ahead of same-priority peers (requeued work resumes before new work
    — no head-of-line *re*-blocking after a backpressure requeue).
    Entries whose request left the QUEUED state (cancelled, expired) are
    skipped and dropped on pop. ``pop(now)`` never returns a request
    whose ``not_before`` is in the future — those stay queued and
    :meth:`next_eligible_delay` says how long until one frees up."""

    def __init__(self, max_depth: Optional[int] = None):
        self._heap: List[Tuple[float, int, object]] = []
        self._seq = itertools.count()
        self._front_seq = itertools.count(-1, -1)
        self.max_depth = max_depth

    def full(self) -> bool:
        """True when a plain :meth:`push` would exceed ``max_depth``."""
        return self.max_depth is not None and len(self) >= self.max_depth

    def push(self, req, front: bool = False) -> None:
        if not front and self.full():
            raise OverflowError(
                f"RequestQueue full: depth {len(self)} >= "
                f"max_depth {self.max_depth}")
        seq = next(self._front_seq if front else self._seq)
        heapq.heappush(self._heap, (-float(req.priority), seq, req))

    def push_front(self, req) -> None:
        self.push(req, front=True)

    def _live(self, req) -> bool:
        return req.state == RequestState.QUEUED

    def pop(self, now: float):
        """Highest-priority eligible request, or None (empty queue or
        every live entry is in a backoff hold)."""
        held = []
        out = None
        while self._heap:
            item = heapq.heappop(self._heap)
            req = item[2]
            if not self._live(req):
                continue                      # lazy drop
            if getattr(req, "not_before", 0.0) > now:
                held.append(item)
                continue
            out = req
            break
        for item in held:
            heapq.heappush(self._heap, item)
        return out

    def peek(self, now: float):
        """Like :meth:`pop` but leaves the request queued."""
        req = self.pop(now)
        if req is not None:
            self.push_front(req)
        return req

    def next_eligible_delay(self, now: float) -> Optional[float]:
        """Seconds until the nearest backoff hold expires (0.0 if an
        entry is already eligible), or None when the queue is empty."""
        best = None
        for _, _, req in self._heap:
            if not self._live(req):
                continue
            d = max(getattr(req, "not_before", 0.0) - now, 0.0)
            best = d if best is None else min(best, d)
        return best

    def depth(self, priority: Optional[float] = None) -> int:
        """Live entry count, optionally restricted to one priority."""
        return sum(1 for _, _, r in self._heap if self._live(r)
                   and (priority is None or r.priority == priority))

    def token_load(self) -> int:
        """Worst-case token debt of queued work: sum of
        ``len(prompt) + max_new`` over live entries. O(n), fine at
        admission-queue scale."""
        return sum(len(r.prompt) + r.max_new
                   for _, _, r in self._heap if self._live(r))

    def __len__(self) -> int:
        return sum(1 for _, _, r in self._heap if self._live(r))

    def __iter__(self):
        """Live queued requests (arbitrary order — expiry scans)."""
        return (r for _, _, r in self._heap if self._live(r))


def pick_victim(candidates: Iterable[Tuple[int, object]],
                max_priority: float = math.inf) -> Optional[int]:
    """Choose the lane to preempt from ``(lane, request)`` pairs.

    Only requests with ``priority < max_priority`` are evictable (strict
    — equal-priority work is never preempted, which is what makes the
    policy livelock-free: a preemptor can never itself be preempted by
    the request it displaced). Among evictable lanes, pick the lowest
    priority; break ties by least progress (fewest emitted tokens =
    least re-prefill work thrown away), then lowest lane id. Returns the
    lane, or None when nothing is evictable."""
    best = None
    best_key = None
    for lane, req in candidates:
        if req.priority >= max_priority:
            continue
        key = (req.priority, len(getattr(req, "_gen", ()) or ()), lane)
        if best_key is None or key < best_key:
            best, best_key = lane, key
    return best
