"""Per-lane token sampling and speculative-decoding acceptance.

Three pieces, all shape-polymorphic over the lane axis so the engine can
jit them once per batch size:

- ``sample_tokens``: temperature / top-k / top-p sampling with a
  per-lane ``(seed, step)`` RNG key.  The filtering order matches the
  NeMo ``text_generation_utils.py`` reference: divide by temperature,
  then keep the top-k logits, then keep the smallest sorted prefix whose
  cumulative probability covers ``top_p`` (the rule is
  ``cum - prob <= top_p`` in sorted-descending space, which always keeps
  the most likely token).  ``temperature <= 0`` short-circuits to argmax
  of the *raw* logits, bit-identical to the greedy decode path.

- ``propose_ngram``: host-side prompt-lookup drafting.  Find the longest
  n-gram (``ngram_min <= n <= ngram_max``) whose most recent earlier
  occurrence in the context matches the context suffix, and propose the
  up-to-``k`` tokens that followed it.  Self-drafting needs no draft
  model; it wins exactly on repetitive continuations, which is also
  where speculative decoding pays off.

- ``spec_accept``: the leading-accepts rule of speculative sampling with
  a *one-hot* draft distribution.  Draft token ``x`` at slot ``j`` is
  accepted with probability ``min(1, p_j(x))`` under the target's
  filtered distribution ``p_j``; the first rejection resamples from
  ``p_j`` with ``x`` masked out (the residual of a one-hot proposal),
  and a fully accepted run earns one bonus token from the next
  position's distribution.  Greedy lanes accept iff the draft equals the
  argmax, so every emitted token is the argmax of its own position's
  logits — token-level bit-identity with non-spec greedy decoding.

RNG discipline: every draw comes from
``fold_in(fold_in(PRNGKey(seed), step), channel)`` where ``step`` is the
token's emission index (0 = the token sampled from prefill logits) and
``channel`` separates the categorical draw (0) from the acceptance
uniform (1).  Draws depend only on ``(seed, step)`` — never on batch
size, lane index, or scheduler — so admission-time sampling on a
``(1, V)`` row, decode-burst sampling on a ``(B, V)`` batch, and a
preemption-resume replay that re-seeds from the emitted-token count all
produce the same tokens.  See docs/sampling.md.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30  # matches models/layers.py masking constant
_MIN_TEMP = 1e-4


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs carried on ``Request.sampling``.

    ``temperature <= 0`` means greedy: top_k/top_p/seed are ignored and
    the decode is bit-identical to a request with no sampling at all.
    ``top_k == 0`` disables the top-k filter; ``top_p == 1.0`` disables
    the nucleus filter.  ``seed`` makes the request replayable: the same
    (prompt, params, seed) always yields the same tokens.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.seed < 0:
            raise ValueError(f"seed must be >= 0, got {self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def _key(seed, step, channel):
    """Derive the draw key for one (request, emission-index, channel)."""
    k = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    return jax.random.fold_in(jax.random.fold_in(k, step), channel)


def _filter_logits(scaled, top_k, top_p):
    """Apply top-k then top-p masks to temperature-scaled logits (V,).

    Works in sorted-descending space and scatters the keep-mask back, the
    same shape as the NeMo reference filter.  Always keeps the top-1
    token, so the filtered distribution is never empty.
    """
    V = scaled.shape[-1]
    sorted_l, sort_idx = jax.lax.top_k(scaled, V)
    rank = jnp.arange(V, dtype=jnp.int32)
    drop_k = (top_k > 0) & (rank >= top_k)
    probs = jax.nn.softmax(jnp.where(drop_k, NEG_INF, sorted_l))
    cum = jnp.cumsum(probs)
    # keep iff the cumulative mass *before* this token is within top_p;
    # the first sorted token always has cum - prob ~ 0 and survives
    drop = drop_k | ((cum - probs) > top_p)
    keep = jnp.zeros((V,), bool).at[sort_idx].set(~drop)
    return jnp.where(keep, scaled, NEG_INF)


def _sample_one(logits, temp, top_k, top_p, seed, step):
    lf = logits.astype(jnp.float32)
    gtok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    filt = _filter_logits(lf / jnp.maximum(temp, _MIN_TEMP), top_k, top_p)
    stok = jax.random.categorical(_key(seed, step, 0), filt)
    return jnp.where(temp <= 0.0, gtok, stok.astype(jnp.int32))


def sample_tokens(logits, temps, top_ks, top_ps, seeds, steps):
    """Sample one token per lane.  logits (B, V); the rest (B,).

    Greedy lanes (temp <= 0) return ``argmax(logits)`` computed on the
    raw dtype — bitwise the token the greedy closure would produce.
    """
    return jax.vmap(_sample_one)(logits, temps, top_ks, top_ps,
                                 seeds, steps)


def _spec_one(logits, drafts, n_drafts, temp, top_k, top_p, seed, step):
    """Accept/resample for one lane.  logits (C, V), drafts (K,), C=K+1.

    Returns (out (C,), n_emit, okrow (C,)): the lane emits
    ``out[:n_emit]`` — the accepted draft prefix plus one token that is
    either the rejection resample or the bonus/bootstrap sample.

    Every per-slot quantity is computed with a vmap over the slot axis
    (not a Python loop — C identical op groups would dominate the
    verify dispatch on small models).  Key derivation is the same
    ``_key(seed, step + j, channel)`` the non-spec path uses, so the
    draw at emission index ``t`` is bit-identical whether ``t`` was
    reached by plain decode or inside a verify step.
    """
    C, V = logits.shape
    K = C - 1
    greedy = temp <= 0.0
    okrow = jnp.isfinite(logits).all(axis=-1)
    iota_c = jnp.arange(C, dtype=jnp.int32)

    lf = logits.astype(jnp.float32)
    gtok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    filt = jax.vmap(_filter_logits, in_axes=(0, None, None))(
        lf / jnp.maximum(temp, _MIN_TEMP), top_k, top_p)
    keys0 = jax.vmap(lambda j: _key(seed, step + j, 0))(iota_c)
    plain = jnp.where(
        greedy, gtok,
        jax.vmap(jax.random.categorical)(keys0, filt).astype(jnp.int32))

    if K:
        keys1 = jax.vmap(lambda j: _key(seed, step + j, 1))(iota_c[:K])
        p_x = jnp.take_along_axis(jax.nn.softmax(filt[:K], axis=-1),
                                  drafts[:, None], axis=1)[:, 0]
        u = jax.vmap(lambda k: jax.random.uniform(k))(keys1)
        acc = (jnp.where(greedy, gtok[:K] == drafts, u < p_x)
               & (iota_c[:K] < n_drafts))
        # residual of a one-hot proposal: target with the draft masked
        # out (the same key as the plain draw — only one of the two is
        # ever emitted for a given step index)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (K, V), 1)
                  == drafts[:, None])
        resamp = jax.vmap(jax.random.categorical)(
            keys0[:K], jnp.where(onehot, NEG_INF, filt[:K]))
        rej = jnp.concatenate(
            [jnp.where(greedy, gtok[:K], resamp.astype(jnp.int32)),
             plain[K:]])
        # m = number of leading accepted drafts
        m = jnp.sum(jnp.cumprod(acc.astype(jnp.int32))).astype(jnp.int32)
    else:
        rej = plain
        m = jnp.int32(0)

    # slot j is emitted iff j <= m: the draft when j < m, else the
    # rejection resample (a draft existed and was refused) or the
    # plain sample (bonus after a full accept / no draft at all)
    tok = jnp.where(m < n_drafts, rej, plain)
    pad_drafts = jnp.concatenate(
        [drafts, jnp.zeros((1,), drafts.dtype)]).astype(jnp.int32)
    out = jnp.where(iota_c < m, pad_drafts, tok)
    return (out.astype(jnp.int32),
            (m + jnp.int32(1)).astype(jnp.int32), okrow)


def spec_accept(logits, drafts, n_drafts, temps, top_ks, top_ps,
                seeds, steps):
    """Vectorized speculative acceptance.

    logits (B, C, V) — verify-step logits, position j conditioned on the
    current token plus drafts[:, :j]; drafts (B, K) with K = C - 1;
    n_drafts (B,) real draft counts (0 for idle lanes); the sampling
    vectors are (B,) and ``steps`` is each lane's next emission index.
    Returns (out (B, C), n_emit (B,), okrow (B, C)).
    """
    return jax.vmap(_spec_one)(logits, drafts, n_drafts, temps,
                               top_ks, top_ps, seeds, steps)


def propose_ngram(ctx, k, ngram_max=3, ngram_min=1):
    """Prompt-lookup draft: ``k`` tokens periodically extending the most
    recent earlier occurrence of the longest matching context suffix
    n-gram.

    Host-side numpy on the request's (prompt + generated) token history.
    A hit at position ``i`` means the suffix recurred at distance
    ``p = L - n - i`` — evidence of period-``p`` structure — so the
    draft reads the continuation ``ctx[i + n + (t % p)]``, wrapping
    cyclically once it reaches the context end.  The wrap matters:
    greedy decode loves short cycles (constant runs are period 1), and
    without it the draft length is capped by how much of the current
    cycle already follows the match (a run of four identical tokens
    could only ever draft one).  Returns an int32 array of length 0 or
    ``k``; length 0 means "no match, verify step degenerates to a plain
    decode step".
    """
    ctx = np.asarray(ctx, dtype=np.int64).ravel()
    L = ctx.size
    if L < 2 or k <= 0:
        return np.zeros(0, np.int32)
    lo = max(int(ngram_min), 1)
    hi = min(int(ngram_max), L - 1)
    for n in range(hi, lo - 1, -1):
        pat = ctx[L - n:]
        win = np.lib.stride_tricks.sliding_window_view(ctx, n)
        hits = np.flatnonzero((win[:L - n] == pat).all(axis=1))
        if hits.size:
            i = int(hits[-1])
            p = L - n - i                      # implied period, >= 1
            t = np.arange(k)
            return ctx[i + n + (t % p)].astype(np.int32)
    return np.zeros(0, np.int32)
