"""Deterministic fault injection for the serving engine.

Chaos testing a serving loop is only useful if a failing run can be
*replayed*: every fault here fires at a scripted invocation count or
from a seeded per-rule RNG — never from wall clock — so a scenario is a
pure function of (workload, fault plan, seed).

Usage::

    fi = FaultInjector(seed=0)
    fi.inject("nan_logits", at=5, lane=1)       # 6th decode step, lane 1
    fi.inject("alloc_exhausted", at=0, times=2) # first two page allocs
    fi.inject("slow_step", every=4, delay_s=0.01)
    eng = Engine(..., faults=fi)

The engine calls :meth:`fire` at each **injection point**; ``fire``
returns the rule's payload dict when a fault should trigger there (and
logs it), else None. Points registered in the engine:

===================  ======================================================
point                effect when fired
===================  ======================================================
``alloc_exhausted``  the paged BlockAllocator reports exhaustion for this
                     allocation (backpressure / preemption path), pages
                     untouched
``evict_cache``      every cached (unreferenced) prefix page is evicted
                     before admission this step — forced cold cache
``nan_logits``       lane ``payload["lane"]`` gets NaN logits on this
                     decode step (the per-lane guard must fail only that
                     request)
``slow_step``        the engine sleeps ``payload["delay_s"]`` seconds at
                     the top of this step (drives deadline expiry
                     deterministically)
``corrupt_artifact`` not wired into the engine — tests fire it themselves
                     and apply :func:`corrupt_file` to an artifact copy
===================  ======================================================

Points registered in the HTTP server (``repro.serving.server``, checked
by the supervisor worker and the SSE writer — docs/server.md):

===================  ======================================================
point                effect when fired
===================  ======================================================
``stuck_step``       the supervisor worker hangs *before* the next engine
                     step for up to ``payload["hang_s"]`` seconds (it
                     wakes early on the watchdog's abort signal), then
                     raises ``StuckStepError`` — exercises watchdog
                     detection + loop restart
``failed_step``      the supervisor worker raises ``RuntimeError`` in
                     place of the next engine step — exercises the
                     fail-poisoned-lane + requeue-bystanders recovery
``disconnect``       the SSE connection is force-closed before writing
                     the next event (``at=N`` = drop after N events) —
                     exercises mid-stream cancel
``slow_consumer``    the SSE writer sleeps ``payload["delay_s"]`` before
                     each flush — drives the bounded buffer into
                     coalesced-flush degradation
===================  ======================================================

Rules are matched against the point's own invocation counter (the
``at``-th call, every ``every``-th call, or an independent seeded
coin-flip with probability ``prob``), fire at most ``times`` times
(default: ``at`` rules once, others unbounded), and record every firing
in :attr:`log` for post-hoc assertions.

:func:`corrupt_file` is the artifact-corruption hook: byte flips or
truncation, seeded, for exercising the loader's integrity errors
(``docs/robustness.md``). It refuses to touch a path outside the
directory you pass as ``within`` — chaos tests corrupt *copies*.
"""
from __future__ import annotations

import dataclasses
import pathlib
import random
from typing import Dict, List, Optional, Tuple

__all__ = ["FaultInjector", "corrupt_file"]


@dataclasses.dataclass
class _Rule:
    point: str
    at: Optional[int]
    every: Optional[int]
    prob: Optional[float]
    times: Optional[int]          # None = unbounded
    payload: dict
    rng: random.Random
    fired: int = 0

    def matches(self, count: int) -> bool:
        if self.times is not None and self.fired >= self.times:
            return False
        if self.at is not None:
            # fires on invocations at, at+1, ... until `times` exhausted
            return count >= self.at
        if self.every is not None:
            return self.every > 0 and count % self.every == self.every - 1
        if self.prob is not None:
            return self.rng.random() < self.prob
        return True                # unconditional (bounded by times)


class FaultInjector:
    """Seeded, scripted fault plan. See module docstring for the point
    vocabulary; :meth:`fire` is the only engine-facing call."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._rules: List[_Rule] = []
        self._counts: Dict[str, int] = {}
        #: every firing as (point, invocation_index, payload) — chaos
        #: tests replay/assert against this
        self.log: List[Tuple[str, int, dict]] = []

    def inject(self, point: str, at: Optional[int] = None,
               every: Optional[int] = None, prob: Optional[float] = None,
               times: Optional[int] = None, **payload) -> "FaultInjector":
        """Register a rule for ``point``. At most one of ``at`` (fire
        from that invocation index on), ``every`` (fire each N-th
        invocation), ``prob`` (seeded coin flip per invocation) may be
        given; none means fire on every invocation. ``times`` caps total
        firings (defaults to 1 for ``at`` rules — i.e. fire exactly on
        invocation ``at`` — unbounded otherwise). Returns self for
        chaining."""
        if sum(x is not None for x in (at, every, prob)) > 1:
            raise ValueError("give at most one of at/every/prob")
        if times is None and at is not None:
            times = 1
        # per-rule RNG: deterministic regardless of other points' traffic
        rng = random.Random((self.seed, point, len(self._rules)).__hash__())
        self._rules.append(_Rule(point, at, every, prob, times,
                                 dict(payload), rng))
        return self

    def fire(self, point: str, **context) -> Optional[dict]:
        """Called by the engine at injection point ``point``; returns the
        payload of the first matching rule (merged over ``context``), or
        None. Increments the point's invocation counter either way."""
        n = self._counts.get(point, 0)
        self._counts[point] = n + 1
        for rule in self._rules:
            if rule.point != point:
                continue
            if rule.matches(n):
                rule.fired += 1
                payload = {**context, **rule.payload}
                self.log.append((point, n, payload))
                return payload
        return None

    def fired(self, point: str) -> int:
        """How many times ``point`` actually injected a fault."""
        return sum(1 for p, _, _ in self.log if p == point)

    def calls(self, point: str) -> int:
        """How many times the engine *reached* ``point``."""
        return self._counts.get(point, 0)

    def summary(self) -> dict:
        return {"seed": self.seed,
                "points": dict(self._counts),
                "fired": {p: self.fired(p)
                          for p in {r.point for r in self._rules}},
                "log": [{"point": p, "n": n, "payload": pl}
                        for p, n, pl in self.log]}


def corrupt_file(path, *, mode: str = "flip", offset: Optional[int] = None,
                 nbytes: int = 1, seed: int = 0, within=None) -> dict:
    """Deterministically damage a file — the artifact-corruption hook.

    mode='flip' XORs ``nbytes`` bytes at ``offset`` (seeded-random
    position past the zip header when None) with 0xFF; mode='truncate'
    cuts the file to ``offset`` bytes (seeded-random fraction when
    None). Returns ``{"mode", "offset", "nbytes", "size"}`` describing
    what was done so a test can report it.

    Safety: refuses paths outside ``within`` when given (tests pass the
    tmp copy's directory), and always requires the file to exist."""
    p = pathlib.Path(path)
    if within is not None:
        if pathlib.Path(within).resolve() not in p.resolve().parents:
            raise ValueError(f"refusing to corrupt {p} outside {within}")
    data = bytearray(p.read_bytes())
    if not data:
        raise ValueError(f"{p} is empty — nothing to corrupt")
    rng = random.Random(seed)
    if mode == "flip":
        off = rng.randrange(min(len(data) - 1, 64),
                            len(data)) if offset is None else offset
        for i in range(off, min(off + nbytes, len(data))):
            data[i] ^= 0xFF
        p.write_bytes(bytes(data))
        return {"mode": mode, "offset": off, "nbytes": nbytes,
                "size": len(data)}
    if mode == "truncate":
        off = (rng.randrange(1, len(data)) if offset is None
               else min(offset, len(data)))
        p.write_bytes(bytes(data[:off]))
        return {"mode": mode, "offset": off, "nbytes": len(data) - off,
                "size": off}
    raise ValueError(f"unknown corruption mode {mode!r} "
                     f"(expected 'flip' or 'truncate')")
