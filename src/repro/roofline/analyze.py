import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline analysis (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e):  peak 197 TFLOP/s bf16 / chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Terms per (arch × shape) on the single-pod mesh, all per-device:

  compute    = FLOPs / 197e12
  memory     = HBM bytes accessed / 819e9
  collective = collective bytes / 50e9

XLA's cost_analysis counts a while-loop body ONCE, so the scanned dry-run
numbers undercount by the trip count. We therefore lower *unrolled*
reduced-layer variants (L₁ and L₂ layers) of every cell on the same mesh
and extrapolate:  total = f(L₁) + (units − 1)·(f(L₂) − f(L₁)), where a
"unit" is a layer (dense/moe/ssm/encoder/vlm) or a (rec,rec,attn)
super-block (hybrid; the rec tail is inside both lowerings and lands in
the intercept). Gradient-accumulation scans are handled the same way: the
variants run one microbatch (accum=1) and the result is scaled by accum,
with the (once-per-step) optimizer bytes added back analytically.

MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill & decode), N_active for MoE —
the "useful" fraction MODEL_FLOPS / HLO_FLOPS exposes remat/attention/
quantizer overhead.
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax

from repro import configs
from repro.configs.base import SHAPES, ShapeConfig, shape_applicable
from repro.launch import dryrun as dr
from repro.launch import mesh as mesh_lib
from repro.launch import pcontext as pctx

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def _variant_layers(cfg):
    if cfg.family == "hybrid":
        # keep the rec tail in both variants: units = super-blocks
        tail = cfg.n_tail_rec
        return 3 + tail, 6 + tail, cfg.n_super_blocks
    return 1, 2, cfg.n_layers


def _lower_variant(cfg, shape, mesh, quant, accum_used, baked=False):
    """Lower one unrolled variant; return per-device (flops, bytes, coll)."""
    step_shape = shape
    if shape.kind == "train" and accum_used > 1:
        step_shape = ShapeConfig(shape.name, shape.seq_len,
                                 shape.global_batch // accum_used, "train")
    step, in_sh, out_sh, args, _ = dr.build_cell(cfg, step_shape, mesh,
                                                 quant, accum="1",
                                                 baked=baked)
    seq_ax = "model" if shape.kind == "train" else None
    with mesh, pctx.activate(mesh, batch_axes=mesh_lib.dp_axes(mesh),
                             model_axis="model", seq_axis=seq_ax):
        compiled = jax.jit(step, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
        ca = compiled.cost_analysis() or {}
        coll = dr.parse_collectives(compiled.as_text())
    coll_bytes = sum(v["bytes"] for v in coll.values())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), coll_bytes, coll)


def analyze_cell(arch: str, shape_name: str, quant: bool = True,
                 arch_cfg=None, label: str = "", baked: bool = False) -> dict:
    cfg0 = arch_cfg or configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg0, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    l1, l2, units = _variant_layers(cfg0)
    accum = 1
    if shape.kind == "train":
        aid = arch.replace("-", "_").replace(".", "_")
        accum = dr.ACCUM.get(aid, 1)
        dp_total = mesh.shape["data"]
        per_dev = max(1, shape.global_batch // dp_total)
        while accum > 1 and (shape.global_batch % accum
                             or (shape.global_batch // accum) % dp_total):
            accum //= 2
        accum = min(accum, per_dev)

    res = {}
    for tag, L in (("l1", l1), ("l2", l2)):
        cfg = dataclasses.replace(cfg0, n_layers=L, scan_layers=False)
        res[tag] = _lower_variant(cfg, shape, mesh, quant, accum, baked)

    def extrap(i):
        per_unit = res["l2"][i] - res["l1"][i]
        return res["l1"][i] + (units - 1) * per_unit

    flops = extrap(0) * accum
    bytes_hbm = extrap(1) * accum
    coll_bytes = extrap(2) * accum
    n_dev = mesh.size

    if shape.kind == "train":
        # optimizer runs once per step but is inside each variant: remove
        # the double count and re-add once (analytic: p bf16 r/w, m,v f32
        # r/w, grad f32 read ≈ 24 B/param, per-device share).
        opt_bytes = 24.0 * cfg0.param_count() / n_dev
        bytes_hbm = bytes_hbm - (accum - 1) * opt_bytes

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    n_param = cfg0.param_count(active_only=True)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        model_flops = 6.0 * n_param * B * S
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_param * B * S
    else:
        model_flops = 2.0 * n_param * B        # one token per sequence
    model_flops_dev = model_flops / n_dev
    useful = model_flops_dev / max(flops, 1.0)
    bound = max(terms.values())
    if shape.kind == "decode":
        # decode is bandwidth-bound by construction: the right roofline
        # fraction is ideal bytes (params once + cache once) / HLO bytes.
        from repro.core import mx as mxlib
        if quant:
            pbytes = cfg0.param_count() * (4.25 / 8)   # packed 4-bit + scales
        else:
            pbytes = cfg0.param_count() * 2            # bf16
        cache_bytes = _cache_bytes(cfg0, B, S)
        ideal = (pbytes + cache_bytes) / n_dev
        roofline_frac = ideal / max(bytes_hbm, 1.0)
    else:
        roofline_frac = (model_flops_dev / PEAK_FLOPS) / max(bound, 1e-30)

    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "label": label or "baseline",
        "quant": bool(quant and shape.kind != "train"),
        "accum": accum, "units": units,
        "flops_per_device": flops,
        "hbm_bytes_per_device": bytes_hbm,
        "collective_bytes_per_device": coll_bytes,
        "collectives_l2": res["l2"][3],
        "terms_s": {k: v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "roofline_fraction": roofline_frac,
        "step_time_lower_bound_s": bound,
    }


def _cache_bytes(cfg, batch: int, seq: int) -> float:
    """Bytes of the decode cache (read once per step, ideally)."""
    if cfg.family == "ssm":
        return (cfg.n_layers * batch
                * (cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4
                   + cfg.conv_dim * (cfg.conv_kernel - 1) * 2))
    if cfg.family == "hybrid":
        a = min(seq, cfg.window)
        return (cfg.n_super_blocks * batch * a * cfg.kv_dim * 2 * 2
                + cfg.n_rec_layers * batch * cfg.lru_width
                * (4 + 2 * (cfg.conv_kernel - 1)))
    return cfg.n_layers * batch * seq * cfg.kv_dim * 2 * 2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    from repro.configs.base import ASSIGNED_SHAPES
    archs = configs.ARCH_IDS if args.arch == "all" else [
        configs.canonical(args.arch)]
    shapes = (list(ASSIGNED_SHAPES) if args.shape == "all"
              else [args.shape])
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    rows = []
    for arch in archs:
        for shp in shapes:
            t0 = time.time()
            try:
                r = analyze_cell(arch, shp, baked=True)
            except Exception as e:  # noqa: BLE001
                r = {"arch": arch, "shape": shp, "status": "failed",
                     "error": f"{type(e).__name__}: {e}"}
            rows.append(r)
            if r["status"] == "ok":
                print(f"{arch:22s} {shp:12s} dom={r['dominant']:10s} "
                      f"cmp={r['terms_s']['compute']*1e3:8.2f}ms "
                      f"mem={r['terms_s']['memory']*1e3:8.2f}ms "
                      f"col={r['terms_s']['collective']*1e3:8.2f}ms "
                      f"frac={r['roofline_fraction']:.3f} "
                      f"({time.time()-t0:.0f}s)", flush=True)
            else:
                print(f"{arch:22s} {shp:12s} {r['status']}: "
                      f"{r.get('reason', r.get('error', ''))[:80]}",
                      flush=True)
            (outdir / f"{arch}__{shp}.json").write_text(
                json.dumps(r, indent=1))
    (outdir / "table.json").write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
