"""AdamW + gradient clipping + schedules, from scratch (no optax here).

Optimizer state is a pytree mirroring the params (so it inherits the param
sharding — ZeRO-3-equivalent under our FSDP param specs)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # 'cosine' | 'constant' | 'linear'
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    # cosine warmup starts at 0.1x (paper D.1: start/end factors 0.1 -> 1)
    warm = 0.1 + 0.9 * warm
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip(s / max(cfg.total_steps, 1), 0.0, 1.0)
        decay = 1.0 - (1.0 - cfg.min_lr_frac) * frac
    else:
        frac = jnp.clip(s / max(cfg.total_steps, 1), 0.0, 1.0)
        decay = (cfg.min_lr_frac + (1.0 - cfg.min_lr_frac)
                 * 0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
    return cfg.lr * warm * decay


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        tree, jnp.float32(0))
    return jnp.sqrt(sq)


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig,
                  mask: Optional[Callable] = None):
    """One AdamW step. ``mask(path_leaf)`` may disable weight decay (we
    decay only >=2D leaves by default, the usual matrix-only rule)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = schedule_lr(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            decay_on = p.ndim >= 2 if mask is None else mask(p)
            delta = delta + (cfg.weight_decay * p.astype(jnp.float32)
                             if decay_on else 0.0)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gnorm, "lr": lr}
