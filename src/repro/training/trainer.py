"""Distributed training loop: pjit'd train step, gradient accumulation,
activation checkpointing (cfg.remat), deterministic-by-step data,
checkpoint/resume, straggler watchdog, and a failure-injection hook used
by the fault-tolerance tests.

Single-process on CPU here; on a cluster the same code runs under
``jax.distributed.initialize`` (scripts/launch_pod.sh) with the mesh from
``make_production_mesh`` — nothing in the loop is host-count-dependent.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import synthetic
from repro.launch import mesh as mesh_lib
from repro.launch import pcontext as pctx
from repro.launch import shardings as sh
from repro.launch import steps as steps_lib
from repro.models import api
from repro.training import checkpoint as ckpt_lib
from repro.training import optimizer as opt


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    batch_size: int = 8
    seq_len: int = 128
    accum: int = 1
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    watchdog_factor: float = 10.0   # straggler alarm: step > factor×median
    opt: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)


class Trainer:
    def __init__(self, cfg: ArchConfig, tc: TrainConfig,
                 mesh=None, log: Callable[[str], None] = print):
        self.cfg, self.tc, self.log = cfg, tc, log
        self.mesh = mesh or mesh_lib.make_host_mesh(
            data=len(jax.devices()), model=1)
        self.source = synthetic.make_source(cfg, tc.batch_size, tc.seq_len,
                                            tc.seed)
        self.step_fn = None
        self.params = None
        self.opt_state = None
        self.step = 0
        self.metrics = []

    # -- setup ---------------------------------------------------------------
    def init_or_resume(self):
        key = jax.random.PRNGKey(self.tc.seed)
        dtype = steps_lib.param_dtype(self.cfg)
        aparams = steps_lib.abstract_params(self.cfg)
        psh = sh.params_shardings(aparams, self.cfg, "train", self.mesh)
        latest = ckpt_lib.latest_step(self.tc.ckpt_dir)
        if latest is not None:
            tree_like = {"params": aparams,
                         "opt": steps_lib.abstract_opt_state(self.cfg)}
            shards = {"params": psh,
                      "opt": sh.opt_state_shardings(
                          tree_like["opt"], psh, self.mesh)}
            restored, manifest = ckpt_lib.restore(
                self.tc.ckpt_dir, tree_like, shardings=shards)
            self.params = restored["params"]
            self.opt_state = restored["opt"]
            self.step = int(manifest["step"])
            self.log(f"[trainer] resumed from step {self.step}")
        else:
            init = jax.jit(lambda k: api.init(k, self.cfg, dtype),
                           out_shardings=psh)
            self.params = init(key)
            self.opt_state = jax.jit(opt.init_state,
                                     out_shardings=sh.opt_state_shardings(
                                         steps_lib.abstract_opt_state(
                                             self.cfg), psh,
                                         self.mesh))(self.params)
        raw = steps_lib.make_train_step(self.cfg, self.tc.opt,
                                        accum=self.tc.accum)
        from jax.sharding import NamedSharding, PartitionSpec as P
        scalar = NamedSharding(self.mesh, P())
        osh = sh.opt_state_shardings(
            steps_lib.abstract_opt_state(self.cfg), psh, self.mesh)
        self.step_fn = jax.jit(
            raw, in_shardings=(psh, osh, sh.train_batch_shardings(
                self.cfg, _shape_of(self.tc), self.mesh)),
            out_shardings=(psh, osh, scalar, scalar),
            donate_argnums=(0, 1))

    # -- loop ----------------------------------------------------------------
    def train(self, fail_at: Optional[int] = None):
        """Run to tc.steps. ``fail_at`` raises mid-run (fault-injection for
        the restart tests)."""
        if self.step_fn is None:
            self.init_or_resume()
        times = []
        with self.mesh, pctx.activate(
                self.mesh, batch_axes=mesh_lib.dp_axes(self.mesh),
                model_axis=mesh_lib.model_axis(self.mesh),
                seq_axis=None):
            while self.step < self.tc.steps:
                if fail_at is not None and self.step == fail_at:
                    raise RuntimeError(f"injected failure at {self.step}")
                t0 = time.time()
                batch = {k: jnp.asarray(v) for k, v in
                         self.source.batch(self.step).items()}
                self.params, self.opt_state, loss, gnorm = self.step_fn(
                    self.params, self.opt_state, batch)
                loss = float(loss)
                dt = time.time() - t0
                times.append(dt)
                med = sorted(times)[len(times) // 2]
                if (len(times) > 5 and dt > self.tc.watchdog_factor * med):
                    self.log(f"[watchdog] step {self.step} took {dt:.2f}s "
                             f"(median {med:.2f}s) — straggler suspected")
                self.step += 1
                if self.step % self.tc.log_every == 0:
                    self.metrics.append({"step": self.step, "loss": loss})
                    self.log(f"[trainer] step {self.step:5d} "
                             f"loss={loss:.4f} ({dt:.2f}s)")
                if self.step % self.tc.ckpt_every == 0 or \
                        self.step == self.tc.steps:
                    self.save()
        return self.metrics

    def save(self):
        ckpt_lib.save(self.tc.ckpt_dir, self.step,
                      {"params": self.params, "opt": self.opt_state},
                      keep=self.tc.keep,
                      extra={"arch": self.cfg.name})

    def eval_ppl(self, n_batches: int = 2) -> float:
        tot, cnt = 0.0, 0
        for i in range(1000, 1000 + n_batches):
            b = self.source.batch(i)
            logits = api.forward(self.params, self.cfg,
                                 jnp.asarray(b["inputs"]))
            nll = api.cross_entropy(logits, jnp.asarray(b["labels"]))
            tot += float(nll)
            cnt += 1
        import math
        return math.exp(tot / cnt)


def _shape_of(tc: TrainConfig):
    from repro.configs.base import ShapeConfig
    return ShapeConfig("custom", tc.seq_len, tc.batch_size, "train")
