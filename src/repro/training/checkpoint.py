"""Fault-tolerant checkpointing.

Design (1000+-node posture, DESIGN.md §3):
  * atomic writes: tmp directory + os.replace (a crash mid-write can never
    corrupt the latest checkpoint),
  * mesh-independent storage: host numpy arrays + a JSON manifest of the
    pytree structure — any mesh whose axes divide the dims can reload
    (elastic rescale),
  * keep-last-N retention, monotonically-numbered steps, auto-resume via
    ``latest_step``,
  * deterministic data replay: the trainer stores the step number; the
    synthetic pipeline is keyed by step, so a restart replays exactly.

On a real cluster every host writes only the shards it owns (via
``jax.experimental.multihost_utils``); on a single host this degrades to
full arrays, which is what we exercise here.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else
            (f"[{p.idx}]" if hasattr(p, "idx") else str(p)) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(ckpt_dir: str | pathlib.Path, step: int, tree: Any,
         keep: int = 3, extra: Optional[dict] = None) -> pathlib.Path:
    """Atomically persist ``tree`` as checkpoint ``step``."""
    root = pathlib.Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f".tmp_step_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    final = root / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)            # atomic on POSIX
    _retain(root, keep)
    return final


def _retain(root: pathlib.Path, keep: int):
    steps = sorted(p for p in root.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | pathlib.Path) -> Optional[int]:
    root = pathlib.Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str | pathlib.Path, tree_like: Any,
            step: Optional[int] = None,
            shardings: Any = None) -> tuple:
    """Load into the structure of ``tree_like``. If ``shardings`` is given
    (a matching pytree of NamedSharding), leaves are placed sharded —
    this is the elastic-rescale path (storage is mesh-independent)."""
    root = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = np.load(d / "arrays.npz")

    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree_like)
    flat_paths, treedef = leaves_with_path
    out = []
    sh_flat = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else None)
    for i, (path, leaf) in enumerate(flat_paths):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else
            (f"[{p.idx}]" if hasattr(p, "idx") else str(p)) for p in path)
        arr = arrays[key]
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        if sh_flat is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), sh_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), out)
    return tree, manifest
