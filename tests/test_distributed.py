"""Distribution correctness on a small host mesh (subprocess with 8 fake
CPU devices so the main test process keeps its single-device view):
sharded train step == unsharded train step; serve step shardability;
elastic checkpoint reload across meshes."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

REPO = pathlib.Path(__file__).resolve().parent.parent

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import ArchConfig, ShapeConfig
    from repro.data import synthetic
    from repro.launch import mesh as mesh_lib, pcontext as pctx
    from repro.launch import shardings as sh, steps as steps_lib
    from repro.models import api
    from repro.training import optimizer as opt

    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                     attn_chunk=64)
    mesh = mesh_lib.make_mesh((4, 2), ("data", "model"))
    params = api.init(jax.random.PRNGKey(0), cfg)
    state = opt.init_state(params)
    src = synthetic.make_source(cfg, 8, 32, 0)
    batch = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
    step = steps_lib.make_train_step(cfg, opt.AdamWConfig(lr=1e-3),
                                     accum=2)

    # unsharded reference
    p1, s1, loss1, g1 = step(params, state, batch)

    # sharded
    psh = sh.params_shardings(params, cfg, "train", mesh)
    osh = sh.opt_state_shardings(state, psh, mesh)
    bsh = sh.train_batch_shardings(
        cfg, ShapeConfig("t", 32, 8, "train"), mesh)
    scalar = NamedSharding(mesh, P())
    with mesh, pctx.activate(mesh, batch_axes=("data",),
                             model_axis="model", seq_axis="model"):
        jstep = jax.jit(step, in_shardings=(psh, osh, bsh),
                        out_shardings=(psh, osh, scalar, scalar))
        p2, s2, loss2, g2 = jstep(params, state, batch)

    dl = abs(float(loss1) - float(loss2))
    dp = max(float(jnp.max(jnp.abs(a - b.astype(a.dtype))))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))

    # serve step sharded
    last, cache = api.prefill(params, cfg, batch["inputs"], max_len=64)
    serve = steps_lib.make_serve_step(cfg)
    csh = sh.cache_shardings(cache, cfg, 8, mesh)
    with mesh, pctx.activate(mesh, batch_axes=("data",),
                             model_axis="model"):
        jserve = jax.jit(serve, in_shardings=(psh, csh,
                                              NamedSharding(mesh, P("data")),
                                              scalar),
                         out_shardings=(NamedSharding(mesh, P("data")),
                                        csh))
        tok_sharded, _ = jserve(params, cache,
                                jnp.zeros((8,), jnp.int32), jnp.int32(32))
    tok_ref, _ = serve(params, cache, jnp.zeros((8,), jnp.int32),
                       jnp.int32(32))
    dserve = int(jnp.sum(tok_sharded != tok_ref))

    print(json.dumps({"dl": dl, "dp": dp, "dserve": dserve}))
""")


def test_sharded_equals_unsharded():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["dl"] < 1e-4, res
    assert res["dp"] < 5e-3, res
    assert res["dserve"] == 0, res


def test_elastic_checkpoint_reload(tmp_path):
    """Checkpoints are mesh-independent: save unsharded, reload under a
    different mesh with shardings applied."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ArchConfig
        from repro.launch import shardings as sh
        from repro.models import api
        from repro.training import checkpoint as ckpt
        cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128)
        params = api.init(jax.random.PRNGKey(0), cfg)
        ckpt.save({str(tmp_path)!r}, 7, params)
        from repro.launch import mesh as mesh_lib
        mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
        psh = sh.params_shardings(params, cfg, "train", mesh)
        restored, man = ckpt.restore({str(tmp_path)!r}, params, shardings=psh)
        assert man["step"] == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
