"""Sampling + speculative decoding: statistical correctness suite.

Pins the three guarantees docs/sampling.md makes:

1. The jitted per-lane sampler draws from the *right distribution*:
   seeded chi-squared tests of temperature / top-k / top-p draws against
   a float64 numpy softmax reference over small vocabularies, plus
   exact-support checks (a draw outside the filtered set is an instant
   failure, not a statistical one).
2. ``temperature=0`` is *bit-identical* to the pre-sampling greedy
   engine on both schedulers and both KV layouts — including mixed
   batches where greedy lanes share a decode dispatch with sampled ones.
3. Speculative decoding *preserves outputs*: greedy spec decode is
   token-bit-identical to non-spec greedy (both layouts), sampled spec
   passes a two-sample frequency test against non-spec sampling at the
   same ``SamplingParams``, rollback never leaks a page
   (``BlockAllocator.check()`` after every engine step), and a
   preemption-resume replays a sampled request's tail deterministically.

Statistical tests are seeded (no flakiness: same jax version -> same
draws) and marked ``slow`` so CI can run them as their own job
(``pytest -m slow``).  Acceptance thresholds use alpha = 1e-3 critical
values from the Wilson-Hilferty approximation — no scipy dependency.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.models import api
from repro.serving.engine import Engine, Request
from repro.serving.policy import (RequestState, SchedulingPolicy,
                                  SpecConfig)
from repro.serving.sampling import (GREEDY, SamplingParams, propose_ngram,
                                    sample_tokens, spec_accept)

slow = pytest.mark.slow


# ---------------------------------------------------------------------------
# Statistical helpers (numpy reference + chi-squared machinery)
# ---------------------------------------------------------------------------

def _chi2_crit(df: int, z: float = 3.0902) -> float:
    """Upper critical value of chi2(df) at alpha ~= 1e-3 via the
    Wilson-Hilferty cube approximation (within ~1% of exact for the
    dof range used here; errs slightly permissive)."""
    assert df >= 1
    c = 2.0 / (9.0 * df)
    return df * (1.0 - c + z * np.sqrt(c)) ** 3


def _ref_filtered_probs(logits, temp, top_k, top_p):
    """float64 numpy mirror of sampling._filter_logits + softmax: the
    NeMo-ordered filter — scale by temperature, keep top-k, keep the
    smallest sorted prefix with ``cum - prob <= top_p`` (top-1 always
    survives) — then softmax over the kept set."""
    scaled = np.asarray(logits, np.float64) / max(temp, 1e-4)
    V = scaled.size
    order = np.argsort(-scaled, kind="stable")   # jax top_k tie order
    s = scaled[order]
    drop_k = np.zeros(V, bool)
    if top_k > 0:
        drop_k[top_k:] = True
    e = np.exp(s - s[~drop_k].max())
    e[drop_k] = 0.0
    probs = e / e.sum()
    cum = np.cumsum(probs)
    drop = drop_k | ((cum - probs) > top_p)
    keep = np.zeros(V, bool)
    keep[order[~drop]] = True
    out = np.zeros(V)
    kept = scaled[keep]
    ee = np.exp(kept - kept.max())
    out[keep] = ee / ee.sum()
    return out


def _chi2_vs_ref(counts, probs):
    """One-sample chi-squared of observed counts against reference
    probabilities; expected bins below 5 are merged into one. Returns
    (stat, df). Draws on zero-probability tokens are asserted out
    before the statistic (exact support check)."""
    counts = np.asarray(counts, np.float64)
    assert counts[probs == 0].sum() == 0, \
        "draw outside the filtered support"
    if (probs > 0).sum() == 1:           # degenerate support: exact
        return 0.0, 1
    n = counts.sum()
    e = n * probs[probs > 0]
    o = counts[probs > 0]
    big = e >= 5
    stat = float((((o - e) ** 2 / e)[big]).sum())
    df = int(big.sum()) - 1
    if (~big).any():
        eo, oo = e[~big].sum(), o[~big].sum()
        stat += (oo - eo) ** 2 / eo
        df += 1
    assert df >= 1
    return stat, df


def _two_sample_chi2(c1, c2, min_bin=8):
    """Two-sample chi-squared over a shared support; bins with combined
    count < min_bin merge into a rest bin. Returns (stat, df)."""
    c1 = np.asarray(c1, np.float64)
    c2 = np.asarray(c2, np.float64)
    tot = c1 + c2
    big = tot >= min_bin
    o1 = np.append(c1[big], c1[~big].sum())
    o2 = np.append(c2[big], c2[~big].sum())
    use = (o1 + o2) > 0
    o1, o2 = o1[use], o2[use]
    n1, n2 = o1.sum(), o2.sum()
    p = (o1 + o2) / (n1 + n2)
    stat = float((((o1 - n1 * p) ** 2) / (n1 * p)).sum()
                 + (((o2 - n2 * p) ** 2) / (n2 * p)).sum())
    df = max(len(o1) - 1, 1)
    return stat, df


def _draw_counts(logits_row, sp: SamplingParams, n: int, seed0: int = 0):
    """n independent draws from one logits row: lane i uses seed
    seed0 + i at emission index 0 (draws depend only on (seed, step),
    so distinct seeds are the independence axis)."""
    V = logits_row.shape[-1]
    lg = jnp.tile(jnp.asarray(logits_row, jnp.float32)[None], (n, 1))
    toks = sample_tokens(
        lg,
        jnp.full((n,), sp.temperature, jnp.float32),
        jnp.full((n,), sp.top_k, jnp.int32),
        jnp.full((n,), sp.top_p, jnp.float32),
        jnp.arange(seed0, seed0 + n, dtype=jnp.uint32),
        jnp.zeros((n,), jnp.int32))
    return np.bincount(np.asarray(toks), minlength=V)


# ---------------------------------------------------------------------------
# Sampler distribution: chi-squared vs the numpy softmax reference
# ---------------------------------------------------------------------------

@slow
@pytest.mark.parametrize("temp", [0.7, 1.0, 1.6])
def test_temperature_matches_softmax_reference(temp):
    rng = np.random.default_rng(0)
    logits = rng.normal(0.0, 1.0, 8).astype(np.float32)
    sp = SamplingParams(temperature=temp)
    counts = _draw_counts(logits, sp, 8000)
    ref = _ref_filtered_probs(logits, temp, 0, 1.0)
    stat, df = _chi2_vs_ref(counts, ref)
    assert stat < _chi2_crit(df), (stat, df, counts, ref)


@slow
@pytest.mark.parametrize("top_k", [1, 3, 5])
def test_top_k_support_and_frequencies(top_k):
    rng = np.random.default_rng(1)
    logits = rng.normal(0.0, 1.5, 16).astype(np.float32)
    sp = SamplingParams(temperature=1.0, top_k=top_k)
    counts = _draw_counts(logits, sp, 8000, seed0=10_000)
    ref = _ref_filtered_probs(logits, 1.0, top_k, 1.0)
    assert (ref > 0).sum() == top_k           # exact support size
    stat, df = _chi2_vs_ref(counts, ref) if top_k > 1 else (0.0, 1)
    if top_k == 1:                            # degenerate: exact check
        assert counts[int(np.argmax(logits))] == 8000
    else:
        assert stat < _chi2_crit(df), (stat, df, counts, ref)


@slow
@pytest.mark.parametrize("top_p", [0.3, 0.6, 0.9])
def test_top_p_nucleus_support_and_frequencies(top_p):
    rng = np.random.default_rng(2)
    logits = rng.normal(0.0, 1.5, 16).astype(np.float32)
    sp = SamplingParams(temperature=1.0, top_p=top_p)
    counts = _draw_counts(logits, sp, 8000, seed0=20_000)
    ref = _ref_filtered_probs(logits, 1.0, 0, top_p)
    # the nucleus rule keeps the smallest cum-prob prefix; every draw
    # must land inside it (asserted inside _chi2_vs_ref)
    stat, df = _chi2_vs_ref(counts, ref)
    assert stat < _chi2_crit(df), (stat, df, counts, ref)


@slow
def test_combined_filters_match_reference():
    rng = np.random.default_rng(3)
    logits = rng.normal(0.0, 1.0, 32).astype(np.float32)
    sp = SamplingParams(temperature=0.9, top_k=6, top_p=0.8)
    counts = _draw_counts(logits, sp, 8000, seed0=30_000)
    ref = _ref_filtered_probs(logits, 0.9, 6, 0.8)
    assert 1 < (ref > 0).sum() <= 6      # both filters actually bite
    stat, df = _chi2_vs_ref(counts, ref)
    assert stat < _chi2_crit(df), (stat, df, counts, ref)


def test_greedy_is_bitwise_argmax():
    """temperature<=0 returns argmax of the *raw* logits regardless of
    seed/step/filters — the greedy bit-exactness anchor."""
    rng = np.random.default_rng(4)
    logits = rng.normal(0.0, 3.0, (32, 64)).astype(np.float32)
    toks = sample_tokens(
        jnp.asarray(logits),
        jnp.zeros(32, jnp.float32),
        jnp.full((32,), 7, jnp.int32),        # ignored when greedy
        jnp.full((32,), 0.5, jnp.float32),    # ignored when greedy
        jnp.arange(32, dtype=jnp.uint32),
        jnp.arange(32, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(toks),
                                  logits.argmax(-1).astype(np.int32))


def test_draws_replayable_and_step_dependent():
    rng = np.random.default_rng(5)
    logits = jnp.asarray(rng.normal(0.0, 1.0, (64, 16)), jnp.float32)
    args = (jnp.ones(64, jnp.float32), jnp.zeros(64, jnp.int32),
            jnp.ones(64, jnp.float32), jnp.full((64,), 3, jnp.uint32))
    a = sample_tokens(logits, *args, jnp.zeros(64, jnp.int32))
    b = sample_tokens(logits, *args, jnp.zeros(64, jnp.int32))
    c = sample_tokens(logits, *args, jnp.ones(64, jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) != np.asarray(c)).any()   # step moves the key


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(seed=-3)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


# ---------------------------------------------------------------------------
# Speculative acceptance rule (module level)
# ---------------------------------------------------------------------------

def _spec_args(n, temp=0.0, top_k=0, top_p=1.0, seed0=0, step=0):
    return (jnp.full((n,), temp, jnp.float32),
            jnp.full((n,), top_k, jnp.int32),
            jnp.full((n,), top_p, jnp.float32),
            jnp.arange(seed0, seed0 + n, dtype=jnp.uint32),
            jnp.full((n,), step, jnp.int32))


def test_spec_greedy_accepts_matching_prefix():
    """Greedy lanes accept a draft iff it equals its own position's
    argmax, so every emitted token is the argmax of its slot — the
    token-level mechanism behind spec==non-spec greedy bit-identity."""
    V, C = 16, 4
    rng = np.random.default_rng(6)
    logits = rng.normal(0.0, 1.0, (C, V)).astype(np.float32)
    t = logits.argmax(-1)                      # target argmax sequence
    # drafts match 2 slots then diverge
    drafts = np.array([t[0], t[1], (t[2] + 1) % V], np.int32)
    out, n_emit, okrow = spec_accept(
        jnp.asarray(logits)[None], jnp.asarray(drafts)[None],
        jnp.asarray([3], jnp.int32), *_spec_args(1))
    assert int(n_emit[0]) == 3
    np.testing.assert_array_equal(np.asarray(out)[0, :3], t[:3])
    assert bool(np.asarray(okrow).all())

    # full accept earns the bonus token from the last position
    out, n_emit, _ = spec_accept(
        jnp.asarray(logits)[None], jnp.asarray(t[:3], jnp.int32)[None],
        jnp.asarray([3], jnp.int32), *_spec_args(1))
    assert int(n_emit[0]) == 4
    np.testing.assert_array_equal(np.asarray(out)[0], t)

    # zero drafts degenerate to a plain decode step
    out, n_emit, _ = spec_accept(
        jnp.asarray(logits)[None], jnp.zeros((1, 3), jnp.int32),
        jnp.asarray([0], jnp.int32), *_spec_args(1))
    assert int(n_emit[0]) == 1
    assert int(np.asarray(out)[0, 0]) == t[0]


@slow
@pytest.mark.parametrize("draft_rank", [0, 2, 6])
def test_spec_acceptance_preserves_marginal(draft_rank):
    """The accept-or-resample rule with a one-hot draft preserves the
    target marginal exactly: accept draft x w.p. p(x), else resample
    from p with x masked — chi-squared of the emitted first token over
    6000 seeds against the filtered softmax, with the draft at high /
    middling / low probability rank."""
    V, N, temp = 8, 6000, 0.9
    rng = np.random.default_rng(7)
    logits = rng.normal(0.0, 1.2, (2, V)).astype(np.float32)
    draft = int(np.argsort(-logits[0])[draft_rank])
    out, n_emit, _ = spec_accept(
        jnp.tile(jnp.asarray(logits)[None], (N, 1, 1)),
        jnp.full((N, 1), draft, jnp.int32),
        jnp.ones((N,), jnp.int32),
        *_spec_args(N, temp=temp, seed0=40_000))
    first = np.asarray(out)[:, 0]
    assert (np.asarray(n_emit) >= 1).all()
    counts = np.bincount(first, minlength=V)
    ref = _ref_filtered_probs(logits[0], temp, 0, 1.0)
    stat, df = _chi2_vs_ref(counts, ref)
    assert stat < _chi2_crit(df), (stat, df, counts, ref)
    # acceptance actually exercised: the draft token is emitted at
    # least as often as its probability implies
    assert counts[draft] > 0


# ---------------------------------------------------------------------------
# Prompt-lookup drafter
# ---------------------------------------------------------------------------

def test_ngram_proposes_periodic_continuation():
    ctx = [1, 2, 3, 1, 2, 3, 1, 2]
    np.testing.assert_array_equal(propose_ngram(ctx, 5),
                                  [3, 1, 2, 3, 1])


def test_ngram_wraps_constant_run():
    # a run of identical tokens is period 1: the drafter proposes k
    # copies, not just the leftover tail of the current cycle
    np.testing.assert_array_equal(propose_ngram([5, 5, 5, 5], 4),
                                  [5, 5, 5, 5])


def test_ngram_no_match_returns_empty():
    assert propose_ngram([1, 2, 3, 4], 4).size == 0
    assert propose_ngram([7], 4).size == 0
    assert propose_ngram([], 4).size == 0


def test_ngram_prefers_longest_then_most_recent_match():
    # suffix [9, 1] occurs twice; the most recent occurrence (followed
    # by 4) supplies the draft, not the earlier one (followed by 2)
    ctx = [9, 1, 2, 9, 1, 4, 9, 1]
    got = propose_ngram(ctx, 1, ngram_max=2)
    np.testing.assert_array_equal(got, [4])


# ---------------------------------------------------------------------------
# Engine integration: bit-exactness, distribution, rollback, resume
# ---------------------------------------------------------------------------

def _cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                attn_chunk=16)
    base.update(kw)
    return ArchConfig(**base)


@pytest.fixture(scope="module")
def tiny():
    cfg = _cfg()
    return api.init(jax.random.PRNGKey(0), cfg), cfg


def _eng_kw(layout):
    kw = dict(batch_size=2, max_len=64, kv_layout=layout)
    if layout == "paged":
        kw.update(page_size=32, n_pages=8)
    return kw


def _reqs(cfg, lens, news, seed=0, sampling=None):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, s)
                    .astype(np.int32), max_new=n,
                    sampling=(dataclasses.replace(sampling, seed=i)
                              if sampling is not None else None))
            for i, (s, n) in enumerate(zip(lens, news))]


def _rep_reqs(cfg, n, seed=0, period=3, prompt_len=12, max_new=24,
              sampling=None):
    """Repetition-friendly prompts (tiled random motifs) so the
    prompt-lookup drafter has something to accept."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        motif = rng.integers(0, cfg.vocab_size, period)
        prompt = np.tile(motif, prompt_len // period + 1)[:prompt_len]
        reqs.append(Request(
            prompt=prompt.astype(np.int32), max_new=max_new,
            sampling=(dataclasses.replace(sampling, seed=i)
                      if sampling is not None else None)))
    return reqs


COMBOS = [("wave", "contiguous"), ("continuous", "contiguous"),
          ("continuous", "paged")]


@pytest.mark.parametrize("scheduler,layout", COMBOS)
def test_temperature_zero_bit_identical_to_greedy(tiny, scheduler,
                                                  layout):
    """A SamplingParams with temperature 0 (whatever the other knobs
    say) decodes bit-identically to a request with no sampling at all,
    on every scheduler x layout combination."""
    params, cfg = tiny
    lens, news = [9, 21, 14, 6], [6, 5, 8, 4]
    sp = SamplingParams(temperature=0.0, top_k=5, top_p=0.5, seed=9)
    outs = {}
    for tag, sampling in (("greedy", None), ("temp0", sp)):
        eng = Engine(params, cfg, QuantMode.off(), scheduler=scheduler,
                     **_eng_kw(layout))
        outs[tag] = eng.generate(_reqs(cfg, lens, news, seed=11,
                                       sampling=sampling))
    for g, t in zip(outs["greedy"], outs["temp0"]):
        np.testing.assert_array_equal(g.out, t.out)


@pytest.mark.parametrize("scheduler,layout", COMBOS)
def test_mixed_batch_keeps_greedy_lanes_bitwise(tiny, scheduler, layout):
    """Sampled and greedy requests sharing decode dispatches: the
    greedy members' outputs are bitwise what an all-greedy engine
    produces (per-lane temperature 0 takes the raw-dtype argmax branch
    inside the sampled closure)."""
    params, cfg = tiny
    lens, news = [9, 21, 14, 6], [6, 5, 8, 4]
    ref = Engine(params, cfg, QuantMode.off(), scheduler=scheduler,
                 **_eng_kw(layout))
    ref_out = ref.generate(_reqs(cfg, lens, news, seed=13))

    eng = Engine(params, cfg, QuantMode.off(), scheduler=scheduler,
                 **_eng_kw(layout))
    reqs = _reqs(cfg, lens, news, seed=13)
    sp = SamplingParams(temperature=1.0, top_k=8)
    for i in (1, 3):                       # lanes 1/3 sample
        reqs[i].sampling = dataclasses.replace(sp, seed=i)
    eng.generate(reqs)
    for i in (0, 2):                       # greedy lanes are untouched
        np.testing.assert_array_equal(reqs[i].out, ref_out[i].out)


def test_sampled_run_is_replayable(tiny):
    """(prompt, params, seed) fully determines a sampled run: two fresh
    engines produce identical tokens, and a third with different seeds
    diverges somewhere."""
    params, cfg = tiny
    sp = SamplingParams(temperature=1.0, top_k=16)

    def run(seed_base):
        eng = Engine(params, cfg, QuantMode.off(),
                     scheduler="continuous", **_eng_kw("paged"))
        reqs = _reqs(cfg, [12, 18, 9], [8, 6, 7], seed=17, sampling=sp)
        for i, r in enumerate(reqs):
            r.sampling = dataclasses.replace(sp, seed=seed_base + i)
        eng.generate(reqs)
        return [list(r.out) for r in reqs]

    assert run(0) == run(0)
    assert run(0) != run(100)


@slow
def test_engine_sampled_first_token_frequency(tiny):
    """End-to-end distribution check: the admission-token draws of many
    same-prompt requests (distinct seeds) are chi-squared-consistent
    with the numpy-filtered softmax of the model's own prefill logits."""
    params, cfg = tiny
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    temp, top_k, N = 1.0, 8, 320
    logits = np.asarray(api.prefill(params, cfg, jnp.asarray(prompt)[None],
                                    QuantMode.off())[0])[0]
    ref = _ref_filtered_probs(logits, temp, top_k, 1.0)

    eng = Engine(params, cfg, QuantMode.off(), scheduler="continuous",
                 batch_size=4, max_len=32)
    reqs = [Request(prompt=prompt, max_new=1,
                    sampling=SamplingParams(temperature=temp,
                                            top_k=top_k, seed=i))
            for i in range(N)]
    eng.generate(reqs)
    counts = np.bincount([int(r.out[0]) for r in reqs],
                         minlength=cfg.vocab_size)
    stat, df = _chi2_vs_ref(counts, ref)
    assert stat < _chi2_crit(df), (stat, df)


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_spec_greedy_bit_identical_to_nonspec(tiny, layout):
    """Distribution preservation, greedy arm: speculative decoding
    changes how many forwards produce the tokens, never the tokens."""
    params, cfg = tiny
    reqs_a = (_rep_reqs(cfg, 3, seed=23)
              + _reqs(cfg, [11, 17], [10, 12], seed=24))
    reqs_b = (_rep_reqs(cfg, 3, seed=23)
              + _reqs(cfg, [11, 17], [10, 12], seed=24))
    ref = Engine(params, cfg, QuantMode.off(), scheduler="continuous",
                 **_eng_kw(layout))
    ref.generate(reqs_a)
    eng = Engine(params, cfg, QuantMode.off(), scheduler="continuous",
                 spec=SpecConfig(k=3), **_eng_kw(layout))
    eng.generate(reqs_b)
    for a, b in zip(reqs_a, reqs_b):
        np.testing.assert_array_equal(a.out, b.out)
    st = eng.stats()
    assert st["spec_proposed_tokens"] > 0
    assert 0.0 <= st["spec_acceptance"] <= 1.0


@slow
def test_spec_sampled_frequency_matches_nonspec(tiny):
    """Distribution preservation, sampled arm: pooled token histograms
    of spec vs non-spec runs at the same SamplingParams pass a
    two-sample chi-squared (the *tokens* differ — acceptance consumes
    different uniforms — but the distribution must not)."""
    params, cfg = tiny
    sp = SamplingParams(temperature=1.0, top_k=8)
    counts = {}
    for tag, spec in (("off", None), ("on", SpecConfig(k=3))):
        eng = Engine(params, cfg, QuantMode.off(),
                     scheduler="continuous", spec=spec,
                     **_eng_kw("paged"))
        reqs = _rep_reqs(cfg, 40, seed=29, max_new=16, sampling=sp)
        eng.generate(reqs)
        toks = np.concatenate([np.asarray(r.out) for r in reqs])
        counts[tag] = np.bincount(toks, minlength=cfg.vocab_size)
        if spec is not None:
            st = eng.stats()
            assert st["spec_accepted_tokens"] > 0   # rule exercised
    stat, df = _two_sample_chi2(counts["off"], counts["on"])
    assert stat < _chi2_crit(df), (stat, df)


def test_spec_rollback_allocator_invariants(tiny):
    """Rollback property: a seeded multi-request spec run with mixed
    accept/reject traffic (repetitive + incompressible prompts, greedy
    + sampled lanes) keeps the page accounting partitioned —
    ``BlockAllocator.check()`` passes and in_use + free + cached ==
    capacity after *every* engine step — and drains with zero leaked
    pages."""
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), scheduler="continuous",
                 batch_size=2, max_len=64, kv_layout="paged",
                 page_size=32, n_pages=8, spec=SpecConfig(k=4))
    sp = SamplingParams(temperature=0.8, top_k=12)
    reqs = (_rep_reqs(cfg, 3, seed=31, max_new=20)
            + _reqs(cfg, [13, 26, 9], [12, 8, 15], seed=32, sampling=sp)
            + _rep_reqs(cfg, 2, seed=33, max_new=10, sampling=sp))
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.busy:
        eng.step()
        steps += 1
        assert steps < 400, "spec run failed to drain"
        acct = eng._alloc.check()    # raises on any partition violation
        assert (acct["in_use"] + acct["free"] + acct["cached"]
                == eng._alloc.capacity)
    assert all(r.state is RequestState.FINISHED for r in reqs)
    assert eng._alloc.in_use == 0                  # zero leaked pages
    assert eng.stats()["spec_proposed_tokens"] > 0


def test_spec_respects_eos_and_budget(tiny):
    """Accepted drafts past the first EOS are discarded; a lane never
    emits more than its max_new budget even when every draft lands."""
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), scheduler="continuous",
                 eos_id=0, spec=SpecConfig(k=4), **_eng_kw("paged"))
    reqs = _rep_reqs(cfg, 4, seed=37, max_new=11)
    done = eng.generate(reqs)
    for r in done:
        assert len(r.out) <= 11
        hits = np.flatnonzero(np.asarray(r.out) == 0)
        if hits.size:                    # EOS kept, nothing after it
            assert hits[0] == len(r.out) - 1


def test_preemption_resume_replays_sampled_tail(tiny):
    """Preemption-resume under sampling: the resumed request re-seeds
    from its emitted-token count, so its output is bit-identical to an
    uninterrupted run — the sampled analogue of the greedy resume
    guarantee in test_faults.py."""
    params, cfg = tiny
    sp_lo = SamplingParams(temperature=0.9, top_k=12, seed=3)
    sp_hi = SamplingParams(temperature=0.7, top_k=6, seed=4)

    def mk():
        rng = np.random.default_rng(41)
        lo = Request(prompt=rng.integers(0, cfg.vocab_size, 40)
                     .astype(np.int32), max_new=10, priority=0,
                     deadline_ms=1e7, sampling=sp_lo)
        hi = Request(prompt=rng.integers(0, cfg.vocab_size, 38)
                     .astype(np.int32), max_new=8, priority=5,
                     sampling=sp_hi)
        return lo, hi

    solo = Engine(params, cfg, QuantMode.off(), scheduler="continuous",
                  batch_size=2, max_len=64, kv_layout="paged",
                  page_size=32, n_pages=3)
    lo_ref, hi_ref = mk()
    solo.generate([lo_ref])
    solo.generate([hi_ref])

    eng = Engine(params, cfg, QuantMode.off(), scheduler="continuous",
                 batch_size=2, max_len=64, kv_layout="paged",
                 page_size=32, n_pages=3,
                 policy=SchedulingPolicy(backoff_base_s=0.001))
    lo, hi = mk()
    eng.submit(lo)
    eng.step()
    assert lo.state is RequestState.RUNNING
    eng.submit(hi)
    eng.drain()
    assert lo.preemptions >= 1
    np.testing.assert_array_equal(lo.out, lo_ref.out)
    np.testing.assert_array_equal(hi.out, hi_ref.out)
    assert eng._alloc.in_use == 0


def test_spec_requires_continuous_scheduler(tiny):
    params, cfg = tiny
    with pytest.raises(ValueError, match="continuous"):
        Engine(params, cfg, QuantMode.off(), scheduler="wave",
               spec=SpecConfig(k=2), batch_size=2, max_len=64)


def test_spec_config_validation():
    with pytest.raises(ValueError):
        SpecConfig(k=0)
    with pytest.raises(ValueError):
        SpecConfig(ngram_min=3, ngram_max=2)
    with pytest.raises(ValueError):
        SpecConfig(ngram_min=0)
