"""MX-quantized KV cache: PackedKV layout round-trips, quantize-on-append
write parity, the flash-decode Pallas kernel vs its oracle vs the dense
jnp attention, and end-to-end quantized-cache serving (both schedulers)
within the documented tolerance — with kv_cache='none' pinned bit-identical
to the dense engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.quantize import KVCacheQuant, QuantMode
from repro.kernels import ops, packing
from repro.models import api, layers
from repro.serving.engine import Engine, Request

KV_FMTS = ["mxfp8", "mxint8", "mxfp4", "mxint4"]


def _cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                attn_chunk=16)
    base.update(kw)
    return ArchConfig(**base)


def _data(shape, seed=0, scale=1.0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, shape, jnp.float32)
    return x * jnp.exp(jax.random.normal(k2, shape, jnp.float32) * 0.5) * scale


# ---------------------------------------------------------------------------
# PackedKV layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", KV_FMTS)
def test_kv_encode_decode_roundtrip_on_grid(fmt):
    """decode∘encode is idempotent: re-encoding decoded values is exact."""
    x = _data((3, 7, 64), seed=1)
    c, s = packing.kv_encode(x, fmt)
    y = packing.kv_decode(c, s, fmt)
    c2, s2 = packing.kv_encode(y, fmt)
    y2 = packing.kv_decode(c2, s2, fmt)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))
    # and the quantization error is bounded by the format's step size
    rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert rel < (0.05 if "8" in fmt else 0.3), (fmt, rel)


@pytest.mark.parametrize("fmt", KV_FMTS)
def test_packedkv_zeros_decode_to_zero(fmt):
    pk = packing.PackedKV.zeros((2, 5, 64), fmt)
    assert pk.shape == (2, 5, 64)
    np.testing.assert_array_equal(np.asarray(pk.to_dense()),
                                  np.zeros((2, 5, 64), np.float32))


def test_kvcachequant_parse():
    assert KVCacheQuant.parse(None) is None
    assert KVCacheQuant.parse("none") is None
    assert KVCacheQuant.parse("bf16") is None
    assert KVCacheQuant.parse("mxfp8").fmt == "mxfp8"
    q = KVCacheQuant("mxint4")
    assert KVCacheQuant.parse(q) is q
    with pytest.raises(ValueError, match="unknown KV-cache fmt"):
        KVCacheQuant.parse("fp16")


# ---------------------------------------------------------------------------
# Quantize-on-append writes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["mxfp8", "mxfp4"])
def test_kv_write_rows_matches_direct_encode(fmt):
    """The decode-step scatter (per-lane rows) stores exactly what a
    direct encode of the same values stores."""
    cache = packing.PackedKV.zeros((3, 8, 64), fmt)
    new = _data((3, 1, 64), seed=2)
    rows = jnp.array([1, 5, 0], jnp.int32)
    out = layers.kv_write_rows(cache, new, rows)
    dense = np.asarray(out.to_dense())
    want = np.asarray(packing.kv_decode(*packing.kv_encode(new, fmt), fmt))
    for b, r in enumerate([1, 5, 0]):
        np.testing.assert_array_equal(dense[b, r], want[b, 0])
    # untouched rows still decode to their zero init
    assert np.all(dense[0, 2:] == 0) and np.all(dense[2, 1:] == 0)


@pytest.mark.parametrize("fmt", ["mxfp8", "mxint4"])
def test_kv_write_slice_matches_direct_encode(fmt):
    """The chunked-prefill contiguous write stores what a direct encode
    stores (traced start index included)."""
    cache = packing.PackedKV.zeros((2, 16, 64), fmt)
    new = _data((2, 4, 64), seed=3)
    out = jax.jit(lambda c, n, s: layers.kv_write_slice(c, n, s)
                  )(cache, new, jnp.int32(5))
    dense = np.asarray(out.to_dense())
    want = np.asarray(packing.kv_decode(*packing.kv_encode(new, fmt), fmt))
    np.testing.assert_array_equal(dense[:, 5:9], want)
    assert np.all(dense[:, :5] == 0) and np.all(dense[:, 9:] == 0)


def test_kv_write_dense_passthrough():
    """The write helpers keep the dense-cache path bit-identical to the
    raw scatter / dynamic_update_slice they replaced."""
    cache = jnp.zeros((2, 8, 32), jnp.float32)
    new = _data((2, 1, 32), seed=4)
    rows = jnp.array([3, 6], jnp.int32)
    a = layers.kv_write_rows(cache, new, rows)
    b = cache.at[jnp.arange(2), rows].set(new[:, 0])
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = layers.kv_write_slice(cache, new, jnp.int32(2))
    d = jax.lax.dynamic_update_slice(cache, new, (0, jnp.int32(2), 0))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(d))


# ---------------------------------------------------------------------------
# Flash-decode kernel vs oracle vs dense jnp attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", KV_FMTS)
@pytest.mark.parametrize("gqa", [(4, 4, 16), (4, 2, 16), (8, 1, 32)],
                         ids=["mha", "gqa2", "mqa"])
def test_flash_decode_matches_ref_gqa(fmt, gqa):
    H, kvh, Dh = gqa
    B, S = 2, 64
    q = _data((B, H, Dh), seed=5)
    kc, ks = packing.kv_encode(_data((B, S, kvh * Dh), seed=6), fmt)
    vc, vs = packing.kv_encode(_data((B, S, kvh * Dh), seed=7), fmt)
    pos = jnp.array([30, 63], jnp.int32)
    yr = ops.mx_attention_ref(q, kc, ks, vc, vs, pos, pos + 1, fmt)
    # single-chunk (the interpret default) AND a 16-wide chunk grid, so
    # the online-softmax accumulation across grid steps is exercised
    for bs in (None, 16):
        y = ops.mx_flash_decode(q, kc, ks, vc, vs, pos, pos + 1, fmt,
                                bs=bs, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("window", [0, 9, 40])
def test_flash_decode_sliding_window(window):
    B, H, kvh, Dh, S = 2, 4, 2, 16, 96
    q = _data((B, H, Dh), seed=8)
    kc, ks = packing.kv_encode(_data((B, S, kvh * Dh), seed=9), "mxfp8")
    vc, vs = packing.kv_encode(_data((B, S, kvh * Dh), seed=10), "mxfp8")
    pos = jnp.array([50, 95], jnp.int32)
    y = ops.mx_flash_decode(q, kc, ks, vc, vs, pos, pos + 1, "mxfp8",
                            window=window, bs=32, interpret=True)
    yr = ops.mx_attention_ref(q, kc, ks, vc, vs, pos, pos + 1, "mxfp8",
                              window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-5, rtol=1e-5)


def test_flash_decode_per_row_kv_len_and_odd_tails():
    """Per-lane fills that land mid-chunk (odd tails) mask exactly: each
    lane must equal a single-lane call at its own fill."""
    B, H, kvh, Dh, S = 4, 4, 2, 16, 96      # chunk grid won't divide fills
    q = _data((B, H, Dh), seed=11)
    k = _data((B, S, kvh * Dh), seed=12)
    v = _data((B, S, kvh * Dh), seed=13)
    kc, ks = packing.kv_encode(k, "mxfp8")
    vc, vs = packing.kv_encode(v, "mxfp8")
    fills = jnp.array([1, 33, 50, 96], jnp.int32)   # 1 chunk edge, 3 odd
    pos = fills - 1
    # bs=32: fills land mid-chunk (33, 50) and at the final edge (96)
    y = ops.mx_flash_decode(q, kc, ks, vc, vs, pos, fills, "mxfp8",
                            bs=32, interpret=True)
    for b in range(B):
        yb = ops.mx_flash_decode(q[b:b + 1], kc[b:b + 1], ks[b:b + 1],
                                 vc[b:b + 1], vs[b:b + 1], pos[b:b + 1],
                                 fills[b:b + 1], "mxfp8", bs=32,
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(y[b]), np.asarray(yb[0]),
                                   atol=1e-6, rtol=1e-6)
    # and the chunk grid agrees with the single-chunk lowering exactly
    # where fills align, tightly where the accumulation order differs
    y1 = ops.mx_flash_decode(q, kc, ks, vc, vs, pos, fills, "mxfp8",
                             interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y1),
                               atol=1e-5, rtol=1e-5)


def test_flash_decode_matches_dense_jnp_attention():
    """The kernel over the packed cache == layers.attention over the
    decoded dense cache (same key selection, same softmax) — the bridge
    between the kernel and the model's reference read path."""
    B, H, kvh, Dh, S = 3, 8, 2, 16, 64
    q = _data((B, 1, H, Dh), seed=14)
    k = _data((B, S, kvh * Dh), seed=15)
    v = _data((B, S, kvh * Dh), seed=16)
    kc, ks = packing.kv_encode(k, "mxfp8")
    vc, vs = packing.kv_encode(v, "mxfp8")
    kd = packing.kv_decode(kc, ks, "mxfp8")
    vd = packing.kv_decode(vc, vs, "mxfp8")
    pos = jnp.array([20, 41, 63], jnp.int32)
    y = ops.mx_flash_decode(q.reshape(B, H, Dh), kc, ks, vc, vs, pos,
                            pos + 1, "mxfp8", interpret=True)
    yj = layers.attention(
        q, kd.reshape(B, S, kvh, Dh), vd.reshape(B, S, kvh, Dh),
        causal=True, q_pos=pos[:, None], kv_len=pos + 1, chunk=16)
    np.testing.assert_allclose(np.asarray(y).reshape(B, 1, H, Dh),
                               np.asarray(yj), atol=1e-5, rtol=1e-5)


def test_flash_decode_contract_predicate():
    """The dispatch predicate admits exactly the kernel's tiling
    contract, and the wrapper rejects violations with a descriptive
    error (such inputs are equally ill-formed for the jnp oracle — the
    graceful fallback lives in models.layers.attention)."""
    from repro.kernels.ops import _flash_decode_contract
    B, H, Dh, S = 1, 4, 16, 32
    q = _data((B, H, Dh), seed=17)
    kc, ks = packing.kv_encode(_data((B, S, 2 * Dh), seed=18), "mxfp8")
    vc, vs = packing.kv_encode(_data((B, S, 2 * Dh), seed=23), "mxfp8")
    assert _flash_decode_contract(q, kc, ks, vc, vs, "mxfp8")
    # a head count the GQA view cannot tile over the kv heads
    assert not _flash_decode_contract(_data((B, 5, Dh), seed=19), kc, ks,
                                      vc, vs, "mxfp8")
    # a format the packed cache cannot hold
    assert not _flash_decode_contract(q, kc, ks, vc, vs, "mxfp6")
    # a scale layout that does not match the codes
    assert not _flash_decode_contract(q, kc, ks[:, : S // 2], vc, vs,
                                      "mxfp8")
    # V shapes that do not match K (would fail opaquely in the kernel)
    assert not _flash_decode_contract(q, kc, ks, vc[:, : S // 2], vs,
                                      "mxfp8")
    assert not _flash_decode_contract(q, kc, ks, vc, vs[:, : S - 1],
                                      "mxfp8")
    pos = jnp.array([31], jnp.int32)
    with pytest.raises(ValueError, match="contract violation"):
        ops.mx_flash_decode(_data((B, 5, Dh), seed=19), kc, ks, vc, vs,
                            pos, pos + 1, "mxfp8", interpret=True)


def test_flash_decode_scalar_broadcast():
    """Scalar q_pos / kv_len (the wave scheduler's shared position)
    broadcast across lanes identically to explicit vectors."""
    B, H, kvh, Dh, S = 3, 4, 2, 16, 64
    q = _data((B, H, Dh), seed=20)
    kc, ks = packing.kv_encode(_data((B, S, kvh * Dh), seed=21), "mxfp8")
    vc, vs = packing.kv_encode(_data((B, S, kvh * Dh), seed=22), "mxfp8")
    y0 = ops.mx_flash_decode(q, kc, ks, vc, vs, jnp.int32(40),
                             jnp.int32(41), "mxfp8", interpret=True)
    y1 = ops.mx_flash_decode(q, kc, ks, vc, vs,
                             jnp.full((B,), 40, jnp.int32),
                             jnp.full((B,), 41, jnp.int32), "mxfp8",
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


# ---------------------------------------------------------------------------
# Model-level: quantized cache vs dense cache logits
# ---------------------------------------------------------------------------

def test_prefill_logits_unaffected_by_kv_quant():
    """Prefill attends its own dense k/v — quantization touches only the
    returned cache, so prefill logits are bit-identical."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 16)),
                       jnp.int32)
    l0, c0 = api.prefill(params, cfg, toks, max_len=32)
    l1, c1 = api.prefill(params, cfg, toks, max_len=32,
                         kv_quant=KVCacheQuant("mxfp8"))
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    assert isinstance(c1["k"], packing.PackedKV)


@pytest.mark.parametrize("fmt,rel_tol", [("mxfp8", 0.05), ("mxint8", 0.05),
                                         ("mxfp4", 0.35)])
def test_decode_logits_close_to_dense_cache(fmt, rel_tol):
    """One decode step against the quantized cache tracks the dense-cache
    logits within the documented tolerance (docs/kv-cache.md)."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(1), cfg)
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 128, (2, 16)),
                       jnp.int32)
    l0, c0 = api.prefill(params, cfg, toks, max_len=32)
    _, cq = api.prefill(params, cfg, toks, max_len=32,
                        kv_quant=KVCacheQuant(fmt))
    nxt = jnp.argmax(l0, axis=-1).astype(jnp.int32)
    ld, _ = api.decode(params, cfg, c0, nxt, jnp.int32(16))
    lq, _ = api.decode(params, cfg, cq, nxt, jnp.int32(16))
    rel = float(jnp.linalg.norm(lq - ld) / jnp.linalg.norm(ld))
    assert rel < rel_tol, (fmt, rel)


def test_decode_fused_matches_ref_backend_on_quantized_cache():
    """ref (decode-in-place) and fused (flash-decode kernel) read the
    same decoded values: decode logits agree tightly."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(2), cfg)
    toks = jnp.asarray(np.random.default_rng(2).integers(0, 128, (2, 16)),
                       jnp.int32)
    kvq = KVCacheQuant("mxfp8")
    _, cq = api.prefill(params, cfg, toks, max_len=32, kv_quant=kvq)
    nxt = jnp.zeros((2,), jnp.int32)
    lr, _ = api.decode(params, cfg, cq, nxt, jnp.int32(16), QuantMode.off())
    lf, _ = api.decode(params, cfg, cq, nxt, jnp.int32(16),
                       QuantMode.off().with_backend("fused"))
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# End-to-end serving
# ---------------------------------------------------------------------------

def _reqs(cfg, lens, news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, s)
                    .astype(np.int32), max_new=n)
            for s, n in zip(lens, news)]


def test_kv_cache_none_stays_bit_identical():
    """kv_cache='none' must reproduce the dense engine token-for-token on
    both schedulers (the acceptance-pinned opt-out)."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    lens, news = [5, 16, 23, 9], [4, 9, 6, 12]
    base_w = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64)
    ref = [list(r.out) for r in base_w.generate(_reqs(cfg, lens, news))]
    none_w = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                    kv_cache="none")
    got = [list(r.out) for r in none_w.generate(_reqs(cfg, lens, news))]
    assert ref == got
    base_c = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                    scheduler="continuous")
    ref_c = [list(r.out) for r in base_c.generate(_reqs(cfg, lens, news))]
    none_c = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                    scheduler="continuous", kv_cache=None)
    got_c = [list(r.out) for r in none_c.generate(_reqs(cfg, lens, news))]
    assert ref_c == got_c


@pytest.mark.parametrize("scheduler", ["wave", "continuous"])
def test_serving_mxfp8_within_tolerance(scheduler):
    """End-to-end with kv_cache='mxfp8' on both schedulers: every request
    completes with its full budget, streams sane tokens, and the greedy
    outputs agree with the dense-cache engine on a clear majority of
    positions (greedy flips near ties are expected and compound; the
    logit-level tolerance is pinned by
    test_decode_logits_close_to_dense_cache)."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    lens, news = [5, 16, 23, 9], [4, 9, 6, 12]
    dense = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                   scheduler=scheduler)
    ref = [list(r.out) for r in dense.generate(_reqs(cfg, lens, news))]
    quant = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                   scheduler=scheduler, kv_cache="mxfp8")
    got = [list(r.out) for r in quant.generate(_reqs(cfg, lens, news))]
    assert [len(g) for g in got] == news
    agree = np.mean([a == b for A, B in zip(ref, got)
                     for a, b in zip(A, B)])
    assert agree >= 0.5, agree
    # first decode token (straight off the un-quantized prefill read for
    # wave; one quantized-prefix read for continuous) matches per request
    assert sum(a[0] == b[0] for a, b in zip(ref, got)) >= 3


def test_serving_fused_backend_runs_flash_decode():
    """The fused backend serves a quantized cache end to end (the Pallas
    kernel in the decode loop) and matches the ref backend's tokens."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    lens, news = [5, 16], [4, 6]
    r = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
               scheduler="continuous", kv_cache="mxfp8")
    ref = [list(x.out) for x in r.generate(_reqs(cfg, lens, news))]
    f = Engine(params, cfg, QuantMode.off().with_backend("fused"),
               batch_size=2, max_len=64, scheduler="continuous",
               kv_cache="mxfp8")
    got = [list(x.out) for x in f.generate(_reqs(cfg, lens, news))]
    assert ref == got


def test_hybrid_ring_buffer_kv_quant():
    """Griffin's windowed ring-buffer cache quantizes too (wave
    scheduler): decode logits track the dense cache, and the engine
    serves end to end."""
    from repro import configs
    cfg = configs.get_reduced("recurrentgemma-2b")
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    l0, c0 = api.prefill(params, cfg, toks, max_len=32)
    l1, cq = api.prefill(params, cfg, toks, max_len=32,
                         kv_quant=KVCacheQuant("mxfp8"))
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    assert isinstance(cq["attn_k"], packing.PackedKV)
    nxt = jnp.argmax(l0, axis=-1).astype(jnp.int32)
    ld, _ = api.decode(params, cfg, c0, nxt, jnp.int32(16))
    lq, _ = api.decode(params, cfg, cq, nxt, jnp.int32(16))
    rel = float(jnp.linalg.norm(lq - ld) / jnp.linalg.norm(ld))
    assert rel < 0.05, rel
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 kv_cache="mxfp8")
    done = eng.generate(_reqs(cfg, [8, 12], [4, 6], seed=5))
    assert [len(r.out) for r in done] == [4, 6]


def test_ssm_rejects_kv_cache():
    from repro import configs
    cfg = configs.get_reduced("mamba2-130m")
    params = api.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="attention KV cache"):
        Engine(params, cfg, QuantMode.off(), kv_cache="mxfp8")


def test_engine_rejects_bad_kv_cache():
    params = api.init(jax.random.PRNGKey(0), _cfg())
    with pytest.raises(ValueError, match="unknown KV-cache fmt"):
        Engine(params, _cfg(), QuantMode.off(), kv_cache="fp16")
    cfg_odd = _cfg(n_kv_heads=1, head_dim=24, n_heads=2)  # kv_dim 24
    params_odd = api.init(jax.random.PRNGKey(0), cfg_odd)
    with pytest.raises(ValueError, match="kv_dim % 32"):
        Engine(params_odd, cfg_odd, QuantMode.off(), kv_cache="mxfp8")


def test_burst_decode_counters_and_streaming():
    """The sync-hoisted burst decode keeps the counters and streaming
    semantics: one decode compile, per-step token counts, on_token
    streams == final outputs."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous")
    reqs = _reqs(cfg, [5, 16, 23, 9, 17, 31], [4, 9, 6, 12, 3, 8])
    streams = []
    for r in reqs:
        chunks = []
        r.on_token = chunks.append
        streams.append(chunks)
    done = eng.generate(reqs)
    for r, s in zip(reqs, streams):
        assert list(r.out) == s
    stats = eng.stats()
    assert stats["decode_compiles"] == 1
    assert stats["useful_decode_tokens"] == sum(
        max(len(r.out) - 1, 0) for r in reqs)
    assert 0.0 < stats["decode_utilization"] <= 1.0
