"""PTQ pipeline integration: GPTQ vs RTN, LATMiX learning dynamics, method
registry, NVFP4 variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import gptq, latmix as lx_lib, mx as mxlib, ptq
from repro.data import synthetic
from repro.models import api


def _cfg():
    return ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      attn_chunk=64)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    src = synthetic.make_source(cfg, 4, 32, 0)
    calib = [{k: jnp.asarray(v) for k, v in src.batch(i).items()}
             for i in range(2)]
    ev = jnp.asarray(src.batch(50)["inputs"])
    return cfg, params, calib, ev


def test_gptq_beats_rtn_on_correlated_inputs():
    rng = np.random.default_rng(0)
    d_in, d_out, n = 96, 48, 1024
    mix = rng.standard_normal((d_in, d_in)) * 0.3 + np.eye(d_in)
    x = rng.standard_normal((n, d_in)) @ mix
    x[:, 5] *= 7.0
    w = rng.standard_normal((d_in, d_out)).astype(np.float32) * 0.2
    H = x.T @ x
    cfg = mxlib.MXConfig(fmt="mxfp4")
    q_g = gptq.gptq_matrix(w.copy(), H, cfg)
    q_r = gptq.rtn_matrix(w, cfg)
    mse_g = float(np.mean((x @ w - x @ q_g) ** 2))
    mse_r = float(np.mean((x @ w - x @ q_r) ** 2))
    assert mse_g < mse_r
    # GPTQ output is on-grid (idempotent under RTN)
    np.testing.assert_allclose(gptq.rtn_matrix(q_g, cfg), q_g, atol=1e-7)


def test_latmix_loss_decreases(setup):
    cfg, params, calib, _ = setup
    pn = api.fold_norms(params, cfg)
    lx = lx_lib.LatmixConfig(kind="lu", steps=40, lr=1e-3)
    omega, tset, hist = lx_lib.learn_transforms(pn, cfg, lx, calib)
    assert min(h["task"] for h in hist[-3:]) < hist[0]["task"]
    # Fig. 3 dynamics: learned A1 departs from orthogonality
    m = lx_lib.transform_metrics(omega, cfg, lx)
    assert m["orthogonality_deviation"] > 1e-3
    assert np.isfinite(m["condition_number"])


@pytest.mark.parametrize("method", ["rtn", "gptq", "quarot", "latmix-lu"])
def test_method_registry_runs(setup, method):
    cfg, params, calib, ev = setup
    res = ptq.apply_method(method, params, cfg, calib, steps=8)
    ppl = ptq.eval_ppl(res, cfg, ev)
    assert np.isfinite(ppl) and ppl > 1.0


def test_t2_inapplicable_for_ssm():
    cfg = ArchConfig(name="s", family="ssm", n_layers=2, d_model=64,
                     vocab_size=97, ssm_state=16, ssm_headdim=16,
                     ssm_chunk=16, tie_embeddings=True)
    assert not lx_lib.t2_applicable(cfg)
    omega = lx_lib.init_omega(jax.random.PRNGKey(0), cfg,
                              lx_lib.LatmixConfig(kind="lu"))
    assert "t2" not in omega


def test_nvfp4_mode(setup):
    cfg, params, calib, ev = setup
    from repro.core.quantize import QuantMode
    qm = QuantMode.nvfp4()
    logits = api.forward(params, cfg, ev[:, :16], qm)
    assert not bool(jnp.isnan(logits).any())
