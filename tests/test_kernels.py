"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

SHAPES = [(8, 32), (16, 64), (64, 256), (128, 512), (33 * 8, 96)]
DTYPES = [jnp.float32, jnp.bfloat16]
FMTS = ["mxfp4", "mxint4"]


def _data(shape, dtype, seed=0, outliers=True):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, shape, jnp.float32)
    if outliers:
        x = x * jnp.exp(jax.random.normal(k2, shape, jnp.float32))
    return x.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_mx_quant_matches_ref(shape, dtype, fmt):
    x = _data(shape, dtype)
    c, s = ops.mx_quantize(x, fmt, interpret=True)
    cr, sr = ops.mx_quant_ref(x, fmt)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@pytest.mark.parametrize("mkn", [(8, 32, 16), (64, 128, 64),
                                 (128, 512, 256), (72, 96, 40)])
@pytest.mark.parametrize("fmt", FMTS)
def test_mx_matmul_matches_ref(mkn, fmt):
    m, k, n = mkn
    x = _data((m, k), jnp.float32, seed=1)
    w = _data((k, n), jnp.float32, seed=2, outliers=False) * 0.3
    wc, ws = ops.quantize_weight_for_kernel(w, fmt)
    y = ops.mx_gemm(x, wc, ws, fmt, interpret=True)
    yr = ops.mx_matmul_ref(x, wc, ws, fmt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_hadamard_quant_matches_ref(shape, fmt):
    x = _data(shape, jnp.float32, seed=3)
    c, s = ops.t3_quantize(x, fmt, interpret=True)
    cr, sr = ops.hadamard_quant_ref(x, fmt)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_mx_matmul_quant_error_bounded():
    """The fused-quant GEMM must stay within the analytic MX error bound
    of the exact product."""
    x = _data((64, 256), jnp.float32, seed=4)
    w = _data((256, 64), jnp.float32, seed=5, outliers=False) * 0.2
    wc, ws = ops.quantize_weight_for_kernel(w)
    y = ops.mx_gemm(x, wc, ws, interpret=True)
    exact = x @ w
    rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
    assert rel < 0.2, rel


def test_gemm_bf16_inputs():
    x = _data((32, 128), jnp.bfloat16, seed=6)
    w = _data((128, 32), jnp.float32, seed=7, outliers=False) * 0.3
    wc, ws = ops.quantize_weight_for_kernel(w)
    y = ops.mx_gemm(x, wc, ws, interpret=True)
    yr = ops.mx_matmul_ref(x.astype(jnp.float32), wc, ws)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-2, rtol=2e-2)
