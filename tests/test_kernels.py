"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

SHAPES = [(8, 32), (16, 64), (64, 256), (128, 512), (33 * 8, 96)]
DTYPES = [jnp.float32, jnp.bfloat16]
FMTS = ["mxfp4", "mxint4"]


def _data(shape, dtype, seed=0, outliers=True):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, shape, jnp.float32)
    if outliers:
        x = x * jnp.exp(jax.random.normal(k2, shape, jnp.float32))
    return x.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_mx_quant_matches_ref(shape, dtype, fmt):
    x = _data(shape, dtype)
    c, s = ops.mx_quantize(x, fmt, interpret=True)
    cr, sr = ops.mx_quant_ref(x, fmt)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@pytest.mark.parametrize("mkn", [(8, 32, 16), (64, 128, 64),
                                 (128, 512, 256), (72, 96, 40)])
@pytest.mark.parametrize("fmt", FMTS)
def test_mx_matmul_matches_ref(mkn, fmt):
    m, k, n = mkn
    x = _data((m, k), jnp.float32, seed=1)
    w = _data((k, n), jnp.float32, seed=2, outliers=False) * 0.3
    wc, ws = ops.quantize_weight_for_kernel(w, fmt)
    y = ops.mx_gemm(x, wc, ws, fmt, interpret=True)
    yr = ops.mx_matmul_ref(x, wc, ws, fmt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_hadamard_quant_matches_ref(shape, fmt):
    x = _data(shape, jnp.float32, seed=3)
    c, s = ops.t3_quantize(x, fmt, interpret=True)
    cr, sr = ops.hadamard_quant_ref(x, fmt)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_mx_matmul_quant_error_bounded():
    """The fused-quant GEMM must stay within the analytic MX error bound
    of the exact product."""
    x = _data((64, 256), jnp.float32, seed=4)
    w = _data((256, 64), jnp.float32, seed=5, outliers=False) * 0.2
    wc, ws = ops.quantize_weight_for_kernel(w)
    y = ops.mx_gemm(x, wc, ws, interpret=True)
    exact = x @ w
    rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
    assert rel < 0.2, rel


@pytest.mark.parametrize("mkn", [(8, 32, 16), (64, 128, 64),
                                 (128, 512, 256), (72, 96, 40)])
@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("t3", [False, True])
def test_mx_matmul_packed_matches_ref(mkn, fmt, t3):
    """Packed-native kernel (nibble codes + E8M0 bytes) vs its oracle."""
    from repro.kernels import packing
    m, k, n = mkn
    x = _data((m, k), jnp.float32, seed=8)
    w = _data((k, n), jnp.float32, seed=9, outliers=False) * 0.3
    b = packing.pack_weight(w, fmt)
    y = ops.mx_gemm_packed(x, b["codes_packed"], b["scales_e8m0"], fmt,
                           t3=t3, interpret=True)
    yr = ops.mx_matmul_packed_ref(x, b["codes_packed"], b["scales_e8m0"],
                                  fmt, t3=t3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-5)


def test_mx_matmul_packed_layouts_agree():
    """Both weight layouts (uint8-per-code and nibble-packed) compute the
    same GEMM: the shared golden reference ties them together."""
    from repro.kernels import packing
    x = _data((16, 64), jnp.float32, seed=10)
    w = _data((64, 32), jnp.float32, seed=11, outliers=False) * 0.3
    wc, ws = ops.quantize_weight_for_kernel(w, "mxfp4")
    b = packing.pack_weight(w, "mxfp4")
    y_u8 = ops.mx_gemm(x, wc, ws, "mxfp4", interpret=True)
    y_pk = ops.mx_gemm_packed(x, b["codes_packed"], b["scales_e8m0"],
                              "mxfp4", interpret=True)
    np.testing.assert_allclose(np.asarray(y_pk), np.asarray(y_u8),
                               atol=1e-5, rtol=1e-6)


def test_mx_matmul_packed_t3_equals_separate_rotate():
    """The fused T3 prologue == hadamard rotate outside, then plain GEMM."""
    from repro.core import transforms as tfm
    from repro.kernels import packing
    x = _data((8, 96), jnp.float32, seed=12)
    w = _data((96, 32), jnp.float32, seed=13, outliers=False) * 0.3
    b = packing.pack_weight(w, "mxfp4")
    h = tfm.hadamard_matrix(32, dtype=jnp.float32)
    xr = tfm.apply_blockwise(x, h)
    y_sep = ops.mx_gemm_packed(xr, b["codes_packed"], b["scales_e8m0"],
                               "mxfp4", interpret=True)
    y_fus = ops.mx_gemm_packed(x, b["codes_packed"], b["scales_e8m0"],
                               "mxfp4", t3=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y_fus), np.asarray(y_sep),
                               atol=1e-4, rtol=1e-5)


def test_mx_matmul_packed_stacked_vmap():
    """Leading (layer/expert) axes map over the kernel."""
    from repro.kernels import packing
    x = _data((3, 8, 64), jnp.float32, seed=14)
    w = _data((3, 64, 16), jnp.float32, seed=15, outliers=False) * 0.3
    b = packing.pack_weight(w, "mxfp4")
    y = ops.mx_gemm_packed(x, b["codes_packed"], b["scales_e8m0"],
                           "mxfp4", interpret=True)
    for i in range(3):
        yr = ops.mx_matmul_packed_ref(x[i], b["codes_packed"][i],
                                      b["scales_e8m0"][i], "mxfp4")
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yr),
                                   atol=1e-4, rtol=1e-5)


def test_gemm_bf16_inputs():
    x = _data((32, 128), jnp.bfloat16, seed=6)
    w = _data((128, 32), jnp.float32, seed=7, outliers=False) * 0.3
    wc, ws = ops.quantize_weight_for_kernel(w)
    y = ops.mx_gemm(x, wc, ws, interpret=True)
    yr = ops.mx_matmul_ref(x.astype(jnp.float32), wc, ws)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-2, rtol=2e-2)
