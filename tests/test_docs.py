"""Documentation health: every relative link and #anchor in README.md,
ROADMAP.md, and docs/** must resolve (the same check CI runs via
scripts/check_markdown_links.py)."""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_markdown_links_and_anchors():
    res = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "check_markdown_links.py"),
         "README.md", "ROADMAP.md", "CHANGES.md", "docs"],
        cwd=ROOT, capture_output=True, text=True)
    assert res.returncode == 0, f"\n{res.stderr}{res.stdout}"


def test_docs_cover_the_subsystems():
    """The docs/ map must exist and name the load-bearing pieces — a
    rename that orphans the docs should fail loudly here."""
    docs = ROOT / "docs"
    arch = (docs / "architecture.md").read_text()
    serving = (docs / "serving.md").read_text()
    fmt = (docs / "artifact-format.md").read_text()
    for needle in ("core/", "kernels/", "artifacts/", "serving/", "launch/"):
        assert needle in arch, f"architecture.md lost the {needle} layer"
    for needle in ("continuous", "wave", "kv_len", "scheduler"):
        assert needle in serving.lower()
    for needle in ("manifest.json", "weights.npz", "aux.npz", "E8M0",
                   "sha256", "schema_version"):
        assert needle.lower() in fmt.lower()
