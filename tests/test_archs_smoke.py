"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train (grad) step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ASSIGNED_SHAPES, SHAPES, shape_applicable
from repro.data import synthetic
from repro.models import api


def _batch(cfg, B=2, S=32, seed=0):
    src = synthetic.make_source(cfg, B, S, seed)
    b = src.batch(0)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_grad_step(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg)
    batch = _batch(cfg)
    logits = api.forward(params, cfg, batch["inputs"])
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), "NaN in forward"

    loss, grads = jax.value_and_grad(api.lm_loss)(params, cfg, batch)
    assert np.isfinite(float(loss)), "non-finite loss"
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.float32(0))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    # one SGD step moves the loss
    lr = 0.5
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
    loss2 = api.lm_loss(params2, cfg, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if configs.get(a).family != "encoder"])
def test_prefill_decode_consistency(arch):
    cfg = configs.get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = api.init(key, cfg)
    batch = _batch(cfg, B=2, S=16)
    inputs = batch["inputs"]
    full = api.forward(params, cfg, inputs)
    last, cache = api.prefill(params, cfg, inputs, max_len=32)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-3)
    if cfg.embed_inputs:
        nxt = jnp.asarray([3, 5], jnp.int32)
        ext = jnp.concatenate([inputs, nxt[:, None]], axis=1)
    else:
        nxt = jnp.asarray(_batch(cfg, B=2, S=1, seed=3)["inputs"][:, 0])
        ext = jnp.concatenate([inputs, nxt[:, None, :]], axis=1)
    lg, _ = api.decode(params, cfg, cache, nxt, jnp.int32(16))
    full2 = api.forward(params, cfg, ext)
    # MoE capacity drops can perturb; tolerance reflects that
    tol = 5e-2 if cfg.family == "moe" else 2e-4
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full2[:, -1]),
                               atol=tol, rtol=tol)


def test_full_configs_match_assignment():
    spec = {
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen2_7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen2_0_5b": (24, 896, 14, 2, 4864, 151936),
        "tinyllama_1_1b": (22, 2048, 32, 4, 5632, 32000),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
        "internvl2_26b": (48, 6144, 48, 8, 16384, 92553),
    }
    for aid, (L, d, H, K, f, V) in spec.items():
        c = configs.get(aid)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, H, K, f, V), aid
    m = configs.get("mamba2_130m")
    assert (m.n_layers, m.d_model, m.vocab_size, m.ssm_state) == \
        (24, 768, 50280, 128)
    assert configs.get("moonshot_v1_16b_a3b").n_experts == 64
    assert configs.get("moonshot_v1_16b_a3b").top_k == 6
    assert configs.get("qwen2_moe_a2_7b").n_experts == 60
    assert configs.get("qwen2_moe_a2_7b").top_k == 4
    assert configs.get("qwen2_moe_a2_7b").n_shared_experts == 4


def test_shape_skip_matrix():
    cells = 0
    skips = []
    for aid in configs.ARCH_IDS:
        cfg = configs.get(aid)
        for name in ASSIGNED_SHAPES:
            sh = SHAPES[name]
            ok, why = shape_applicable(cfg, sh)
            if ok:
                cells += 1
            else:
                skips.append((aid, sh.name, why))
    assert cells == 31, (cells, skips)
    assert ("hubert_xlarge", "decode_32k",
            "encoder-only arch has no decode step") in skips
    assert sum(1 for s in skips if s[1] == "long_500k") == 8
