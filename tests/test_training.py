"""Training substrate: optimizer, trainer convergence, checkpoint/restart
fault tolerance, deterministic data replay."""
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.data import synthetic
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.trainer import TrainConfig, Trainer


def _tiny():
    return ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      attn_chunk=64)


def test_adamw_quadratic():
    """AdamW minimizes a quadratic."""
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init_state(params)
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                          total_steps=200, schedule="constant")
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.apply_updates(params, g, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_trainer_learns_synthetic_structure(tmp_path):
    cfg = _tiny()
    tc = TrainConfig(steps=60, batch_size=8, seq_len=64, ckpt_every=1000,
                     ckpt_dir=str(tmp_path / "ck"), log_every=1000,
                     opt=opt.AdamWConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=60))
    tr = Trainer(cfg, tc, log=lambda *_: None)
    tr.init_or_resume()
    b0 = tr.source.batch(0)
    from repro.models import api
    ppl0 = api.perplexity(tr.params, cfg, jnp.asarray(b0["inputs"]))
    tr.train()
    ppl1 = tr.eval_ppl()
    # must beat the untrained model decisively (planted bigram structure)
    assert ppl1 < 0.7 * ppl0, (ppl0, ppl1)


def test_checkpoint_restart_exact_replay(tmp_path):
    """Fault tolerance: crash mid-run, restart from checkpoint, end state
    identical to an uninterrupted run (deterministic-by-step data)."""
    cfg = _tiny()

    def make(tcdir):
        return TrainConfig(steps=20, batch_size=4, seq_len=32,
                           ckpt_every=10, ckpt_dir=tcdir, log_every=1000,
                           opt=opt.AdamWConfig(lr=1e-3, warmup_steps=2,
                                               total_steps=20))

    # uninterrupted
    tr_a = Trainer(cfg, make(str(tmp_path / "a")), log=lambda *_: None)
    tr_a.train()
    # interrupted at step 13 (checkpoint exists at 10), then resumed
    tr_b = Trainer(cfg, make(str(tmp_path / "b")), log=lambda *_: None)
    with pytest.raises(RuntimeError):
        tr_b.train(fail_at=13)
    tr_b2 = Trainer(cfg, make(str(tmp_path / "b")), log=lambda *_: None)
    tr_b2.train()
    assert tr_b2.step == 20

    la = jax.tree.leaves(tr_a.params)
    lb = jax.tree.leaves(tr_b2.params)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-5)


def test_checkpoint_atomicity_and_retention(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,))}}
    for s in [1, 2, 3, 4]:
        ckpt.save(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert steps == ["step_00000003", "step_00000004"]
    restored, man = ckpt.restore(tmp_path, tree)
    assert man["step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_data_determinism():
    cfg = _tiny()
    s1 = synthetic.make_source(cfg, 4, 32, seed=7)
    s2 = synthetic.make_source(cfg, 4, 32, seed=7)
    for i in [0, 3, 17]:
        a, b = s1.batch(i), s2.batch(i)
        np.testing.assert_array_equal(a["inputs"], b["inputs"])
        np.testing.assert_array_equal(a["labels"], b["labels"])
    # different steps differ
    assert not np.array_equal(s1.batch(0)["inputs"], s1.batch(1)["inputs"])


def test_grad_accum_equivalence():
    """accum=4 must equal accum=1 up to numerics."""
    from repro.launch import steps as steps_lib
    cfg = _tiny()
    key = jax.random.PRNGKey(0)
    from repro.models import api
    params = api.init(key, cfg)
    state = opt.init_state(params)
    src = synthetic.make_source(cfg, 8, 32, 0)
    batch = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    s1 = steps_lib.make_train_step(cfg, ocfg, accum=1)
    s4 = steps_lib.make_train_step(cfg, ocfg, accum=4)
    p1, _, l1, _ = s1(params, state, batch)
    p4, _, l4, _ = s4(params, state, batch)
    assert abs(float(l1) - float(l4)) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)
