"""Kernel-dispatch execution backend: fused (packed-native Pallas) path
must match the reference path across formats, roles, T3, and weight
stackings; ineligible calls must fall back cleanly; the fused lowering
must never materialize a dense fp weight; artifact serving with
backend='fused' must reproduce reference-engine logits."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import mx as mxlib
from repro.core import ptq
from repro.core.quantize import QuantMode, qeinsum, qlinear
from repro.data import synthetic
from repro.kernels.packing import PackedWeight
from repro.models import api
from repro.serving.engine import Engine, Request

FMTS = ["mxfp4", "mxint4"]


def _packed(shape, fmt="mxfp4", seed=0, scale=0.3):
    """A PackedWeight whose dense values sit exactly on the MX grid."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)
    cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
    wq = jnp.swapaxes(mxlib.quantize(jnp.swapaxes(w, -1, -2), cfg,
                                     ste=False), -1, -2)
    return PackedWeight.from_dense(wq, fmt), wq


def _modes(fmt, t3):
    qm = QuantMode.mxfp4(t3=t3) if fmt == "mxfp4" else \
        QuantMode.mxint4(t3=t3)
    return qm, qm.with_backend("fused")


# ---------------------------------------------------------------------------
# qlinear / qeinsum parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("t3", [False, True])
@pytest.mark.parametrize("role", ["ffn_in", "ffn_down", "qkv"])
def test_qlinear_fused_matches_ref_2d(fmt, t3, role):
    pw, _ = _packed((64, 48), fmt)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 5, 64)),
                    jnp.float32)
    b = jnp.asarray(np.random.default_rng(2).standard_normal(48),
                    jnp.float32)
    qm_ref, qm_fused = _modes(fmt, t3)
    yr = qlinear(x, pw, b, qm_ref, role)
    yf = qlinear(x, pw, b, qm_fused, role)
    assert yf.dtype == yr.dtype and yf.shape == yr.shape
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yr),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("fmt", FMTS)
def test_qlinear_fused_matches_ref_stacked(fmt):
    """Layer-stacked (L, K, N) weights: leading axis becomes a vmap axis."""
    pw, _ = _packed((3, 64, 32), fmt, seed=3)
    x = jnp.asarray(np.random.default_rng(4).standard_normal((3, 6, 64)),
                    jnp.float32)
    qm_ref, qm_fused = _modes(fmt, t3=False)
    yr = qlinear(x, pw, None, qm_ref, "ffn_in")
    yf = qlinear(x, pw, None, qm_fused, "ffn_in")
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yr),
                               atol=1e-4, rtol=1e-5)


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("t3", [False, True])
@pytest.mark.parametrize("spec", ["gecd,edf->gecf", "gecf,efd->gecd"])
def test_qeinsum_expert_fused_matches_ref(fmt, t3, spec):
    role = "ffn_down" if t3 else "ffn_in"
    pw, _ = _packed((3, 64, 32), fmt, seed=5)
    x = jnp.asarray(np.random.default_rng(6).standard_normal((2, 3, 4, 64)),
                    jnp.float32)
    qm_ref, qm_fused = _modes(fmt, t3)
    yr = qeinsum(spec, x, pw, qm_ref, role)
    yf = qeinsum(spec, x, pw, qm_fused, role)
    assert yf.shape == yr.shape
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yr),
                               atol=1e-4, rtol=1e-5)


def test_qlinear_fused_bf16_activation():
    pw, _ = _packed((64, 32))
    x = jnp.asarray(np.random.default_rng(7).standard_normal((4, 64)),
                    jnp.bfloat16)
    qm_ref, qm_fused = _modes("mxfp4", t3=False)
    yr = qlinear(x, pw, None, qm_ref, "ffn_in")
    yf = qlinear(x, pw, None, qm_fused, "ffn_in")
    assert yf.dtype == yr.dtype
    np.testing.assert_allclose(np.asarray(yf, np.float32),
                               np.asarray(yr, np.float32),
                               atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# Fallbacks: ineligible calls take the reference path, identically
# ---------------------------------------------------------------------------

def test_fused_falls_back_cleanly():
    rng = np.random.default_rng(8)
    qm = QuantMode.mxfp4(backend="fused")
    # dense weight -> reference path
    w = jnp.asarray(rng.standard_normal((64, 32)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(qlinear(x, w, None, qm, "ffn_in")),
        np.asarray(qlinear(x, w, None, qm.with_backend("ref"), "ffn_in")))
    # head stays fp unless quantize_head
    pw, wq = _packed((64, 32))
    np.testing.assert_array_equal(
        np.asarray(qlinear(x, pw, None, qm, "head")),
        np.asarray(x @ wq))
    # act fmt mismatching the packed fmt -> reference path (no crash)
    pw_int, _ = _packed((64, 32), "mxint4")
    y = qlinear(x, pw_int, None, qm, "ffn_in")
    yr = qlinear(x, pw_int, None, qm.with_backend("ref"), "ffn_in")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    # odd activation batch sizes still kernel-eligible (block shrink), and
    # rank-mismatched stacked shapes fall back instead of erroring
    x3 = jnp.asarray(rng.standard_normal((2, 7, 64)), jnp.float32)
    pw3, _ = _packed((3, 64, 32))
    yr = qlinear(x3[:, :, :], pw, None, qm.with_backend("ref"), "ffn_in")
    np.testing.assert_allclose(
        np.asarray(qlinear(x3, pw, None, qm, "ffn_in")), np.asarray(yr),
        atol=1e-4, rtol=1e-5)
    with pytest.raises(Exception):
        # ref batched-matmul can't broadcast (2,7,64)@(3,64,32) either;
        # the dispatcher must not invent semantics the ref path lacks
        qlinear(x3, pw3, None, qm, "ffn_in")


def test_qeinsum_fused_rejects_rank_mismatch_like_ref():
    """A rank-mismatched activation must error under both backends, not
    silently compute under 'fused'."""
    pw, _ = _packed((3, 64, 32))
    bad = jnp.zeros((2, 3, 4, 7, 64), jnp.float32)  # spec demands rank 4
    for backend in ("ref", "fused"):
        with pytest.raises(Exception):
            qeinsum("gecd,edf->gecf", bad, pw,
                    QuantMode.mxfp4(backend=backend), "ffn_in")


def test_nvfp4_never_fuses():
    """NVFP4 (block 16, fp8 scales) has no packed layout — backend='fused'
    must leave it on the reference path."""
    qm = dataclasses.replace(QuantMode.nvfp4(t3=False), backend="fused")
    pw, _ = _packed((64, 32))
    x = jnp.asarray(np.random.default_rng(9).standard_normal((4, 64)),
                    jnp.float32)
    yr = qlinear(x, pw, None, dataclasses.replace(qm, backend="ref"),
                 "ffn_in")
    np.testing.assert_array_equal(
        np.asarray(qlinear(x, pw, None, qm, "ffn_in")), np.asarray(yr))


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        QuantMode.mxfp4(backend="cuda")


def test_skip_requant_matches_explicit_requant():
    """The reference path's decode->encode->decode skip for on-grid
    PackedWeights is bit-exact (MX pow2 quantization is idempotent)."""
    for fmt in FMTS:
        pw, wq = _packed((96, 32), fmt, seed=10)
        cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
        requant = jnp.swapaxes(
            mxlib.quantize(jnp.swapaxes(pw.to_dense(), -1, -2), cfg,
                           ste=False), -1, -2)
        np.testing.assert_array_equal(np.asarray(requant),
                                      np.asarray(pw.to_dense()))
        qm = QuantMode.mxfp4() if fmt == "mxfp4" else QuantMode.mxint4()
        x = jnp.asarray(np.random.default_rng(11).standard_normal((4, 96)),
                        jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(qlinear(x, pw, None, qm, "ffn_in")),
            np.asarray(qlinear(x, wq, None, qm, "ffn_in")))


# ---------------------------------------------------------------------------
# Lowering: the fused path must not materialize a dense fp weight
# ---------------------------------------------------------------------------

def _float_avals_of_size(fn, args, size, skip=("pallas_call",)):
    """Collect float intermediates of a given element count from the
    jaxpr of fn(*args), recursing through call primitives but NOT into
    the Pallas kernel body (in-kernel tiles are VMEM-resident by
    construction)."""
    found = []

    def visit(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name in skip:
                continue
            for v in eqn.outvars:
                aval = v.aval
                if (getattr(aval, "size", 0) == size
                        and jnp.issubdtype(aval.dtype, jnp.floating)):
                    found.append((eqn.primitive.name, aval))
            for p in eqn.params.values():
                for sub in (p if isinstance(p, (tuple, list)) else (p,)):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        visit(sub.jaxpr)
                    elif isinstance(sub, jax.core.Jaxpr):
                        visit(sub)

    visit(jax.make_jaxpr(fn)(*args).jaxpr)
    return found


def test_fused_lowering_has_no_dense_weight():
    K, N, M = 64, 96, 8
    pw, _ = _packed((K, N))
    x = jnp.asarray(np.random.default_rng(12).standard_normal((M, K)),
                    jnp.float32)

    def run(backend):
        qm = QuantMode.mxfp4(backend=backend)
        return lambda xx, c, s: qlinear(
            xx, PackedWeight(c, s, "mxfp4", "float32"), None, qm, "ffn_in")

    args = (x, pw.codes_packed, pw.scales_e8m0)
    dense_in_ref = _float_avals_of_size(run("ref"), args, K * N)
    assert dense_in_ref, "detector lost its reference signal"
    dense_in_fused = _float_avals_of_size(run("fused"), args, K * N)
    assert not dense_in_fused, (
        f"fused path materializes dense-weight-sized float buffers: "
        f"{dense_in_fused}")


# ---------------------------------------------------------------------------
# Engine / artifact integration
# ---------------------------------------------------------------------------

def _artifact(tmp_path, cfg, name, seed=0):
    from repro.artifacts import export_artifact
    params = api.init(jax.random.PRNGKey(seed), cfg)
    src = synthetic.make_source(cfg, 4, 32, 0)
    calib = [{k: jnp.asarray(v) for k, v in src.batch(i).items()}
             for i in range(2)]
    res = ptq.apply_method("rtn", params, cfg, calib, fmt="mxfp4")
    out = tmp_path / name
    export_artifact(res, cfg, out)
    toks = jnp.asarray(src.batch(50)["inputs"])[:, :16]
    return out, toks


def test_fused_forward_matches_ref_dense_artifact(tmp_path):
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                     attn_chunk=64)
    out, toks = _artifact(tmp_path, cfg, "dense")
    from repro.artifacts import load_artifact
    params, cfg2, qm = load_artifact(out)
    assert qm.backend == "ref"
    ref = np.asarray(api.forward(params, cfg2, toks, qm))
    params_f, _, qm_f = load_artifact(out, backend="fused")
    assert qm_f.backend == "fused"
    got = np.asarray(api.forward(params_f, cfg2, toks, qm_f))
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_fused_forward_matches_ref_moe_artifact(tmp_path):
    cfg = ArchConfig(name="tm", family="moe", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                     n_experts=4, top_k=2, n_shared_experts=1,
                     attn_chunk=64)
    out, toks = _artifact(tmp_path, cfg, "moe", seed=1)
    from repro.artifacts import load_artifact
    params, cfg2, qm = load_artifact(out)
    ref = np.asarray(api.forward(params, cfg2, toks, qm))
    params_f, _, qm_f = load_artifact(out, backend="fused")
    got = np.asarray(api.forward(params_f, cfg2, toks, qm_f))
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_engine_from_artifact_fused_matches_ref(tmp_path):
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                     attn_chunk=16)
    out, _ = _artifact(tmp_path, cfg, "eng")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(2)]
    ref_eng = Engine.from_artifact(out, batch_size=2, max_len=64)
    fused_eng = Engine.from_artifact(out, batch_size=2, max_len=64,
                                     backend="fused")
    assert fused_eng.qm.backend == "fused"
    ref = ref_eng.generate([Request(prompt=p, max_new=6) for p in prompts])
    got = fused_eng.generate([Request(prompt=p, max_new=6) for p in prompts])
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)


def test_wave_bucketing_counts_compiles():
    """Distinct prompt lengths inside one chunk bucket must reuse one
    prefill compile; the count is surfaced in throughput() output."""
    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                     attn_chunk=16)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64)
    rng = np.random.default_rng(0)

    def wave(lengths):
        eng.generate([Request(prompt=rng.integers(
            0, 128, s).astype(np.int32), max_new=2) for s in lengths])

    wave([9, 12])    # bucket 16
    wave([13, 15])   # bucket 16 again -> no new compile
    assert eng.prefill_compiles == 1
    wave([17, 20])   # bucket 32
    assert eng.prefill_compiles == 2
    stats = eng.throughput(n_requests=2, prompt_len=8, max_new=2)
    assert stats["prefill_compiles"] == eng.prefill_compiles
    assert stats["backend"] == "ref"


def test_wave_bucketing_respects_cache_budget():
    """When rounding up would overflow max_len - max_new, the raw length
    is kept (old behavior) so decode never writes past the cache."""
    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                     attn_chunk=64)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=64)
    assert eng._bucket_len(12, max_new=6) == 12   # 64 + 6 > 64 -> raw
    assert eng._bucket_len(12, max_new=0) == 64   # fits -> bucket
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, 128, 12).astype(np.int32),
                    max_new=6)]
    done = eng.generate(reqs)
    assert len(done[0].out) == 6


def test_bucketing_opt_out_preserves_unpadded_waves():
    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                     attn_chunk=16)
    params = api.init(jax.random.PRNGKey(0), cfg)
    on = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=64)
    off = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=64,
                 bucket_prompts=False)
    assert on._bucket_len(9, max_new=2) == 16
    assert off._bucket_len(9, max_new=2) == 9
    # unbucketed single-prompt wave matches teacher forcing even for a
    # length off the chunk grid (no attended pad tokens)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 128, 9).astype(np.int32)
    done = off.generate([Request(prompt=prompt, max_new=4)])
    seq = list(prompt)
    for tok in done[0].out:
        logits = api.forward(params, cfg, jnp.asarray([seq], jnp.int32))
        assert int(jnp.argmax(logits[0, -1])) == int(tok)
        seq.append(int(tok))
