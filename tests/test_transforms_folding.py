"""Transform parameterizations, volume regularizer, folding exactness,
computational invariance — unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import seed_property

from repro.configs.base import ArchConfig
from repro.core import folding as fl
from repro.core import mx as mxlib
from repro.core import transforms as tfm
from repro.core.quantize import QuantMode
from repro.models import api, transformer as dense

KINDS = ["lu", "qr", "orthogonal", "invertible", "hadamard",
         "block_hadamard", "kron", "identity"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("granularity", ["full", "block"])
def test_invertibility(kind, granularity):
    if granularity == "block" and kind in ("hadamard", "kron", "identity",
                                           "block_hadamard"):
        pytest.skip("granularity applies to learned kinds")
    spec = tfm.TransformSpec(kind=kind, d=64, block=32,
                             granularity=granularity)
    p = tfm.init_params(jax.random.PRNGKey(0), spec)
    a, v = tfm.materialize(p, spec)
    err = float(jnp.max(jnp.abs(a @ tfm.inverse(a) - jnp.eye(64))))
    assert err < 1e-3
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 64))
    rt = tfm.backward(tfm.forward(x, a, v), tfm.inverse(a), v)
    np.testing.assert_allclose(np.asarray(rt), np.asarray(x), atol=1e-3)


def test_volume_regularizer_zero_at_rotation_init():
    spec = tfm.TransformSpec(kind="lu", d=64, block=32, init_noise=0.0)
    p = tfm.init_params(jax.random.PRNGKey(2), spec)
    assert float(tfm.loss_vol(p, spec)) < 1e-6


@seed_property(max_examples=20)
def test_property_lu_determinant_matches_logs(seed):
    """|det A| == exp(Σ log|s|) for the LU parameterization."""
    spec = tfm.TransformSpec(kind="lu", d=32, block=16)
    p = tfm.init_params(jax.random.PRNGKey(seed), spec)
    a, _ = tfm.materialize(p, spec)
    logdet = float(jnp.linalg.slogdet(a)[1])
    assert abs(logdet - float(jnp.sum(p["learn"]["logs"]))) < 1e-3


@seed_property(max_examples=15)
def test_property_hadamard_preserves_norm(seed):
    x = np.random.default_rng(seed).standard_normal((4, 64)).astype(np.float32)
    h = tfm.random_hadamard(jax.random.PRNGKey(seed), 64)
    y = jnp.asarray(x) @ h
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(x, axis=-1), rtol=1e-4)


def test_theorem_ordering_on_outlier_data():
    """Numerical check of the Section 3.1 ordering: learned-affine-style
    full transforms can beat block-Hadamard which beats identity on
    outlier-heavy data (C1)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 128))
    x = x.at[:, 3].mul(40.0).at[:, 77].mul(25.0)
    cfg = mxlib.MXConfig(fmt="mxfp4")
    errs = {}
    for kind in ["identity", "hadamard", "block_hadamard"]:
        spec = tfm.TransformSpec(kind=kind, d=128, block=32)
        p = tfm.init_params(jax.random.PRNGKey(1), spec)
        a, v = tfm.materialize(p, spec)
        errs[kind] = float(tfm.transform_mse(x, a, v, cfg))
    assert errs["block_hadamard"] < errs["identity"]
    assert errs["hadamard"] < errs["identity"]


def _tiny_cfg(**kw):
    return ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                      attn_chunk=64, **kw)


def test_identity_fold_is_exact():
    cfg = _tiny_cfg(qkv_bias=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
    ref = api.forward(params, cfg, toks)
    pn = api.fold_norms(params, cfg)
    ts = fl.identity_set(cfg.d_model, cfg.n_layers, cfg.head_dim,
                         t3_block=32)
    pf = api.fold(pn, cfg, ts)
    out = api.forward(pf, cfg, toks, QuantMode.off(t3=32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_rotation_fold_computational_invariance():
    """Orthogonal T1/T2 with zero bias keep the FP model exactly
    equivalent (Ashkboos et al. invariance; paper Section 3.2)."""
    cfg = _tiny_cfg()
    params = api.init(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 97)
    ref = api.forward(params, cfg, toks)
    pn = api.fold_norms(params, cfg)
    s1 = tfm.TransformSpec(kind="orthogonal", d=cfg.d_model, block=32,
                           learn_bias=False)
    a1, _ = tfm.materialize(tfm.init_params(jax.random.PRNGKey(4), s1), s1)
    s2 = tfm.TransformSpec(kind="orthogonal", d=cfg.head_dim, block=16,
                           learn_bias=False)
    a2, _ = tfm.materialize(tfm.init_params(jax.random.PRNGKey(5), s2), s2)
    ts = fl.TransformSet(
        a1=a1, v1=jnp.zeros(cfg.d_model),
        a2=jnp.tile(a2[None], (cfg.n_layers, 1, 1)),
        v2=jnp.zeros((cfg.n_layers, cfg.head_dim)), t3_block=32)
    pf = api.fold(pn, cfg, ts)
    out = api.forward(pf, cfg, toks, QuantMode.off(t3=32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


@pytest.mark.parametrize("family,arch", [("moe", "moonshot_v1_16b_a3b"),
                                         ("ssm", "mamba2_130m"),
                                         ("hybrid", "recurrentgemma_2b")])
def test_identity_fold_other_families(family, arch):
    from repro import configs
    cfg = configs.get_reduced(arch)
    params = api.init(jax.random.PRNGKey(0), cfg)
    from repro.data import synthetic
    b = synthetic.make_source(cfg, 2, 16, 0).batch(0)
    inp = jnp.asarray(b["inputs"])
    ref = api.forward(params, cfg, inp)
    pn = api.fold_norms(params, cfg)
    n_t2 = cfg.n_super_blocks if family == "hybrid" else cfg.n_layers
    hd = cfg.head_dim if cfg.n_heads else 16
    ts = fl.identity_set(cfg.d_model, n_t2, hd, t3_block=32)
    pf = api.fold(pn, cfg, ts)
    out = api.forward(pf, cfg, inp, QuantMode.off(t3=32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=1e-3)
