"""MX quantization invariants — unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import scale_seed_property, seed_property

from repro.core import mx as mxlib


@pytest.mark.parametrize("fmt", ["mxfp4", "mxint4", "mxfp8", "mxfp6"])
def test_scales_are_powers_of_two(fmt):
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 128)) * 10
    s = np.asarray(mxlib.compute_scales(x, mxlib.MXConfig(fmt=fmt)))
    np.testing.assert_array_equal(np.log2(s), np.round(np.log2(s)))


def test_idempotent():
    cfg = mxlib.MXConfig()
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64)) * 5
    q1 = mxlib.quantize(x, cfg, ste=False)
    q2 = mxlib.quantize(q1, cfg, ste=False)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-7)


def test_encode_decode_roundtrip():
    for fmt in ["mxfp4", "mxint4", "mxfp8"]:
        cfg = mxlib.MXConfig(fmt=fmt)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 96)) * 3
        c, s = mxlib.encode(x, cfg)
        dec = mxlib.decode(c, s, cfg)
        q = mxlib.quantize(x, cfg, ste=False)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(q), atol=1e-6)


def test_ste_gradient_is_identity():
    cfg = mxlib.MXConfig()
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    g = jax.grad(lambda z: jnp.sum(mxlib.quantize(z, cfg) * 2.0))(x)
    np.testing.assert_allclose(np.asarray(g), 2.0)


def test_nvfp4_block16():
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 64)) * 4
    q = mxlib.quantize(x, mxlib.NVFP4, ste=False)
    assert q.shape == x.shape
    assert np.isfinite(np.asarray(q)).all()


@scale_seed_property(max_examples=30)
def test_property_relative_error_bound(scale, seed):
    """MX FP4 relative block error is bounded: per-element error <= half the
    largest grid step times the block scale => block-relative error < 2/3."""
    cfg = mxlib.MXConfig(fmt="mxfp4")
    x = np.random.default_rng(seed).standard_normal((2, 64)) * scale
    x = jnp.asarray(x, jnp.float32)
    q = mxlib.quantize(x, cfg, ste=False)
    xb = np.asarray(x).reshape(2, 2, 32)
    qb = np.asarray(q).reshape(2, 2, 32)
    amax = np.abs(xb).max(-1, keepdims=True)
    # FP4 max quantization step is 1 at scale 2^e where amax < 8*2^e
    # => |err| <= scale = 2^e <= amax/4; elementwise err <= amax/4 (+eps)
    assert (np.abs(xb - qb) <= amax / 4 + 1e-6).all()


@seed_property(max_examples=30)
def test_property_quantized_value_magnitude(seed):
    """|Q(x)| never exceeds max-grid x scale and sign is preserved."""
    cfg = mxlib.MXConfig(fmt="mxint4")
    x = np.random.default_rng(seed).standard_normal((4, 32)).astype(np.float32)
    q = np.asarray(mxlib.quantize(jnp.asarray(x), cfg, ste=False))
    assert ((q == 0) | (np.sign(q) == np.sign(x))).all()
    s = np.asarray(mxlib.compute_scales(jnp.asarray(x), cfg))  # (4, 1)
    assert (np.abs(q) <= s * 7 + 1e-9).all()


def test_packed_nbytes():
    cfg = mxlib.MXConfig(fmt="mxfp4", block_size=32)
    # 4-bit codes: n/2 bytes; scales: n/32 bytes
    assert mxlib.packed_nbytes((64, 64), cfg) == 64 * 64 // 2 + 64 * 64 // 32
