"""Fault-tolerant request lifecycle + deterministic chaos harness.

Covers the policy layer (states, queue, victim selection), the seeded
FaultInjector, BlockAllocator invariants under randomized chaos, and the
engine's failure paths end to end: deadlines, cancellation, preemption
with bit-identical resume, per-lane NaN isolation, and full quiescence
under a mixed seeded fault plan (docs/robustness.md)."""
import time

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.models import api
from repro.serving.engine import BlockAllocator, Engine, Request
from repro.serving.faults import FaultInjector, corrupt_file
from repro.serving.policy import (RequestQueue, RequestState,
                                  SchedulingPolicy, SpecConfig,
                                  TERMINAL_STATES, pick_victim)
from repro.serving.sampling import SamplingParams


def _cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                attn_chunk=16)
    base.update(kw)
    return ArchConfig(**base)


@pytest.fixture(scope="module")
def tiny():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _requests(cfg, lens, news, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, s)
                    .astype(np.int32), max_new=n, **kw)
            for s, n in zip(lens, news)]


# ---------------------------------------------------------------------------
# FaultInjector: scripted, seeded, replayable
# ---------------------------------------------------------------------------

def test_injector_at_fires_exactly_once():
    fi = FaultInjector()
    fi.inject("p", at=2, lane=1)
    hits = [fi.fire("p") for _ in range(6)]
    assert [h is not None for h in hits] == [False, False, True,
                                             False, False, False]
    assert hits[2] == {"lane": 1}
    assert fi.fired("p") == 1 and fi.calls("p") == 6


def test_injector_at_with_times_fires_consecutively():
    fi = FaultInjector()
    fi.inject("p", at=1, times=3)
    hits = [fi.fire("p") is not None for _ in range(6)]
    assert hits == [False, True, True, True, False, False]


def test_injector_every_and_times():
    fi = FaultInjector()
    fi.inject("p", every=3, times=2)
    hits = [fi.fire("p") is not None for _ in range(9)]
    # fires on the 3rd and 6th invocation, then the cap stops it
    assert hits == [False, False, True, False, False, True,
                    False, False, False]


def test_injector_prob_is_seed_deterministic():
    def run(seed):
        fi = FaultInjector(seed=seed)
        fi.inject("p", prob=0.5)
        return [fi.fire("p") is not None for _ in range(32)]

    a, b = run(7), run(7)
    assert a == b                       # same seed -> same firing pattern
    assert run(8) != a                  # and the seed matters
    assert 1 <= sum(a) <= 31            # a real coin, not a constant


def test_injector_context_merges_under_payload():
    fi = FaultInjector()
    fi.inject("p", delay_s=0.5)
    hit = fi.fire("p", delay_s=0.1, step=4)
    assert hit == {"delay_s": 0.5, "step": 4}   # payload wins, context rides
    assert fi.summary()["fired"]["p"] == 1
    assert fi.log == [("p", 0, {"delay_s": 0.5, "step": 4})]


def test_injector_rejects_conflicting_triggers():
    with pytest.raises(ValueError, match="at most one"):
        FaultInjector().inject("p", at=1, every=2)


def test_corrupt_file_flip_and_truncate(tmp_path):
    f = tmp_path / "blob.bin"
    payload = bytes(range(256)) * 8
    f.write_bytes(payload)
    info = corrupt_file(f, mode="flip", offset=100, nbytes=2,
                        within=tmp_path)
    assert info["mode"] == "flip" and info["offset"] == 100
    got = f.read_bytes()
    assert got[100] == payload[100] ^ 0xFF and got[99] == payload[99]

    f.write_bytes(payload)
    info = corrupt_file(f, mode="truncate", offset=64, within=tmp_path)
    assert f.stat().st_size == 64 and info["size"] == 64

    # same seed -> same damage (replayable chaos)
    f.write_bytes(payload)
    a = corrupt_file(f, seed=3, within=tmp_path)
    f.write_bytes(payload)
    b = corrupt_file(f, seed=3, within=tmp_path)
    assert a == b


def test_corrupt_file_refuses_outside_within(tmp_path):
    inside = tmp_path / "sub"
    inside.mkdir()
    f = tmp_path / "precious.bin"
    f.write_bytes(b"x" * 64)
    with pytest.raises(ValueError, match="refusing"):
        corrupt_file(f, within=inside)
    assert f.read_bytes() == b"x" * 64


# ---------------------------------------------------------------------------
# Policy layer: queue ordering, backoff holds, victim selection
# ---------------------------------------------------------------------------

def _qreq(priority=0, not_before=0.0):
    r = Request(prompt=np.zeros(4, np.int32), max_new=4,
                priority=priority)
    r.state = RequestState.QUEUED
    r.not_before = not_before
    return r


def test_queue_priority_then_fifo():
    q = RequestQueue()
    lo1, lo2, hi = _qreq(0), _qreq(0), _qreq(5)
    for r in (lo1, lo2, hi):
        q.push(r)
    assert q.pop(0.0) is hi
    assert q.pop(0.0) is lo1            # FIFO within a priority level
    assert q.pop(0.0) is lo2
    assert q.pop(0.0) is None


def test_queue_push_front_beats_same_priority_peers():
    q = RequestQueue()
    a, b, c = _qreq(), _qreq(), _qreq()
    q.push(a)
    q.push(b)
    q.push_front(c)                     # a requeued/preempted request
    assert q.pop(0.0) is c


def test_queue_drops_non_queued_lazily():
    q = RequestQueue()
    a, b = _qreq(), _qreq()
    q.push(a)
    q.push(b)
    a.state = RequestState.CANCELLED
    assert len(q) == 1
    assert q.pop(0.0) is b


def test_queue_backoff_hold_and_delay():
    q = RequestQueue()
    held = _qreq(priority=9, not_before=100.0)
    ready = _qreq(priority=0)
    q.push(held)
    q.push(ready)
    assert q.pop(50.0) is ready          # high-pri entry is held, skip it
    assert q.pop(50.0) is None
    assert q.next_eligible_delay(50.0) == pytest.approx(50.0)
    assert q.pop(100.5) is held          # hold expired
    assert q.next_eligible_delay(0.0) is None


def test_queue_peek_preserves_order():
    q = RequestQueue()
    a = _qreq(priority=2)
    q.push(a)
    assert q.peek(0.0) is a
    assert len(q) == 1 and q.pop(0.0) is a


def test_pick_victim_strictness_and_tiebreaks():
    def slot(pri, gen_n):
        r = _qreq(priority=pri)
        r._gen = list(range(gen_n))
        return r

    lanes = [(0, slot(1, 5)), (1, slot(0, 7)), (2, slot(0, 3))]
    # lowest priority first, then least progress
    assert pick_victim(lanes) == 2
    # strict <: nothing below priority 0 -> no victim (livelock-free)
    assert pick_victim(lanes, max_priority=0) is None
    assert pick_victim(lanes, max_priority=1) == 2
    assert pick_victim([]) is None


def test_policy_backoff_schedule():
    p = SchedulingPolicy(backoff_base_s=0.01)
    assert p.backoff_s(1) == pytest.approx(0.01)
    assert p.backoff_s(3) == pytest.approx(0.04)
    assert RequestState.FINISHED.terminal
    assert not RequestState.RUNNING.terminal
    assert len(TERMINAL_STATES) == 6
    assert RequestState.SHED in TERMINAL_STATES


# ---------------------------------------------------------------------------
# BlockAllocator invariants under randomized chaos (property-style)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_allocator_invariants_under_chaos(seed):
    """Seeded interleaving of alloc/incref/decref/register/lookup/
    flush_cache: the free/cached/referenced partition must hold after
    every single operation, refcounts return to zero, and nothing
    leaks."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(n_pages=17, page_size=32, reserved=1)
    held = []                           # [(page, extra_refs)]
    next_hash = [0]

    def op_alloc():
        n = int(rng.integers(1, 4))
        pages = alloc.alloc(n)
        if pages is not None:
            held.extend((p, 0) for p in pages)

    def op_release():
        if not held:
            return
        i = int(rng.integers(len(held)))
        p, extra = held.pop(i)
        for _ in range(extra + 1):
            alloc.decref(p)

    def op_incref():
        if not held:
            return
        i = int(rng.integers(len(held)))
        p, extra = held[i]
        alloc.incref(p)
        held[i] = (p, extra + 1)

    def op_register():
        if not held:
            return
        p, _ = held[int(rng.integers(len(held)))]
        alloc.register(f"h{next_hash[0]}", p)
        next_hash[0] += 1

    def op_lookup():
        if next_hash[0]:
            alloc.lookup(f"h{int(rng.integers(next_hash[0]))}")

    def op_flush():
        alloc.flush_cache()

    ops = [op_alloc, op_alloc, op_release, op_release, op_incref,
           op_register, op_lookup, op_flush]
    for _ in range(400):
        ops[int(rng.integers(len(ops)))]()
        acct = alloc.check()            # raises on any violation
        assert (acct["in_use"] + acct["free"] + acct["cached"]
                == alloc.capacity)

    # drain: return every ref; no page may leak
    while held:
        p, extra = held.pop()
        for _ in range(extra + 1):
            alloc.decref(p)
    acct = alloc.check()
    assert acct["in_use"] == 0
    assert acct["free"] + acct["cached"] == alloc.capacity
    alloc.flush_cache()
    assert alloc.free == alloc.capacity


def test_allocator_check_catches_corruption():
    alloc = BlockAllocator(n_pages=4, page_size=32, reserved=1)
    alloc.check()
    pages = alloc.alloc(2)
    alloc.check()
    alloc._free.append(pages[0])        # simulate a double-free bug
    with pytest.raises(AssertionError, match="two states"):
        alloc.check()


# ---------------------------------------------------------------------------
# Engine lifecycle: cancellation
# ---------------------------------------------------------------------------

def test_cancel_queued_and_running(tiny):
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=64,
                 scheduler="continuous")
    running, queued = _requests(cfg, [12, 12], [16, 8], seed=1)
    # a (far-future) deadline caps the decode burst, so one step() leaves
    # the request mid-flight — that's the state cancel() must handle
    running.deadline_ms = 1e7
    eng.submit(running)
    eng.submit(queued)
    eng.step()                          # admits `running`; `queued` waits
    assert running.state is RequestState.RUNNING
    assert eng.cancel(queued.request_id)
    assert queued.state is RequestState.CANCELLED
    assert queued.error == "cancelled by client"
    assert len(queued.out) == 0

    assert eng.cancel(running.request_id)
    assert running.state is RequestState.CANCELLED
    assert 0 < len(running.out) < running.max_new   # partial tokens kept
    assert not eng.busy                 # lane freed mid-flight
    assert not eng.cancel(running.request_id)       # idempotent
    assert not eng.cancel("no-such-id")
    st = eng.stats()
    assert st["terminal"]["cancelled"] == 2
    assert st["submitted"] == 2


def test_cancel_running_paged_derefs_pages(tiny):
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32)
    req = _requests(cfg, [20], [16], seed=2, deadline_ms=1e7)[0]
    eng.submit(req)                     # deadline caps the burst: the
    eng.step()                          # request is mid-flight after one step
    assert eng._alloc.in_use > 0
    assert eng.cancel(req.request_id)
    assert eng._alloc.in_use == 0       # pages deref'd mid-flight
    eng._alloc.check()


# ---------------------------------------------------------------------------
# Engine lifecycle: deadlines
# ---------------------------------------------------------------------------

def test_queued_deadline_expires_without_prefill(tiny):
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=64,
                 scheduler="continuous")
    ok_req, doomed = _requests(cfg, [12, 12], [4, 4], seed=3)
    doomed.ttft_deadline_ms = 0.0       # expired the moment it queues
    eng.submit(ok_req)
    eng.submit(doomed)
    done = eng.drain()
    assert set(done) == {ok_req, doomed}
    assert doomed.state is RequestState.TIMED_OUT
    assert "TTFT deadline" in doomed.error and "queued" in doomed.error
    assert len(doomed.out) == 0
    assert ok_req.state is RequestState.FINISHED
    st = eng.stats()
    assert st["terminal"]["timed_out"] == 1
    assert st["terminal"]["finished"] == 1
    # no first token -> no TTFT sample (a zero would fake a great p99)
    assert eng.metrics.get("serving_ttft_seconds").count == 1


def test_running_deadline_times_out_mid_decode(tiny):
    params, cfg = tiny
    fi = FaultInjector().inject("slow_step", every=1, delay_s=0.03)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=128,
                 scheduler="continuous", faults=fi,
                 policy=SchedulingPolicy(deadline_burst_cap=2))
    # admit under a far-future deadline (caps bursts at 2, so the
    # request is mid-flight after one step), then tighten it to one
    # that has already elapsed — robust to arbitrary host load, unlike
    # racing a real small deadline against jit/scheduler latency
    req = _requests(cfg, [12], [96], seed=4, deadline_ms=1e7)[0]
    eng.submit(req)
    eng.step()
    assert req.state is RequestState.RUNNING and len(req._gen) > 0
    req.deadline_ms = 0.1
    done = eng.drain()
    assert done == [req]
    assert req.state is RequestState.TIMED_OUT
    assert "end-to-end deadline" in req.error
    assert 0 < len(req.out) < req.max_new       # partial output delivered
    assert fi.fired("slow_step") >= 1


def test_policy_default_deadline_applies_at_submit(tiny):
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=64,
                 scheduler="continuous",
                 policy=SchedulingPolicy(deadline_ms=0.0))
    explicit, defaulted = _requests(cfg, [8, 8], [4, 4], seed=5)
    explicit.deadline_ms = 10_000.0     # own deadline survives the policy
    eng.submit(explicit)
    eng.submit(defaulted)
    eng.drain()
    assert explicit.state is RequestState.FINISHED
    assert defaulted.state is RequestState.TIMED_OUT
    assert defaulted.deadline_ms == 0.0


# ---------------------------------------------------------------------------
# Engine lifecycle: NaN/Inf guard isolates the poisoned lane
# ---------------------------------------------------------------------------

def test_nan_guard_isolates_lane_continuous(tiny):
    params, cfg = tiny
    lens, news = [12, 17], [8, 8]
    clean = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                   scheduler="continuous")
    ref = clean.generate(_requests(cfg, lens, news, seed=6))

    fi = FaultInjector().inject("nan_logits", at=2, lane=1)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", faults=fi)
    reqs = _requests(cfg, lens, news, seed=6)
    eng.generate(reqs)
    victim, neighbor = reqs[1], reqs[0]
    assert victim.state is RequestState.FAILED
    assert "non-finite logits" in victim.error
    assert len(victim.out) < victim.max_new
    # its already-emitted tokens are the fault-free prefix
    np.testing.assert_array_equal(victim.out,
                                  ref[1].out[:len(victim.out)])
    # the neighbor lane is bit-identical to the fault-free run
    assert neighbor.state is RequestState.FINISHED
    np.testing.assert_array_equal(neighbor.out, ref[0].out)
    assert eng.stats()["nan_guard_trips"] == 1


def test_nan_guard_isolates_lane_wave(tiny):
    params, cfg = tiny
    lens, news = [12, 17], [8, 8]
    clean = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64)
    ref = clean.generate(_requests(cfg, lens, news, seed=6))

    fi = FaultInjector().inject("nan_logits", at=2, lane=0)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 faults=fi)
    reqs = _requests(cfg, lens, news, seed=6)
    eng.generate(reqs)
    victim, neighbor = reqs[0], reqs[1]
    assert victim.state is RequestState.FAILED
    assert len(victim.out) == 3         # prefill tok + 2 clean steps
    np.testing.assert_array_equal(victim.out, ref[0].out[:3])
    assert neighbor.state is RequestState.FINISHED
    np.testing.assert_array_equal(neighbor.out, ref[1].out)


# ---------------------------------------------------------------------------
# Engine lifecycle: preemption + bit-identical resume
# ---------------------------------------------------------------------------

def test_preemption_resumes_bit_identically(tiny):
    """Pool fits one request: a higher-priority arrival preempts the
    running low-priority request (pages deref'd, requeued with backoff);
    both finish and the preempted request's output is bit-identical to
    an uninterrupted run — greedy resume over prompt+emitted tokens."""
    params, cfg = tiny

    def mk():
        # lo's far-future deadline caps its decode bursts, so it is
        # still mid-flight when hi arrives (tokens are unaffected)
        lo = _requests(cfg, [40], [10], seed=7, priority=0,
                       deadline_ms=1e7)[0]
        hi = _requests(cfg, [38], [8], seed=8, priority=5)[0]
        return lo, hi

    # fault-free reference: each runs alone
    solo = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                  scheduler="continuous", kv_layout="paged", page_size=32,
                  n_pages=3)
    lo_ref, hi_ref = mk()
    solo.generate([lo_ref])
    solo.generate([hi_ref])

    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=3,
                 policy=SchedulingPolicy(backoff_base_s=0.001))
    lo, hi = mk()
    eng.submit(lo)
    eng.step()                          # lo admitted, takes both pages
    assert lo.state is RequestState.RUNNING
    eng.submit(hi)
    eng.drain()
    assert hi.state is RequestState.FINISHED
    assert lo.state is RequestState.FINISHED
    assert lo.preemptions >= 1
    assert eng.stats()["preemptions"] >= 1
    np.testing.assert_array_equal(lo.out, lo_ref.out)
    np.testing.assert_array_equal(hi.out, hi_ref.out)
    assert eng._alloc.in_use == 0
    eng._alloc.check()


def test_preemption_retry_budget_exhausts_to_terminal(tiny):
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=3,
                 policy=SchedulingPolicy(max_retries=0))
    lo = _requests(cfg, [40], [10], seed=7, priority=0,
                   deadline_ms=1e7)[0]
    hi = _requests(cfg, [38], [8], seed=8, priority=5)[0]
    eng.submit(lo)
    eng.step()
    eng.submit(hi)
    eng.drain()
    assert hi.state is RequestState.FINISHED
    assert lo.state is RequestState.PREEMPTED   # out of retry budget
    assert "retry budget" in lo.error
    assert len(lo.out) >= 1             # partial tokens delivered
    st = eng.stats()
    assert st["terminal"]["preempted"] == 1
    assert eng._alloc.in_use == 0


def test_equal_priority_never_preempts(tiny):
    """Strictly-lower-priority victims only: same-priority contention
    falls back to backpressure (the pre-lifecycle behavior), which is
    what makes preemption livelock-free."""
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=3)
    reqs = _requests(cfg, [40, 38], [8, 8], seed=9)
    eng.generate(reqs)
    assert [r.state for r in reqs] == [RequestState.FINISHED] * 2
    assert eng.stats()["preemptions"] == 0


# ---------------------------------------------------------------------------
# Full chaos scenario: seeded faults -> quiescence, nothing leaks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [None, SpecConfig(k=3)],
                         ids=["plain", "spec"])
def test_chaos_scenario_reaches_quiescence(tiny, spec):
    """Mixed seeded fault plan (forced exhaustion, forced cache flush,
    NaN lane, slow steps) over mixed-priority traffic with a cancel, a
    zero-deadline request, and a never-fit request: the engine reaches
    quiescence with every request terminal, terminal counters summing
    to submitted, and zero leaked pages.

    Runs twice: the plain decode path, and the same scenario under
    speculative decoding + per-request sampling (the spec verify /
    rollback path must uphold the same lifecycle + page-accounting
    invariants — the rollback property test of docs/sampling.md)."""
    params, cfg = tiny
    fi = (FaultInjector(seed=0)
          .inject("alloc_exhausted", at=1, times=2)
          .inject("evict_cache", at=2)
          .inject("nan_logits", at=5, lane=0)
          .inject("slow_step", every=4, delay_s=0.001))
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=5,
                 policy=SchedulingPolicy(backoff_base_s=0.001),
                 faults=fi, spec=spec)
    reqs = _requests(cfg, [20, 40, 12, 33, 8], [6, 10, 4, 8, 5], seed=10,
                     deadline_ms=1e7)   # far-future: caps bursts only
    for pri, r in zip([0, 0, 3, 1, 0], reqs):
        r.priority = pri
    if spec is not None:                # mixed greedy + sampled lanes
        for i, r in enumerate(reqs[::2]):
            r.sampling = SamplingParams(temperature=0.8, top_k=12,
                                        seed=i)
    reqs.append(Request(prompt=np.zeros(60, np.int32), max_new=40))  # never fits
    doomed = _requests(cfg, [10], [4], seed=11, deadline_ms=0.0)[0]
    reqs.append(doomed)
    for r in reqs:
        eng.submit(r)
    steps = 0
    while not any(r.state is RequestState.RUNNING for r in reqs):
        eng.step()
        steps += 1
        assert steps < 50, "nothing ever ran"
    victim = next(r for r in reqs if r.state is RequestState.RUNNING)
    assert eng.cancel(victim.request_id)

    steps = 0
    while eng.busy:
        eng.step()
        steps += 1
        assert steps < 500, "chaos scenario failed to reach quiescence"
        eng._alloc.check()              # invariants hold mid-flight too

    assert all(r.state in TERMINAL_STATES for r in reqs)
    st = eng.stats()
    assert st["submitted"] == len(reqs)
    assert sum(st["terminal"].values()) == st["submitted"]
    assert st["terminal"]["cancelled"] == 1
    assert st["terminal"]["timed_out"] == 1
    assert st["terminal"]["failed"] >= 1        # never-fit (+ maybe NaN)
    assert st["blocks_in_use"] == 0             # zero leaked pages
    acct = eng._alloc.check()
    assert acct["in_use"] == 0
    assert fi.fired("alloc_exhausted") == 2
    assert fi.fired("evict_cache") == 1
    # the plan is replayable: the summary records every firing
    assert [e["point"] for e in fi.summary()["log"]].count(
        "alloc_exhausted") == 2


def test_wave_never_fit_is_terminal_failed(tiny):
    params, cfg = tiny
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=32)
    big = Request(prompt=np.zeros(30, np.int32), max_new=40)
    ok_req = _requests(cfg, [8], [4], seed=12)[0]
    eng.submit(big)
    eng.submit(ok_req)
    done = eng.drain()
    assert set(done) == {big, ok_req}
    assert big.state is RequestState.FAILED
    assert "never fit" in big.error
    assert ok_req.state is RequestState.FINISHED
