"""MX artifact store: export -> load -> forward bit-exactness vs the
in-memory PTQResult (dense + MoE), manifest/hash tamper detection, packed
byte accounting, engine + CLI integration."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.artifacts import (ArtifactError, IntegrityError, export_artifact,
                             load_artifact, verify_artifact)
from repro.artifacts.cli import main as cli_main
from repro.artifacts.manifest import MANIFEST_FILE, WEIGHTS_FILE
from repro.configs.base import ArchConfig
from repro.core import mx as mxlib, ptq
from repro.core.quantize import QuantMode
from repro.data import synthetic
from repro.kernels.packing import PackedWeight
from repro.models import api
from repro.serving.engine import Engine, Request


def _dense_cfg():
    return ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      attn_chunk=64)


def _moe_cfg():
    return ArchConfig(name="tm", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                      n_experts=4, top_k=2, n_shared_experts=1,
                      attn_chunk=64)


def _quantized(cfg, method="rtn", fmt="mxfp4", seed=0):
    params = api.init(jax.random.PRNGKey(seed), cfg)
    src = synthetic.make_source(cfg, 4, 32, 0)
    calib = [{k: jnp.asarray(v) for k, v in src.batch(i).items()}
             for i in range(2)]
    toks = jnp.asarray(src.batch(50)["inputs"])[:, :16]
    res = ptq.apply_method(method, params, cfg, calib, fmt=fmt, steps=6)
    return res, toks


@pytest.fixture(scope="module")
def dense_artifact(tmp_path_factory):
    cfg = _dense_cfg()
    res, toks = _quantized(cfg)
    out = tmp_path_factory.mktemp("art") / "dense"
    export_artifact(res, cfg, out)
    return cfg, res, toks, out


@pytest.mark.parametrize("eager", [False, True])
def test_export_load_forward_bit_exact_dense(dense_artifact, eager):
    cfg, res, toks, out = dense_artifact
    ref = np.asarray(api.forward(res.params, cfg, toks, res.qm))
    params, cfg2, qm = load_artifact(out, eager=eager)
    assert cfg2 == cfg
    got = np.asarray(api.forward(params, cfg2, toks, qm))
    np.testing.assert_array_equal(got, ref)


def test_lazy_load_keeps_weights_packed(dense_artifact):
    _, _, _, out = dense_artifact
    params, _, _ = load_artifact(out)
    assert isinstance(params["blocks"]["wq"], PackedWeight)
    assert params["blocks"]["wq"].codes_packed.dtype == jnp.uint8
    assert isinstance(params["head"], jax.Array)  # head stays fp
    eager, _, _ = load_artifact(out, eager=True)
    assert isinstance(eager["blocks"]["wq"], jax.Array)


def test_export_load_forward_bit_exact_moe(tmp_path):
    cfg = _moe_cfg()
    res, toks = _quantized(cfg, seed=1)
    out = tmp_path / "moe"
    export_artifact(res, cfg, out)
    ref = np.asarray(api.forward(res.params, cfg, toks, res.qm))
    params, cfg2, qm = load_artifact(out)
    for k in ("router", "eg", "eu", "ed", "sg"):
        assert isinstance(params["blocks"][k], PackedWeight)
    got = np.asarray(api.forward(params, cfg2, toks, qm))
    np.testing.assert_array_equal(got, ref)


def test_export_mxint4(tmp_path):
    cfg = _dense_cfg()
    res, toks = _quantized(cfg, fmt="mxint4", seed=2)
    out = tmp_path / "int4"
    export_artifact(res, cfg, out)
    ref = np.asarray(api.forward(res.params, cfg, toks, res.qm))
    params, cfg2, qm = load_artifact(out)
    np.testing.assert_array_equal(
        np.asarray(api.forward(params, cfg2, toks, qm)), ref)


def test_export_load_bfloat16_params(tmp_path):
    """bf16 params (the full-size config default) must survive the npz
    store: ml_dtypes leaves are byte-encoded, and the load reconstructs
    the logical dtype with bitwise-identical values."""
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(3), cfg, dtype=jnp.bfloat16)
    src = synthetic.make_source(cfg, 4, 32, 0)
    calib = [{k: jnp.asarray(v) for k, v in src.batch(0).items()}]
    toks = jnp.asarray(src.batch(50)["inputs"])[:, :16]
    res = ptq.apply_method("rtn", params, cfg, calib, fmt="mxfp4")
    out = tmp_path / "bf16"
    export_artifact(res, cfg, out)
    p2, cfg2, qm2 = load_artifact(out)
    assert p2["blocks"]["ln1"].dtype == jnp.bfloat16
    assert p2["blocks"]["wq"].to_dense().dtype == jnp.bfloat16
    ref = np.asarray(api.forward(res.params, cfg, toks, res.qm),
                     dtype=np.float32)
    got = np.asarray(api.forward(p2, cfg2, toks, qm2), dtype=np.float32)
    np.testing.assert_array_equal(got, ref)


def test_packed_bytes_match_roofline_accounting(dense_artifact):
    """No fp copies of quantized weights in the artifact: stored bytes ==
    mx.packed_nbytes for every packed tensor."""
    cfg, res, _, out = dense_artifact
    man = json.loads((out / MANIFEST_FILE).read_text())
    mxcfg = mxlib.MXConfig(fmt=man["fmt"], block_size=32)
    with np.load(out / WEIGHTS_FILE) as z:
        stored = {k: z[k] for k in z.files}
    total = 0
    for t in man["tensors"]:
        if t["kind"] != "packed":
            continue
        nb = (stored[t["key"] + ".codes"].nbytes
              + stored[t["key"] + ".scales"].nbytes)
        assert nb == t["packed_nbytes"] == mxlib.packed_nbytes(
            t["shape"], mxcfg)
        total += nb
    assert total == man["totals"]["packed_nbytes"]
    assert verify_artifact(out)["packed_nbytes"] == total


def test_export_rejects_fp_result(tmp_path):
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    res = ptq.PTQResult(params, QuantMode.off(), None, [], "fp")
    with pytest.raises(ArtifactError, match="unquantized"):
        export_artifact(res, cfg, tmp_path / "fp")


def test_export_rejects_off_grid_weights(tmp_path):
    """Unquantized fp weights under a quantized QuantMode must not be
    silently re-quantized at export."""
    cfg = _dense_cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    qm = QuantMode(enabled=True,
                   act_cfg=mxlib.MXConfig(fmt="mxfp4", block_size=32))
    res = ptq.PTQResult(params, qm, None, [], "rtn")
    with pytest.raises(ArtifactError, match="grid"):
        export_artifact(res, cfg, tmp_path / "offgrid")


def test_tamper_detection_weights(dense_artifact, tmp_path):
    import shutil
    _, _, _, src = dense_artifact
    art = tmp_path / "tampered"
    shutil.copytree(src, art)
    wz = art / WEIGHTS_FILE
    data = bytearray(wz.read_bytes())
    data[len(data) // 2] ^= 0xFF
    wz.write_bytes(bytes(data))
    with pytest.raises(IntegrityError):
        load_artifact(art)


def test_tamper_detection_manifest(dense_artifact, tmp_path):
    import shutil
    _, _, _, src = dense_artifact
    art = tmp_path / "tampered_man"
    shutil.copytree(src, art)
    man = json.loads((art / MANIFEST_FILE).read_text())
    packed = [t for t in man["tensors"] if t["kind"] == "packed"]
    packed[0]["sha256_codes"] = "0" * 64
    (art / MANIFEST_FILE).write_text(json.dumps(man))
    with pytest.raises(IntegrityError, match="hash mismatch"):
        load_artifact(art)


def test_truncated_weights_raise_descriptive_error(dense_artifact,
                                                   tmp_path):
    """A truncated tensor file must surface as one descriptive
    IntegrityError naming the file and the cure — not as a zipfile
    traceback from deep inside numpy's unpacking."""
    import shutil
    from repro.serving.faults import corrupt_file
    _, _, _, src = dense_artifact
    art = tmp_path / "truncated"
    shutil.copytree(src, art)
    info = corrupt_file(art / WEIGHTS_FILE, mode="truncate", seed=1,
                        within=art)
    assert info["mode"] == "truncate"
    with pytest.raises(IntegrityError, match="corrupt or truncated"):
        load_artifact(art)
    with pytest.raises(IntegrityError, match="re-export"):
        load_artifact(art, verify=False)    # zip damage beats no-verify


def test_flipped_bytes_raise_descriptive_error(dense_artifact, tmp_path):
    """Seeded byte flips (the fault injector's corruption hook) are
    caught either by the zip layer or by sha256 verification — always
    as a descriptive IntegrityError."""
    import shutil
    from repro.serving.faults import corrupt_file
    _, _, _, src = dense_artifact
    art = tmp_path / "flipped"
    shutil.copytree(src, art)
    corrupt_file(art / WEIGHTS_FILE, mode="flip", nbytes=4, seed=2,
                 within=art)
    with pytest.raises(IntegrityError, match="corrupt|hash mismatch"):
        load_artifact(art)


def test_load_rejects_wrong_schema(dense_artifact, tmp_path):
    import shutil
    _, _, _, src = dense_artifact
    art = tmp_path / "schema"
    shutil.copytree(src, art)
    man = json.loads((art / MANIFEST_FILE).read_text())
    man["schema_version"] = 99
    (art / MANIFEST_FILE).write_text(json.dumps(man))
    with pytest.raises(ArtifactError, match="schema_version"):
        load_artifact(art)


@pytest.mark.parametrize("eager", [False, True])
def test_engine_from_artifact_matches_in_memory(dense_artifact, eager):
    cfg, res, _, out = dense_artifact
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(2)]
    ref_eng = Engine(res.params, cfg, res.qm, batch_size=2, max_len=64)
    ref = ref_eng.generate([Request(prompt=p, max_new=6) for p in prompts])
    eng = Engine.from_artifact(out, batch_size=2, max_len=64, eager=eager)
    got = eng.generate([Request(prompt=p, max_new=6) for p in prompts])
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)


def test_throughput_zero_dt_guard(dense_artifact, monkeypatch):
    cfg, res, _, _ = dense_artifact
    eng = Engine(res.params, cfg, res.qm, batch_size=2, max_len=64)
    # intervals are measured with the monotonic clock (perf_counter);
    # freeze it so throughput() sees dt == 0
    monkeypatch.setattr("repro.serving.engine.time.perf_counter",
                        lambda: 42.0)
    stats = eng.throughput(n_requests=2, prompt_len=8, max_new=2)
    assert stats["tok_per_s"] == float("inf")  # no ZeroDivisionError


def test_cli_inspect_and_verify(dense_artifact, capsys, tmp_path):
    _, _, _, out = dense_artifact
    assert cli_main(["inspect", str(out), "--tensors"]) == 0
    text = capsys.readouterr().out
    assert "blocks/wq" in text and "packed" in text
    assert cli_main(["verify", str(out)]) == 0
    assert "OK" in capsys.readouterr().out
    # corrupt -> verify fails with exit 1
    import shutil
    art = tmp_path / "bad"
    shutil.copytree(out, art)
    man = json.loads((art / MANIFEST_FILE).read_text())
    [t for t in man["tensors"] if t["kind"] == "packed"][0][
        "sha256_scales"] = "f" * 64
    (art / MANIFEST_FILE).write_text(json.dumps(man))
    assert cli_main(["verify", str(art)]) == 1
    assert "FAIL" in capsys.readouterr().err
