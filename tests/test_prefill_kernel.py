"""Pallas flash-prefill over the packed paged MX pool: kernel vs the
jnp oracle (``mx_prefill_ref``) vs a from-scratch dense computation
across formats / GQA / windows / ragged tails / scattered block tables;
the fused chunk bytes bitwise-equal to ``packing.kv_encode``; the
engine's fused chunked-prefill path token-identical to the ref fallback
and to the contiguous scheduler; and batched prefill admission
(``policy.max_prefill_lanes_per_step``) bitwise-equal to serial
admission with prefix-cache hits preserved. See ``docs/paged-kv.md``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.quantize import KVCacheQuant, QuantMode
from repro.kernels import ops, packing
from repro.models import api
from repro.serving.engine import Engine, Request
from repro.serving.policy import SchedulingPolicy

KV_FMTS = ["mxfp8", "mxint8", "mxfp4", "mxint4"]


def _cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                attn_chunk=16)
    base.update(kw)
    return ArchConfig(**base)


def _requests(cfg, lens, news, seed=0, prefix=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for s, n in zip(lens, news):
        p = rng.integers(0, cfg.vocab_size, s).astype(np.int32)
        if prefix is not None:
            p = np.concatenate([prefix, p])
        reqs.append(Request(prompt=p, max_new=n))
    return reqs


def _case(seed, B, C, H, kvh, Dh, n_pages, P, fmt, starts):
    """A random prefill case: pool pages hold each lane's real prefix
    (quantized), the chunk is dense, block tables are scattered."""
    D = kvh * Dh
    rng = np.random.default_rng(seed)
    maxp = -(-(max(starts) + C) // P)
    n_pages = max(n_pages, B * maxp)   # distinct pages per lane
    pool_k = jnp.asarray(rng.normal(size=(n_pages, P, D)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(n_pages, P, D)), jnp.float32)
    kc, ks = packing.kv_encode(pool_k, fmt)
    vc, vs = packing.kv_encode(pool_v, fmt)
    perm = rng.permutation(n_pages)[:B * maxp].reshape(B, maxp)
    q = jnp.asarray(rng.normal(size=(B, C, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, C, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, C, D)), jnp.float32)
    st = jnp.asarray(starts, jnp.int32)
    return q, k, v, kc, ks, vc, vs, jnp.asarray(perm, jnp.int32), st


@pytest.mark.parametrize("fmt", KV_FMTS)
@pytest.mark.parametrize("gqa", [1, 2])
def test_prefill_kernel_matches_ref(fmt, gqa):
    kvh, Dh = 2, 32
    q, k, v, kc, ks, vc, vs, bt, st = _case(
        0, B=2, C=32, H=kvh * gqa, kvh=kvh, Dh=Dh, n_pages=8, P=16,
        fmt=fmt, starts=[16, 32])
    kl = st + 32
    got = ops.mx_flash_prefill(q, k, v, kc, ks, vc, vs, bt, st, kl, fmt,
                               qb=16, kvb=16, interpret=True)
    want = ops.mx_prefill_ref(q, k, v, kc, ks, vc, vs, bt, st, kl, fmt)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=2e-5, rtol=2e-5)
    for g, w in zip(got[1:], want[1:]):   # packed chunk bytes: bitwise
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_prefill_chunk_bytes_match_kv_encode():
    """The kernel's in-tile quantize-on-append emits the exact bytes the
    fallback's ``packing.kv_encode`` would write — the property that
    keeps the fused and fallback engine paths bit-identical."""
    fmt = "mxint4"
    q, k, v, kc, ks, vc, vs, bt, st = _case(
        1, B=2, C=32, H=4, kvh=2, Dh=32, n_pages=8, P=16, fmt=fmt,
        starts=[0, 16])
    got = ops.mx_flash_prefill(q, k, v, kc, ks, vc, vs, bt, st, st + 32,
                               fmt, qb=16, kvb=16, interpret=True)
    ek, es = packing.kv_encode(k, fmt)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ek))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(es))
    ev, evs = packing.kv_encode(v, fmt)
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(ev))
    np.testing.assert_array_equal(np.asarray(got[4]), np.asarray(evs))


@pytest.mark.parametrize("window", [8, 24])
def test_prefill_kernel_sliding_window(window):
    q, k, v, kc, ks, vc, vs, bt, st = _case(
        2, B=2, C=32, H=4, kvh=2, Dh=32, n_pages=8, P=16, fmt="mxfp8",
        starts=[16, 48])
    kl = st + 32
    got = ops.mx_flash_prefill(q, k, v, kc, ks, vc, vs, bt, st, kl,
                               "mxfp8", window=window, qb=16, kvb=16,
                               interpret=True)
    want = ops.mx_prefill_ref(q, k, v, kc, ks, vc, vs, bt, st, kl,
                              "mxfp8", window=window)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=2e-5, rtol=2e-5)


def test_prefill_kernel_ragged_tail_and_midpage_start():
    """kv_len < start + C (right-padded final chunk) plus a start that
    is not page-aligned (mid-page prefix resume): tail rows past kv_len
    and pool rows at/after start must both stay masked."""
    q, k, v, kc, ks, vc, vs, bt, st = _case(
        3, B=2, C=32, H=4, kvh=2, Dh=32, n_pages=8, P=16, fmt="mxfp8",
        starts=[13, 27])
    kl = st + jnp.asarray([32, 21], jnp.int32)   # lane 1 ragged tail
    got = ops.mx_flash_prefill(q, k, v, kc, ks, vc, vs, bt, st, kl,
                               "mxfp8", qb=16, kvb=16, interpret=True)
    want = ops.mx_prefill_ref(q, k, v, kc, ks, vc, vs, bt, st, kl,
                              "mxfp8")
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               atol=2e-5, rtol=2e-5)


def test_prefill_ref_matches_dense_jnp():
    """The oracle itself against a from-scratch dense computation:
    gather pages through the scattered table, splice in the chunk's
    quantize-roundtrip, run plain softmax attention."""
    fmt = "mxfp8"
    B, C, H, kvh, Dh, P = 2, 32, 4, 2, 32, 16
    q, k, v, kc, ks, vc, vs, bt, st = _case(
        4, B=B, C=C, H=H, kvh=kvh, Dh=Dh, n_pages=8, P=P, fmt=fmt,
        starts=[16, 32])
    kl = st + C
    out = np.asarray(ops.mx_prefill_ref(q, k, v, kc, ks, vc, vs, bt, st,
                                        kl, fmt)[0])
    stn, kln = np.asarray(st), np.asarray(kl)
    for b in range(B):
        kd = np.asarray(packing.kv_decode(
            jnp.take(kc, bt[b], axis=0), jnp.take(ks, bt[b], axis=0),
            fmt))
        vd = np.asarray(packing.kv_decode(
            jnp.take(vc, bt[b], axis=0), jnp.take(vs, bt[b], axis=0),
            fmt))
        kd = kd.reshape(-1, kvh * Dh).copy()
        vd = vd.reshape(-1, kvh * Dh).copy()
        kd[stn[b]:stn[b] + C] = np.asarray(packing.kv_decode(
            *packing.kv_encode(k[b:b + 1], fmt), fmt))[0]
        vd[stn[b]:stn[b] + C] = np.asarray(packing.kv_decode(
            *packing.kv_encode(v[b:b + 1], fmt), fmt))[0]
        kd = kd.reshape(-1, kvh, Dh)
        vd = vd.reshape(-1, kvh, Dh)
        qb = np.asarray(q[b])                    # (C, H, Dh)
        for c in range(C):
            qp = stn[b] + c
            for h in range(H):
                g = h // (H // kvh)
                logit = (qb[c, h] @ kd[:, g].T) / np.sqrt(Dh)
                kp = np.arange(kd.shape[0])
                mask = (kp <= qp) & (kp < kln[b])
                logit = np.where(mask, logit, -np.inf)
                w = np.exp(logit - logit.max())
                w /= w.sum()
                ref = w @ vd[:, g]
                np.testing.assert_allclose(out[b, c, h], ref,
                                           atol=2e-5, rtol=2e-5)


def test_prefill_explicit_blocks_and_off_contract():
    """Explicit qb/kvb that do not divide C raise descriptively; so do
    a dense (fmt='none') pool and malformed shapes."""
    q, k, v, kc, ks, vc, vs, bt, st = _case(
        5, B=1, C=32, H=4, kvh=2, Dh=32, n_pages=4, P=16, fmt="mxfp8",
        starts=[0])
    kl = st + 32
    with pytest.raises(ValueError, match="does not divide"):
        ops.mx_flash_prefill(q, k, v, kc, ks, vc, vs, bt, st, kl,
                             "mxfp8", qb=24, interpret=True)
    with pytest.raises(ValueError, match="does not divide"):
        ops.mx_flash_prefill(q, k, v, kc, ks, vc, vs, bt, st, kl,
                             "mxfp8", kvb=24, interpret=True)
    with pytest.raises(ValueError, match="contract"):
        ops.mx_flash_prefill(q, k, v, kc, ks, vc, vs, bt, st, kl,
                             "none", interpret=True)
    with pytest.raises(ValueError, match="contract"):
        ops.mx_flash_prefill(q[0], k, v, kc, ks, vc, vs, bt, st, kl,
                             "mxfp8", interpret=True)


# ---------------------------------------------------------------------------
# Engine: the fused chunked-prefill path
# ---------------------------------------------------------------------------

def _paged_engine(params, cfg, backend, fmt, knob=1, **kw):
    return Engine(params, cfg, QuantMode(backend=backend), batch_size=2,
                  max_len=96, scheduler="continuous", kv_layout="paged",
                  page_size=32, kv_cache=fmt,
                  policy=SchedulingPolicy(max_prefill_lanes_per_step=knob),
                  **kw)


@pytest.mark.parametrize("fmt", ["mxfp8", "mxint4"])
def test_engine_fused_prefill_token_identical(fmt):
    """The fused engine (kernel prefill + scatter of its bytes) emits
    the same tokens as the ref engine (quantize + write + dense jnp) and
    as the contiguous continuous scheduler — multi-chunk prompts, no
    leaked pages."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    lens, news = [40, 44, 38, 52], [6, 5, 7, 4]
    cont = Engine(params, cfg, QuantMode(backend="ref"), batch_size=2,
                  max_len=96, scheduler="continuous",
                  bucket_prompts=False, kv_cache=fmt)
    want = [r.out for r in cont.generate(_requests(cfg, lens, news, 1))]
    for backend in ("fused", "ref"):
        eng = _paged_engine(params, cfg, backend, fmt)
        got = eng.generate(_requests(cfg, lens, news, 1))
        for w, g in zip(want, got):
            np.testing.assert_array_equal(g.out, w)
        eng._alloc.check()
        assert eng.stats()["blocks_in_use"] == 0


def test_engine_fused_path_uses_kernel():
    """The fused engine's chunked-prefill jaxpr contains the pallas
    kernel (and the ref engine's does not) — the dispatch is structural,
    not a tolerance accident."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    cache = api.init_cache_paged(cfg, 8, 32, jnp.float32,
                                 KVCacheQuant.parse("mxfp8"))
    toks = jnp.zeros((1, cfg.attn_chunk), jnp.int32)
    bt = jnp.zeros((1, 3), jnp.int32)
    jaxprs = {}
    for backend in ("fused", "ref"):
        jaxprs[backend] = str(jax.make_jaxpr(
            lambda c, t: api.prefill_chunk_paged(
                params, cfg, c, bt, t, jnp.int32(0),
                jnp.int32(cfg.attn_chunk - 1),
                QuantMode(backend=backend)))(cache, toks))
    assert "pallas_call" in jaxprs["fused"]
    assert "pallas_call" not in jaxprs["ref"]


def test_batched_admission_matches_serial():
    """N queued prompts admitted through the batched prefill loop emit
    bitwise the tokens serial admission emits, with fewer chunked-
    prefill dispatches and the same per-lane work; pages all return."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    lens, news = [40, 44, 38, 52, 35], [6, 5, 7, 4, 8]
    outs, stats = {}, {}
    for knob in (1, 4):
        eng = _paged_engine(params, cfg, "fused", "mxfp8", knob=knob)
        outs[knob] = [r.out for r in
                      eng.generate(_requests(cfg, lens, news, 2))]
        stats[knob] = eng.stats()
        eng._alloc.check()
        assert stats[knob]["blocks_in_use"] == 0
    for a, b in zip(outs[1], outs[4]):
        np.testing.assert_array_equal(a, b)
    s1, s4 = stats[1], stats[4]
    assert s4["prefill_chunk_steps"] < s1["prefill_chunk_steps"]
    assert s4["prefill_lane_steps"] == s1["prefill_lane_steps"]
    assert s4["prefill_batched_steps"] > 0
    assert s4["prefill_lanes_per_step"] > 1.0
    assert s1["prefill_batched_steps"] == 0
    assert s1["prefill_lanes_per_step"] == 1.0


def test_batched_admission_preserves_prefix_hits():
    """Requests sharing a prompt prefix would register the same pages;
    the batched collector defers the collision so the shared prefix is
    still prefilled exactly once — hit tokens and chunk steps match the
    serial schedule, outputs are bitwise equal."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    sysp = np.random.default_rng(7).integers(
        0, cfg.vocab_size, 64).astype(np.int32)
    outs, hits, steps = {}, {}, {}
    for knob in (1, 4):
        eng = Engine(params, cfg, QuantMode(backend="fused"),
                     batch_size=4, max_len=128, scheduler="continuous",
                     kv_layout="paged", page_size=32, kv_cache="mxfp8",
                     policy=SchedulingPolicy(
                         max_prefill_lanes_per_step=knob))
        got = eng.generate(_requests(cfg, [6, 4, 8], [4, 4, 4], seed=9,
                                     prefix=sysp))
        outs[knob] = [r.out for r in got]
        st = eng.stats()
        hits[knob], steps[knob] = (st["prefix_hit_tokens"],
                                   st["prefill_chunk_steps"])
        eng._alloc.check()
        assert st["blocks_in_use"] == 0
    for a, b in zip(outs[1], outs[4]):
        np.testing.assert_array_equal(a, b)
    assert hits[4] == hits[1] > 0
    assert steps[4] == steps[1]


def test_batched_admission_metrics():
    """The observability satellite: the batch-size histogram and the
    batched/lane-step counters land in the metrics registry with the
    documented names."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = _paged_engine(params, cfg, "fused", "mxfp8", knob=4)
    eng.generate(_requests(cfg, [40, 44, 38], [4, 4, 4], seed=3))
    names = set(eng.metrics.snapshot())
    assert "serving_prefill_batch_size" in names
    assert "serving_prefill_batched_steps_total" in names
    assert "serving_prefill_lane_steps_total" in names
    # one batch-size observation per chunked-prefill invocation
    assert eng._h_prefill_batch.count == eng.stats()["prefill_chunk_steps"]
