"""Shared test helpers: property-test decorators that use hypothesis when
installed (dev extra) and degrade to fixed-seed parametrization on clean
machines, so tier-1 runs everywhere with the same test set."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_FALLBACK_SEEDS = (0, 1, 7, 12345)


def seed_property(max_examples=20):
    """@given(seed=...) or parametrize over fixed seeds."""
    def deco(f):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(seed=st.integers(0, 2 ** 16))(f))
        return pytest.mark.parametrize("seed", list(_FALLBACK_SEEDS))(f)
    return deco


def scale_seed_property(max_examples=30):
    """@given(scale=..., seed=...) or fixed (scale, seed) pairs."""
    def deco(f):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=max_examples, deadline=None)(
                given(scale=st.floats(min_value=1e-3, max_value=1e3),
                      seed=st.integers(0, 2 ** 16))(f))
        return pytest.mark.parametrize(
            "scale,seed", [(1e-3, 0), (0.5, 1), (12.0, 7), (1e3, 12345)])(f)
    return deco
