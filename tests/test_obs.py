"""Observability subsystem: histogram quantiles vs numpy, registry
label/type discipline, Chrome trace-event schema validation, and the
serving-engine integration (request-lifecycle spans populate the trace;
TTFT/TPOT histograms populate ``stats()`` without disturbing its
pre-existing keys)."""
import json

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       Tracer, validate_trace)
from repro.obs.metrics import DEFAULT_BUCKETS, log_buckets


# ---------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------

class TestMetrics:
    def test_counter_monotone(self):
        c = Counter("requests_total")
        c.inc()
        c.inc(3)
        assert c.value == 4
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_moves_both_ways(self):
        g = Gauge("queue_depth")
        g.set(5)
        g.dec(2)
        g.inc()
        assert g.value == 4

    def test_histogram_quantiles_match_numpy(self):
        # up to max_samples the reservoir holds every observation and
        # quantile() is np.percentile bit-for-bit
        rng = np.random.default_rng(0)
        xs = rng.lognormal(mean=-3.0, sigma=1.5, size=5000)
        h = Histogram("lat", unit="s")
        for x in xs:
            h.observe(x)
        assert h.exact
        for q in (0.5, 0.9, 0.99, 0.999):
            assert h.quantile(q) == float(np.percentile(xs, 100.0 * q))
        d = h.data()
        assert d["count"] == 5000 and d["exact"]
        assert d["p50"] == float(np.percentile(xs, 50))
        assert d["min"] == xs.min() and d["max"] == xs.max()

    def test_histogram_reservoir_degrades_gracefully(self):
        rng = np.random.default_rng(1)
        xs = rng.lognormal(mean=0.0, sigma=1.0, size=20000)
        h = Histogram("lat", max_samples=512)
        for x in xs:
            h.observe(x)
        assert not h.exact
        assert h.count == 20000
        # uniform reservoir: quantile estimates stay in the ballpark
        for q in (0.5, 0.9):
            true = float(np.percentile(xs, 100.0 * q))
            assert abs(h.quantile(q) - true) / true < 0.25
        # exact aggregates are unaffected by the reservoir cap
        assert h.sum == pytest.approx(xs.sum())
        assert h.min == xs.min() and h.max == xs.max()

    def test_histogram_buckets_partition_observations(self):
        h = Histogram("lat", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 0.5, 5.0, 50.0):
            h.observe(v)
        assert h._counts == [1, 2, 1, 1]          # last = +inf overflow
        assert sum(h._counts) == h.count

    def test_empty_histogram_quantile_nan(self):
        assert np.isnan(Histogram("lat").quantile(0.5))

    def test_log_buckets_validation(self):
        bs = log_buckets(1e-3, 1e3, per_decade=2)
        assert bs == sorted(bs) and bs[0] == 1e-3
        with pytest.raises(ValueError):
            log_buckets(0.0, 1.0)
        with pytest.raises(ValueError):
            Histogram("bad", buckets=(1.0, 0.5))
        assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)

    def test_registry_identity_per_name_and_labels(self):
        reg = MetricsRegistry()
        a = reg.counter("ops_total", {"op": "gemm"})
        b = reg.counter("ops_total", {"op": "gemm"})
        c = reg.counter("ops_total", {"op": "attn"})
        assert a is b and a is not c
        a.inc()
        assert reg.get("ops_total", {"op": "gemm"}).value == 1
        assert reg.get("ops_total", {"op": "attn"}).value == 0
        assert reg.get("nope") is None
        assert len(reg) == 2

    def test_registry_type_collision_raises(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x_total")

    def test_registry_label_keyset_collision_raises(self):
        # same name with a different label *keyset* is a collision;
        # different label *values* are just new series
        reg = MetricsRegistry()
        reg.counter("ops_total", {"op": "gemm"})
        with pytest.raises(ValueError, match="label keys"):
            reg.counter("ops_total", {"path": "fused"})
        reg.counter("ops_total", {"op": "other"})     # fine

    def test_snapshot_and_renders(self):
        reg = MetricsRegistry()
        reg.counter("reqs_total", help="requests").inc(2)
        reg.gauge("depth").set(3)
        h = reg.histogram("lat_seconds", unit="s", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        snap = reg.snapshot()
        assert snap["reqs_total"][0]["value"] == 2
        assert snap["lat_seconds"][0]["count"] == 2
        json.loads(reg.render_json())                 # JSON-safe
        text = reg.render_prometheus()
        assert "# TYPE reqs_total counter" in text
        assert "reqs_total 2" in text
        # histogram buckets are cumulative, terminated by +Inf == count
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert "lat_seconds_count 2" in text


# ---------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------

class TestTracing:
    def test_span_roundtrip_and_validation(self, tmp_path):
        tr = Tracer()
        with tr.span("outer", track="req-0", kind="request"):
            with tr.span("inner", track="req-0"):
                pass
            tr.instant("first_token", track="req-0")
        with tr.span("thread_local_span"):
            pass
        path = tmp_path / "trace.json"
        tr.export(path)
        doc = json.loads(path.read_text())
        assert doc["traceEvents"]
        evs = validate_trace(str(path))
        names = {e["name"] for e in evs}
        assert {"outer", "inner", "first_token",
                "thread_local_span"} <= names
        outer = next(e for e in evs if e["name"] == "outer")
        inner = next(e for e in evs if e["name"] == "inner")
        assert outer["ph"] == "X" and outer["dur"] >= inner["dur"]
        assert outer["args"] == {"kind": "request"}
        # metadata rows name every track
        meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
        assert {m["args"]["name"] for m in meta} >= {"req-0"}

    def test_required_fields_enforced(self):
        with pytest.raises(ValueError, match="missing"):
            validate_trace([{"ph": "X", "name": "a"}])
        with pytest.raises(ValueError, match="dur"):
            validate_trace([{"ph": "X", "name": "a", "ts": 0.0,
                             "pid": 0, "tid": 0}])
        with pytest.raises(ValueError, match="bad ts"):
            validate_trace([{"ph": "i", "name": "a", "ts": -1.0,
                             "pid": 0, "tid": 0}])
        with pytest.raises(ValueError, match="ph"):
            validate_trace([{"name": "a"}])

    def test_stack_discipline(self):
        def ev(name, ts, dur, tid=0):
            return {"ph": "X", "name": name, "ts": ts, "dur": dur,
                    "pid": 0, "tid": tid}
        # nesting and adjacency are fine
        validate_trace([ev("a", 0, 10), ev("b", 2, 3), ev("c", 5, 5)])
        # partial overlap on one track is not
        with pytest.raises(ValueError, match="overlaps"):
            validate_trace([ev("a", 0, 10), ev("b", 5, 10)])
        # the same interval on another track is fine
        validate_trace([ev("a", 0, 10), ev("b", 5, 10, tid=1)])

    def test_retroactive_complete_spans(self):
        import time
        tr = Tracer()
        t0 = time.perf_counter()
        with tr.span("child", track="req-1"):
            pass
        tr.complete("parent", t0, time.perf_counter(), track="req-1")
        validate_trace(tr.events())

    def test_next_index_per_key(self):
        tr = Tracer()
        assert [tr.next_index("req") for _ in range(3)] == [0, 1, 2]
        assert tr.next_index("other") == 0


# ---------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------

class TestEngineIntegration:
    @pytest.fixture(scope="class")
    def served(self):
        import jax
        from repro.configs.base import ArchConfig
        from repro.core.quantize import QuantMode
        from repro.models import api
        from repro.serving.engine import Engine, Request

        cfg = ArchConfig(name="obs-tiny", family="dense", n_layers=2,
                         d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab_size=128, attn_chunk=16)
        params = api.init(jax.random.PRNGKey(0), cfg)
        tracer = Tracer()
        eng = Engine(params, cfg, QuantMode.off(), batch_size=2,
                     max_len=64, scheduler="continuous", tracer=tracer)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 4 + 3 * i)
                        .astype(np.int32), max_new=3 + i)
                for i in range(4)]
        done = eng.generate(reqs)
        return eng, tracer, done

    def test_stats_keeps_legacy_keys_and_adds_latency(self, served):
        eng, _, done = served
        st = eng.stats()
        for key in ("scheduler", "admitted", "decode_steps", "slot_steps",
                    "useful_decode_tokens", "decode_utilization",
                    "prefill_chunk_steps", "prefill_compiles",
                    "prefill_chunk_compiles", "decode_compiles",
                    "prefix_hit_tokens", "blocks_in_use",
                    "blocks_evicted", "kv_cache"):
            assert key in st, key
        assert st["admitted"] == len(done) == 4
        # legacy attribute views stay equal to the registry-backed stats
        assert eng.admitted == st["admitted"]
        assert eng.decode_steps == st["decode_steps"]
        assert eng.useful_decode_tokens == st["useful_decode_tokens"]
        # latency summaries: one TTFT observation per finished request
        assert st["ttft_p50"] is not None and st["ttft_p50"] >= 0
        assert st["ttft_p99"] >= st["ttft_p50"]
        h = eng.metrics.get("serving_ttft_seconds")
        assert h.count == len(done)
        lat = eng.metrics.get("serving_request_latency_seconds")
        assert lat.count == len(done)
        # windowed view starts equal to cumulative, then resets
        assert st["window"]["admitted"] == st["admitted"]
        eng.reset_stats()
        st2 = eng.stats()
        assert st2["window"]["admitted"] == 0
        assert st2["admitted"] == st["admitted"]      # cumulative kept

    def test_trace_has_lifecycle_and_step_spans(self, served, tmp_path):
        eng, tracer, done = served
        path = tmp_path / "engine_trace.json"
        tracer.export(path)
        evs = validate_trace(str(path))
        by_name = {}
        for e in evs:
            by_name.setdefault(e["name"], []).append(e)
        # one request-lifecycle span per request, on its own track
        assert len(by_name["request"]) == len(done)
        assert len({e["tid"] for e in by_name["request"]}) == len(done)
        assert len(by_name["first_token"]) == len(done)
        # engine-step machinery spans
        assert by_name["engine_step"]
        assert by_name["decode_step"]
        assert by_name["prefill_chunk"]
        # compile events are instant markers, distinct from exec spans
        assert all(e["ph"] == "i" for e in by_name["compile:decode"])

    def test_prometheus_export_nonempty(self, served):
        eng, _, _ = served
        text = eng.metrics.render_prometheus()
        assert "serving_requests_admitted_total 4" in text
        assert "serving_ttft_seconds_count 4" in text
