"""HTTP/SSE serving front end: admission shedding, drain, disconnect
propagation, bounded streaming, and the engine supervisor.

Every test runs a real asyncio server on an ephemeral 127.0.0.1 port and
speaks HTTP over real sockets (stdlib only — no pytest-asyncio: sync
tests drive ``asyncio.run``). The invariants pinned here are the ones
docs/server.md promises:

* shed requests are terminal (``sum(terminal) == submitted``) and carry
  Retry-After from the backoff schedule;
* a client disconnect cancels within one engine step, bystander lanes
  are bit-identical to an undisturbed run, and the allocator audit is
  clean;
* the slow-consumer buffer stays bounded (coalesced flushes, no drops);
* drain reaches all-terminal quiescence with zero leaked pages;
* a stuck/failed step fails only the poisoned lane — queued/bystander
  work resumes bit-identically under greedy decoding.
"""
import asyncio
import json

import numpy as np
import pytest

from repro.serving.engine import Request
from repro.serving.faults import FaultInjector
from repro.serving.policy import (RequestState, SchedulingPolicy,
                                  ShedError)
from repro.serving.server import (EngineSupervisor, Server, ServerConfig,
                                  _TokenStream, demo_engine)


# ---------------------------------------------------------------------------
# HTTP helpers (raw sockets — the client the tests trust is the protocol)
# ---------------------------------------------------------------------------

async def _http(port, method, path, body=None):
    """One request/response; returns (code, headers, payload_bytes)."""
    r, w = await asyncio.open_connection("127.0.0.1", port)
    data = b"" if body is None else json.dumps(body).encode()
    w.write((f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
             f"Content-Length: {len(data)}\r\n\r\n").encode() + data)
    await w.drain()
    raw = await r.read()
    w.close()
    head, _, payload = raw.partition(b"\r\n\r\n")
    headers = {}
    for line in head.split(b"\r\n")[1:]:
        if b": " in line:
            k, v = line.decode().split(": ", 1)
            headers[k.lower()] = v
    return int(head.split()[1]), headers, payload


async def _generate(port, prompt, max_new, stream=False, **fields):
    body = {"prompt": list(map(int, prompt)), "max_new": max_new,
            "stream": stream, **fields}
    code, headers, payload = await _http(port, "POST", "/v1/generate", body)
    if stream:
        return code, headers, payload
    return code, headers, (json.loads(payload) if payload else {})


def _sse_parse(payload: bytes):
    """[(event, data_dict), ...] from a raw SSE body."""
    out, event = [], None
    for line in payload.decode().split("\n"):
        if line.startswith("event:"):
            event = line[6:].strip()
        elif line.startswith("data:"):
            out.append((event, json.loads(line[5:])))
    return out


async def _serve(policy_kw=None, server_kw=None, faults=None, **engine_kw):
    eng = demo_engine(faults=faults, **{**(policy_kw or {}), **engine_kw})
    srv = Server(eng, ServerConfig(port=0, **(server_kw or {})),
                 faults=faults)
    await srv.start()
    return srv


def _run(coro):
    return asyncio.run(coro)


# ---------------------------------------------------------------------------
# Admission control / shedding
# ---------------------------------------------------------------------------

def test_shed_keeps_terminal_invariant_and_retry_after():
    async def body():
        srv = await _serve(max_queue_depth=1, batch_size=1)
        p = srv.port
        outs = await asyncio.gather(*[
            _generate(p, [1, 2, 3], 16) for _ in range(6)])
        codes = sorted(c for c, _, _ in outs)
        assert 429 in codes and 200 in codes
        for code, headers, payload in outs:
            if code == 429:
                assert int(headers["retry-after"]) >= 1
                assert float(headers["x-retry-after-s"]) > 0
                assert payload["error"] == "shed"
                assert "queue full" in payload["reason"]
        rep = await srv.shutdown()
        assert rep["clean"], rep
        assert rep["terminal"]["shed"] == sum(
            1 for c, _, _ in outs if c == 429)
        assert rep["terminal_sum"] == rep["submitted"] == 6
        return rep
    rep = _run(body())
    assert rep["all_terminal"] and rep["allocator_clean"]


def test_shed_retry_after_grows_with_consecutive_sheds():
    """Sustained overload pushes clients out along the backoff schedule;
    a successful admission resets the streak."""
    eng = demo_engine(max_queue_depth=0)   # queue always "full"
    pol = eng.policy
    waits = []
    for _ in range(3):
        with pytest.raises(ShedError) as ei:
            eng.submit(Request(prompt=np.arange(4, dtype=np.int32),
                               max_new=4))
        waits.append(ei.value.retry_after_s)
    assert waits == [pol.backoff_s(1), pol.backoff_s(2), pol.backoff_s(3)]
    assert eng._shed_streak == 3
    st = eng.stats()
    assert st["terminal"]["shed"] == 3 and st["submitted"] == 3


def test_token_budget_and_per_priority_caps_shed():
    eng_b = demo_engine(admit_token_budget=24)
    # first fits (4+16=20 <= 24), second would blow the budget
    eng_b.submit(Request(prompt=np.arange(4, dtype=np.int32), max_new=16))
    with pytest.raises(ShedError) as ei:
        eng_b.submit(Request(prompt=np.arange(4, dtype=np.int32),
                             max_new=16))
    assert "token budget" in ei.value.reason
    eng_b.drain()

    pol = SchedulingPolicy(max_queue_depth_per_priority=1)
    from repro.serving.policy import RequestQueue
    q = RequestQueue()
    hi = Request(prompt=np.arange(4, dtype=np.int32), max_new=4, priority=1)
    hi.state = RequestState.QUEUED
    q.push(hi)
    lo = Request(prompt=np.arange(4, dtype=np.int32), max_new=4, priority=0)
    assert pol.shed_reason(q, lo) is None          # other priority lane
    hi2 = Request(prompt=np.arange(4, dtype=np.int32), max_new=4,
                  priority=1)
    assert "priority 1 lane full" in pol.shed_reason(q, hi2)


def test_draining_server_rejects_new_work_with_503():
    async def body():
        srv = await _serve()
        p = srv.port
        srv.draining = True                        # drain flag only
        code, headers, payload = await _generate(p, [1, 2], 4)
        assert code == 503 and "retry-after" in headers
        code, _, _ = await _http(p, "GET", "/readyz")
        assert code == 503
        code, _, _ = await _http(p, "GET", "/healthz")
        assert code == 200                         # liveness != readiness
        srv.draining = False
        rep = await srv.shutdown()
        assert rep["clean"]
    _run(body())


# ---------------------------------------------------------------------------
# Streaming: parity, disconnect propagation, bounded buffer
# ---------------------------------------------------------------------------

def test_http_stream_matches_direct_engine_generate():
    """Tokens over SSE are bit-identical to a direct library run with
    the same prompt (greedy) — the front end adds no token semantics."""
    async def body():
        srv = await _serve()
        p = srv.port
        code, _, payload = await _generate(p, [7, 8, 9, 10], 12,
                                           stream=True)
        assert code == 200
        events = _sse_parse(payload)
        toks = [t for ev, d in events if ev == "token" for t in d["tokens"]]
        done = [d for ev, d in events if ev == "done"]
        assert done and done[0]["state"] == "finished"
        assert toks == done[0]["tokens"]
        rep = await srv.shutdown()
        assert rep["clean"]
        return toks
    toks = _run(body())
    eng = demo_engine()
    [req] = eng.generate([Request(
        prompt=np.array([7, 8, 9, 10], np.int32), max_new=12)])
    assert toks == [int(t) for t in req.out]


def test_disconnect_cancels_within_one_step_and_bystander_identical():
    """Drop an SSE connection mid-stream: its request ends CANCELLED
    with pages freed, while a concurrent request on another lane
    finishes bit-identically to an undisturbed run."""
    bystander_prompt = np.array([11, 12, 13], np.int32)
    eng0 = demo_engine(deadline_ms=1e9)            # burst-capped decode
    [undisturbed] = eng0.generate([Request(prompt=bystander_prompt.copy(),
                                           max_new=24)])

    async def body():
        srv = await _serve(deadline_ms=1e9, batch_size=2)
        p = srv.port
        # victim: open the SSE stream by hand so we can drop it
        r, w = await asyncio.open_connection("127.0.0.1", p)
        data = json.dumps({"prompt": [1, 2, 3], "max_new": 64}).encode()
        w.write((f"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(data)}\r\n\r\n").encode() + data)
        await w.drain()
        buf = b""
        while b"event: token" not in buf:
            buf += await r.read(512)
        bystander = asyncio.ensure_future(_generate(
            p, bystander_prompt, 24, stream=True))
        w.close()                                  # mid-stream disconnect
        code, _, payload = await bystander
        assert code == 200
        for _ in range(500):
            if srv.sup.idle():
                break
            await asyncio.sleep(0.01)
        rep = await srv.shutdown()
        return rep, payload

    rep, payload = _run(body())
    assert rep["clean"], rep
    assert rep["terminal"]["cancelled"] == 1
    assert rep["terminal"]["finished"] == 1
    events = _sse_parse(payload)
    toks = [t for ev, d in events if ev == "token" for t in d["tokens"]]
    assert toks == [int(t) for t in undisturbed.out]


def test_disconnect_fault_point_is_deterministic():
    """The server-level ``disconnect`` fault force-drops the stream
    after N events — same cancel path, no real client needed."""
    async def body():
        fi = FaultInjector(seed=0)
        fi.inject("disconnect", at=2)              # drop after 2 events
        srv = await _serve(deadline_ms=1e9, faults=fi)
        p = srv.port
        code, _, payload = await _generate(p, [5, 5, 5], 64, stream=True)
        assert code == 200
        for _ in range(500):
            if srv.sup.idle():
                break
            await asyncio.sleep(0.01)
        rep = await srv.shutdown()
        assert fi.fired("disconnect") == 1
        return rep, payload
    rep, payload = _run(body())
    assert rep["terminal"]["cancelled"] == 1 and rep["clean"], rep
    assert len(_sse_parse(payload)) >= 1           # stream died mid-way


def test_slow_consumer_buffer_bounded_and_coalesces():
    """With the writer slowed, pending flushes cap at stream_buffer and
    overflow merges into multi-token events — every token still arrives
    exactly once, in order."""
    async def body():
        fi = FaultInjector(seed=0)
        fi.inject("slow_consumer", every=1, delay_s=0.05)
        srv = await _serve(deadline_ms=1e9, faults=fi,
                           server_kw={"stream_buffer": 4})
        p = srv.port
        code, _, payload = await _generate(p, [3, 1, 4], 48, stream=True)
        assert code == 200
        rep = await srv.shutdown()
        return rep, payload
    rep, payload = _run(body())
    assert rep["clean"], rep
    events = _sse_parse(payload)
    toks = [t for ev, d in events if ev == "token" for t in d["tokens"]]
    done = [d for ev, d in events if ev == "done"][0]
    assert toks == done["tokens"] and len(toks) == 48
    assert done["coalesced_flushes"] > 0           # buffer did overflow
    token_events = [d for ev, d in events if ev == "token"]
    assert any(len(d["tokens"]) > 1 for d in token_events)
    # bound: no single flush carries more than the whole budget, and the
    # number of events is far below one-per-token
    assert len(token_events) < 48


def test_token_stream_buffer_never_exceeds_limit():
    async def body():
        loop = asyncio.get_running_loop()
        ts = _TokenStream(loop, limit=4)
        for t in range(100):
            ts._feed(t)
            assert len(ts._pending) <= 4
        got = []
        ts._finish(Request(prompt=np.zeros(1, np.int32)))  # any terminal
        while (u := await ts.next()) is not None:
            got.append(u)
        assert [t for u in got for t in u] == list(range(100))
        assert ts.coalesced > 0
    _run(body())


# ---------------------------------------------------------------------------
# Graceful drain
# ---------------------------------------------------------------------------

def test_drain_under_load_reaches_quiescence_zero_leaks():
    """Shutdown with streams in flight: every request terminal,
    sum(terminal) == submitted, allocator check clean."""
    async def body():
        srv = await _serve(deadline_ms=1e9, batch_size=2,
                           server_kw={"drain_timeout_s": 60.0})
        p = srv.port
        inflight = [asyncio.ensure_future(
            _generate(p, [i + 1, i + 2, i + 3], 32, stream=True))
            for i in range(5)]
        await asyncio.sleep(0.3)                   # let some admit
        rep = await srv.shutdown()
        results = await asyncio.gather(*inflight, return_exceptions=True)
        ok = [r for r in results if not isinstance(r, Exception)]
        return rep, ok
    rep, ok = _run(body())
    assert rep["clean"], rep
    assert rep["all_terminal"] and rep["terminal_sum"] == rep["submitted"]
    assert rep["allocator_clean"]
    # streams admitted before the drain flag ran to completion
    finished = [r for r in ok if r[0] == 200 and
                any(ev == "done" and d.get("state") == "finished"
                    for ev, d in _sse_parse(r[2]))]
    assert finished, "drain should let in-flight streams finish"


def test_drain_timeout_cancels_stragglers():
    async def body():
        fi = FaultInjector(seed=0)
        fi.inject("slow_step", every=1, delay_s=0.05)   # ~50ms per step
        srv = await _serve(deadline_ms=1e9, faults=fi,
                           server_kw={"drain_timeout_s": 0.1})
        p = srv.port
        task = asyncio.ensure_future(
            _generate(p, [1, 2, 3], 100, stream=True))
        await asyncio.sleep(0.5)                   # long request admitted
        rep = await srv.shutdown()
        task.cancel()
        return rep
    rep = _run(body())
    assert rep["cancelled_stragglers"]
    assert rep["clean"], rep
    assert rep["terminal"]["cancelled"] >= 1


# ---------------------------------------------------------------------------
# Engine supervisor: failed / stuck steps
# ---------------------------------------------------------------------------

def test_supervisor_failed_step_fails_one_resumes_rest_bit_identical():
    """An injected step failure fails exactly the blamed request;
    bystanders requeue (no retry-budget charge) and finish with the
    same tokens as an undisturbed run."""
    prompts = [np.array([2, 7, 1, 8], np.int32),
               np.array([3, 1, 4, 1], np.int32)]
    eng0 = demo_engine(deadline_ms=1e9, batch_size=2)
    base = eng0.generate([Request(prompt=p.copy(), max_new=16)
                          for p in prompts])

    async def body():
        fi = FaultInjector(seed=0)
        fi.inject("failed_step", at=2, lane=0, error="injected")
        srv = await _serve(deadline_ms=1e9, batch_size=2, faults=fi)
        p = srv.port
        outs = await asyncio.gather(*[
            _generate(p, pr, 16) for pr in prompts])
        rep = await srv.shutdown()
        assert fi.fired("failed_step") == 1
        return rep, outs
    rep, outs = _run(body())
    assert rep["supervisor_restarts"] == 1
    assert rep["terminal"]["failed"] == 1
    assert rep["terminal"]["finished"] == 1
    assert rep["clean"], rep
    by_state = {o[2]["state"]: o for o in outs}
    assert set(by_state) == {"failed", "finished"}
    code, _, failed = by_state["failed"]
    assert code == 500 and "supervisor" in failed["error"]
    code, _, fin = by_state["finished"]
    survivor = fin["tokens"]
    twins = [[int(t) for t in b.out] for b in base]
    assert survivor in twins                       # bit-identical resume
    # bystander requeue must not charge the preemption retry budget
    assert rep["terminal"]["preempted"] == 0


def test_supervisor_watchdog_unsticks_stuck_step():
    """A stuck step (cooperative hang) is detected by the watchdog,
    aborted, and the loop restarts; queued work still completes."""
    async def body():
        fi = FaultInjector(seed=0)
        fi.inject("stuck_step", at=1, hang_s=30.0)
        srv = await _serve(
            deadline_ms=1e9, batch_size=1, faults=fi,
            server_kw={"watchdog_timeout_s": 0.2,
                       "watchdog_poll_s": 0.05})
        p = srv.port
        outs = await asyncio.gather(
            _generate(p, [1, 2, 3], 8),
            _generate(p, [4, 5, 6], 8))
        rep = await srv.shutdown()
        assert fi.fired("stuck_step") == 1
        return rep, outs
    rep, outs = _run(body())
    assert rep["supervisor_restarts"] == 1
    # detection, not a 30s stall: the failed request names the watchdog
    failed = [o for _, _, o in outs if o["state"] == "failed"]
    assert failed and "watchdog" in failed[0]["error"]
    states = sorted(o["state"] for _, _, o in outs)
    assert states == ["failed", "finished"]
    assert rep["clean"], rep


def test_supervisor_restart_metrics_and_queue_survival():
    """Queued (not yet admitted) requests survive a restart untouched."""
    async def body():
        fi = FaultInjector(seed=0)
        fi.inject("failed_step", at=0, error="boom")
        srv = await _serve(deadline_ms=1e9, batch_size=1, faults=fi)
        p = srv.port
        outs = await asyncio.gather(*[
            _generate(p, [i + 1] * 3, 8) for i in range(3)])
        rep = await srv.shutdown()
        return rep, outs
    rep, outs = _run(body())
    # at=0 fires before anything is admitted: nothing to blame, the
    # loop just restarts and every request completes
    assert rep["supervisor_restarts"] == 1
    assert rep["terminal"]["finished"] == 3
    assert rep["clean"], rep
    assert all(o["state"] == "finished" for _, _, o in outs)


# ---------------------------------------------------------------------------
# Endpoints
# ---------------------------------------------------------------------------

def test_health_metrics_statz_endpoints():
    async def body():
        srv = await _serve()
        p = srv.port
        code, _, body_ = await _http(p, "GET", "/healthz")
        assert code == 200 and body_ == b"ok\n"
        code, _, body_ = await _http(p, "GET", "/readyz")
        assert code == 200 and json.loads(body_)["ready"]
        await _generate(p, [1, 2], 4)
        code, _, metrics = await _http(p, "GET", "/metrics")
        assert code == 200
        for needle in (b"serving_requests_shed_total",
                       b"serving_supervisor_restarts_total",
                       b"http_requests_total",
                       b"serving_requests_submitted_total"):
            assert needle in metrics, needle
        code, _, statz = await _http(p, "GET", "/statz")
        st = json.loads(statz)
        assert code == 200 and st["submitted"] == 1
        code, _, _ = await _http(p, "GET", "/nope")
        assert code == 404
        code, _, err = await _http(p, "POST", "/v1/generate",
                                   {"prompt": "not-ints"})
        assert code == 400
        rep = await srv.shutdown()
        assert rep["clean"]
    _run(body())
