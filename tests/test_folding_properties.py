"""Appendix C folding algebra — hypothesis property tests on the role
helpers (exact identities, independent of any model)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                                         "(pip install .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import folding as fl
from repro.core import transforms as tfm


def _affine(seed, d, block=16):
    spec = tfm.TransformSpec(kind="lu", d=d, block=min(block, d))
    a, v = tfm.materialize(
        tfm.init_params(jax.random.PRNGKey(seed), spec), spec)
    return a, v + 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (d,))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_read_fold(seed):
    """x @ W == T(x) @ W̃ + b̃ (Eq. 30)."""
    d, o = 32, 24
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((5, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, o)) * 0.3, jnp.float32)
    a, v = _affine(seed, d)
    wt, bt = fl.fold_read(w, None, tfm.inverse(a), v)
    lhs = tfm.forward(x, a, v) @ wt + bt
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(x @ w),
                               atol=2e-4, rtol=2e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_write_then_read_cancels(seed):
    """A residual-stream round trip: write-fold then read-fold composes to
    the identity on the function level."""
    d = 32
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, d)), jnp.float32)
    w_out = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)
    w_in = jnp.asarray(rng.standard_normal((d, d)) * 0.2, jnp.float32)
    a, v = _affine(seed, d)
    wo_t, _ = fl.fold_write(w_out, None, a)
    wi_t, bi_t = fl.fold_read(w_in, None, tfm.inverse(a), v)
    # original: (x @ w_out) @ w_in ; stream transform cancels up to +v
    stream = x @ wo_t + v  # transformed stream carries +v once
    lhs = stream @ wi_t + bi_t
    np.testing.assert_allclose(np.asarray(lhs),
                               np.asarray((x @ w_out) @ w_in),
                               atol=2e-4, rtol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_value_attnout_pipeline(seed):
    """Per-head T2 through a row-stochastic mixer is exact (Appendix B)."""
    d, dh, K, H, S = 32, 8, 2, 4, 6
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, S, d)), jnp.float32)
    wv = jnp.asarray(rng.standard_normal((d, K * dh)) * 0.3, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((H * dh, d)) * 0.3, jnp.float32)
    bv = jnp.asarray(rng.standard_normal((K * dh,)) * 0.1, jnp.float32)
    a1, v1 = _affine(seed, d)
    a2, v2 = _affine(seed + 7, dh, block=8)
    p_mat = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((2, H, S, S)), jnp.float32), -1)

    def attn(xin, wv_, bv_, wo_, bo_):
        vals = (xin @ wv_ + bv_).reshape(2, S, K, dh)
        vals = jnp.repeat(vals, H // K, axis=2)
        out = jnp.einsum("bhst,bthd->bshd", p_mat, vals).reshape(2, S,
                                                                 H * dh)
        return out @ wo_ + (0 if bo_ is None else bo_)

    wvt, bvt = fl.fold_value(wv, bv, tfm.inverse(a1), v1, a2, v2, n_kv=K)
    wot, bot = fl.fold_attn_out(wo, None, a1, tfm.inverse(a2), v2,
                                n_heads=H)
    got = attn(tfm.forward(x, a1, v1), wvt, bvt, wot, bot)
    want = attn(x, wv, bv, wo, None) @ a1
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4, rtol=5e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_t3_fold(seed):
    d, f = 24, 64
    rng = np.random.default_rng(seed)
    act = jnp.asarray(rng.standard_normal((3, f)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((f, d)) * 0.3, jnp.float32)
    wdt = fl.fold_t3(wd, 32)
    h = tfm.hadamard_matrix(32)
    got = tfm.apply_blockwise(act, h) @ wdt
    np.testing.assert_allclose(np.asarray(got), np.asarray(act @ wd),
                               atol=2e-4, rtol=2e-3)
